//! LAD with outliers — the paper's motivation for least absolute
//! deviations (§1): an ℓ₂ fit is dragged by gross outliers while the LAD
//! fit is robust; DVI makes the LAD path cheap.
//!
//! This example:
//!   1. generates a linear dataset with 10% gross outliers;
//!   2. fits least squares (normal equations, for contrast) and a LAD
//!      path with DVI screening;
//!   3. reports coefficient recovery error of both and the screening
//!      statistics.
//!
//! Run: `cargo run --release --example lad_outliers`

use dvi_screen::data::{synth, Rng};
use dvi_screen::linalg::{self, Rows};
use dvi_screen::path::{PathConfig, PathRunner};
use dvi_screen::problem::{Instance, Model};
use dvi_screen::screening::RuleKind;

/// Plain least squares via normal equations (n is small here); Gaussian
/// elimination with partial pivoting.
fn least_squares(x: &Rows, y: &[f64]) -> Vec<f64> {
    let n = x.cols();
    // A = XᵀX, b = Xᵀy
    let mut a = vec![vec![0.0; n]; n];
    let mut b = vec![0.0; n];
    for i in 0..x.rows() {
        let row = x.row(i).to_vec();
        for p in 0..n {
            b[p] += row[p] * y[i];
            for q in 0..n {
                a[p][q] += row[p] * row[q];
            }
        }
    }
    // solve A w = b
    for col in 0..n {
        let piv = (col..n)
            .max_by(|&r1, &r2| a[r1][col].abs().partial_cmp(&a[r2][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-12, "singular normal equations");
        for r in col + 1..n {
            let f = a[r][col] / d;
            for c2 in col..n {
                a[r][c2] -= f * a[col][c2];
            }
            b[r] -= f * b[col];
        }
    }
    let mut w = vec![0.0; n];
    for r in (0..n).rev() {
        let mut s = b[r];
        for c2 in r + 1..n {
            s -= a[r][c2] * w[c2];
        }
        w[r] = s / a[r][r];
    }
    w
}

fn main() {
    let n = 6;
    // ground-truth weights via the same generator the dataset uses
    let mut rng = Rng::new(0x0DD);
    let w_true: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
    // regenerate the dataset deterministically from that seed
    let ds = {
        let mut d = synth::linear_regression(0x0DD, 3000, n, 0.3, 0.10, 40.0);
        d.name = "outliers-demo".into();
        d
    };
    println!(
        "dataset: {} instances, {} features, 10% outliers at 40x noise",
        ds.len(),
        ds.dim()
    );

    // --- least squares (non-robust) -----------------------------------
    let w_ls = least_squares(&ds.x, &ds.y);
    let err_ls = {
        let d: Vec<f64> = w_ls.iter().zip(&w_true).map(|(a, b)| a - b).collect();
        linalg::norm(&d)
    };

    // --- LAD path with DVI screening -----------------------------------
    let cfg = PathConfig::log_grid(1e-2, 10.0, 100).with_validation(true);
    let out = PathRunner::new(Model::Lad, cfg, RuleKind::DviW).run(&ds);
    // w from the final (largest-C, loss-dominated) path point
    let inst = Instance::from_dataset(Model::Lad, &ds);
    let c_last = out.steps.last().unwrap().c;
    let w_lad = inst.w_from_theta(c_last, &out.final_theta);
    let err_lad = {
        let d: Vec<f64> = w_lad.iter().zip(&w_true).map(|(a, b)| a - b).collect();
        linalg::norm(&d)
    };

    println!("‖w_LS  − w*‖ = {err_ls:.4}   (least squares, dragged by outliers)");
    println!("‖w_LAD − w*‖ = {err_lad:.4}   (LAD at C={c_last:.2})");
    println!(
        "LAD path: {:.2}s total, {:.1}% mean rejection, screening {:.4}s, worst KKT {:.1e}",
        out.total_secs,
        100.0 * out.mean_rejection(),
        out.screen_secs,
        out.worst_violation().unwrap()
    );
    assert!(
        err_lad < err_ls,
        "LAD should beat least squares under gross outliers"
    );
    println!("robustness confirmed: LAD error < LS error");
}
