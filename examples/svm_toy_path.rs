//! The paper's Figure 1 workload as a runnable demo: DVI_s rejection
//! stacked-area charts on Toy1/Toy2/Toy3, plus the R̃ vs L̃ split the
//! paper discusses (separated classes ⇒ R̃ dominates; overlapping ⇒ L̃
//! grows to a comparable share).
//!
//! Run: `cargo run --release --example svm_toy_path [-- <per_class>]`

use dvi_screen::data::synth;
use dvi_screen::path::{PathConfig, PathRunner};
use dvi_screen::problem::Model;
use dvi_screen::report::StackedArea;
use dvi_screen::screening::RuleKind;

fn main() {
    let per_class: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);

    let cfg = PathConfig::log_grid(1e-2, 10.0, 100);
    for ds in synth::paper_toys(per_class) {
        let out = PathRunner::new(Model::Svm, cfg.clone(), RuleKind::DviW).run(&ds);
        let (lo, hi) = out.rejection_series();
        let r_share: f64 = lo.iter().sum::<f64>() / lo.len() as f64;
        let l_share: f64 = hi.iter().sum::<f64>() / hi.len() as f64;
        println!(
            "{}: mean rejection {:.1}%  (R̃ {:.1}%, L̃ {:.1}%)  path {:.2}s",
            ds.name,
            100.0 * out.mean_rejection(),
            100.0 * r_share,
            100.0 * l_share,
            out.total_secs
        );
        let chart = StackedArea::new(ds.name.clone(), lo, hi).height(14);
        println!("{}", chart.render());
    }
    println!(
        "Observation (paper §7.1): as the classes overlap more (toy1→toy3),\n\
         the L̃ region (▒) grows while R̃ (█) shrinks — yet DVI still\n\
         discards most instances."
    );
}
