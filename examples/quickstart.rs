//! Quickstart: train an SVM regularization path with DVI screening and
//! see how much of the data the rule discards — in ~20 lines.
//!
//! Run: `cargo run --release --example quickstart`

use dvi_screen::data::synth;
use dvi_screen::path::{PathConfig, PathRunner};
use dvi_screen::problem::Model;
use dvi_screen::screening::RuleKind;

fn main() {
    // Toy1 from the paper: two well-separated gaussian classes in 2-D.
    let ds = synth::toy_gaussian(1, 1000, 1.5, 0.75);
    println!("dataset: {} ({} instances, {} features)", ds.name, ds.len(), ds.dim());

    // The paper's protocol: 100 C values in [1e-2, 10], log-spaced.
    let cfg = PathConfig::log_grid(1e-2, 10.0, 100).with_validation(true);

    // Run the path twice: without screening, then with DVI.
    let plain = PathRunner::new(Model::Svm, cfg.clone(), RuleKind::None).run(&ds);
    let dvi = PathRunner::new(Model::Svm, cfg, RuleKind::DviW).run(&ds);

    println!(
        "no screening : {:>8.3}s  ({} gradient evals)",
        plain.total_secs,
        plain.total_grad_evals()
    );
    println!(
        "with DVI     : {:>8.3}s  ({} gradient evals, {:.1}% mean rejection)",
        dvi.total_secs,
        dvi.total_grad_evals(),
        100.0 * dvi.mean_rejection()
    );
    println!(
        "speedup      : {:>8.2}x  (screening itself took {:.4}s)",
        plain.total_secs / dvi.total_secs,
        dvi.screen_secs
    );
    // Safety: the screened path must satisfy the full-problem KKT system
    // at every grid point — this is the paper's "exact" guarantee.
    let worst = dvi.worst_violation().unwrap();
    println!("worst full-KKT violation along the path: {worst:.2e} (safe ≡ tiny)");
    assert!(worst < 1e-4);
}
