//! END-TO-END DRIVER — the full paper reproduction in one binary.
//!
//! Exercises every layer of the stack on a real (simulated-real) workload:
//!   * L1/L2: the AOT-compiled JAX/Pallas screening artifact (PJRT), when
//!     `artifacts/` is present — the DVI scan on the hot path runs through
//!     XLA, with the native rust scan as fallback;
//!   * L3: the coordinator's path runner, the dual-CD solver, all four
//!     screening rules, and the reporting stack;
//! and regenerates **every table and figure** of the paper's §7 at the
//! requested scale, recording the results in `results/`.
//!
//! Run: `cargo run --release --example full_repro [-- <scale> [points]]`
//! Defaults: scale 0.25 of the paper's dataset sizes, 100 grid points
//! (the paper's protocol). EXPERIMENTS.md records a full run.

use dvi_screen::experiments::{self, ExpOptions};
use dvi_screen::runtime::ArtifactManifest;
use std::time::Instant;

fn main() {
    let mut args = std::env::args().skip(1);
    let scale: f64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(0.25);
    let points: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(100);

    // Prove the three layers compose: run one real screening step through
    // the AOT PJRT artifact and check it against the native scan. The
    // timed tables below use the native scan — the CPU PJRT client
    // executes the interpret-lowered Pallas loop serially, so its latency
    // is an architecture demonstration, not a perf claim (bench_micro
    // quantifies it; real-TPU projections live in EXPERIMENTS.md §Perf).
    let artifacts_dir = dvi_screen::runtime::artifacts::default_dir();
    match ArtifactManifest::load(&artifacts_dir) {
        Ok(m) if m.check_files().is_ok() => {
            let n_buckets = m.buckets.len();
            match dvi_screen::runtime::PjrtScreener::new(m) {
                Ok(mut screener) => {
                    use dvi_screen::problem::{Instance, Model};
                    let ds = dvi_screen::data::synth::toy_gaussian(1, 1000, 1.5, 0.75);
                    let inst = Instance::from_dataset(Model::Svm, &ds);
                    let solver = dvi_screen::solver::CdSolver::new(Default::default());
                    let r = solver.solve(&inst, 0.5, inst.cold_start());
                    let pjrt = screener
                        .try_scan(&inst, 0.575, 0.075, &r.u)
                        .expect("pjrt scan");
                    let native =
                        dvi_screen::screening::dvi::dvi_scan(&inst, 0.575, 0.075, &r.u);
                    let agree = pjrt.iter().zip(&native).filter(|(a, b)| a == b).count();
                    println!(
                        "[e2e] PJRT artifact check: {} buckets; scan parity {}/{} \
                         (f32 guard keeps the rest)",
                        n_buckets,
                        agree,
                        native.len()
                    );
                }
                Err(e) => println!("[e2e] PJRT unavailable: {e}"),
            }
        }
        _ => println!("[e2e] artifacts missing — run `make artifacts` for the PJRT check"),
    }

    let opts = ExpOptions {
        scale,
        points,
        tol: 1e-6,
        out_dir: "results".into(),
        use_pjrt: false,
        validate: false,
        threads: 0, // auto-detect: drive the sharded scan engine
    };
    println!(
        "[e2e] scale {scale} (IJCNN1 -> {} rows), {points}-point grid\n",
        ((49_990.0 * scale) as usize).max(16)
    );

    let t0 = Instant::now();
    for id in ["fig1", "tab1", "fig2", "tab2", "fig3", "tab3", "ablation"] {
        let t = Instant::now();
        let report = experiments::run(id, &opts).expect(id);
        println!("{report}");
        println!("[e2e] {id} regenerated in {:.1}s\n", t.elapsed().as_secs_f64());
    }
    println!(
        "[e2e] full reproduction finished in {:.1}s — CSVs in {}/",
        t0.elapsed().as_secs_f64(),
        opts.out_dir.display()
    );
}
