//! The coordinator as a service: submit a batch of heterogeneous
//! screening/path jobs through the line-JSON front-end (exactly what
//! `dvi serve` exposes on stdin/stdout) and consume the streamed results.
//!
//! Run: `cargo run --release --example screening_service`

use dvi_screen::config::parse_json;
use dvi_screen::coordinator::ScreeningService;

fn main() {
    let requests = r#"
# SVM rule comparison on a toy (miniature scale)
{"dataset": "toy2", "rule": "ssnsv",  "scale": 0.2, "points": 25}
{"dataset": "toy2", "rule": "essnsv", "scale": 0.2, "points": 25}
{"dataset": "toy2", "rule": "dvi",    "scale": 0.2, "points": 25}
# LAD on two simulated real sets
{"dataset": "houses", "model": "lad", "scale": 0.05, "points": 25}
{"dataset": "magic",  "model": "lad", "scale": 0.05, "points": 25}
# and one deliberately bad request to show failure isolation
{"dataset": "not-a-dataset"}
"#;

    let mut svc = ScreeningService::new(2);
    let mut out = Vec::new();
    svc.serve(requests.as_bytes(), &mut out).expect("serve");
    let text = String::from_utf8(out).unwrap();

    println!("{:<22} {:<8} {:>10} {:>10}", "dataset/rule", "ok", "rejection", "secs");
    let mut oks = 0;
    for line in text.lines() {
        let j = parse_json(line).expect("response json");
        let ok = j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false);
        if ok {
            oks += 1;
            println!(
                "{:<22} {:<8} {:>9.1}% {:>10.3}",
                format!(
                    "{}/{}",
                    j.get("dataset").unwrap().as_str().unwrap(),
                    j.get("rule").unwrap().as_str().unwrap()
                ),
                "ok",
                100.0 * j.get("mean_rejection").unwrap().as_float().unwrap(),
                j.get("total_secs").unwrap().as_float().unwrap(),
            );
        } else {
            println!(
                "{:<22} {:<8} {}",
                "-",
                "ERROR",
                j.get("error").and_then(|v| v.as_str()).unwrap_or("?")
            );
        }
    }
    println!("\ncoordinator metrics:\n{}", svc.metrics().render());
    assert_eq!(oks, 5, "five good jobs expected");
    svc.shutdown();
}
