//! The coordinator as a service: submit heterogeneous screening/path jobs
//! through the line-JSON front-end (exactly what `dvi serve` exposes on
//! stdin/stdout) and consume the ordered results.
//!
//! Demonstrates the three request shapes — single path runs, a
//! `{"batch": [...]}` fan-out, and the lightweight `"screen"` kind — and
//! the resident instance cache amortizing construction across jobs that
//! name the same dataset.
//!
//! Run: `cargo run --release --example screening_service`

use dvi_screen::config::parse_json;
use dvi_screen::coordinator::ScreeningService;

fn main() {
    // --- session 1: independent request lines ---------------------------
    // three rules on ONE dataset: the pool builds the toy2 instance once
    // and shares it (watch instance_cache_misses/hits below)
    let requests = r#"
# SVM rule comparison on a toy (miniature scale)
{"dataset": "toy2", "rule": "ssnsv",  "scale": 0.2, "points": 25}
{"dataset": "toy2", "rule": "essnsv", "scale": 0.2, "points": 25}
{"dataset": "toy2", "rule": "dvi",    "scale": 0.2, "points": 25}
# LAD on two simulated real sets
{"dataset": "houses", "model": "lad", "scale": 0.05, "points": 25}
{"dataset": "magic",  "model": "lad", "scale": 0.05, "points": 25}
# and one deliberately bad request to show failure isolation
{"dataset": "not-a-dataset"}
"#;

    let mut svc = ScreeningService::new(2);
    let mut out = Vec::new();
    svc.serve(requests.as_bytes(), &mut out).expect("serve");
    let text = String::from_utf8(out).unwrap();

    println!("{:<22} {:<8} {:>10} {:>10}", "dataset/rule", "ok", "rejection", "secs");
    let mut oks = 0;
    for line in text.lines() {
        let j = parse_json(line).expect("response json");
        let ok = j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false);
        if ok {
            oks += 1;
            println!(
                "{:<22} {:<8} {:>9.1}% {:>10.3}",
                format!(
                    "{}/{}",
                    j.get("dataset").unwrap().as_str().unwrap(),
                    j.get("rule").unwrap().as_str().unwrap()
                ),
                "ok",
                100.0 * j.get("mean_rejection").unwrap().as_float().unwrap(),
                j.get("total_secs").unwrap().as_float().unwrap(),
            );
        } else {
            println!(
                "{:<22} {:<8} {}",
                "-",
                "ERROR",
                j.get("error").and_then(|v| v.as_str()).unwrap_or("?")
            );
        }
    }
    assert_eq!(oks, 5, "five good jobs expected");

    // --- session 2: one batch line, mixing path + screen kinds ----------
    // the screen job reuses the toy2 instance already resident from
    // session 1 and runs one DVI scan per (c_prev, c) pair
    let batch = r#"{"batch": [
        {"dataset": "toy2", "rule": "dvi", "scale": 0.2, "points": 10},
        {"kind": "screen", "dataset": "toy2", "scale": 0.2,
         "pairs": [[0.1, 0.2], [0.2, 0.5], [0.5, 2.0]], "tol": 1e-6},
        {"dataset": "toy2", "rule": "none", "scale": 0.2, "points": 10}
    ]}"#
        .replace('\n', " ");
    let mut out = Vec::new();
    svc.serve(batch.as_bytes(), &mut out).expect("serve batch");
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 1, "a batch answers with one ordered line");
    let j = parse_json(lines[0]).expect("batch json");
    let entries = j.get("batch").unwrap().as_array().unwrap();
    assert_eq!(entries.len(), 3);

    println!("\nbatch response ({} entries):", entries.len());
    for e in entries {
        let ok = e.get("ok").and_then(|v| v.as_bool()).unwrap_or(false);
        assert!(ok, "batch entry failed: {e:?}");
        match e.get("kind").and_then(|v| v.as_str()) {
            Some("screen") => {
                let pairs = e.get("pairs").unwrap().as_array().unwrap();
                let sweep: Vec<String> = pairs
                    .iter()
                    .map(|p| {
                        format!(
                            "C={:.1}: {} screened",
                            p.get("c").unwrap().as_float().unwrap(),
                            p.get("n_lo").unwrap().as_int().unwrap()
                                + p.get("n_hi").unwrap().as_int().unwrap()
                        )
                    })
                    .collect();
                println!("  screen  toy2  {} ({} anchor solves)",
                    sweep.join(", "),
                    e.get("anchor_solves").unwrap().as_int().unwrap());
            }
            _ => println!(
                "  path    {}/{}  mean rejection {:.1}%",
                e.get("dataset").unwrap().as_str().unwrap(),
                e.get("rule").unwrap().as_str().unwrap(),
                100.0 * e.get("mean_rejection").unwrap().as_float().unwrap()
            ),
        }
    }

    // the five toy2 jobs across both sessions shared ONE construction
    let misses = svc.metrics().counter("instance_cache_misses").get();
    let hits = svc.metrics().counter("instance_cache_hits").get();
    assert!(hits >= 4, "expected ≥4 cache hits, got {hits}");
    println!("\ninstance cache: {misses} builds, {hits} hits");
    println!("\ncoordinator metrics:\n{}", svc.metrics().render());
    svc.shutdown();
}
