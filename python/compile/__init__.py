"""Build-time compile path: L1 Pallas kernels + L2 JAX model + AOT export.

Nothing in this package is imported at runtime by the rust coordinator —
`make artifacts` runs :mod:`compile.aot` once, producing HLO text under
``artifacts/`` which `rust/src/runtime` loads via PJRT.
"""
