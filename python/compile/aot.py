"""AOT export: lower the L2 screening graph to HLO text per shape bucket.

Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (what
``make artifacts`` runs). Writes one ``dvi_screen_{l}x{n}.hlo.txt`` per
bucket plus ``manifest.json`` for rust/src/runtime/artifacts.rs.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.ref import GUARD_EPS
from .kernels.screen import BLOCK_L
from . import model

# Shape buckets: every (l, n) a dataset can present is padded up to the
# smallest bucket that fits. l is a multiple of BLOCK_L (the Pallas row
# tile); n covers the paper's datasets (max n = 54, Covertype).
BUCKETS = [
    (2048, 8),      # toys (2000×2)
    (4096, 8),
    (8192, 8),      # houses analog scaled
    (8192, 16),     # wine (6497×12)
    (8192, 32),     # computer (8192×21)
    (16384, 32),    # ijcnn1 quarter-scale
    (24576, 16),    # magic (19020×10), houses (20640×8)
    (24576, 64),
    (40960, 64),    # covertype (37877×54)
    (53248, 32),    # ijcnn1 (49990×22)
]


def to_hlo_text(fn, *example_args) -> str:
    """Lower a jittable fn to XLA HLO text via stablehlo."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def bucket_specs(l, n):
    """Abstract input specs for one bucket (f32 end to end)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((l, n), f32),   # z
        jax.ShapeDtypeStruct((n,), f32),     # u
        jax.ShapeDtypeStruct((l,), f32),     # ybar
        jax.ShapeDtypeStruct((l,), f32),     # znorm
        jax.ShapeDtypeStruct((), f32),       # mid
        jax.ShapeDtypeStruct((), f32),       # rad
    )


def build(out_dir: str, buckets=None, verbose=True) -> dict:
    """Lower every bucket and write artifacts + manifest. Returns the
    manifest dict."""
    buckets = buckets or BUCKETS
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for l, n in buckets:
        assert l % BLOCK_L == 0, f"bucket l={l} must be a multiple of {BLOCK_L}"
        fname = f"dvi_screen_{l}x{n}.hlo.txt"
        text = to_hlo_text(model.dvi_screen_graph, *bucket_specs(l, n))
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entries.append({"l": l, "n": n, "file": fname})
        if verbose:
            print(f"  {fname}: {len(text)} chars")
    manifest = {
        "version": 1,
        "dtype": "f32",
        "guard_eps": GUARD_EPS,
        "block_l": BLOCK_L,
        "buckets": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(entries)} buckets + manifest.json to {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="only the smallest bucket (CI / tests)",
    )
    args = ap.parse_args()
    buckets = BUCKETS[:1] if args.quick else BUCKETS
    build(args.out_dir, buckets)


if __name__ == "__main__":
    main()
