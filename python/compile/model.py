"""L2: the JAX screening graph that the AOT artifacts freeze.

``dvi_screen_graph`` is the function `aot.py` lowers per shape bucket:
it computes ‖u‖ once (whole-vector reduction), then invokes the fused
L1 Pallas kernel. The rust runtime calls the compiled artifact with

    (z, u, ybar, znorm, mid, rad) -> (codes,)

where codes are float32 0/1/2 (keep / at-lower / at-upper).

Also here: padding helpers (datasets are padded up to the static bucket
shape — padded rows have z = 0, ‖z‖ = 0, ȳ = 0 so they never screen), and
a jnp dual-objective used by the python test-suite as an independent check
of the rust solver's numerics.
"""

import jax
import jax.numpy as jnp

from .kernels import ref, screen


def dvi_screen_graph(z, u, ybar, znorm, mid, rad):
    """The artifact entry point (bucket-static shapes, f32)."""
    return screen.dvi_screen(z, u, ybar, znorm, mid, rad)


def dvi_screen_reference(z, u, ybar, znorm, mid, rad):
    """Same graph wired to the jnp oracle (for lowering-parity tests)."""
    return ref.dvi_screen(z, u, ybar, znorm, mid, rad)


def pad_inputs(z, u, ybar, znorm, l_pad, n_pad):
    """Zero-pad runtime inputs up to the bucket shape (mirrors the logic
    in rust/src/runtime/pjrt.rs; tested for agreement)."""
    l, n = z.shape
    if l > l_pad or n > n_pad:
        raise ValueError(f"shape ({l},{n}) exceeds bucket ({l_pad},{n_pad})")
    zp = jnp.zeros((l_pad, n_pad), z.dtype).at[:l, :n].set(z)
    up = jnp.zeros((n_pad,), u.dtype).at[:n].set(u)
    yp = jnp.zeros((l_pad,), ybar.dtype).at[:l].set(ybar)
    np_ = jnp.zeros((l_pad,), znorm.dtype).at[:l].set(znorm)
    return zp, up, yp, np_


def dual_objective(z, theta, ybar, c):
    """g(θ) = C/2·‖Zᵀθ‖² − ⟨ȳ, θ⟩ — problem (12); used to cross-check the
    rust solver from the python tests via shared fixtures."""
    u = z.T @ theta
    return 0.5 * c * jnp.sum(u * u) - jnp.dot(ybar, theta)


def kkt_classify(z, w, ybar, tol):
    """Membership by Eq. (14): 1 = R (−⟨w,z_i⟩ > ȳ_i), 2 = L, 0 = E."""
    s = -(z @ w)
    return jnp.where(s > ybar + tol, 1, jnp.where(s < ybar - tol, 2, 0))


def example_inputs(l_pad, n_pad, seed=0):
    """Deterministic example inputs of a bucket shape (for lowering and
    smoke tests)."""
    k = jax.random.PRNGKey(seed)
    kz, ku, ky = jax.random.split(k, 3)
    z = jax.random.normal(kz, (l_pad, n_pad), jnp.float32)
    u = jax.random.normal(ku, (n_pad,), jnp.float32)
    ybar = jnp.sign(jax.random.normal(ky, (l_pad,), jnp.float32)) * 1.0
    znorm = jnp.sqrt(jnp.sum(z * z, axis=1))
    mid = jnp.float32(1.1)
    rad = jnp.float32(0.1)
    return z, u, ybar, znorm, mid, rad
