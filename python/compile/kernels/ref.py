"""Pure-jnp correctness oracle for the screening kernels.

These are the semantics the Pallas kernels must reproduce *exactly* (same
dtype, same guard band) — pytest asserts bit-equality of the decision
codes and allclose on the intermediate scores.

DVI rule (paper Thm 7 / Cor 9), evaluated at the *next* path point with
``mid = (C_{k+1}+C_k)/2`` and ``rad = (C_{k+1}-C_k)/2`` and ``u = Zᵀθ*(C_k)``:

    score_i = mid·⟨u, z_i⟩
    slack_i = rad·‖u‖·‖z_i‖
    code_i  = 1  (R, θ→α)  if score_i − slack_i > ȳ_i + τ_i
            = 2  (L, θ→β)  if score_i + slack_i < ȳ_i − τ_i
            = 0  (keep)    otherwise

τ is the conservative f32 guard band: rounding in f32 may only ever turn a
screening decision into a *keep* (never the reverse), so the AOT artifact
stays safe. τ_i = guard·(|score_i| + slack_i + |ȳ_i| + 1).
"""

from functools import partial

import jax
import jax.numpy as jnp

# Default guard band, chosen ≳ 2^-17 ≈ 7.6e-6: a couple of orders above
# f32's eps (1.2e-7) to absorb accumulated matvec rounding across n ≤ 64
# features, while screening negligibly less than exact f64 (parity tests
# in rust/tests/integration_runtime.rs quantify the gap).
GUARD_EPS = 1e-5


def scores(z, u):
    """p_i = ⟨u, z_i⟩ for every row of z: the (l, n) @ (n,) matvec."""
    return z @ u


@partial(jax.jit, static_argnames=("guard",))
def dvi_screen(z, u, ybar, znorm, mid, rad, guard=GUARD_EPS):
    """Reference DVI screening: decision codes per instance.

    Args:
      z: (l, n) instance matrix (rows z_i = a_i·x_i).
      u: (n,) — Zᵀθ*(C_k).
      ybar: (l,) — b_i·y_i.
      znorm: (l,) — ‖z_i‖ (precomputed once per dataset).
      mid, rad: scalars (see module docstring).
      guard: conservative band (static).

    Returns:
      (l,) float32 codes: 0 keep / 1 at-lower / 2 at-upper.
    """
    dt = z.dtype
    u = u.astype(dt)
    unorm = jnp.sqrt(jnp.sum(u * u))
    p = scores(z, u)
    score = mid.astype(dt) * p
    slack = rad.astype(dt) * unorm * znorm.astype(dt)
    tau = dt.type(guard) * (jnp.abs(score) + slack + jnp.abs(ybar) + dt.type(1.0))
    at_lo = score - slack > ybar + tau
    at_hi = score + slack < ybar - tau
    return jnp.where(at_lo, 1.0, jnp.where(at_hi, 2.0, 0.0)).astype(jnp.float32)


def row_norms(z):
    """‖z_i‖ per row (the one-time norm precomputation)."""
    return jnp.sqrt(jnp.sum(z * z, axis=1))
