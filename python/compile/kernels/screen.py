"""Pallas kernels for the DVI screening scan.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
screening is a single O(l·n) pass over the data. On TPU-shaped hardware
that maps to streaming the (l, n) instance matrix HBM→VMEM in
(BLOCK_L, n) row tiles while the shared n-vector u, the thresholds and the
scalars stay resident in VMEM. Per tile the kernel fuses:

  1. the (BLOCK_L, n) @ (n,) matvec p = z_tile · u   (MXU-friendly),
  2. the norm lookup and both DVI inequalities,
  3. the guard-banded decision code emit,

so every instance is touched exactly once and no l×l Gram matrix is ever
materialized (the w-form rule of Cor. 9 replaces the paper's O(l²) Gram
trick).

Kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU perf is estimated from the BlockSpec VMEM
footprint in EXPERIMENTS.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GUARD_EPS

# Default row-tile. VMEM estimate per grid step (f32):
#   z tile: 512·n_pad·4B ≤ 512·64·4 = 128 KiB, u: ≤ 256 B, vectors: 3·2 KiB
# — comfortably inside a 16 MiB VMEM budget with double-buffering room.
BLOCK_L = 512


def _screen_kernel(z_ref, u_ref, ybar_ref, znorm_ref, sc_ref, code_ref, *, guard):
    """One (BLOCK_L, n) tile: fused matvec + rule application.

    sc_ref packs the scalars [mid, rad, unorm] (3,) — computed once in the
    L2 graph (‖u‖ is a whole-vector reduction, so it cannot live in the
    per-tile kernel).
    """
    z = z_ref[...]
    u = u_ref[...]
    mid = sc_ref[0]
    rad = sc_ref[1]
    unorm = sc_ref[2]
    p = z @ u  # (BLOCK_L,)
    score = mid * p
    slack = rad * unorm * znorm_ref[...]
    ybar = ybar_ref[...]
    one = jnp.asarray(1.0, z.dtype)
    tau = jnp.asarray(guard, z.dtype) * (jnp.abs(score) + slack + jnp.abs(ybar) + one)
    at_lo = score - slack > ybar + tau
    at_hi = score + slack < ybar - tau
    code_ref[...] = jnp.where(at_lo, 1.0, jnp.where(at_hi, 2.0, 0.0)).astype(
        jnp.float32
    )


@partial(jax.jit, static_argnames=("block_l", "guard"))
def dvi_screen(z, u, ybar, znorm, mid, rad, *, block_l=BLOCK_L, guard=GUARD_EPS):
    """Pallas DVI screening scan. Semantics = :func:`compile.kernels.ref.dvi_screen`.

    Requires l % block_l == 0 (the AOT shape buckets guarantee it; tests
    exercise ragged shapes via the bucket-padding helper in model.py).
    """
    l, n = z.shape
    if l % block_l != 0:
        raise ValueError(f"l={l} not a multiple of block_l={block_l}")
    dt = z.dtype
    unorm = jnp.sqrt(jnp.sum(u.astype(dt) ** 2))
    scalars = jnp.stack(
        [mid.astype(dt), rad.astype(dt), unorm.astype(dt)]
    )  # (3,)
    grid = (l // block_l,)
    return pl.pallas_call(
        partial(_screen_kernel, guard=guard),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l, n), lambda i: (i, 0)),  # stream z tiles
            pl.BlockSpec((n,), lambda i: (0,)),  # u resident
            pl.BlockSpec((block_l,), lambda i: (i,)),
            pl.BlockSpec((block_l,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),  # scalars resident
        ],
        out_specs=pl.BlockSpec((block_l,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), jnp.float32),
        interpret=True,
    )(z, u.astype(dt), ybar, znorm, scalars)


def _matvec_kernel(z_ref, u_ref, p_ref):
    p_ref[...] = z_ref[...] @ u_ref[...]


@partial(jax.jit, static_argnames=("block_l",))
def scores(z, u, *, block_l=BLOCK_L):
    """Standalone tiled matvec p = z @ u (used by the ablation bench and
    the kernel-level tests)."""
    l, n = z.shape
    if l % block_l != 0:
        raise ValueError(f"l={l} not a multiple of block_l={block_l}")
    return pl.pallas_call(
        _matvec_kernel,
        grid=(l // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_l,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), z.dtype),
        interpret=True,
    )(z, u.astype(z.dtype))


def _row_norm_kernel(z_ref, out_ref):
    z = z_ref[...]
    out_ref[...] = jnp.sqrt(jnp.sum(z * z, axis=1))


@partial(jax.jit, static_argnames=("block_l",))
def row_norms(z, *, block_l=BLOCK_L):
    """Tiled per-row norms — the one-time per-dataset precomputation."""
    l, n = z.shape
    if l % block_l != 0:
        raise ValueError(f"l={l} not a multiple of block_l={block_l}")
    return pl.pallas_call(
        _row_norm_kernel,
        grid=(l // block_l,),
        in_specs=[pl.BlockSpec((block_l, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_l,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), z.dtype),
        interpret=True,
    )(z)


def vmem_bytes(block_l: int, n: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency per grid step for the fused kernel — used
    by the §Perf notes and asserted against the 16 MiB budget in tests."""
    z_tile = block_l * n * dtype_bytes
    u = n * dtype_bytes
    vecs = 3 * block_l * dtype_bytes  # ybar, znorm, codes
    scalars = 3 * dtype_bytes
    return z_tile + u + vecs + scalars
