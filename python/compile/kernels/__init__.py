"""L1 Pallas kernels for the DVI screening hot-spot.

The kernels here are authored once at build time, verified against the
pure-jnp oracle in :mod:`compile.kernels.ref` by pytest, composed into the
L2 JAX graph in :mod:`compile.model`, and AOT-lowered to HLO text by
:mod:`compile.aot`. Python never runs on the rust request path.
"""

from . import ref, screen  # noqa: F401
