import os
import sys

# make `compile` importable when pytest runs from python/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
