"""L1 kernel correctness: Pallas vs the pure-jnp oracle.

The decision codes must be *bit-identical* (same dtype, same guard) —
anything weaker could silently flip a screening decision, which breaks the
safety guarantee the whole paper rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, screen


def make_inputs(l, n, seed, dtype=jnp.float32, spread=1.0):
    k = jax.random.PRNGKey(seed)
    kz, ku, ky, km = jax.random.split(k, 4)
    z = (jax.random.normal(kz, (l, n)) * spread).astype(dtype)
    u = jax.random.normal(ku, (n,)).astype(dtype)
    ybar = jnp.sign(jax.random.normal(ky, (l,))).astype(dtype)
    znorm = jnp.sqrt(jnp.sum(z.astype(jnp.float32) ** 2, axis=1)).astype(dtype)
    mid, rad = jnp.asarray(1.3, dtype), jnp.asarray(0.2, dtype)
    return z, u, ybar, znorm, mid, rad


class TestMatvecKernel:
    @pytest.mark.parametrize("l,n", [(512, 2), (1024, 8), (512, 54), (2048, 22)])
    def test_matches_jnp(self, l, n):
        z, u, *_ = make_inputs(l, n, seed=l + n)
        got = screen.scores(z, u)
        want = ref.scores(z, u)
        # f32 matvec accumulation order differs between the tiled kernel
        # and the fused jnp dot — allow a few ulps of drift
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_ragged(self):
        z, u, *_ = make_inputs(512, 4, seed=1)
        with pytest.raises(ValueError, match="multiple"):
            screen.scores(z[:100], u)

    def test_block_sizes_agree(self):
        z, u, *_ = make_inputs(2048, 16, seed=2)
        a = screen.scores(z, u, block_l=512)
        b = screen.scores(z, u, block_l=256)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


class TestRowNorms:
    @pytest.mark.parametrize("l,n", [(512, 3), (1024, 64)])
    def test_matches_jnp(self, l, n):
        z, *_ = make_inputs(l, n, seed=l)
        np.testing.assert_allclose(
            screen.row_norms(z), ref.row_norms(z), rtol=1e-6, atol=1e-6
        )

    def test_zero_rows(self):
        z = jnp.zeros((512, 4), jnp.float32)
        assert (screen.row_norms(z) == 0.0).all()


class TestScreenKernel:
    @pytest.mark.parametrize("l,n", [(512, 2), (1024, 12), (512, 54), (1536, 22)])
    def test_codes_bit_identical(self, l, n):
        z, u, ybar, znorm, mid, rad = make_inputs(l, n, seed=3 * l + n)
        got = screen.dvi_screen(z, u, ybar, znorm, mid, rad)
        want = ref.dvi_screen(z, u, ybar, znorm, mid, rad)
        assert got.dtype == jnp.float32
        assert (got == want).all(), f"codes differ at {np.where(got != want)}"

    def test_screens_something_separable(self):
        # strongly separated scores ⇒ rule should fire
        z, u, ybar, znorm, mid, rad = make_inputs(512, 4, seed=9, spread=4.0)
        got = screen.dvi_screen(z, u, ybar, znorm, mid, rad)
        assert int((got > 0).sum()) > 0

    def test_zero_u_keeps_all_near_margin(self):
        # u = 0 ⇒ score = slack = 0; codes decided purely by sign of ȳ ± τ
        z, _, ybar, znorm, mid, rad = make_inputs(512, 4, seed=10)
        u0 = jnp.zeros((4,), jnp.float32)
        got = screen.dvi_screen(z, u0, ybar, znorm, mid, rad)
        want = ref.dvi_screen(z, u0, ybar, znorm, mid, rad)
        assert (got == want).all()

    def test_padded_rows_inert(self):
        # identical data with and without zero padding ⇒ same codes prefix
        z, u, ybar, znorm, mid, rad = make_inputs(512, 8, seed=11)
        from compile import model

        zp, up, yp, npad = model.pad_inputs(z, u, ybar, znorm, 1024, 16)
        got = screen.dvi_screen(zp, up, yp, npad, mid, rad)
        base = screen.dvi_screen(z, u, ybar, znorm, mid, rad)
        assert (got[:512] == base).all()

    def test_guard_monotone(self):
        # a larger guard can only turn decisions into keeps
        z, u, ybar, znorm, mid, rad = make_inputs(1024, 8, seed=12)
        tight = screen.dvi_screen(z, u, ybar, znorm, mid, rad, guard=0.0)
        loose = screen.dvi_screen(z, u, ybar, znorm, mid, rad, guard=1e-2)
        flipped = (loose != tight) & (loose != 0)
        assert not bool(flipped.any()), "guard created a new decision"

    @staticmethod
    def assert_parity(got, want):
        """Codes must agree except possibly *at* the guard boundary, where
        differing f32 accumulation order can flip screen↔keep. A 1↔2 flip
        (lower vs upper bound) is impossible and always an error."""
        got = np.asarray(got)
        want = np.asarray(want)
        diff = got != want
        # never AtLo vs AtHi
        assert not bool(((got > 0) & (want > 0) & diff).any()), "1<->2 flip"
        # boundary flips must be rare (< 0.5% of instances)
        assert diff.mean() < 5e-3, f"{diff.sum()} disagreements"

    @settings(max_examples=25, deadline=None)
    @given(
        l=st.sampled_from([512, 1024, 1536]),
        n=st.integers(min_value=1, max_value=64),
        seed=st.integers(min_value=0, max_value=2**31),
        spread=st.sampled_from([0.1, 1.0, 10.0]),
    )
    def test_hypothesis_parity(self, l, n, seed, spread):
        z, u, ybar, znorm, mid, rad = make_inputs(l, n, seed=seed, spread=spread)
        got = screen.dvi_screen(z, u, ybar, znorm, mid, rad)
        want = ref.dvi_screen(z, u, ybar, znorm, mid, rad)
        self.assert_parity(got, want)

    @settings(max_examples=10, deadline=None)
    @given(
        mid=st.floats(min_value=0.02, max_value=20.0),
        frac=st.floats(min_value=0.001, max_value=0.999),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_scalar_sweep(self, mid, frac, seed):
        # rad < mid always (C_{k+1} > C_k > 0 ⇒ rad/mid < 1)
        z, u, ybar, znorm, _, _ = make_inputs(512, 8, seed=seed)
        midj = jnp.asarray(mid, jnp.float32)
        radj = jnp.asarray(mid * frac, jnp.float32)
        got = screen.dvi_screen(z, u, ybar, znorm, midj, radj)
        want = ref.dvi_screen(z, u, ybar, znorm, midj, radj)
        self.assert_parity(got, want)


class TestVmemBudget:
    def test_default_block_within_budget(self):
        # 16 MiB VMEM with ≥2x headroom for double buffering
        for n in (8, 16, 32, 64):
            assert screen.vmem_bytes(screen.BLOCK_L, n) * 2 < 16 * 1024 * 1024
