"""L2 graph + AOT export tests: the lowered artifact must agree with the
live jax graph, and the manifest must describe what was written."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


class TestModelGraph:
    def test_graph_matches_reference_oracle(self):
        args = model.example_inputs(1024, 16, seed=4)
        got = model.dvi_screen_graph(*args)
        want = model.dvi_screen_reference(*args)
        assert (got == want).all()

    def test_pad_inputs_rejects_oversize(self):
        z, u, ybar, znorm, *_ = model.example_inputs(1024, 16, seed=5)
        with pytest.raises(ValueError):
            model.pad_inputs(z, u, ybar, znorm, 512, 16)

    def test_dual_objective_matches_manual(self):
        z = jnp.asarray([[1.0, 0.0], [0.0, 2.0]], jnp.float32)
        theta = jnp.asarray([0.5, 1.0], jnp.float32)
        ybar = jnp.asarray([1.0, -1.0], jnp.float32)
        c = 2.0
        # u = [0.5, 2.0]; g = 1.0*(0.25+4.0)/... C/2*4.25 - (0.5 - 1.0)
        want = 0.5 * c * 4.25 - (0.5 - 1.0)
        got = float(model.dual_objective(z, theta, ybar, c))
        assert abs(got - want) < 1e-6

    def test_kkt_classify(self):
        z = jnp.asarray([[-2.0], [-1.0], [-0.5]], jnp.float32)  # z = -x
        w = jnp.asarray([1.0], jnp.float32)
        ybar = jnp.ones((3,), jnp.float32)
        codes = model.kkt_classify(z, w, ybar, 1e-6)
        assert codes.tolist() == [1, 0, 2]


class TestAot:
    def test_quick_build_writes_artifacts(self, tmp_path):
        out = str(tmp_path / "artifacts")
        manifest = aot.build(out, buckets=[(1024, 8)], verbose=False)
        assert manifest["buckets"][0]["file"] == "dvi_screen_1024x8.hlo.txt"
        path = os.path.join(out, "dvi_screen_1024x8.hlo.txt")
        text = open(path).read()
        assert text.startswith("HloModule")
        # entry signature covers all six params
        assert "f32[1024,8]" in text
        on_disk = json.load(open(os.path.join(out, "manifest.json")))
        assert on_disk["guard_eps"] == ref.GUARD_EPS
        assert on_disk["version"] == 1

    def test_lowered_graph_numerics_roundtrip(self, tmp_path):
        """Compile the lowered stablehlo with jax's own client and compare
        against the eager graph — proves the artifact, not just the
        tracer, computes the rule."""
        args = model.example_inputs(1024, 8, seed=6)
        lowered = jax.jit(model.dvi_screen_graph).lower(*args)
        compiled = lowered.compile()
        got = np.asarray(compiled(*args)[0] if isinstance(compiled(*args), tuple) else compiled(*args))
        want = np.asarray(model.dvi_screen_reference(*args))
        np.testing.assert_array_equal(got.ravel(), want.ravel())

    def test_bucket_specs_shapes(self):
        specs = aot.bucket_specs(2048, 8)
        assert specs[0].shape == (2048, 8)
        assert specs[4].shape == ()
        assert all(s.dtype == jnp.float32 for s in specs)

    def test_all_declared_buckets_tile_aligned(self):
        for l, n in aot.BUCKETS:
            assert l % aot.BLOCK_L == 0
            assert 1 <= n <= 64
