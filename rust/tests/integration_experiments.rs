//! Experiment-harness integration at miniature scale: every regenerator
//! runs, writes its CSVs, and reproduces the paper's qualitative *shape*
//! (orderings), which is the reproduction criterion in DESIGN.md.

use dvi_screen::data::{registry, simreal, Task};
use dvi_screen::experiments::{self, ExpOptions};
use dvi_screen::path::{PathConfig, PathRunner};
use dvi_screen::problem::Model;
use dvi_screen::screening::RuleKind;

fn opts(tag: &str) -> ExpOptions {
    let mut dir = std::env::temp_dir();
    dir.push(format!("dvi_exp_int_{}_{tag}", std::process::id()));
    ExpOptions {
        scale: 0.03,
        points: 6,
        tol: 1e-5,
        out_dir: dir,
        use_pjrt: false,
        validate: false,
        threads: 2, // exercise the sharded engine through the harness
    }
}

#[test]
fn all_experiments_run_and_write_csv() {
    let o = opts("all");
    let report = experiments::run("all", &o).expect("all experiments");
    for needle in ["Figure 1", "Table 1", "Figure 2", "Table 2", "Figure 3", "Table 3"] {
        assert!(report.contains(needle), "missing `{needle}`");
    }
    for f in [
        "fig1_toy1.csv",
        "fig1_toy3.csv",
        "tab1.csv",
        "fig2_ijcnn1-sim_dvi.csv",
        "fig2_wine-sim_ssnsv.csv",
        "tab2.csv",
        "fig3_houses-sim.csv",
        "tab3.csv",
    ] {
        assert!(o.out_dir.join(f).exists(), "missing {f}");
    }
    std::fs::remove_dir_all(&o.out_dir).ok();
}

/// The paper's Fig. 2 headline: DVI ≥ ESSNSV ≥ SSNSV in rejection, on
/// every SVM evaluation set — *under the paper's 100-point protocol*.
/// (On much coarser grids the sequential DVI radius grows and the static
/// ESSNSV region can win; that regime is covered by the ablation bench.)
#[test]
fn rule_ordering_matches_paper() {
    let cfg = || {
        PathConfig::log_grid(1e-2, 10.0, 100).with_solver(
            dvi_screen::config::SolverConfig { tol: 1e-6, ..Default::default() },
        )
    };
    for name in simreal::SVM_SETS {
        let ds = registry::resolve(name, 0.04, Task::Classification).unwrap();
        let r_ssnsv = PathRunner::new(Model::Svm, cfg(), RuleKind::Ssnsv).run(&ds);
        let r_essnsv = PathRunner::new(Model::Svm, cfg(), RuleKind::Essnsv).run(&ds);
        let r_dvi = PathRunner::new(Model::Svm, cfg(), RuleKind::DviW).run(&ds);
        assert!(
            r_essnsv.mean_rejection() >= r_ssnsv.mean_rejection() - 1e-9,
            "{name}: essnsv {} < ssnsv {}",
            r_essnsv.mean_rejection(),
            r_ssnsv.mean_rejection()
        );
        assert!(
            r_dvi.mean_rejection() >= r_essnsv.mean_rejection() - 1e-9,
            "{name}: dvi {} < essnsv {}",
            r_dvi.mean_rejection(),
            r_essnsv.mean_rejection()
        );
    }
}

/// Fig. 1 shape: Toy1 (separated) screens more than Toy3 (overlapping),
/// and Toy3's L̃ share is comparable to its R̃ share (the paper's
/// observation about overlapping classes).
#[test]
fn toy_shapes_match_paper() {
    let cfg = PathConfig::log_grid(1e-2, 10.0, 25)
        .with_solver(dvi_screen::config::SolverConfig { tol: 1e-6, ..Default::default() });
    let toys = dvi_screen::data::synth::paper_toys(120);
    let outs: Vec<_> = toys
        .iter()
        .map(|ds| PathRunner::new(Model::Svm, cfg.clone(), RuleKind::DviW).run(ds))
        .collect();
    assert!(
        outs[0].mean_rejection() > outs[2].mean_rejection(),
        "toy1 {} !> toy3 {}",
        outs[0].mean_rejection(),
        outs[2].mean_rejection()
    );
    // Toy3: over the path, the hi (L) side must be a substantial share
    let (lo3, hi3) = outs[2].rejection_series();
    let lo_sum: f64 = lo3.iter().sum();
    let hi_sum: f64 = hi3.iter().sum();
    assert!(
        hi_sum > 0.2 * lo_sum,
        "toy3 L̃ share too small: {hi_sum} vs R̃ {lo_sum}"
    );
    // Toy1: R̃ dominates (clearly separated classes)
    let (lo1, hi1) = outs[0].rejection_series();
    assert!(lo1.iter().sum::<f64>() > 3.0 * hi1.iter().sum::<f64>());
}

/// Table 1/3 shape: screening speeds the path up on every dataset (wall
/// clock), with the separated toy gaining at least as much as the most
/// overlapped one in solver-work terms.
#[test]
fn speedup_shape() {
    let o = ExpOptions { scale: 0.05, points: 12, tol: 1e-6, ..opts("speedup") };
    let toys = dvi_screen::data::synth::paper_toys(150);
    let mut updates_ratio = Vec::new();
    for ds in &toys {
        let cfg = PathConfig::log_grid(1e-2, 10.0, o.points)
            .with_solver(dvi_screen::config::SolverConfig { tol: o.tol, ..Default::default() });
        let plain = PathRunner::new(Model::Svm, cfg.clone(), RuleKind::None).run(ds);
        let dvi = PathRunner::new(Model::Svm, cfg, RuleKind::DviW).run(ds);
        // gradient evaluations are the honest work metric: shrinking
        // skips *updates* but still pays the O(n) scan per active coord
        assert!(
            dvi.total_grad_evals() < plain.total_grad_evals(),
            "{}: screening did not reduce solver work",
            ds.name
        );
        updates_ratio
            .push(plain.total_grad_evals() as f64 / dvi.total_grad_evals().max(1) as f64);
    }
    std::fs::remove_dir_all(&o.out_dir).ok();
    // work-reduction at least ~2x somewhere in the toy family
    assert!(updates_ratio.iter().cloned().fold(0.0, f64::max) > 2.0, "{updates_ratio:?}");
}

/// LAD fig3 shape: houses (low noise) rejects more than magic (heavy
/// overlap) — the paper's ordering of speedups 115x > 10x.
#[test]
fn lad_rejection_ordering() {
    let cfg = || {
        PathConfig::log_grid(1e-2, 10.0, 100).with_solver(
            dvi_screen::config::SolverConfig { tol: 1e-6, ..Default::default() },
        )
    };
    let houses = registry::resolve("houses", 0.03, Task::Regression).unwrap();
    let magic = registry::resolve("magic", 0.03, Task::Regression).unwrap();
    let r_h = PathRunner::new(Model::Lad, cfg(), RuleKind::DviW).run(&houses);
    let r_m = PathRunner::new(Model::Lad, cfg(), RuleKind::DviW).run(&magic);
    assert!(
        r_h.mean_rejection() > r_m.mean_rejection(),
        "houses {} !> magic {}",
        r_h.mean_rejection(),
        r_m.mean_rejection()
    );
}
