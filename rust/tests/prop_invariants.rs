//! Property tests (shrink-lite harness from `dvi_screen::testutil`) over
//! the mathematical invariants the paper's derivation rests on.

use dvi_screen::config::SolverConfig;
use dvi_screen::data::{synth, Rng};
use dvi_screen::linalg;
use dvi_screen::problem::{Instance, Model};
use dvi_screen::screening::dvi::theorem6_ball;
use dvi_screen::screening::ssnsv::lemma20_min;
use dvi_screen::solver::{CdSolver, PgSolver};
use dvi_screen::testutil::{assert_close, check, PropConfig};

fn solver() -> CdSolver {
    CdSolver::new(SolverConfig { tol: 1e-9, max_outer: 100_000, ..Default::default() })
}

fn random_instance(rng: &mut Rng, size: usize) -> Instance {
    let l = 8 + size;
    let n = 2 + size % 5;
    if rng.bernoulli(0.5) {
        Instance::from_dataset(Model::Svm, &synth::random_classification(rng, l, n))
    } else {
        Instance::from_dataset(Model::Lad, &synth::random_regression(rng, l, n))
    }
}

/// Solver output is always inside the box and KKT-stationary.
#[test]
fn prop_solver_feasible_and_stationary() {
    check(PropConfig { cases: 16, seed: 0x51 }, "solver-kkt", |rng, size| {
        let inst = random_instance(rng, size.0);
        let c = 10f64.powf(rng.uniform_in(-2.0, 1.0));
        let r = solver().solve(&inst, c, inst.cold_start());
        if !inst.in_box(&r.theta, 1e-9) {
            return Err("θ outside the box".into());
        }
        let v = CdSolver::kkt_violation(&inst, c, &r.theta);
        if v > 1e-6 {
            return Err(format!("KKT violation {v}"));
        }
        Ok(())
    });
}

/// Theorem 6: Zᵀθ*(C_{k+1}) lies inside the DVI ball built from θ*(C_k).
#[test]
fn prop_theorem6_ball_contains_solution() {
    check(PropConfig { cases: 16, seed: 0x52 }, "thm6-ball", |rng, size| {
        let inst = random_instance(rng, size.0);
        let c0 = 10f64.powf(rng.uniform_in(-2.0, 0.5));
        let c1 = c0 * rng.uniform_in(1.001, 4.0);
        let t0 = solver().solve(&inst, c0, inst.cold_start()).theta;
        let t1 = solver().solve(&inst, c1, inst.cold_start()).theta;
        let (dist, radius) = theorem6_ball(&inst, c0, c1, &t0, &t1);
        if dist > radius + 1e-6 {
            return Err(format!("ball violated: dist {dist} > radius {radius}"));
        }
        Ok(())
    });
}

/// Strong duality: primal(w*(C)) = −C·dual(θ*(C)) at the optimum.
#[test]
fn prop_strong_duality() {
    check(PropConfig { cases: 12, seed: 0x53 }, "strong-duality", |rng, size| {
        let inst = random_instance(rng, size.0);
        let c = 10f64.powf(rng.uniform_in(-1.5, 0.5));
        let r = solver().solve(&inst, c, inst.cold_start());
        let w = inst.w_from_theta(c, &r.theta);
        let p = inst.primal_objective(c, &w);
        let d = -c * inst.dual_objective(c, &r.theta);
        assert_close(p, d, 1e-6, 1e-5, "primal vs dual")
    });
}

/// The two solvers (CD, projected gradient) find the same objective — an
/// algorithm-independence check on the optimum.
#[test]
fn prop_cd_pg_agree() {
    check(PropConfig { cases: 8, seed: 0x54 }, "cd-vs-pg", |rng, size| {
        let inst = random_instance(rng, size.0 / 2);
        let c = 10f64.powf(rng.uniform_in(-1.0, 0.3));
        let cd = solver().solve(&inst, c, inst.cold_start());
        let (pg, _) = PgSolver { tol: 1e-9, max_iters: 200_000 }.solve(&inst, c, inst.cold_start());
        let g1 = inst.dual_objective(c, &cd.theta);
        let g2 = inst.dual_objective(c, &pg);
        assert_close(g1, g2, 1e-6, 1e-6, "cd vs pg objective")
    });
}

/// Lemma 20's closed form never exceeds the value at random feasible
/// points (it is the minimum).
#[test]
fn prop_lemma20_is_lower_bound() {
    check(PropConfig { cases: 24, seed: 0x55 }, "lemma20", |rng, size| {
        let n = 2 + size.0 % 6;
        let v: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
        let u: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
        let o: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
        let r = rng.uniform_in(0.2, 3.0);
        let d = linalg::dot(&u, &o) + rng.uniform_in(0.0, r * linalg::norm(&u));
        let fstar = lemma20_min(&v, &u, d, &o, r);
        for _ in 0..200 {
            let dir: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
            let nn = linalg::norm(&dir);
            if nn == 0.0 {
                continue;
            }
            let rad = r * rng.uniform().powf(1.0 / n as f64);
            let w: Vec<f64> =
                o.iter().zip(&dir).map(|(oi, di)| oi + rad * di / nn).collect();
            if linalg::dot(&u, &w) <= d {
                let val = linalg::dot(&v, &w);
                if val < fstar - 1e-9 {
                    return Err(format!("feasible {val} < f* {fstar}"));
                }
            }
        }
        Ok(())
    });
}

/// DVI rejection is monotone in the grid gap: a smaller C-step screens at
/// least as many instances (slack shrinks pointwise).
#[test]
fn prop_dvi_monotone_in_gap() {
    use dvi_screen::screening::Dvi;
    check(PropConfig { cases: 12, seed: 0x56 }, "dvi-monotone", |rng, size| {
        let inst = random_instance(rng, size.0);
        let c0 = 10f64.powf(rng.uniform_in(-1.5, 0.0));
        let r0 = solver().solve(&inst, c0, inst.cold_start());
        let near = Dvi::new_w().screen(&inst, c0, c0 * 1.05, &r0.theta, &r0.u);
        let far = Dvi::new_w().screen(&inst, c0, c0 * 2.0, &r0.theta, &r0.u);
        // pointwise: every far decision is also made by near
        for (i, (nf, ff)) in near.decisions.iter().zip(&far.decisions).enumerate() {
            if *ff != dvi_screen::screening::Decision::Keep && nf != ff {
                return Err(format!("coord {i}: far screened {ff:?} but near said {nf:?}"));
            }
        }
        Ok(())
    });
}

/// u = Zᵀθ is unique at the optimum even when θ is not: perturbing the
/// solve order must not change u (within tolerance).
#[test]
fn prop_u_unique_across_seeds() {
    check(PropConfig { cases: 8, seed: 0x57 }, "u-unique", |rng, size| {
        let inst = random_instance(rng, size.0);
        let c = 0.5;
        let a = CdSolver::new(SolverConfig { tol: 1e-10, seed: rng.next_u64(), ..Default::default() })
            .solve(&inst, c, inst.cold_start());
        let b = CdSolver::new(SolverConfig { tol: 1e-10, seed: rng.next_u64(), ..Default::default() })
            .solve(&inst, c, inst.cold_start());
        let d = linalg::max_abs_diff(&a.u, &b.u);
        let scale = linalg::norm(&a.u).max(1e-9);
        if d > 1e-4 * scale.max(1.0) {
            return Err(format!("u differs across solver seeds: {d}"));
        }
        Ok(())
    });
}
