//! Acceptance gate for the composable ScreeningRule engine.
//!
//! Three contracts, locked at the decision level:
//!
//! 1. **Bit-identity with the enum-dispatch path** — every trait rule
//!    (`dvi`, `dvi-theta`, `ssnsv`, `essnsv` expressed through
//!    [`RuleExpr::build`]) makes byte-for-byte the decisions of the
//!    pre-refactor rule structs ([`Dvi`], [`Ssnsv`]), for svm/wsvm/lad ×
//!    dense/CSR × {1, 2, 4} scan threads.
//! 2. **Composed safety** — every row a composed rule rejects is
//!    confirmed non-support against an exactly solved KKT point at the
//!    target C (AtLo ⇒ the paper's R set, AtHi ⇒ L).
//! 3. **Composed dominance** — on the SAME solved step context, the
//!    composite rejects every row any member rejects (intersection of
//!    member regions keeps the tightest per-row bounds).

use dvi_screen::config::SolverConfig;
use dvi_screen::data::{synth, Dataset};
use dvi_screen::linalg::Storage;
use dvi_screen::problem::{Instance, Model};
use dvi_screen::screening::{
    Decision, Dvi, RuleExpr, ScreenReport, ScreeningRule, Ssnsv, SsnsvContext, StepContext,
};
use dvi_screen::solver::CdSolver;
use dvi_screen::validation::check_safety;

fn solver_cfg() -> SolverConfig {
    SolverConfig { tol: 1e-9, max_outer: 100_000, ..Default::default() }
}

fn solve(inst: &Instance, c: f64) -> dvi_screen::solver::SolveResult {
    CdSolver::new(solver_cfg()).solve(inst, c, inst.cold_start())
}

/// Everything one screening step needs, solved once per (model, storage).
struct Anchored {
    inst: Instance,
    c_prev: f64,
    c_next: f64,
    theta: Vec<f64>,
    u: Vec<f64>,
    w_feasible: Vec<f64>,
}

impl Anchored {
    fn new(model: Model, ds: &Dataset, c_prev: f64, c_next: f64, c_max: f64) -> Anchored {
        let inst = Instance::from_dataset(model, ds);
        let r = solve(&inst, c_prev);
        // u recomputed from θ exactly, so both the legacy structs and the
        // engine consume identical floats
        let u = inst.u_from_theta(&r.theta);
        let w_feasible = {
            let rf = solve(&inst, c_max);
            inst.w_from_theta(c_max, &rf.theta)
        };
        Anchored { inst, c_prev, c_next, theta: r.theta, u, w_feasible }
    }

    fn ctx(&self) -> StepContext<'_> {
        StepContext {
            c_prev: self.c_prev,
            c_next: self.c_next,
            theta_prev: &self.theta,
            u_prev: &self.u,
            w_feasible: Some(&self.w_feasible),
        }
    }

    /// Run a rule expression through the trait engine.
    fn screen_expr(&self, expr: &str, threads: usize) -> Vec<Decision> {
        let mut engine = RuleExpr::parse(expr).expect("valid expression").build(threads);
        engine.init(&self.inst, threads);
        let region = engine.prepare(&self.inst, &self.ctx());
        engine.screen_rows(&self.inst, &region, threads)
    }

    /// The pre-refactor enum-dispatch decisions for one atom.
    fn screen_legacy(&self, atom: &str) -> Vec<Decision> {
        match atom {
            "dvi" => {
                Dvi::new_w().screen(&self.inst, self.c_prev, self.c_next, &self.theta, &self.u)
            }
            "dvi-theta" => Dvi::new_theta(&self.inst).screen(
                &self.inst,
                self.c_prev,
                self.c_next,
                &self.theta,
                &self.u,
            ),
            "ssnsv" | "essnsv" => {
                let w_anchor = self.inst.w_from_theta(self.c_prev, &self.theta);
                let ctx = SsnsvContext { w_anchor: &w_anchor, w_feasible: &self.w_feasible };
                Ssnsv::new(atom == "essnsv").screen(&self.inst, &ctx)
            }
            other => panic!("no legacy dispatch for `{other}`"),
        }
        .decisions
    }
}

fn dense_and_csr(model: Model, seed: u64) -> Vec<Dataset> {
    let sparse = match model {
        Model::Lad => synth::sparse_regression(seed, 140, 30, 0.15, 0.2),
        _ => synth::sparse_classes(seed, 160, 40, 0.12),
    };
    assert!(sparse.x.is_sparse());
    let dense = sparse.clone().into_storage(Storage::Dense);
    vec![dense, sparse]
}

/// Contract 1: trait rules reproduce the enum path bit-for-bit across
/// models, storages, and scan-thread counts.
#[test]
fn trait_rules_match_enum_rules_bitwise() {
    for (model, seed) in [(Model::Svm, 11u64), (Model::WeightedSvm, 22), (Model::Lad, 33)] {
        let atoms: &[&str] = if model == Model::Lad {
            &["dvi", "dvi-theta"] // SSNSV family is SVM-only
        } else {
            &["dvi", "dvi-theta", "ssnsv", "essnsv"]
        };
        for ds in dense_and_csr(model, seed) {
            let a = Anchored::new(model, &ds, 0.3, 0.6, 2.0);
            for atom in atoms {
                let legacy = a.screen_legacy(atom);
                for threads in [1usize, 2, 4] {
                    let got = a.screen_expr(atom, threads);
                    assert_eq!(
                        got, legacy,
                        "{atom} diverged from the enum path ({model:?}, {}, t={threads})",
                        ds.x.storage_name(),
                    );
                }
            }
        }
    }
}

/// Contract 2: rows rejected by composed rules are non-support at the
/// exactly solved target C (same oracle the validation layer ships:
/// AtLo ⇒ KKT class R, AtHi ⇒ L on a tol=1e-9 solve).
#[test]
fn composed_rejections_are_non_support_at_the_target() {
    for ds in dense_and_csr(Model::Svm, 44) {
        let a = Anchored::new(Model::Svm, &ds, 0.3, 0.6, 2.0);
        for expr in ["dvi+essnsv", "dvi-theta+ssnsv", "dvi+dvi-theta+essnsv"] {
            let rep = ScreenReport::from_decisions(a.screen_expr(expr, 2));
            let safety = check_safety(&a.inst, a.c_next, &rep, &solver_cfg(), 1e-7);
            assert!(safety.n_screened > 0, "{expr}: vacuous test, nothing screened");
            assert!(
                safety.violations.is_empty(),
                "{expr}: unsafe rejections {:?}",
                safety.violations
            );
        }
    }
}

/// Contract 3: on one shared context the composite rejects at least the
/// union of its members' rejections, and is thread-invariant.
#[test]
fn composite_dominates_every_member_on_shared_context() {
    for ds in dense_and_csr(Model::Svm, 55) {
        let a = Anchored::new(Model::Svm, &ds, 0.25, 0.5, 2.0);
        let members = ["dvi", "essnsv"];
        let composite = a.screen_expr("dvi+essnsv", 1);
        for threads in [2usize, 4] {
            assert_eq!(
                composite,
                a.screen_expr("dvi+essnsv", threads),
                "composite not thread-invariant (t={threads})"
            );
        }
        for m in members {
            let alone = a.screen_expr(m, 1);
            for i in 0..alone.len() {
                if alone[i] != Decision::Keep {
                    assert_ne!(
                        composite[i],
                        Decision::Keep,
                        "row {i}: member `{m}` rejected but the composite kept it"
                    );
                }
            }
        }
    }
}
