//! Asynchronous ("wild") CD solver acceptance suite — the contract of
//! `--cd-mode async` (`solver::cd_async`):
//!
//! 1. the async solve returns a KKT-valid point at the same `tol`;
//! 2. downstream DVI screening decisions AND the KKT support/E-set
//!    classification are identical to the sync solver's, for
//!    svm/wsvm/lad × dense/CSR × {2, 4, 7} threads;
//! 3. `cd_mode` is inert at `solver_threads = 1`: both modes are
//!    byte-identical to the serial solver there;
//! 4. `cd_mode = sync` (the default) stays byte-identical to the
//!    pre-mode block-synchronous solver at every thread count — adding
//!    the async arm must not perturb the sync arm's numerics;
//! 5. `max_outer` still bounds the solve.
//!
//! What this suite deliberately does NOT assert: run-to-run bitwise
//! reproducibility of the async arm. Wild sweeps race atomic updates on
//! the shared u with no block barrier, so two async solves may take
//! different trajectories — both valid. That trade is the arm's contract
//! (see README §Solver); the sync default keeps the determinism suite
//! (`integration_cd_par`) green unchanged.

use dvi_screen::config::{CdMode, SolverConfig};
use dvi_screen::data::{synth, Dataset};
use dvi_screen::linalg::Storage;
use dvi_screen::problem::{classify_kkt, Instance, Model};
use dvi_screen::screening::dvi::{ball_params, dvi_scan};
use dvi_screen::solver::CdSolver;

const THREADS: [usize; 3] = [2, 4, 7];
/// Solve tolerance; the KKT re-check allows 100× for the incremental
/// u-maintenance drift all arms share.
const TOL: f64 = 1e-9;
/// KKT dead-band for the E-set comparison — three orders above the
/// solve tolerance, so optimum differences (≈ tol) cannot flip a margin
/// across the band edge.
const E_BAND: f64 = 1e-6;

fn cfg(mode: CdMode, solver_threads: usize) -> SolverConfig {
    SolverConfig {
        tol: TOL,
        max_outer: 200_000,
        solver_threads: Some(solver_threads),
        cd_mode: mode,
        ..Default::default()
    }
}

/// Solve sync-serial and async on both storages of one dataset and hold
/// every clause of the contract.
fn check_model(model: Model, sparse: Dataset, c: f64, c_next: f64) {
    assert!(sparse.x.is_sparse());
    let dense = sparse.clone().into_storage(Storage::Dense);
    for (ds, stag) in [(&dense, "dense"), (&sparse, "csr")] {
        let inst = Instance::from_dataset(model, ds);
        let serial = CdSolver::new(cfg(CdMode::Sync, 1)).solve(&inst, c, inst.cold_start());
        assert!(serial.stats.converged, "{model:?}/{stag}: serial did not converge");

        let (mid, rad) = ball_params(c, c_next);
        let u_serial = inst.u_from_theta(&serial.theta);
        let decisions_serial = dvi_scan(&inst, mid, rad, &u_serial);
        let members_serial =
            classify_kkt(&inst, &inst.w_from_theta(c, &serial.theta), E_BAND);

        for threads in THREADS {
            let wild =
                CdSolver::new(cfg(CdMode::Async, threads)).solve(&inst, c, inst.cold_start());
            let tag = format!("{model:?}/{stag}/async/t={threads}");
            assert!(wild.stats.converged, "{tag}: did not converge");
            assert!(inst.in_box(&wild.theta, 1e-12), "{tag}: θ leaves the box");
            assert_eq!(wild.stats.active_coords, serial.stats.active_coords, "{tag}");

            // KKT-valid at the same tol (fresh full-problem recompute)
            let v = CdSolver::kkt_violation(&inst, c, &wild.theta);
            assert!(v < 100.0 * TOL, "{tag}: violation {v}");

            // identical downstream screening decisions
            let u_wild = inst.u_from_theta(&wild.theta);
            assert_eq!(
                dvi_scan(&inst, mid, rad, &u_wild),
                decisions_serial,
                "{tag}: DVI screening decisions diverged"
            );
            // identical support/E-set classification
            let members_wild =
                classify_kkt(&inst, &inst.w_from_theta(c, &wild.theta), E_BAND);
            assert_eq!(
                members_wild.classes, members_serial.classes,
                "{tag}: KKT membership diverged"
            );
        }
    }
}

#[test]
fn svm_async_solver_matches_sync() {
    check_model(Model::Svm, synth::sparse_classes(911, 180, 60, 0.08), 0.5, 0.8);
}

#[test]
fn weighted_svm_async_solver_matches_sync() {
    check_model(Model::WeightedSvm, synth::sparse_classes(912, 150, 50, 0.1), 0.5, 0.8);
}

#[test]
fn lad_async_solver_matches_sync() {
    check_model(Model::Lad, synth::sparse_regression(913, 160, 40, 0.12, 0.2), 0.5, 0.8);
}

/// Clause 3: at one solver thread the mode knob must be completely
/// inert — both spellings take the serial path, bit for bit.
#[test]
fn cd_mode_is_inert_at_one_thread() {
    let ds = synth::sparse_classes(914, 140, 40, 0.1);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let a = CdSolver::new(cfg(CdMode::Sync, 1)).solve(&inst, 0.7, inst.cold_start());
    let b = CdSolver::new(cfg(CdMode::Async, 1)).solve(&inst, 0.7, inst.cold_start());
    assert_eq!(a.theta, b.theta);
    assert_eq!(a.u, b.u);
    assert_eq!(a.stats.outer_iters, b.stats.outer_iters);
    assert_eq!(a.stats.grad_evals, b.stats.grad_evals);
    assert_eq!(a.stats.coord_updates, b.stats.coord_updates);
}

/// Clause 4 — the sync-mode byte-identity pin: an explicit
/// `cd_mode = sync` and the default config must both reproduce the
/// block-synchronous solver exactly, at every thread count, run to run.
/// This is the regression guard that adding the async arm (and routing
/// the sweeps through the persistent pool) left the sync numerics
/// untouched.
#[test]
fn sync_mode_is_byte_identical_to_default_at_all_thread_counts() {
    let ds = synth::sparse_classes(915, 170, 48, 0.1);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    for threads in [1usize, 2, 4, 7, 0] {
        let default_cfg = SolverConfig {
            tol: TOL,
            max_outer: 200_000,
            solver_threads: Some(threads),
            ..Default::default()
        };
        assert_eq!(default_cfg.cd_mode, CdMode::Sync, "sync must stay the default");
        let a = CdSolver::new(default_cfg).solve(&inst, 0.7, inst.cold_start());
        let b = CdSolver::new(cfg(CdMode::Sync, threads)).solve(&inst, 0.7, inst.cold_start());
        let c = CdSolver::new(cfg(CdMode::Sync, threads)).solve(&inst, 0.7, inst.cold_start());
        for (other, otag) in [(&b, "explicit sync"), (&c, "repeat run")] {
            assert_eq!(a.theta, other.theta, "t={threads} vs {otag}: θ drifted");
            assert_eq!(a.u, other.u, "t={threads} vs {otag}: u drifted");
            assert_eq!(a.stats.outer_iters, other.stats.outer_iters, "t={threads} {otag}");
            assert_eq!(a.stats.grad_evals, other.stats.grad_evals, "t={threads} {otag}");
            assert_eq!(
                a.stats.final_violation.to_bits(),
                other.stats.final_violation.to_bits(),
                "t={threads} {otag}"
            );
        }
    }
}

/// Clause 5: `max_outer` bounds wild rounds and confirmation sweeps
/// alike — a hopeless tolerance terminates instead of spinning.
#[test]
fn async_max_outer_still_bounds_the_solve() {
    let ds = synth::sparse_classes(916, 200, 40, 0.1);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let s = CdSolver::new(SolverConfig {
        tol: 1e-16,
        max_outer: 3,
        solver_threads: Some(4),
        cd_mode: CdMode::Async,
        ..Default::default()
    });
    let r = s.solve(&inst, 10.0, inst.cold_start());
    assert!(r.stats.outer_iters <= 3);
    assert!(!r.stats.converged);
    assert!(inst.in_box(&r.theta, 1e-12), "even a truncated solve stays feasible");
}
