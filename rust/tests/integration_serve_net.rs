//! Network serving semantics: N concurrent TCP clients multiplexed onto
//! one worker pool must each see exactly the bytes a serial stdin
//! session would have produced; `"stream": true` re-sorted by id must be
//! byte-identical to the buffered session; admission control answers
//! typed `overloaded`/`rejected` errors without killing the connection;
//! and a `--model-dir` registry restart serves predict-by-id with zero
//! retrains.

use dvi_screen::config::{parse_json, Json};
use dvi_screen::coordinator::ScreeningService;
use dvi_screen::serve::{ModelRegistry, ServeOptions, Server};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};

/// The mixed deterministic session each client plays: two path runs,
/// one screen job, one batch line (path + screen), one job error, one
/// parse error. Everything under `"timings": false`, so the bytes are
/// scheduling-independent.
const SESSION: &str = r#"{"dataset": "toy1", "scale": 0.05, "points": 4, "rule": "dvi", "tol": 1e-6, "timings": false}
{"dataset": "toy1", "scale": 0.05, "points": 4, "rule": "essnsv", "tol": 1e-6, "timings": false}
{"kind": "screen", "dataset": "toy1", "scale": 0.05, "pairs": [[0.5, 0.9]], "tol": 1e-6, "timings": false}
{"batch": [{"dataset": "toy1", "scale": 0.05, "points": 3, "rule": "none", "tol": 1e-6, "timings": false}, {"kind": "screen", "dataset": "toy1", "scale": 0.05, "pairs": [[0.8, 1.6]], "tol": 1e-6, "timings": false}]}
{"dataset": "no-such-set", "points": 4, "timings": false}
{"dataset": "toy1", "points": 0}
"#;

/// Run `input` through a fresh single-service stdin session — the byte
/// reference every network client is compared against.
fn serial_reference(input: &str) -> Vec<String> {
    let mut svc = ScreeningService::new(2);
    let mut out = Vec::new();
    svc.serve(input.as_bytes(), &mut out).unwrap();
    svc.shutdown();
    String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
}

/// Play `input` against a TCP server and collect the response lines.
fn tcp_session(addr: std::net::SocketAddr, input: &str) -> Vec<String> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(input.as_bytes()).unwrap();
    // half-close: the server sees EOF, replays buffered responses, and
    // the read side below drains them until the server closes
    stream.shutdown(Shutdown::Write).unwrap();
    let mut lines = Vec::new();
    for line in BufReader::new(stream).lines() {
        lines.push(line.unwrap());
    }
    lines
}

#[test]
fn four_tcp_clients_match_serial_stdin_byte_for_byte() {
    let reference = serial_reference(SESSION);
    assert_eq!(reference.len(), 6);

    let svc = ScreeningService::new(3);
    let mut server = Server::new(svc.pool_handle(), ServeOptions::default());
    let addr = server.bind_tcp("127.0.0.1:0").unwrap();

    let sessions: Vec<Vec<String>> = std::thread::scope(|s| {
        let handles: Vec<_> =
            (0..4).map(|_| s.spawn(move || tcp_session(addr, SESSION))).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (client, lines) in sessions.iter().enumerate() {
        assert_eq!(lines, &reference, "client {client} diverged from the serial session");
    }

    // all 4 clients shared ONE resident instance: exactly one build
    let pool = svc.pool_handle();
    assert_eq!(pool.metrics.counter("instance_cache_misses").get(), 1);
    assert_eq!(pool.metrics.counter("serve_connections_opened").get(), 4);

    server.stop();
    svc.shutdown();
}

#[test]
fn streamed_sorted_by_id_equals_buffered() {
    let buffered = serial_reference(SESSION);

    // the same session with "stream": true stamped on every line
    let streamed_input: String = SESSION
        .lines()
        .map(|l| {
            let mut l = l.trim_start_matches('{').to_string();
            l.insert_str(0, "{\"stream\": true, ");
            l.push('\n');
            l
        })
        .collect();

    let svc = ScreeningService::new(3);
    let mut server = Server::new(svc.pool_handle(), ServeOptions::default());
    let addr = server.bind_tcp("127.0.0.1:0").unwrap();
    let mut lines = tcp_session(addr, &streamed_input);

    // a streamed batch answers one line PER entry instead of one
    // wrapper line: 5 singles + 2 batch entries
    assert_eq!(lines.len(), 7, "{lines:?}");

    // order by id; the one id-less line (the parse error consumed no
    // id) sorts last, where input order put it
    lines.sort_by_key(|l| {
        parse_json(l).ok().and_then(|j| j.get("id").and_then(Json::as_int)).unwrap_or(i64::MAX)
    });

    // re-wrap the streamed batch entries (ids 3 and 4) the way the
    // buffered session's one `{"batch": [...]}` line carries them
    let wrapper = {
        let mut o = BTreeMap::new();
        o.insert(
            "batch".to_string(),
            Json::Array(vec![
                parse_json(&lines[3]).unwrap(),
                parse_json(&lines[4]).unwrap(),
            ]),
        );
        Json::Object(o).to_string()
    };
    let rewrapped: Vec<String> = lines[..3]
        .iter()
        .cloned()
        .chain(std::iter::once(wrapper))
        .chain(lines[5..].iter().cloned())
        .collect();
    assert_eq!(rewrapped, buffered);

    server.stop();
    svc.shutdown();
}

#[test]
fn over_budget_answers_overloaded_and_connection_stays_usable() {
    let svc = ScreeningService::new(2);
    // a 1-unit global budget: any path run (points × 1000 units) can
    // never fit, while a stats request (1 unit) always can
    let opts = ServeOptions { queue_cost: 1, ..Default::default() };
    let mut server = Server::new(svc.pool_handle(), opts);
    let addr = server.bind_tcp("127.0.0.1:0").unwrap();

    let input = "{\"dataset\": \"toy1\", \"points\": 4, \"timings\": false}\n\
                 {\"kind\": \"stats\", \"timings\": false}\n";
    let lines = tcp_session(addr, input);
    assert_eq!(lines.len(), 2, "{lines:?}");

    let refused = parse_json(&lines[0]).unwrap();
    assert_eq!(refused.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(refused.get("code").unwrap().as_str(), Some("overloaded"), "{lines:?}");
    assert!(refused.get("id").is_none(), "refused requests consume no id");

    // the SAME connection then serves a cheap request under id 0
    let stats = parse_json(&lines[1]).unwrap();
    assert_eq!(stats.get("ok").unwrap().as_bool(), Some(true), "{lines:?}");
    assert_eq!(stats.get("id").unwrap().as_int(), Some(0));
    let counters = stats.get("counters").unwrap();
    assert_eq!(counters.get("serve_overloaded").unwrap().as_int(), Some(1));

    server.stop();
    svc.shutdown();
}

#[test]
fn per_connection_cap_answers_rejected() {
    let svc = ScreeningService::new(2);
    let opts = ServeOptions { max_inflight: 1, ..Default::default() };
    let mut server = Server::new(svc.pool_handle(), opts);
    let addr = server.bind_tcp("127.0.0.1:0").unwrap();

    // line 1 occupies the single in-flight slot for at least one full
    // instance build + 8 path steps; line 2 is read (and refused)
    // microseconds later, long before line 1 can complete
    let input = "{\"dataset\": \"toy2\", \"scale\": 0.5, \"points\": 8, \"timings\": false}\n\
                 {\"kind\": \"stats\", \"timings\": false}\n";
    let lines = tcp_session(addr, input);
    assert_eq!(lines.len(), 2, "{lines:?}");

    let ok = parse_json(&lines[0]).unwrap();
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true), "{lines:?}");
    assert_eq!(ok.get("id").unwrap().as_int(), Some(0));

    let refused = parse_json(&lines[1]).unwrap();
    assert_eq!(refused.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(refused.get("code").unwrap().as_str(), Some("rejected"), "{lines:?}");
    assert!(refused.get("id").is_none());

    server.stop();
    svc.shutdown();
}

#[test]
fn draining_server_refuses_new_work_and_flushes_in_flight() {
    let svc = ScreeningService::new(2);
    let mut server = Server::new(svc.pool_handle(), ServeOptions::default());
    let drain = server.drain_handle();
    let addr = server.bind_tcp("127.0.0.1:0").unwrap();
    assert!(!drain.is_draining());

    // a normal request completes before the drain begins
    let lines = tcp_session(
        addr,
        "{\"dataset\": \"toy1\", \"scale\": 0.05, \"points\": 3, \"tol\": 1e-6, \
         \"timings\": false}\n",
    );
    let ok = parse_json(&lines[0]).unwrap();
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true), "{lines:?}");

    drain.begin();
    assert!(drain.is_draining());
    // nothing was in flight, so the drain settles immediately (modulo
    // the writer grace period)
    assert!(drain.wait_idle(std::time::Duration::from_secs(10)));

    // post-drain requests answer the typed refusal, id-less, and the
    // connection itself still works end to end
    let lines = tcp_session(
        addr,
        "{\"dataset\": \"toy1\", \"points\": 3, \"timings\": false}\n\
         {\"kind\": \"stats\", \"timings\": false}\n",
    );
    assert_eq!(lines.len(), 2, "{lines:?}");
    for line in &lines {
        let j = parse_json(line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{lines:?}");
        assert_eq!(j.get("code").unwrap().as_str(), Some("draining"), "{lines:?}");
        assert!(j.get("id").is_none(), "refused requests consume no id");
    }

    server.stop();
    svc.shutdown();
}

/// Scrape `path` once from the metrics endpoint and return the whole
/// HTTP response (status line, headers, body).
fn scrape(addr: std::net::SocketAddr, path: &str) -> String {
    use std::io::Read;
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: dvi\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut text = String::new();
    stream.read_to_string(&mut text).unwrap();
    text
}

/// The value of a plain (unlabelled) counter/gauge sample line, or 0.0
/// if the family has not been touched yet.
fn sample_value(body: &str, name: &str) -> f64 {
    body.lines()
        .filter(|l| !l.starts_with('#'))
        .find_map(|l| {
            let mut it = l.split_whitespace();
            (it.next() == Some(name)).then(|| it.next().unwrap().parse().unwrap())
        })
        .unwrap_or(0.0)
}

#[test]
fn metrics_endpoint_serves_complete_families_and_monotone_counters() {
    let svc = ScreeningService::new(3);
    let mut server = Server::new(svc.pool_handle(), ServeOptions::default());
    let addr = server.bind_tcp("127.0.0.1:0").unwrap();

    let registry = svc.pool_handle().metrics.clone();
    let render = std::sync::Arc::new(move || {
        dvi_screen::obs::expo::render_exposition(Some(&registry))
    });
    let maddr = dvi_screen::obs::expo::serve_metrics("127.0.0.1:0", render).unwrap();

    let first = scrape(maddr, "/metrics");
    assert!(first.starts_with("HTTP/1.1 200 OK"), "{first}");
    assert!(first.contains("text/plain; version=0.0.4"), "{first}");

    // two concurrent clients drive every layer of the serving stack
    std::thread::scope(|s| {
        let handles: Vec<_> =
            (0..2).map(|_| s.spawn(move || tcp_session(addr, SESSION))).collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    let second = scrape(maddr, "/metrics");
    // every layer's families render: pool job counters and latency
    // histograms, serve admission gauges, the dispatcher backlog and
    // request-latency summary, solver-pool gauges, and per-rule
    // screening telemetry
    for needle in [
        "# TYPE jobs_done counter",
        "service_requests",
        "serve_inflight",
        "serve_queue_cost",
        "serve_dispatcher_backlog",
        "serve_request_secs_count",
        "job_secs_count",
        "pool_queue_depth",
        "pool_workers_spawned_total",
        "screen_rows_scanned_total{rule=\"dvi\"}",
        "screen_rows_rejected_total{rule=\"dvi\"}",
    ] {
        assert!(second.contains(needle), "missing `{needle}` in scrape:\n{second}");
    }

    // counters only move up between scrapes, and the sessions above
    // must have moved them
    for counter in ["jobs_done", "service_requests"] {
        let (a, b) = (sample_value(&first, counter), sample_value(&second, counter));
        assert!(b >= a, "{counter} went backwards: {a} -> {b}");
        assert!(b > 0.0, "{counter} never moved:\n{second}");
    }
    // both sessions fully drained: admission gauges are back to zero
    assert_eq!(sample_value(&second, "serve_inflight"), 0.0, "{second}");
    assert_eq!(sample_value(&second, "pool_queue_depth"), 0.0, "{second}");

    // anything but GET /metrics is a 404, and the endpoint answers
    // again after it
    assert!(scrape(maddr, "/other").starts_with("HTTP/1.1 404"), "404 for non-metrics paths");
    assert!(scrape(maddr, "/metrics").starts_with("HTTP/1.1 200 OK"));

    server.stop();
    svc.shutdown();
}

#[test]
fn model_dir_restart_serves_predict_without_retraining() {
    let dir = std::env::temp_dir().join(format!("dvi_serve_net_registry_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // server 1: train with "persist": true writes the artifact
    let model_id = {
        let svc = ScreeningService::new(2);
        let opts = ServeOptions { model_dir: Some(dir.clone()), ..Default::default() };
        let mut server = Server::new(svc.pool_handle(), opts);
        let addr = server.bind_tcp("127.0.0.1:0").unwrap();
        let lines = tcp_session(
            addr,
            "{\"kind\": \"train\", \"dataset\": \"toy1\", \"scale\": 0.03, \"c\": 0.5, \
             \"tol\": 1e-6, \"persist\": true, \"timings\": false}\n",
        );
        let j = parse_json(&lines[0]).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{lines:?}");
        let id = j.get("model_id").unwrap().as_str().unwrap().to_string();
        let persisted = j.get("persisted").unwrap().as_str().unwrap().to_string();
        assert!(std::path::Path::new(&persisted).exists());
        server.stop();
        svc.shutdown();
        id
    };

    // without a registry, "persist": true is a typed refusal
    {
        let svc = ScreeningService::new(1);
        let mut server = Server::new(svc.pool_handle(), ServeOptions::default());
        let addr = server.bind_tcp("127.0.0.1:0").unwrap();
        let lines = tcp_session(
            addr,
            "{\"kind\": \"train\", \"dataset\": \"toy1\", \"scale\": 0.03, \"c\": 0.5, \
             \"persist\": true, \"timings\": false}\n",
        );
        let j = parse_json(&lines[0]).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("--model-dir"), "{lines:?}");
        server.stop();
        svc.shutdown();
    }

    // server 2 ("restart"): the startup scan makes the artifact resident,
    // so predict by model_id pays a cache hit, not a train job
    {
        let svc = ScreeningService::new(2);
        let pool = svc.pool_handle();
        let scan = ModelRegistry::new(&dir).load_all(&pool.models, &pool.metrics).unwrap();
        assert_eq!(scan.loaded.len(), 1, "{scan:?}");
        assert_eq!(scan.loaded[0].0, model_id);

        let opts = ServeOptions { model_dir: Some(dir.clone()), ..Default::default() };
        let mut server = Server::new(pool.clone(), opts);
        let addr = server.bind_tcp("127.0.0.1:0").unwrap();
        let input = format!(
            "{{\"kind\": \"predict\", \"model_id\": \"{model_id}\", \"dataset\": \"toy1\", \
             \"scale\": 0.03, \"timings\": false}}\n\
             {{\"kind\": \"stats\", \"timings\": false}}\n"
        );
        let lines = tcp_session(addr, &input);
        let p = parse_json(&lines[0]).unwrap();
        assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{lines:?}");
        assert_eq!(p.get("rows").unwrap().as_int(), Some(60));

        let stats = parse_json(&lines[1]).unwrap();
        let counters = stats.get("counters").unwrap();
        assert_eq!(counters.get("model_registry_loaded").unwrap().as_int(), Some(1));
        // the scoring model came out of the resident cache — nothing was
        // re-trained and nothing was re-read from disk
        assert_eq!(counters.get("model_cache_hits").unwrap().as_int(), Some(1));
        assert!(counters.get("model_cache_loads").is_none(), "{lines:?}");

        server.stop();
        svc.shutdown();
    }
    let _ = std::fs::remove_dir_all(&dir);
}
