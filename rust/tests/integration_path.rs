//! Path-level integration: the screen→reduce→solve loop reproduces the
//! unscreened path exactly, warm starts behave, and the experiment
//! protocol's bookkeeping (init/screen/solve splits) is consistent.

use dvi_screen::config::SolverConfig;
use dvi_screen::data::{synth, Rng};
use dvi_screen::path::{PathConfig, PathRunner};
use dvi_screen::problem::Model;
use dvi_screen::screening::RuleKind;

fn cfg(points: usize) -> PathConfig {
    PathConfig::log_grid(1e-2, 10.0, points)
        .with_solver(SolverConfig { tol: 1e-8, max_outer: 100_000, ..Default::default() })
        .with_validation(true)
}

/// Every rule must produce the identical sequence of dual objectives —
/// screening changes *work*, never *answers*.
#[test]
fn all_rules_same_path_objectives() {
    let ds = synth::toy_gaussian(61, 120, 1.0, 0.75);
    let base = PathRunner::new(Model::Svm, cfg(10), RuleKind::None).run(&ds);
    for rule in [RuleKind::DviW, RuleKind::DviTheta, RuleKind::Ssnsv, RuleKind::Essnsv] {
        let out = PathRunner::new(Model::Svm, cfg(10), rule).run(&ds);
        for (a, b) in out.steps.iter().zip(&base.steps) {
            let tol = 1e-6 * b.dual_obj.abs().max(1.0);
            assert!(
                (a.dual_obj - b.dual_obj).abs() < tol,
                "{:?} diverged at C={}: {} vs {}",
                rule,
                a.c,
                a.dual_obj,
                b.dual_obj
            );
        }
        assert!(out.worst_violation().unwrap() < 1e-6, "{rule:?}");
    }
}

/// LAD paths: same equivalence.
#[test]
fn lad_path_equivalence() {
    let mut rng = Rng::new(7);
    let ds = synth::random_regression(&mut rng, 150, 6);
    let base = PathRunner::new(Model::Lad, cfg(10), RuleKind::None).run(&ds);
    let dvi = PathRunner::new(Model::Lad, cfg(10), RuleKind::DviW).run(&ds);
    for (a, b) in dvi.steps.iter().zip(&base.steps) {
        let tol = 1e-6 * b.dual_obj.abs().max(1.0);
        assert!((a.dual_obj - b.dual_obj).abs() < tol, "at C={}", a.c);
    }
    assert!(dvi.mean_rejection() > 0.0);
}

/// Screening reduces solver work measurably on a separable problem.
/// Gradient evaluations are the honest metric: shrinking avoids updates
/// but every sweep still scans its active coordinates.
#[test]
fn screening_reduces_coordinate_updates() {
    let ds = synth::toy_gaussian(62, 400, 1.5, 0.75);
    let base = PathRunner::new(Model::Svm, cfg(12), RuleKind::None).run(&ds);
    let dvi = PathRunner::new(Model::Svm, cfg(12), RuleKind::DviW).run(&ds);
    assert!(
        dvi.total_grad_evals() < base.total_grad_evals() / 2,
        "dvi {} !< half of base {}",
        dvi.total_grad_evals(),
        base.total_grad_evals()
    );
}

/// Denser grids screen more (the DVI radius shrinks with grid spacing) —
/// the mechanism behind the paper's 100-point protocol.
#[test]
fn denser_grid_screens_more() {
    let ds = synth::toy_gaussian(63, 200, 0.75, 0.75);
    let coarse = PathRunner::new(Model::Svm, cfg(6), RuleKind::DviW).run(&ds);
    let dense = PathRunner::new(Model::Svm, cfg(40), RuleKind::DviW).run(&ds);
    assert!(
        dense.mean_rejection() > coarse.mean_rejection(),
        "dense {} !> coarse {}",
        dense.mean_rejection(),
        coarse.mean_rejection()
    );
}

/// The init bookkeeping matches the paper's protocol: SSNSV init ≈ two
/// solves, DVI init ≈ one.
#[test]
fn init_accounting_matches_protocol() {
    let ds = synth::toy_gaussian(64, 300, 1.0, 0.75);
    let dvi = PathRunner::new(Model::Svm, cfg(8), RuleKind::DviW).run(&ds);
    let ssnsv = PathRunner::new(Model::Svm, cfg(8), RuleKind::Ssnsv).run(&ds);
    // SSNSV must pay for the extra C_max solve
    assert!(
        ssnsv.init_secs > dvi.init_secs,
        "ssnsv init {} !> dvi init {}",
        ssnsv.init_secs,
        dvi.init_secs
    );
    // screening time is recorded and positive on screened paths
    assert!(dvi.screen_secs > 0.0);
    // steps' recorded times sum to no more than the total wall clock
    let step_sum: f64 =
        dvi.steps.iter().map(|s| s.screen_secs + s.solve_secs).sum();
    assert!(step_sum <= dvi.total_secs * 1.05 + 1e-3);
}

/// Rejection series are well-formed fractions that sum ≤ 1 with the kept
/// fraction.
#[test]
fn rejection_series_well_formed() {
    let ds = synth::toy_gaussian(65, 150, 0.5, 0.75);
    let out = PathRunner::new(Model::Svm, cfg(15), RuleKind::DviW).run(&ds);
    let (lo, hi) = out.rejection_series();
    for k in 0..lo.len() {
        assert!(lo[k] >= 0.0 && hi[k] >= 0.0 && lo[k] + hi[k] <= 1.0 + 1e-12);
        let expect_free = out.l as f64 * (1.0 - lo[k] - hi[k]);
        assert!((out.steps[k].free as f64 - expect_free).abs() < 1.5);
    }
    // first step never screens
    assert_eq!(out.steps[0].free, out.l);
}

/// Weighted SVM (the paper's §8 extension): per-coordinate dual boxes,
/// full path with DVI — safe and equivalent to the unscreened path.
#[test]
fn weighted_svm_path() {
    let ds = synth::gaussian_classes(77, 200, 4, 1.2, 1.0, 0.2, 1.5);
    let base = PathRunner::new(Model::WeightedSvm, cfg(10), RuleKind::None).run(&ds);
    let dvi = PathRunner::new(Model::WeightedSvm, cfg(10), RuleKind::DviW).run(&ds);
    for (a, b) in dvi.steps.iter().zip(&base.steps) {
        let tol = 1e-6 * b.dual_obj.abs().max(1.0);
        assert!((a.dual_obj - b.dual_obj).abs() < tol, "at C={}", a.c);
    }
    assert!(dvi.worst_violation().unwrap() < 1e-6);
    assert!(dvi.mean_rejection() > 0.0);
}

/// Cold-baseline protocol flag: same answers, more work.
#[test]
fn cold_baseline_equivalent_but_slower_in_work() {
    let ds = synth::toy_gaussian(68, 200, 1.0, 0.75);
    let warm = PathRunner::new(Model::Svm, cfg(10), RuleKind::None).run(&ds);
    let cold =
        PathRunner::new(Model::Svm, cfg(10).with_cold_baseline(), RuleKind::None).run(&ds);
    for (a, b) in warm.steps.iter().zip(&cold.steps) {
        let tol = 1e-6 * b.dual_obj.abs().max(1.0);
        assert!((a.dual_obj - b.dual_obj).abs() < tol, "at C={}", a.c);
    }
    assert!(cold.total_grad_evals() > warm.total_grad_evals());
}

/// A custom (non-log) grid works as long as it is ascending.
#[test]
fn custom_grid_supported() {
    let ds = synth::toy_gaussian(66, 80, 1.0, 0.75);
    let pc = PathConfig {
        grid: vec![0.1, 0.11, 0.5, 2.0, 9.9],
        solver: SolverConfig { tol: 1e-8, ..Default::default() },
        validate: true,
        warm_start: true,
    };
    let out = PathRunner::new(Model::Svm, pc, RuleKind::DviW).run(&ds);
    assert_eq!(out.steps.len(), 5);
    assert!(out.worst_violation().unwrap() < 1e-6);
    // the tight 0.1→0.11 step should screen far more than the 0.5→2.0 one
    let tight = out.steps[1].rejection(out.l);
    let wide = out.steps[3].rejection(out.l);
    assert!(tight >= wide, "tight {tight} < wide {wide}");
}
