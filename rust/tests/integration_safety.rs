//! The paper's central claim, machine-checked at scale: DVI, SSNSV and
//! ESSNSV are *safe* — across models, datasets, grids and C-ranges, no
//! screened instance is ever a support vector of the exact solution.

use dvi_screen::config::SolverConfig;
use dvi_screen::data::{synth, Rng};
use dvi_screen::problem::{Instance, Model};
use dvi_screen::screening::{Dvi, ScreenReport, Ssnsv, SsnsvContext};
use dvi_screen::solver::CdSolver;
use dvi_screen::validation::check_safety;

fn solver_cfg() -> SolverConfig {
    SolverConfig { tol: 1e-9, max_outer: 100_000, ..Default::default() }
}

fn solve(inst: &Instance, c: f64) -> dvi_screen::solver::SolveResult {
    CdSolver::new(solver_cfg()).solve(inst, c, inst.cold_start())
}

/// Sweep DVI safety over random SVM problems and random C-pairs.
#[test]
fn dvi_safety_sweep_svm() {
    let mut rng = Rng::new(0xAB);
    for trial in 0..12 {
        let l = 40 + 30 * trial;
        let ds = synth::random_classification(&mut rng, l, 2 + trial % 6);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let c0 = 10f64.powf(rng.uniform_in(-2.0, 0.5));
        let c1 = c0 * rng.uniform_in(1.01, 3.0);
        let r0 = solve(&inst, c0);
        let rep = Dvi::new_w().screen(&inst, c0, c1, &r0.theta, &r0.u);
        let safety = check_safety(&inst, c1, &rep, &solver_cfg(), 1e-7);
        assert!(
            safety.is_safe(),
            "trial {trial}: {} violations, first {:?}",
            safety.violations.len(),
            safety.violations.first()
        );
    }
}

/// Sweep DVI safety over random LAD problems.
#[test]
fn dvi_safety_sweep_lad() {
    let mut rng = Rng::new(0xCD);
    for trial in 0..12 {
        let ds = synth::random_regression(&mut rng, 60 + 25 * trial, 2 + trial % 5);
        let inst = Instance::from_dataset(Model::Lad, &ds);
        let c0 = 10f64.powf(rng.uniform_in(-2.0, 0.0));
        let c1 = c0 * rng.uniform_in(1.01, 2.5);
        let r0 = solve(&inst, c0);
        let rep = Dvi::new_w().screen(&inst, c0, c1, &r0.theta, &r0.u);
        let safety = check_safety(&inst, c1, &rep, &solver_cfg(), 1e-7);
        assert!(safety.is_safe(), "trial {trial}: {:?}", safety.violations.first());
    }
}

/// DVI θ-form must make exactly the decisions of the w-form (they are the
/// same bound, evaluated differently), hence equally safe.
#[test]
fn dvi_theta_form_identical_decisions() {
    let mut rng = Rng::new(0xEF);
    for _ in 0..6 {
        let ds = synth::random_classification(&mut rng, 80, 3);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = solve(&inst, 0.4);
        let w_form = Dvi::new_w().screen(&inst, 0.4, 0.9, &r.theta, &r.u);
        let t_form = Dvi::new_theta(&inst).screen(&inst, 0.4, 0.9, &r.theta, &r.u);
        assert_eq!(w_form.decisions, t_form.decisions);
    }
}

/// SSNSV/ESSNSV safety across every interior grid point of a short path,
/// and the dominance chain SSNSV ⊆ ESSNSV (region inclusion).
#[test]
fn ssnsv_family_safety_and_dominance_along_path() {
    let mut rng = Rng::new(0x11);
    for trial in 0..5 {
        let ds = synth::random_classification(&mut rng, 120, 2 + trial);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let grid = [0.05, 0.2, 0.8, 3.0];
        let w_feas = {
            let r = solve(&inst, *grid.last().unwrap());
            inst.w_from_theta(*grid.last().unwrap(), &r.theta)
        };
        for k in 0..grid.len() - 1 {
            let r = solve(&inst, grid[k]);
            let w_anchor = inst.w_from_theta(grid[k], &r.theta);
            let ctx = SsnsvContext { w_anchor: &w_anchor, w_feasible: &w_feas };
            let base = Ssnsv::new(false).screen(&inst, &ctx);
            let enh = Ssnsv::new(true).screen(&inst, &ctx);
            for (b, e) in base.decisions.iter().zip(&enh.decisions) {
                if *b != dvi_screen::screening::Decision::Keep {
                    assert_eq!(b, e, "ESSNSV lost an SSNSV decision");
                }
            }
            for rep in [&base, &enh] {
                let safety = check_safety(&inst, grid[k + 1], rep, &solver_cfg(), 1e-7);
                assert!(
                    safety.is_safe(),
                    "trial {trial} k={k}: {:?}",
                    safety.violations.first()
                );
            }
        }
    }
}

/// Weighted-SVM extension: per-coordinate boxes, same guarantee.
#[test]
fn weighted_svm_safety() {
    let mut rng = Rng::new(0x22);
    for trial in 0..6 {
        let ds = synth::gaussian_classes(
            rng.next_u64(),
            100,
            3,
            rng.uniform_in(0.5, 1.5),
            1.0,
            0.25,
            1.0,
        );
        let inst = Instance::from_dataset(Model::WeightedSvm, &ds);
        let c0 = 0.1 * (trial + 1) as f64;
        let c1 = c0 * 1.4;
        let r0 = solve(&inst, c0);
        let rep = Dvi::new_w().screen(&inst, c0, c1, &r0.theta, &r0.u);
        let safety = check_safety(&inst, c1, &rep, &solver_cfg(), 1e-7);
        assert!(safety.is_safe(), "trial {trial}: {:?}", safety.violations.first());
    }
}

/// Degenerate inputs: duplicated rows, zero rows, constant labels.
#[test]
fn dvi_safety_degenerate_inputs() {
    use dvi_screen::data::{Dataset, Task};
    use dvi_screen::linalg::RowMatrix;
    // duplicated + zero rows
    let mut x = RowMatrix::zeros(6, 2);
    x.set(0, 0, 1.0);
    x.set(1, 0, 1.0); // duplicate of row 0
    x.set(2, 1, -2.0);
    // rows 3..5 zero
    let ds = Dataset::new(
        "degenerate",
        Task::Classification,
        x,
        vec![1.0, 1.0, -1.0, 1.0, -1.0, 1.0],
    );
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let r0 = solve(&inst, 0.5);
    let rep = Dvi::new_w().screen(&inst, 0.5, 1.0, &r0.theta, &r0.u);
    let safety = check_safety(&inst, 1.0, &rep, &solver_cfg(), 1e-7);
    assert!(safety.is_safe(), "{:?}", safety.violations);
}

/// Screening must never change the recovered optimum: solve the reduced
/// problem after screening and compare against the full solve.
#[test]
fn reduced_solve_equals_full_solve_after_screening() {
    let mut rng = Rng::new(0x33);
    for _ in 0..6 {
        let ds = synth::random_classification(&mut rng, 150, 4);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let (c0, c1) = (0.2, 0.35);
        let r0 = solve(&inst, c0);
        let rep: ScreenReport = Dvi::new_w().screen(&inst, c0, c1, &r0.theta, &r0.u);
        let mut theta0 = r0.theta.clone();
        rep.apply_to_theta(&inst, &mut theta0);
        let reduced =
            CdSolver::new(solver_cfg()).solve_free(&inst, c1, theta0, &rep.free_indices());
        dvi_screen::validation::check_exactness(&inst, c1, &reduced.theta, &solver_cfg(), 1e-6)
            .expect("reduced solve drifted from the full optimum");
    }
}
