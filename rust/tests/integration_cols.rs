//! Column-shard acceptance suite: the `--shard-axis` knob must be a pure
//! performance choice. For every model (SVM / weighted SVM / LAD), both
//! storages (dense / CSR), and thread counts {1, 2, 4, 7}, the
//! column-sharded reconstructions must reproduce the row path bit for
//! bit: screening decisions, u = Zᵀθ iterates, extracted model artifact
//! bytes, and θ-form Gram matrices. `auto` must resolve deterministically
//! from the instance shape and agree with whichever fixed axis it picks.

use dvi_screen::config::SolverConfig;
use dvi_screen::data::synth;
use dvi_screen::linalg::{ShardAxis, Storage};
use dvi_screen::model::{format, TrainedModel};
use dvi_screen::problem::{Instance, Model};
use dvi_screen::screening::dvi::screen_w_par;
use dvi_screen::screening::Dvi;
use dvi_screen::solver::CdSolver;

const THREADS: [usize; 4] = [1, 2, 4, 7];
const AXES: [ShardAxis; 3] = [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Auto];

fn dataset(model: Model, storage: Storage) -> dvi_screen::data::Dataset {
    match model {
        Model::Svm | Model::WeightedSvm => {
            // uneven sparse rows, prime-ish dims: no shard count divides
            // the column slabs evenly
            synth::sparse_classes(61, 83, 37, 0.2).into_storage(storage)
        }
        Model::Lad => {
            let mut rng = dvi_screen::data::Rng::new(62);
            synth::random_regression(&mut rng, 90, 23).into_storage(storage)
        }
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn cols_axis_reproduces_rows_axis_bit_for_bit() {
    for model in [Model::Svm, Model::WeightedSvm, Model::Lad] {
        for storage in [Storage::Dense, Storage::Csr] {
            let ds = dataset(model, storage);
            let inst = Instance::from_dataset(model, &ds);
            // the CD solve itself stays serial (threads: 1 below) so the
            // anchor θ is one fixed bit pattern; the axis/thread sweep
            // then exercises only the reconstruction paths under test
            let r = CdSolver::new(SolverConfig { tol: 1e-7, ..Default::default() })
                .solve(&inst, 0.4, inst.cold_start());

            let u_ref = inst.u_from_theta(&r.theta);
            let w_ref = inst.w_from_theta(0.4, &r.theta);
            let dec_ref = screen_w_par(&inst, 0.4, 0.7, &u_ref, 1).decisions;
            let model_ref =
                TrainedModel::from_solution(&inst, "cols-suite", 1.0, 0.4, 1e-7, &r.theta);
            let bytes_ref = format::encode(&model_ref);

            for threads in THREADS {
                for axis in AXES {
                    let tag = format!(
                        "{model:?} {storage:?} threads={threads} axis={}",
                        axis.name()
                    );
                    let u = inst.u_from_theta_axis(&r.theta, axis, threads);
                    assert_eq!(bits(&u), bits(&u_ref), "u diverged: {tag}");
                    let w = inst.w_from_theta_axis(0.4, &r.theta, axis, threads);
                    assert_eq!(bits(&w), bits(&w_ref), "w diverged: {tag}");
                    let dec = screen_w_par(&inst, 0.4, 0.7, &u, threads).decisions;
                    assert_eq!(dec, dec_ref, "decisions diverged: {tag}");
                    let tm = TrainedModel::from_solution_axis(
                        &inst,
                        "cols-suite",
                        1.0,
                        0.4,
                        1e-7,
                        &r.theta,
                        axis,
                        threads,
                    );
                    assert_eq!(format::encode(&tm), bytes_ref, "artifact diverged: {tag}");
                    assert_eq!(tm.id(), model_ref.id(), "model id diverged: {tag}");
                    assert_eq!(
                        bits(&tm.reconstruct_w_threads(threads)),
                        bits(&model_ref.reconstruct_w()),
                        "reconstructed w diverged: {tag}"
                    );
                }
            }
        }
    }
}

#[test]
fn theta_form_gram_is_axis_invariant() {
    for storage in [Storage::Dense, Storage::Csr] {
        let ds = dataset(Model::Svm, storage);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = CdSolver::new(SolverConfig { tol: 1e-7, ..Default::default() })
            .solve(&inst, 0.4, inst.cold_start());
        let serial = Dvi::new_theta(&inst);
        let want = serial.screen(&inst, 0.4, 0.7, &r.theta, &r.u).decisions;
        for threads in THREADS {
            for axis in AXES {
                let rule = Dvi::new_theta_axis(&inst, threads, axis);
                let got = rule.screen(&inst, 0.4, 0.7, &r.theta, &r.u).decisions;
                assert_eq!(
                    got,
                    want,
                    "{storage:?} threads={threads} axis={}",
                    axis.name()
                );
            }
        }
    }
}

#[test]
fn auto_axis_resolves_deterministically_from_shape() {
    // tall-and-narrow: auto must pick rows
    let tall = Instance::from_dataset(
        Model::Svm,
        &synth::sparse_classes(63, 200, 40, 0.2),
    );
    assert_eq!(tall.pick_axis(ShardAxis::Auto), ShardAxis::Rows);

    // short-and-wide (n ≥ 1024, 4n ≥ l): auto must pick cols, and keep
    // picking it on every call — the heuristic reads only cached shape
    let wide = Instance::from_dataset(
        Model::Svm,
        &synth::sparse_classes(64, 40, 1100, 0.02),
    );
    for _ in 0..3 {
        assert_eq!(wide.pick_axis(ShardAxis::Auto), ShardAxis::Cols);
    }
    // fixed axes always pass through, whatever the shape
    for inst in [&tall, &wide] {
        assert_eq!(inst.pick_axis(ShardAxis::Rows), ShardAxis::Rows);
        assert_eq!(inst.pick_axis(ShardAxis::Cols), ShardAxis::Cols);
    }

    // and the auto-resolved reconstruction is still bit-identical on the
    // wide instance, where it actually takes the cols path
    let r = CdSolver::new(SolverConfig { tol: 1e-6, ..Default::default() })
        .solve(&wide, 0.5, wide.cold_start());
    let want = wide.u_from_theta(&r.theta);
    for threads in THREADS {
        let got = wide.u_from_theta_axis(&r.theta, ShardAxis::Auto, threads);
        assert_eq!(bits(&got), bits(&want), "threads={threads}");
    }
    // the mirror was built lazily exactly once, and its bytes were
    // charged up front
    assert!(wide.cols_built());
    assert_eq!(wide.cols().approx_bytes(), wide.mirror_bytes());
}
