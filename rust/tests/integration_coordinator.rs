//! Coordinator end-to-end: pool scheduling, service framing, failure
//! isolation, metrics accounting, and instance-cache sharing.

use dvi_screen::config::{GridConfig, RunConfig, SolverConfig};
use dvi_screen::coordinator::{JobSpec, ScreeningService, WorkerPool};

fn quick(dataset: &str, model: &str, rule: &str) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: dataset.into(),
        scale: 0.03,
        rule: rule.into(),
        storage: "auto".into(),
        grid: GridConfig { c_min: 0.01, c_max: 10.0, points: 5 },
        solver: SolverConfig { tol: 1e-5, max_outer: 20_000, ..Default::default() },
        use_pjrt: false,
        validate: true,
    }
}

#[test]
fn pool_runs_the_paper_matrix() {
    // the paper's full rule×dataset matrix at miniature scale
    let mut specs = Vec::new();
    let mut id = 0;
    for ds in ["toy1", "toy2", "toy3"] {
        for rule in ["none", "dvi", "dvi-theta", "ssnsv", "essnsv"] {
            specs.push(JobSpec::path(id, quick(ds, "svm", rule)));
            id += 1;
        }
    }
    for ds in ["magic", "computer", "houses"] {
        let mut run = quick(ds, "lad", "dvi");
        // plain dual CD converges slowly on LAD at large C; keep the
        // miniature matrix inside a (generous) iteration cap
        run.grid = GridConfig { c_min: 0.01, c_max: 1.0, points: 5 };
        run.solver.max_outer = 300_000;
        specs.push(JobSpec::path(id, run));
        id += 1;
    }
    let pool = WorkerPool::new(4);
    let outcomes = pool.run_all(specs);
    assert_eq!(outcomes.len(), 18);
    for o in &outcomes {
        let r = o.result.as_ref().unwrap_or_else(|e| panic!("job {}: {e}", o.id));
        let s = r.as_path().unwrap();
        if let Some(v) = s.worst_violation {
            assert!(v < 1e-4, "job {} violation {v}", o.id);
        }
    }
    assert_eq!(pool.metrics.counter("jobs_done").get(), 18);
    assert_eq!(pool.metrics.counter("jobs_failed").get(), 0);
    // the matrix names 6 distinct (dataset, model) pairs at one scale and
    // storage each — five rules per toy share a single resident instance
    assert_eq!(pool.metrics.counter("instance_cache_misses").get(), 6);
    assert_eq!(pool.metrics.counter("instance_cache_hits").get(), 12);
    pool.shutdown();
}

#[test]
fn service_handles_mixed_traffic() {
    let mut svc = ScreeningService::new(2);
    let input = br#"
{"dataset": "toy1", "scale": 0.03, "points": 4, "tol": 1e-5}
{"dataset": "houses", "model": "lad", "scale": 0.01, "points": 4, "tol": 1e-5}
{"bad json
{"dataset": "toy2", "rule": "essnsv", "scale": 0.03, "points": 4, "tol": 1e-5}
{"dataset": "wine", "model": "lad", "points": 4}
"#;
    let mut out = Vec::new();
    svc.serve(&input[..], &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    // 1 parse error + 4 job responses
    assert_eq!(lines.len(), 5, "{text}");
    let oks = lines
        .iter()
        .filter(|l| {
            dvi_screen::config::parse_json(l).unwrap().get("ok").unwrap().as_bool()
                == Some(true)
        })
        .count();
    // wine+lad is a task mismatch → error; bad json → error
    assert_eq!(oks, 3, "{text}");
    svc.shutdown();
}

#[test]
fn service_reports_rejection_series_lengths() {
    let mut svc = ScreeningService::new(1);
    let id = svc.submit(ScreeningService::parse_request(
        r#"{"dataset": "toy1", "scale": 0.03, "points": 7, "tol": 1e-5}"#,
    )
    .unwrap());
    let outcome = svc.recv().unwrap();
    assert_eq!(outcome.id, id);
    let reply = outcome.result.unwrap();
    let s = reply.as_path().unwrap();
    assert_eq!(s.rejection_lo.len(), 7);
    assert_eq!(s.grid.len(), 7);
    assert!(s.grid.windows(2).all(|w| w[0] < w[1]));
    svc.shutdown();
}

#[test]
fn pool_survives_panicking_job() {
    // a degenerate grid (c_min == c_max) trips the GridConfig assert
    // inside the worker; the pool must surface it as a failed outcome and
    // keep serving
    let mut run = quick("toy1", "svm", "dvi");
    run.grid = GridConfig { c_min: 1.0, c_max: 1.0, points: 2 };
    let pool = WorkerPool::new(1);
    let outcomes = pool.run_all(vec![
        JobSpec::path(0, run),
        JobSpec::path(1, quick("toy1", "svm", "dvi")),
    ]);
    assert!(outcomes[0].result.is_err());
    assert!(outcomes[1].result.is_ok());
    pool.shutdown();
}
