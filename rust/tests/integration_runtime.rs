//! PJRT runtime integration: load the AOT screening artifact, execute it
//! on real problem data, and verify parity with the native f64 scan.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise —
//! CI runs artifacts first).

use dvi_screen::config::SolverConfig;
use dvi_screen::data::synth;
use dvi_screen::path::DviScanBackend;
use dvi_screen::problem::{Instance, Model};
use dvi_screen::runtime::{ArtifactManifest, PjrtScreener};
use dvi_screen::screening::{dvi::dvi_scan, Decision};
use dvi_screen::solver::CdSolver;

fn manifest() -> Option<ArtifactManifest> {
    let dir = dvi_screen::runtime::artifacts::default_dir();
    match ArtifactManifest::load(&dir) {
        Ok(m) => {
            if m.check_files().is_ok() {
                Some(m)
            } else {
                eprintln!("artifacts incomplete; run `make artifacts`");
                None
            }
        }
        Err(e) => {
            eprintln!("skipping PJRT tests: {e}");
            None
        }
    }
}

/// Native (f64, no guard) decisions — the exactness baseline.
fn native(inst: &Instance, mid: f64, rad: f64, u: &[f64]) -> Vec<Decision> {
    dvi_scan(inst, mid, rad, u)
}

#[test]
fn pjrt_scan_matches_native_on_solved_problem() {
    let Some(m) = manifest() else { return };
    let mut screener = PjrtScreener::new(m).expect("pjrt client");

    let ds = synth::toy_gaussian(1, 1000, 1.5, 0.75); // the paper's Toy1
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let solver = CdSolver::new(SolverConfig { tol: 1e-8, ..Default::default() });
    let r = solver.solve(&inst, 0.5, inst.cold_start());

    let (c_prev, c_next) = (0.5, 0.65);
    let mid = 0.5 * (c_next + c_prev);
    let rad = 0.5 * (c_next - c_prev);

    let got = screener.try_scan(&inst, mid, rad, &r.u).expect("pjrt scan");
    assert_eq!(screener.fallbacks, 0);
    let want = native(&inst, mid, rad, &r.u);
    assert_eq!(got.len(), want.len());

    let mut boundary_flips = 0usize;
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        if g == w {
            continue;
        }
        // the f32 kernel runs a conservative guard band: it may KEEP an
        // instance the f64 rule screens (never the reverse, and never a
        // lo<->hi flip)
        assert_eq!(*g, Decision::Keep, "unsafe PJRT decision at {i}: {g:?} vs {w:?}");
        boundary_flips += 1;
    }
    let frac = boundary_flips as f64 / want.len() as f64;
    assert!(frac < 0.02, "guard band too lossy: {boundary_flips} flips");
    // and the scan must actually screen a meaningful share on Toy1
    let screened = got.iter().filter(|&&d| d != Decision::Keep).count();
    assert!(screened > got.len() / 4, "screened only {screened}");
}

#[test]
fn pjrt_scan_is_safe_against_exact_solve() {
    let Some(m) = manifest() else { return };
    let mut screener = PjrtScreener::new(m).expect("pjrt client");
    let ds = synth::toy_gaussian(2, 800, 0.75, 0.75);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let cfg = SolverConfig { tol: 1e-9, ..Default::default() };
    let solver = CdSolver::new(cfg);
    let (c_prev, c_next) = (0.3, 0.42);
    let r0 = solver.solve(&inst, c_prev, inst.cold_start());
    let mid = 0.5 * (c_next + c_prev);
    let rad = 0.5 * (c_next - c_prev);
    let decisions = screener.try_scan(&inst, mid, rad, &r0.u).expect("scan");

    // exact membership at c_next
    let r1 = solver.solve(&inst, c_next, inst.cold_start());
    let w1 = inst.w_from_theta(c_next, &r1.theta);
    let truth = dvi_screen::problem::classify_kkt(&inst, &w1, 1e-7);
    for (i, d) in decisions.iter().enumerate() {
        match d {
            Decision::AtLo => {
                assert_eq!(truth.classes[i], dvi_screen::problem::KktClass::R, "i={i}")
            }
            Decision::AtHi => {
                assert_eq!(truth.classes[i], dvi_screen::problem::KktClass::L, "i={i}")
            }
            Decision::Keep => {}
        }
    }
}

#[test]
fn pjrt_lad_scan_parity() {
    let Some(m) = manifest() else { return };
    let mut screener = PjrtScreener::new(m).expect("pjrt client");
    let mut rng = dvi_screen::data::Rng::new(3);
    let ds = synth::random_regression(&mut rng, 600, 7);
    let inst = Instance::from_dataset(Model::Lad, &ds);
    let solver = CdSolver::new(SolverConfig { tol: 1e-8, ..Default::default() });
    let r = solver.solve(&inst, 0.2, inst.cold_start());
    let (mid, rad) = (0.24, 0.04);
    let got = screener.try_scan(&inst, mid, rad, &r.u).expect("scan");
    let want = native(&inst, mid, rad, &r.u);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        if g != w {
            assert_eq!(*g, Decision::Keep, "unsafe LAD decision at {i}");
        }
    }
}

#[test]
fn pjrt_bucket_reuse_and_eviction() {
    let Some(m) = manifest() else { return };
    let mut screener = PjrtScreener::new(m).expect("pjrt client");
    let ds = synth::toy_gaussian(3, 500, 0.5, 0.75);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let u = vec![0.5, -0.25];
    let a = screener.try_scan(&inst, 1.0, 0.1, &u).expect("scan 1");
    let b = screener.try_scan(&inst, 1.0, 0.1, &u).expect("scan 2 (cached)");
    assert_eq!(a, b);
    assert_eq!(screener.scans, 2);
    screener.evict(&inst);
    let c = screener.try_scan(&inst, 1.0, 0.1, &u).expect("scan 3 (re-upload)");
    assert_eq!(a, c);
}

#[test]
fn pjrt_backend_in_path_runner_matches_native() {
    let Some(m) = manifest() else { return };
    use dvi_screen::path::{PathConfig, PathRunner};
    use dvi_screen::screening::RuleKind;
    let ds = synth::toy_gaussian(4, 400, 1.0, 0.75);
    let cfg = PathConfig::log_grid(0.05, 5.0, 8)
        .with_solver(SolverConfig { tol: 1e-7, max_outer: 50_000, ..Default::default() })
        .with_validation(true);
    let screener = PjrtScreener::new(m).expect("client");
    let out_pjrt = PathRunner::new(Model::Svm, cfg.clone(), RuleKind::DviW)
        .with_backend(Box::new(screener))
        .run(&ds);
    let out_native = PathRunner::new(Model::Svm, cfg, RuleKind::DviW).run(&ds);
    // same optima (validation), nearly the same screening power
    assert!(out_pjrt.worst_violation().unwrap() < 1e-5);
    let d = (out_pjrt.mean_rejection() - out_native.mean_rejection()).abs();
    assert!(d < 0.02, "rejection differs by {d}");
}

/// Failure injection: a corrupted artifact must not poison results — the
/// compile error surfaces and the backend falls back to the native scan.
#[test]
fn corrupted_artifact_falls_back() {
    let Some(_) = manifest() else { return };
    // stage a broken artifact dir
    let mut dir = std::env::temp_dir();
    dir.push(format!("dvi_bad_artifacts_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule utterly { broken").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version":1,"dtype":"f32","guard_eps":1e-5,
            "buckets":[{"l":2048,"n":8,"file":"broken.hlo.txt"}]}"#,
    )
    .unwrap();
    let m = ArtifactManifest::load(&dir).unwrap();
    let mut screener = PjrtScreener::new(m).expect("client");
    let ds = synth::toy_gaussian(7, 100, 1.0, 0.75);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let u = vec![0.1, -0.2];
    assert!(screener.try_scan(&inst, 1.0, 0.1, &u).is_err());
    // trait path: silently correct via native fallback
    let d = screener.scan(&inst, 1.0, 0.1, &u);
    assert_eq!(d, native(&inst, 1.0, 0.1, &u));
    assert!(screener.fallbacks >= 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_bucket_is_reported_and_falls_back() {
    let Some(m) = manifest() else { return };
    let mut screener = PjrtScreener::new(m).expect("client");
    // n=80 exceeds every declared bucket width
    let ds = synth::gaussian_classes(9, 64, 80, 1.0, 1.0, 0.5, 1.0);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let u = vec![0.0; 80];
    let err = screener.try_scan(&inst, 1.0, 0.1, &u).unwrap_err();
    assert!(err.to_string().contains("bucket"), "{err}");
    // the trait impl must fall back to native rather than fail
    let d = screener.scan(&inst, 1.0, 0.1, &u);
    assert_eq!(d.len(), 64);
    assert_eq!(screener.fallbacks, 1);
}
