//! Model-artifact acceptance suite (ISSUE 4): the train → persist →
//! predict loop end to end.
//!
//! * predict ≡ direct in-memory scoring, bit for bit, across dense/CSR
//!   input batches and 1/2/4 scoring threads;
//! * save → load → predict round-trips byte-identically (artifact bytes
//!   AND scores);
//! * truncated / bit-flipped artifacts are rejected with typed errors;
//! * the support-only fast path agrees with full-w scoring within 0 ULP
//!   on SVM and weighted SVM (and LAD);
//! * the service's `"kind": "train"` / `"kind": "predict"` requests are
//!   input-order deterministic and their scores match the in-memory
//!   engine exactly.

use dvi_screen::config::{parse_json, Json, SolverConfig};
use dvi_screen::coordinator::ScreeningService;
use dvi_screen::data::synth;
use dvi_screen::linalg::{Rows, Storage};
use dvi_screen::model::{self, format, PredictOptions, TrainedModel};
use dvi_screen::problem::{Instance, Model};
use dvi_screen::solver::CdSolver;

fn train(model: Model, storage: Storage, c: f64) -> (TrainedModel, Instance) {
    let ds = match model {
        Model::Svm | Model::WeightedSvm => {
            synth::gaussian_classes(5, 140, 6, 1.2, 1.0, 0.4, 1.0).into_storage(storage)
        }
        Model::Lad => {
            let mut rng = dvi_screen::data::Rng::new(7);
            synth::random_regression(&mut rng, 120, 5).into_storage(storage)
        }
    };
    let inst = Instance::from_dataset(model, &ds);
    let r = CdSolver::new(SolverConfig { tol: 1e-8, ..Default::default() })
        .solve(&inst, c, inst.cold_start());
    let tm = TrainedModel::from_solution(&inst, "acceptance", 1.0, c, 1e-8, &r.theta);
    (tm, inst)
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn batch(storage: Storage, n: usize) -> Rows {
    synth::gaussian_classes(99, 73, n, 1.2, 1.0, 0.4, 1.0).x.into_storage(storage)
}

#[test]
fn predict_is_bit_identical_to_in_memory_scoring() {
    let (tm, _) = train(Model::Svm, Storage::Dense, 0.5);
    let dense = batch(Storage::Dense, tm.n());
    // ground truth: the plain per-row dot against the model's w
    let direct: Vec<f64> = (0..dense.rows()).map(|i| dense.row(i).dot(&tm.w)).collect();
    for storage in [Storage::Dense, Storage::Csr] {
        let rows = batch(storage, tm.n());
        for threads in [1usize, 2, 4] {
            let got =
                model::scores(&tm, &rows, &PredictOptions { threads, support_only: false })
                    .unwrap();
            assert_eq!(bits(&got), bits(&direct), "storage {storage:?} threads {threads}");
        }
    }
}

#[test]
fn save_load_predict_round_trip_is_byte_identical() {
    for storage in [Storage::Dense, Storage::Csr] {
        let (tm, _) = train(Model::Svm, storage, 0.5);
        let mut p = std::env::temp_dir();
        p.push(format!(
            "dvi_integration_model_{}_{}.pallas-model",
            std::process::id(),
            storage.name()
        ));
        format::save(&tm, &p).unwrap();
        let loaded = format::load(&p).unwrap();
        // artifact bytes round-trip exactly
        assert_eq!(format::encode(&loaded), format::encode(&tm));
        assert_eq!(loaded.id(), tm.id());
        assert_eq!(bits(&loaded.w), bits(&tm.w));
        assert_eq!(bits(&loaded.theta_active), bits(&tm.theta_active));
        assert_eq!(loaded.support, tm.support);
        // and predictions from the loaded model match exactly
        let rows = batch(Storage::Dense, tm.n());
        let a = model::scores(&tm, &rows, &PredictOptions::default()).unwrap();
        let b = model::scores(&loaded, &rows, &PredictOptions::default()).unwrap();
        assert_eq!(bits(&a), bits(&b), "storage {storage:?}");
        std::fs::remove_file(&p).ok();
    }
}

#[test]
fn corrupt_artifacts_are_rejected() {
    let (tm, _) = train(Model::Svm, Storage::Csr, 0.5);
    let enc = format::encode(&tm);
    // truncation at a spread of prefixes
    for cut in [0usize, 4, 11, 40, enc.len() / 3, enc.len() - 1] {
        assert!(format::decode(&enc[..cut]).is_err(), "prefix {cut} decoded");
    }
    // a single flipped bit anywhere fails the checksum (or magic)
    for pos in [9usize, 30, enc.len() / 2, enc.len() - 4] {
        let mut bad = enc.clone();
        bad[pos] ^= 0x40;
        assert!(format::decode(&bad).is_err(), "bit flip at {pos} decoded");
    }
    // loading a non-artifact file is a typed error, not a panic
    let mut p = std::env::temp_dir();
    p.push(format!("dvi_integration_model_junk_{}.pallas-model", std::process::id()));
    std::fs::write(&p, b"definitely not a model").unwrap();
    assert!(matches!(format::load(&p), Err(model::ModelIoError::Corrupt(_) | model::ModelIoError::BadMagic)));
    std::fs::remove_file(&p).ok();
}

#[test]
fn support_only_path_is_zero_ulp_from_full_w() {
    for (m, c) in [(Model::Svm, 0.5), (Model::WeightedSvm, 0.4), (Model::Lad, 0.3)] {
        for storage in [Storage::Dense, Storage::Csr] {
            let (tm, _) = train(m, storage, c);
            // the re-derived w must equal the stored w bit for bit
            assert_eq!(bits(&tm.reconstruct_w()), bits(&tm.w), "{m:?} {storage:?}");
            let rows = batch(Storage::Dense, tm.n());
            let full = model::scores(&tm, &rows, &PredictOptions::default()).unwrap();
            let sup = model::scores(
                &tm,
                &rows,
                &PredictOptions { threads: 3, support_only: true },
            )
            .unwrap();
            assert_eq!(bits(&full), bits(&sup), "{m:?} {storage:?}");
        }
    }
}

#[test]
fn support_set_is_a_genuine_reduction() {
    // the artifact's reason to exist: far fewer active rows than l on a
    // solved SVM, and the support (E) set is a subset of the active set
    let (tm, inst) = train(Model::Svm, Storage::Dense, 0.5);
    assert!(tm.active.len() < tm.l, "active {} of {}", tm.active.len(), tm.l);
    assert!(tm.support.len() < tm.l);
    assert_eq!(inst.len(), tm.l);
    assert!(tm.support.iter().all(|&i| (i as usize) < tm.l));
    assert!(tm.active.iter().all(|&i| (i as usize) < tm.l));
    // the artifact is smaller than the instance it came from
    assert!(tm.approx_bytes() < inst.approx_bytes());
}

fn serve_lines(svc: &mut ScreeningService, input: &str) -> Vec<String> {
    let mut out = Vec::new();
    svc.serve(input.as_bytes(), &mut out).unwrap();
    String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
}

/// The ISSUE acceptance path: train through the service (persisting the
/// artifact), predict through the service against that artifact, and
/// hold the scores to (a) input-order determinism, (b) bit-equality with
/// direct in-memory evaluation.
#[test]
fn service_train_predict_matches_in_memory_bit_for_bit() {
    let mut p = std::env::temp_dir();
    p.push(format!("dvi_integration_svc_{}.pallas-model", std::process::id()));
    let mut svc = ScreeningService::new(1); // in-order execution

    let train_line = format!(
        r#"{{"kind": "train", "dataset": "toy1", "scale": 0.05, "c": 0.5, "tol": 1e-6, "save": "{}", "timings": false}}"#,
        p.display()
    );
    let lines = serve_lines(&mut svc, &train_line);
    let tj = parse_json(&lines[0]).unwrap();
    assert_eq!(tj.get("ok").unwrap().as_bool(), Some(true), "{lines:?}");
    let model_id = tj.get("model_id").unwrap().as_str().unwrap().to_string();
    let model_name = tj.get("model").unwrap().as_str().unwrap().to_string();
    assert_eq!(Model::parse(&model_name), Some(Model::Svm), "model name round-trips");
    assert!(model_id.starts_with("svm-"));

    // the same requests as a batch: one predict by id, one by file, one
    // inline-rows predict — all deterministic, in input order
    let batch_line = format!(
        concat!(
            r#"{{"batch": ["#,
            r#"{{"kind": "predict", "model_id": "{id}", "dataset": "toy1", "scale": 0.05, "threads": 2, "timings": false}}, "#,
            r#"{{"kind": "predict", "model_file": "{file}", "dataset": "toy1", "scale": 0.05, "support_only": true, "timings": false}}, "#,
            r#"{{"kind": "predict", "model_id": "{id}", "rows": [[0.25, -1.5], [2.0, 2.0]], "timings": false}}"#,
            r#"]}}"#
        ),
        id = model_id,
        file = p.display()
    );
    let out1 = serve_lines(&mut svc, &batch_line);
    let out2 = serve_lines(&mut svc, &batch_line);
    assert_eq!(out1.len(), 1);
    let strip_ids = |line: &str| {
        let j = parse_json(line).unwrap();
        j.get("batch")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|e| match e {
                Json::Object(o) => {
                    let mut o = o.clone();
                    o.remove("id");
                    Json::Object(o).to_string()
                }
                other => other.to_string(),
            })
            .collect::<Vec<String>>()
    };
    assert_eq!(strip_ids(&out1[0]), strip_ids(&out2[0]), "double run byte-identical");

    // scores from entry 0 (full-w by id) and entry 1 (support-only from
    // the artifact file) must be identical
    let j = parse_json(&out1[0]).unwrap();
    let entries = j.get("batch").unwrap().as_array().unwrap();
    for e in entries {
        assert_eq!(e.get("ok").unwrap().as_bool(), Some(true), "{e:?}");
    }
    let s0 = entries[0].get("scores").unwrap();
    let s1 = entries[1].get("scores").unwrap();
    assert_eq!(s0.to_string(), s1.to_string(), "full-w ≡ support-only over the wire");

    // bit-for-bit against direct in-memory evaluation of the artifact
    let tm = format::load(&p).unwrap();
    let ds = dvi_screen::data::registry::resolve("toy1", 0.05, dvi_screen::data::Task::Classification)
        .unwrap();
    let direct: Vec<f64> = (0..ds.len()).map(|i| ds.x.row(i).dot(&tm.w)).collect();
    let wire: Vec<f64> = s0
        .as_array()
        .unwrap()
        .iter()
        .map(|v| v.as_float().unwrap())
        .collect();
    assert_eq!(bits(&wire), bits(&direct), "service scores ≡ in-memory scores");

    // inline-rows entry agrees with direct evaluation too
    let s2: Vec<f64> = entries[2]
        .get("scores")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|v| v.as_float().unwrap())
        .collect();
    let want0 = Rows::Dense(dvi_screen::linalg::RowMatrix::from_flat(
        2,
        2,
        vec![0.25, -1.5, 2.0, 2.0],
    ));
    let want: Vec<f64> = (0..2).map(|i| want0.row(i).dot(&tm.w)).collect();
    assert_eq!(bits(&s2), bits(&want));

    std::fs::remove_file(&p).ok();
    svc.shutdown();
}

/// `"kind": "cache"` lists both caches and evicts entries by key.
#[test]
fn service_cache_introspection_covers_both_caches() {
    let mut svc = ScreeningService::new(1);
    let lines = serve_lines(
        &mut svc,
        concat!(
            r#"{"kind": "train", "dataset": "toy2", "scale": 0.03, "c": 0.4, "tol": 1e-5, "timings": false}"#,
            "\n",
            r#"{"kind": "cache", "timings": false}"#,
            "\n"
        ),
    );
    assert_eq!(lines.len(), 2);
    let model_id = parse_json(&lines[0])
        .unwrap()
        .get("model_id")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    let cj = parse_json(&lines[1]).unwrap();
    let instances = cj.get("instances").unwrap().as_array().unwrap().to_vec();
    let models = cj.get("models").unwrap().as_array().unwrap().to_vec();
    assert_eq!(instances.len(), 1);
    assert_eq!(instances[0].get("dataset").unwrap().as_str(), Some("toy2"));
    assert!(instances[0].get("bytes").unwrap().as_int().unwrap() > 0);
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].get("id").unwrap().as_str(), Some(model_id.as_str()));

    // evict the instance by its full key, then the model by id
    let evicts = format!(
        concat!(
            r#"{{"kind": "cache", "op": "evict", "target": "instance", "dataset": "toy2", "model": "svm", "storage": "auto", "scale": 0.03, "timings": false}}"#,
            "\n",
            r#"{{"kind": "cache", "op": "evict", "target": "model", "model_id": "{}", "timings": false}}"#,
            "\n"
        ),
        model_id
    );
    let lines = serve_lines(&mut svc, &evicts);
    let a = parse_json(&lines[0]).unwrap();
    assert_eq!(a.get("evicted").unwrap().as_bool(), Some(true), "{lines:?}");
    assert_eq!(a.get("instances").unwrap().as_array().unwrap().len(), 0);
    let b = parse_json(&lines[1]).unwrap();
    assert_eq!(b.get("evicted").unwrap().as_bool(), Some(true));
    assert_eq!(b.get("models").unwrap().as_array().unwrap().len(), 0);

    // evicting again reports false (nothing there), never an error
    let again = serve_lines(
        &mut svc,
        &format!(
            r#"{{"kind": "cache", "op": "evict", "target": "model", "model_id": "{model_id}", "timings": false}}"#
        ),
    );
    let j = parse_json(&again[0]).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("evicted").unwrap().as_bool(), Some(false));
    svc.shutdown();
}
