//! Dense ↔ CSR equivalence suite — the acceptance gate for the
//! storage-polymorphic data layer.
//!
//! The CSR kernels are constructed to be *bit-identical* to their dense
//! counterparts (same accumulator striping, same addition order over the
//! stored entries — see `linalg::csr`), so this suite asserts the
//! strongest possible property: randomized sparse datasets pushed through
//! the full path runner (screen → reduce → solve over the whole C-grid)
//! produce identical screened sets, identical rejection rates, and
//! identical solver iterates on both storages, for the serial scan and
//! the sharded ParScan at 1, 2, and 4 threads.

use dvi_screen::config::SolverConfig;
use dvi_screen::data::io::{read_libsvm_storage, write_libsvm};
use dvi_screen::data::{synth, Dataset, Task};
use dvi_screen::linalg::Storage;
use dvi_screen::path::{PathConfig, PathOutput, PathRunner};
use dvi_screen::problem::{Instance, Model};
use dvi_screen::screening::dvi::{dvi_scan, dvi_scan_par};
use dvi_screen::screening::RuleKind;

fn path_cfg(points: usize, threads: usize) -> PathConfig {
    PathConfig::log_grid(1e-2, 10.0, points)
        .with_solver(SolverConfig {
            tol: 1e-7,
            max_outer: 50_000,
            threads,
            // this suite asserts bitwise θ equality ACROSS storages and
            // scan-thread counts, so the CD solver must stay serial: the
            // sharded sweep partitions the active set by stored-entry
            // count, which legitimately differs between dense and CSR
            // (its decision-level equivalence is integration_cd_par.rs's
            // contract)
            solver_threads: Some(1),
            ..Default::default()
        })
        .with_validation(true)
}

fn run(model: Model, ds: &Dataset, rule: RuleKind, threads: usize) -> PathOutput {
    PathRunner::new(model, path_cfg(10, threads), rule).run(ds)
}

/// Assert two path outputs are equivalent: identical screened sets per
/// step (the lo/hi splits and the surviving free count), identical
/// rejection rates, and final θ within tolerance (we assert exact
/// equality — the kernels are bit-compatible by construction).
fn assert_paths_equivalent(a: &PathOutput, b: &PathOutput, tag: &str) {
    assert_eq!(a.steps.len(), b.steps.len(), "{tag}: step counts");
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert_eq!(sa.c, sb.c, "{tag}: grid mismatch");
        assert_eq!(
            (sa.n_lo, sa.n_hi, sa.free),
            (sb.n_lo, sb.n_hi, sb.free),
            "{tag}: screened sets differ at C={}",
            sa.c
        );
    }
    assert_eq!(
        a.mean_rejection(),
        b.mean_rejection(),
        "{tag}: rejection rates differ"
    );
    assert_eq!(a.final_theta, b.final_theta, "{tag}: final theta differs");
    // both runs validated full-problem KKT along the way
    assert!(a.worst_violation().unwrap() < 1e-5, "{tag}: dense-side KKT");
    assert!(b.worst_violation().unwrap() < 1e-5, "{tag}: csr-side KKT");
}

#[test]
fn svm_path_equivalent_across_storage_and_threads() {
    let sparse = synth::sparse_classes(101, 180, 60, 0.08);
    assert!(sparse.x.is_sparse());
    let dense = sparse.clone().into_storage(Storage::Dense);
    let base = run(Model::Svm, &dense, RuleKind::DviW, 1);
    assert!(base.mean_rejection() > 0.0, "toy too hard: nothing screened");
    for threads in [1usize, 2, 4] {
        let d = run(Model::Svm, &dense, RuleKind::DviW, threads);
        let s = run(Model::Svm, &sparse, RuleKind::DviW, threads);
        assert_paths_equivalent(&base, &d, &format!("svm dense t={threads}"));
        assert_paths_equivalent(&base, &s, &format!("svm csr t={threads}"));
    }
}

#[test]
fn weighted_svm_path_equivalent() {
    let sparse = synth::sparse_classes(202, 150, 50, 0.1);
    let dense = sparse.clone().into_storage(Storage::Dense);
    for threads in [1usize, 2, 4] {
        let d = run(Model::WeightedSvm, &dense, RuleKind::DviW, threads);
        let s = run(Model::WeightedSvm, &sparse, RuleKind::DviW, threads);
        assert_paths_equivalent(&d, &s, &format!("wsvm t={threads}"));
    }
}

#[test]
fn lad_path_equivalent() {
    let sparse = synth::sparse_regression(303, 160, 40, 0.12, 0.2);
    let dense = sparse.clone().into_storage(Storage::Dense);
    for threads in [1usize, 2, 4] {
        let d = run(Model::Lad, &dense, RuleKind::DviW, threads);
        let s = run(Model::Lad, &sparse, RuleKind::DviW, threads);
        assert_paths_equivalent(&d, &s, &format!("lad t={threads}"));
    }
}

#[test]
fn theta_form_and_baseline_rules_equivalent() {
    // Gram-based DVI (θ-form) and the SSNSV/ESSNSV baselines also run on
    // the polymorphic interface
    let sparse = synth::sparse_classes(404, 120, 40, 0.1);
    let dense = sparse.clone().into_storage(Storage::Dense);
    for rule in [RuleKind::DviTheta, RuleKind::Ssnsv, RuleKind::Essnsv] {
        let d = run(Model::Svm, &dense, rule, 2);
        let s = run(Model::Svm, &sparse, rule, 2);
        assert_paths_equivalent(&d, &s, rule.name());
    }
}

#[test]
fn raw_scan_decisions_identical() {
    // the scan itself, outside the runner: serial and sharded, both
    // storages, decisions byte-identical
    let sparse = synth::sparse_classes(505, 211, 64, 0.07); // prime l
    let dense = sparse.clone().into_storage(Storage::Dense);
    let si = Instance::from_dataset(Model::Svm, &sparse);
    let di = Instance::from_dataset(Model::Svm, &dense);
    assert_eq!(si.z_norms_sq, di.z_norms_sq);
    let u: Vec<f64> = (0..si.dim()).map(|j| (j as f64 * 0.31).sin()).collect();
    let want = dvi_scan(&di, 1.1, 0.1, &u);
    assert_eq!(dvi_scan(&si, 1.1, 0.1, &u), want);
    for threads in [1usize, 2, 4] {
        assert_eq!(dvi_scan_par(&di, 1.1, 0.1, &u, threads), want, "dense t={threads}");
        assert_eq!(dvi_scan_par(&si, 1.1, 0.1, &u, threads), want, "csr t={threads}");
    }
}

#[test]
fn libsvm_roundtrip_preserves_equivalence() {
    // write a sparse set, load it back as CSR and as dense, and run the
    // full path on both loads: the file is the single source of truth and
    // the storages must agree
    let ds = synth::sparse_classes(606, 100, 45, 0.1);
    let mut p = std::env::temp_dir();
    p.push(format!("dvi_storage_equiv_{}.svm", std::process::id()));
    write_libsvm(&ds, &p).unwrap();
    let as_csr = read_libsvm_storage(&p, Task::Classification, 0, Storage::Csr).unwrap();
    let as_dense = read_libsvm_storage(&p, Task::Classification, 0, Storage::Dense).unwrap();
    let as_auto = read_libsvm_storage(&p, Task::Classification, 0, Storage::Auto).unwrap();
    std::fs::remove_file(&p).ok();
    assert!(as_csr.x.is_sparse());
    assert!(!as_dense.x.is_sparse());
    assert!(as_auto.x.is_sparse(), "10% density must auto-select CSR");
    let a = run(Model::Svm, &as_dense, RuleKind::DviW, 2);
    let b = run(Model::Svm, &as_csr, RuleKind::DviW, 2);
    assert_paths_equivalent(&a, &b, "libsvm roundtrip");
}
