//! The tracing determinism and well-formedness contract: arming
//! `--trace-out` must not perturb a `"timings": false` session's
//! response bytes, and the exported Chrome trace must be valid JSON
//! whose spans pair up, sort by timestamp, cover the whole request
//! lifecycle (connection -> request -> queue_wait -> job -> screen /
//! sweep), and whose in-trace parents begin before their children.
//!
//! Everything lives in ONE test function: tracing is armed
//! process-globally, so the untraced reference bytes must be captured
//! before `set_trace_out` and this binary must not race a second test
//! against the shared ring.

use dvi_screen::config::{parse_json, Json};
use dvi_screen::coordinator::ScreeningService;
use std::collections::{HashMap, HashSet};

/// A deterministic mixed session: two path runs (dvi / composed rule),
/// one screen job, one job error. `"timings": false` throughout, so the
/// bytes are scheduling-independent — the exact property tracing must
/// preserve.
const SESSION: &str = r#"{"dataset": "toy1", "scale": 0.05, "points": 4, "rule": "dvi", "tol": 1e-6, "timings": false}
{"dataset": "toy1", "scale": 0.05, "points": 3, "rule": "dvi+essnsv", "tol": 1e-6, "timings": false}
{"kind": "screen", "dataset": "toy1", "scale": 0.05, "pairs": [[0.5, 0.9]], "tol": 1e-6, "timings": false}
{"dataset": "no-such-set", "points": 4, "timings": false}
"#;

/// Play the session through a fresh service's stdin adapter (the same
/// per-connection handler the network listeners run) and keep the raw
/// output bytes.
fn run_session_bytes(input: &str) -> Vec<u8> {
    let mut svc = ScreeningService::new(2);
    let mut out = Vec::new();
    svc.serve(input.as_bytes(), &mut out).unwrap();
    svc.shutdown();
    out
}

#[test]
fn traced_session_bytes_identical_and_trace_well_formed() {
    // reference bytes BEFORE tracing exists anywhere in the process
    let reference = run_session_bytes(SESSION);
    assert!(!reference.is_empty());

    let target =
        std::env::temp_dir().join(format!("dvi_obs_trace_{}.json", std::process::id()));
    dvi_screen::obs::set_trace_out(target.clone());
    let traced = run_session_bytes(SESSION);
    assert_eq!(
        traced, reference,
        "arming --trace-out changed the response byte stream"
    );

    let written = dvi_screen::obs::flush().unwrap().expect("a trace target was set");
    assert_eq!(written, target);
    let text = std::fs::read_to_string(&written).unwrap();
    let doc = parse_json(&text).expect("the trace file is valid JSON");
    let events = doc.get("traceEvents").unwrap().as_array().unwrap();
    assert!(!events.is_empty(), "a traced session must export spans");

    // lifecycle coverage: one span name per instrumented layer
    let names: HashSet<&str> =
        events.iter().filter_map(|e| e.get("name").and_then(Json::as_str)).collect();
    for want in ["connection", "request", "queue_wait", "job", "path_step", "solve", "sweep", "screen_rows"]
    {
        assert!(names.contains(want), "span `{want}` missing from trace: {names:?}");
    }

    // timestamps are sorted ascending across the whole file
    let ts: Vec<f64> =
        events.iter().map(|e| e.get("ts").unwrap().as_float().unwrap()).collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "trace ts not monotone");

    // every end has exactly one begin, and the begin comes first; the
    // exporter keys both halves by the hex span id in args
    let mut begins: HashMap<&str, usize> = HashMap::new();
    let mut ends: HashMap<&str, usize> = HashMap::new();
    for (i, e) in events.iter().enumerate() {
        let args = e.get("args").unwrap();
        let id = args.get("id").unwrap().as_str().unwrap();
        match e.get("ph").unwrap().as_str().unwrap() {
            "B" | "b" => {
                assert!(begins.insert(id, i).is_none(), "duplicate begin for {id}");
            }
            "E" | "e" => {
                assert!(begins.contains_key(id), "end before begin for {id}");
                assert!(ends.insert(id, i).is_none(), "duplicate end for {id}");
            }
            other => panic!("unexpected phase {other}"),
        }
    }
    assert_eq!(begins.len(), ends.len(), "unpaired spans escaped the exporter");

    // a parent that is itself in the trace must have begun no later
    // than its child (absent parents — e.g. CLI one-shot request ids —
    // are legal: the exporter only promises pairs)
    for e in events.iter() {
        let args = e.get("args").unwrap();
        let Some(parent) = args.get("parent").and_then(Json::as_str) else { continue };
        if parent == "0x0" {
            continue;
        }
        let child = args.get("id").unwrap().as_str().unwrap();
        if let Some(&pi) = begins.get(parent) {
            let ci = begins[child];
            assert!(
                ts[pi] <= ts[ci],
                "parent {parent} begins after child {child}: {} > {}",
                ts[pi],
                ts[ci]
            );
        }
    }

    std::fs::remove_file(&written).ok();
}
