//! Cross-backend equivalence: the sharded `ParScan` engine must produce
//! decision vectors **byte-identical** to `NativeScan` — per thread count,
//! per model (SVM, weighted SVM, LAD), and for shard-hostile sizes
//! (l prime, l not divisible by the shard count, l < threads).

use dvi_screen::config::SolverConfig;
use dvi_screen::data::{synth, Dataset, Rng};
use dvi_screen::path::{DviScanBackend, NativeScan, ParScan, PathConfig, PathRunner};
use dvi_screen::problem::{Instance, Model};
use dvi_screen::screening::RuleKind;
use dvi_screen::solver::CdSolver;

const THREAD_COUNTS: [usize; 5] = [1, 2, 4, 7, 0];

fn assert_backends_agree(inst: &Instance, c0: f64, c1: f64, what: &str) {
    let solver = CdSolver::new(SolverConfig { tol: 1e-8, max_outer: 100_000, ..Default::default() });
    let r = solver.solve(inst, c0, inst.cold_start());
    let mid = 0.5 * (c1 + c0);
    let rad = 0.5 * (c1 - c0);
    let want = NativeScan.scan(inst, mid, rad, &r.u);
    for threads in THREAD_COUNTS {
        let got = ParScan::new(threads).scan(inst, mid, rad, &r.u);
        assert_eq!(got, want, "{what}: ParScan({threads}) diverged from NativeScan (l={})", inst.len());
    }
}

#[test]
fn svm_parscan_matches_native() {
    // l = 206 (= 2·103, prime factor 103) never splits evenly over 4 or 7
    let ds = synth::toy_gaussian(81, 103, 1.0, 0.75);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    assert_backends_agree(&inst, 0.3, 0.55, "svm-toy");
}

#[test]
fn weighted_svm_parscan_matches_native() {
    let ds = synth::gaussian_classes(82, 121, 4, 1.2, 1.0, 0.25, 1.5);
    let inst = Instance::from_dataset(Model::WeightedSvm, &ds);
    assert_backends_agree(&inst, 0.2, 0.4, "weighted-svm");
}

#[test]
fn lad_parscan_matches_native() {
    let mut rng = Rng::new(83);
    let ds = synth::random_regression(&mut rng, 101, 6);
    let inst = Instance::from_dataset(Model::Lad, &ds);
    assert_backends_agree(&inst, 0.15, 0.3, "lad");
}

/// Fewer rows than workers: every shard is ≤ 1 row, empty shards must not
/// corrupt the merged order.
#[test]
fn tiny_instance_fewer_rows_than_threads() {
    let ds = synth::gaussian_classes(84, 5, 3, 1.0, 1.0, 0.5, 1.0);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    assert_backends_agree(&inst, 0.5, 0.9, "tiny");
}

/// Degenerate rows (all-zero features) must survive sharding unchanged.
#[test]
fn degenerate_rows_parscan_matches_native() {
    use dvi_screen::linalg::RowMatrix;
    let mut x = RowMatrix::zeros(9, 2);
    x.set(0, 0, 1.0);
    x.set(1, 0, 1.0);
    x.set(2, 1, -2.0);
    let ds = Dataset::new(
        "degenerate",
        dvi_screen::data::Task::Classification,
        x,
        vec![1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0],
    );
    let inst = Instance::from_dataset(Model::Svm, &ds);
    assert_backends_agree(&inst, 0.4, 0.8, "degenerate");
}

/// End-to-end: a full path run with `solver.threads` set routes the scan
/// through ParScan and must reproduce the serial path bit-for-bit —
/// identical screening counts at every step and an identical final θ.
/// (The CD solver is pinned serial: `threads` now also drives the
/// sharded sweep by default, whose iterates are deliberately not bitwise
/// across thread counts — integration_cd_par.rs covers that contract.)
#[test]
fn sharded_path_run_is_bit_identical_to_serial() {
    let ds = synth::toy_gaussian(85, 150, 1.0, 0.75);
    let cfg = |threads: usize| {
        let mut solver = SolverConfig {
            tol: 1e-7,
            max_outer: 50_000,
            solver_threads: Some(1),
            ..Default::default()
        };
        solver.threads = threads;
        PathConfig::log_grid(1e-2, 10.0, 10).with_solver(solver).with_validation(true)
    };
    let serial = PathRunner::new(Model::Svm, cfg(1), RuleKind::DviW).run(&ds);
    for threads in [2usize, 4, 7] {
        let sharded = PathRunner::new(Model::Svm, cfg(threads), RuleKind::DviW).run(&ds);
        assert_eq!(serial.steps.len(), sharded.steps.len());
        for (a, b) in serial.steps.iter().zip(&sharded.steps) {
            assert_eq!((a.n_lo, a.n_hi, a.free), (b.n_lo, b.n_hi, b.free), "at C={}", a.c);
            assert_eq!(a.dual_obj, b.dual_obj, "objective drifted at C={}", a.c);
        }
        assert_eq!(serial.final_theta, sharded.final_theta, "threads={threads}");
        assert!(sharded.worst_violation().unwrap() < 1e-5);
    }
}

/// The θ-form rule with a sharded Gram build screens identically along a
/// path (same counts per step as the serial θ-form and the w-form).
#[test]
fn sharded_theta_path_matches_serial_theta() {
    let ds = synth::toy_gaussian(86, 80, 1.0, 0.75);
    let cfg = |threads: usize| {
        let mut solver = SolverConfig {
            tol: 1e-7,
            max_outer: 50_000,
            solver_threads: Some(1),
            ..Default::default()
        };
        solver.threads = threads;
        PathConfig::log_grid(1e-2, 10.0, 6).with_solver(solver)
    };
    let serial = PathRunner::new(Model::Svm, cfg(1), RuleKind::DviTheta).run(&ds);
    let sharded = PathRunner::new(Model::Svm, cfg(3), RuleKind::DviTheta).run(&ds);
    for (a, b) in serial.steps.iter().zip(&sharded.steps) {
        assert_eq!((a.n_lo, a.n_hi), (b.n_lo, b.n_hi), "at C={}", a.c);
    }
}
