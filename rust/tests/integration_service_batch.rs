//! Batch protocol semantics: a batch of B requests must behave exactly
//! like B independent single-request lines — same response objects (byte
//! for byte with `"timings": false`), same error isolation, same θ — and
//! must construct each named instance exactly once via the pool's
//! resident cache, cold or warm.

use dvi_screen::config::parse_json;
use dvi_screen::config::Json;
use dvi_screen::coordinator::ScreeningService;

/// The mixed session used throughout: three path runs naming the SAME
/// dataset (different rules), one screen job on that dataset, one job
/// error (unknown dataset), and one parse error (bad points). All
/// deterministic (`timings: false`).
const ENTRIES: [&str; 6] = [
    r#"{"dataset": "toy1", "scale": 0.05, "points": 5, "rule": "dvi", "tol": 1e-6, "timings": false}"#,
    r#"{"dataset": "toy1", "scale": 0.05, "points": 5, "rule": "essnsv", "tol": 1e-6, "timings": false}"#,
    r#"{"dataset": "toy1", "scale": 0.05, "points": 5, "rule": "none", "tol": 1e-6, "timings": false}"#,
    r#"{"kind": "screen", "dataset": "toy1", "scale": 0.05, "pairs": [[0.5, 0.8], [0.8, 1.6]], "tol": 1e-6, "timings": false}"#,
    r#"{"dataset": "no-such-set", "points": 4, "timings": false}"#,
    r#"{"dataset": "toy1", "points": 0}"#,
];

fn batch_line() -> String {
    format!("{{\"batch\": [{}]}}", ENTRIES.join(", "))
}

fn serve_lines(svc: &mut ScreeningService, input: &str) -> Vec<String> {
    let mut out = Vec::new();
    svc.serve(input.as_bytes(), &mut out).unwrap();
    String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
}

#[test]
fn batch_is_byte_identical_to_singles() {
    // session A: the entries as independent lines
    let mut single_svc = ScreeningService::new(3);
    let singles = serve_lines(&mut single_svc, &ENTRIES.join("\n"));
    assert_eq!(singles.len(), ENTRIES.len());
    single_svc.shutdown();

    // session B: the same entries as one batch line
    let mut batch_svc = ScreeningService::new(3);
    let lines = serve_lines(&mut batch_svc, &batch_line());
    assert_eq!(lines.len(), 1, "a batch answers with ONE response line");
    let j = parse_json(&lines[0]).unwrap();
    let entries = j.get("batch").unwrap().as_array().unwrap();
    assert_eq!(entries.len(), ENTRIES.len());

    // every batch entry serializes to exactly the single-request line
    // (ids align because both sessions number jobs from 0 in input order)
    for (i, (entry, single)) in entries.iter().zip(&singles).enumerate() {
        assert_eq!(&entry.to_string(), single, "entry {i} diverged");
    }

    // per-entry error isolation: 4 ok, 2 errors, in place
    let oks: Vec<bool> = entries
        .iter()
        .map(|e| e.get("ok").unwrap().as_bool().unwrap())
        .collect();
    assert_eq!(oks, vec![true, true, true, true, false, false]);

    // acceptance: B requests naming one dataset constructed the instance
    // exactly once (1 miss), everyone else hit
    let m = batch_svc.metrics();
    assert_eq!(m.counter("instance_cache_misses").get(), 1);
    assert_eq!(m.counter("instance_cache_hits").get(), 3);
    assert_eq!(batch_svc.cache().len(), 1);
    batch_svc.shutdown();
}

#[test]
fn batch_cold_then_warm_is_identical() {
    // the same batch twice through ONE service: the first run builds the
    // instance (cold), the second hits the cache (warm) — responses other
    // than ids must be identical, proving residency changes nothing
    let mut svc = ScreeningService::new(2);
    let input = format!("{}\n{}\n", batch_line(), batch_line());
    let lines = serve_lines(&mut svc, &input);
    assert_eq!(lines.len(), 2);

    let strip_ids = |line: &str| -> Vec<String> {
        let j = parse_json(line).unwrap();
        j.get("batch")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|e| match e {
                Json::Object(o) => {
                    let mut o = o.clone();
                    o.remove("id");
                    Json::Object(o).to_string()
                }
                other => other.to_string(),
            })
            .collect()
    };
    assert_eq!(strip_ids(&lines[0]), strip_ids(&lines[1]));

    // both batches' submitted jobs share one construction; the service
    // interleaves the two batches' jobs on the pool, so cold/warm split
    // is scheduling-dependent — but the total is exact
    let m = svc.metrics();
    assert_eq!(m.counter("instance_cache_misses").get(), 1);
    assert_eq!(m.counter("instance_cache_hits").get(), 7);
    svc.shutdown();
}

#[test]
fn screen_theta_round_trips_through_the_wire() {
    // ask a screen job for its anchor θ, feed it back as the supplied
    // warm start: the second job must pay zero solves and reproduce the
    // first job's decisions exactly
    let mut svc = ScreeningService::new(1);
    let first = serve_lines(
        &mut svc,
        r#"{"kind": "screen", "dataset": "toy2", "scale": 0.05, "pairs": [[0.5, 0.9]], "tol": 1e-6, "return_theta": true, "timings": false}"#,
    );
    let j = parse_json(&first[0]).unwrap();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("anchor_solves").unwrap().as_int(), Some(1));
    assert_eq!(j.get("theta_c").unwrap().as_float(), Some(0.5));
    let theta = j.get("theta").unwrap();
    let pairs_out = j.get("pairs").unwrap().to_string();

    let req2 = format!(
        r#"{{"kind": "screen", "dataset": "toy2", "scale": 0.05, "pairs": [[0.5, 0.9]], "tol": 1e-6, "theta": {}, "timings": false}}"#,
        theta.to_string()
    );
    let second = serve_lines(&mut svc, &req2);
    let j2 = parse_json(&second[0]).unwrap();
    assert_eq!(j2.get("ok").unwrap().as_bool(), Some(true), "{second:?}");
    assert_eq!(j2.get("anchor_solves").unwrap().as_int(), Some(0), "supplied θ skips the solve");
    assert_eq!(j2.get("pairs").unwrap().to_string(), pairs_out);
    svc.shutdown();
}

#[test]
fn screen_batch_amortizes_one_instance_over_many_scans() {
    // a batch of screen jobs with distinct pairs against one dataset:
    // exactly one construction, every job otherwise scan-only
    let entries: Vec<String> = (0..5)
        .map(|k| {
            let c0 = 0.2 + 0.1 * k as f64;
            format!(
                r#"{{"kind": "screen", "dataset": "toy1", "scale": 0.05, "pairs": [[{c0}, {}]], "tol": 1e-5, "timings": false}}"#,
                c0 + 0.3
            )
        })
        .collect();
    let mut svc = ScreeningService::new(4);
    let lines = serve_lines(&mut svc, &format!("{{\"batch\": [{}]}}", entries.join(", ")));
    let j = parse_json(&lines[0]).unwrap();
    let arr = j.get("batch").unwrap().as_array().unwrap();
    assert_eq!(arr.len(), 5);
    for e in arr {
        assert_eq!(e.get("ok").unwrap().as_bool(), Some(true), "{e:?}");
    }
    let m = svc.metrics();
    assert_eq!(m.counter("instance_cache_misses").get(), 1);
    assert_eq!(m.counter("instance_cache_hits").get(), 4);
    svc.shutdown();
}

#[test]
fn malformed_batch_lines_answer_as_errors() {
    let mut svc = ScreeningService::new(1);
    let input = r#"
{"batch": "not an array"}
{"batch": [], "extra": 1}
{"batch": []}
{"batch": [{"batch": []}]}
"#;
    let lines = serve_lines(&mut svc, input);
    assert_eq!(lines.len(), 4);
    let j0 = parse_json(&lines[0]).unwrap();
    assert_eq!(j0.get("ok").unwrap().as_bool(), Some(false));
    let j1 = parse_json(&lines[1]).unwrap();
    assert_eq!(j1.get("ok").unwrap().as_bool(), Some(false));
    // an empty batch is a legal no-op
    let j2 = parse_json(&lines[2]).unwrap();
    assert_eq!(j2.get("batch").unwrap().as_array().unwrap().len(), 0);
    // nesting is rejected per entry, inside the batch envelope
    let j3 = parse_json(&lines[3]).unwrap();
    let inner = &j3.get("batch").unwrap().as_array().unwrap()[0];
    assert_eq!(inner.get("ok").unwrap().as_bool(), Some(false));
    svc.shutdown();
}
