//! Parallel CD solver acceptance suite — the contract of the
//! block-synchronous sharded sweep (`solver::cd_par`):
//!
//! 1. the parallel solve returns a KKT-valid point at the same `tol`;
//! 2. downstream DVI screening decisions AND the KKT support/E-set
//!    classification are identical to the serial solver's, for
//!    svm/wsvm/lad × dense/CSR × {1, 2, 4, 7} threads;
//! 3. `solver_threads = 1` is byte-identical to the serial solver;
//! 4. a fixed `(seed, threads)` pair is run-to-run deterministic;
//! 5. the whole warm-started path (screen → reduce → solve) screens the
//!    same sets with the parallel solver as with the serial one.
//!
//! Unlike the sharded *scan* (integration_parscan) and the storage layer
//! (integration_storage), the parallel sweep does NOT promise bitwise
//! equality across thread counts — shards see block-start u, so iterates
//! differ in the low bits — which is why those suites pin
//! `solver_threads = 1` and this one compares at the decision level.

use dvi_screen::config::SolverConfig;
use dvi_screen::data::{synth, Dataset};
use dvi_screen::linalg::Storage;
use dvi_screen::path::{PathConfig, PathRunner};
use dvi_screen::problem::{classify_kkt, Instance, Model};
use dvi_screen::screening::dvi::{ball_params, dvi_scan};
use dvi_screen::screening::RuleKind;
use dvi_screen::solver::CdSolver;

const THREADS: [usize; 4] = [1, 2, 4, 7];
/// Solve tolerance; the KKT re-check allows 100× for the incremental
/// u-maintenance drift both solvers share.
const TOL: f64 = 1e-9;
/// KKT dead-band for the E-set comparison — three orders above the
/// solve tolerance, so serial/parallel optimum differences (≈ tol)
/// cannot flip a margin across the band edge.
const E_BAND: f64 = 1e-6;

fn cfg(solver_threads: usize) -> SolverConfig {
    SolverConfig {
        tol: TOL,
        max_outer: 200_000,
        solver_threads: Some(solver_threads),
        ..Default::default()
    }
}

/// Solve serial and parallel on both storages of one dataset and hold
/// every clause of the contract.
fn check_model(model: Model, sparse: Dataset, c: f64, c_next: f64) {
    assert!(sparse.x.is_sparse());
    let dense = sparse.clone().into_storage(Storage::Dense);
    for (ds, stag) in [(&dense, "dense"), (&sparse, "csr")] {
        let inst = Instance::from_dataset(model, ds);
        let serial = CdSolver::new(cfg(1)).solve(&inst, c, inst.cold_start());
        assert!(serial.stats.converged, "{model:?}/{stag}: serial did not converge");

        let (mid, rad) = ball_params(c, c_next);
        let u_serial = inst.u_from_theta(&serial.theta);
        let decisions_serial = dvi_scan(&inst, mid, rad, &u_serial);
        let members_serial =
            classify_kkt(&inst, &inst.w_from_theta(c, &serial.theta), E_BAND);

        for threads in THREADS {
            let par = CdSolver::new(cfg(threads)).solve(&inst, c, inst.cold_start());
            let tag = format!("{model:?}/{stag}/t={threads}");
            assert!(par.stats.converged, "{tag}: did not converge");
            assert!(inst.in_box(&par.theta, 1e-12), "{tag}: θ leaves the box");
            assert_eq!(par.stats.active_coords, serial.stats.active_coords, "{tag}");

            // KKT-valid at the same tol (fresh full-problem recompute)
            let v = CdSolver::kkt_violation(&inst, c, &par.theta);
            assert!(v < 100.0 * TOL, "{tag}: violation {v}");

            if threads == 1 {
                // byte-identical to the serial solver, trajectory and all
                assert_eq!(par.theta, serial.theta, "{tag}: θ drifted");
                assert_eq!(par.u, serial.u, "{tag}: u drifted");
                assert_eq!(par.stats.outer_iters, serial.stats.outer_iters);
                assert_eq!(par.stats.grad_evals, serial.stats.grad_evals);
            }

            // identical downstream screening decisions
            let u_par = inst.u_from_theta(&par.theta);
            assert_eq!(
                dvi_scan(&inst, mid, rad, &u_par),
                decisions_serial,
                "{tag}: DVI screening decisions diverged"
            );
            // identical support/E-set classification
            let members_par =
                classify_kkt(&inst, &inst.w_from_theta(c, &par.theta), E_BAND);
            assert_eq!(
                members_par.classes, members_serial.classes,
                "{tag}: KKT membership diverged"
            );
        }
    }
}

#[test]
fn svm_parallel_solver_matches_serial() {
    check_model(Model::Svm, synth::sparse_classes(901, 180, 60, 0.08), 0.5, 0.8);
}

#[test]
fn weighted_svm_parallel_solver_matches_serial() {
    check_model(Model::WeightedSvm, synth::sparse_classes(902, 150, 50, 0.1), 0.5, 0.8);
}

#[test]
fn lad_parallel_solver_matches_serial() {
    check_model(Model::Lad, synth::sparse_regression(903, 160, 40, 0.12, 0.2), 0.5, 0.8);
}

#[test]
fn fixed_seed_threads_is_run_to_run_deterministic() {
    let ds = synth::sparse_classes(904, 170, 48, 0.1);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    // 0 = auto resolves to one machine-dependent count and must still be
    // reproducible within the machine
    for threads in [2usize, 4, 7, 0] {
        let a = CdSolver::new(cfg(threads)).solve(&inst, 0.7, inst.cold_start());
        let b = CdSolver::new(cfg(threads)).solve(&inst, 0.7, inst.cold_start());
        assert_eq!(a.theta, b.theta, "threads={threads}: θ not reproducible");
        assert_eq!(a.u, b.u, "threads={threads}: u not reproducible");
        assert_eq!(a.stats.outer_iters, b.stats.outer_iters, "threads={threads}");
        assert_eq!(a.stats.grad_evals, b.stats.grad_evals, "threads={threads}");
        assert_eq!(a.stats.coord_updates, b.stats.coord_updates, "threads={threads}");
        assert_eq!(
            a.stats.final_violation.to_bits(),
            b.stats.final_violation.to_bits(),
            "threads={threads}"
        );
    }
}

/// The warm-started path — screen, snap screened coordinates, reduced
/// solve via `solve_free_with_u` — must screen the exact same sets at
/// every grid point whichever solver runs the sweeps, and stay
/// full-problem KKT-valid throughout. This is the end-to-end form of the
/// "screening composes with any solver" argument the parallel sweep
/// leans on.
#[test]
fn warm_started_path_screens_identically_with_parallel_solver() {
    let cases = [
        (Model::Svm, synth::sparse_classes(905, 160, 50, 0.1)),
        (Model::Lad, synth::sparse_regression(906, 140, 30, 0.15, 0.2)),
    ];
    for (model, sparse) in cases {
        let dense = sparse.clone().into_storage(Storage::Dense);
        for ds in [&dense, &sparse] {
            // 24 grid points: DVI's sequential radius shrinks with the
            // grid spacing, and LAD needs a reasonably fine grid before
            // anything screens at all (cf. the runner's own LAD test)
            let path_cfg = |solver_threads: usize| {
                PathConfig::log_grid(1e-2, 10.0, 24)
                    .with_solver(SolverConfig {
                        tol: 1e-9,
                        max_outer: 200_000,
                        solver_threads: Some(solver_threads),
                        ..Default::default()
                    })
                    .with_validation(true)
            };
            let serial = PathRunner::new(model, path_cfg(1), RuleKind::DviW).run(ds);
            let par = PathRunner::new(model, path_cfg(4), RuleKind::DviW).run(ds);
            assert_eq!(serial.steps.len(), par.steps.len());
            for (a, b) in serial.steps.iter().zip(&par.steps) {
                assert_eq!(
                    (a.n_lo, a.n_hi, a.free),
                    (b.n_lo, b.n_hi, b.free),
                    "{model:?} {}: screened sets diverged at C={}",
                    ds.x.storage_name(),
                    a.c
                );
            }
            assert_eq!(serial.mean_rejection(), par.mean_rejection());
            if model == Model::Svm {
                assert!(serial.mean_rejection() > 0.0, "nothing screened — test is vacuous");
            }
            assert!(par.worst_violation().unwrap() < 1e-6, "{model:?}: parallel path KKT");
        }
    }
}
