//! Shared bench harness (criterion is unavailable offline): warmup +
//! repeated timing with mean/p50/min reporting, and CLI arg handling
//! (`cargo bench` passes `--bench`; we also accept `--scale`, `--points`).

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
}

impl BenchStats {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>4} iters  mean {:>12.6}s  p50 {:>12.6}s  min {:>12.6}s",
            self.name, self.iters, self.mean_s, self.p50_s, self.min_s
        )
    }
}

/// Run `f` until `min_time_s` elapses (at least `min_iters` times) and
/// report stats. `f` should return something observable to keep the
/// optimizer honest; we black-box it.
pub fn bench<T>(name: &str, min_iters: usize, min_time_s: f64, mut f: impl FnMut() -> T) -> BenchStats {
    // warmup
    std::hint::black_box(f());
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_time_s {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        min_s: samples[0],
        p50_s: samples[samples.len() / 2],
    };
    println!("{}", stats.line());
    stats
}

/// Parse `--key value` bench args, ignoring cargo's `--bench` flag.
pub fn arg_f64(key: &str, default: f64) -> f64 {
    let args: Vec<String> = std::env::args().collect();
    for i in 0..args.len() {
        if args[i] == format!("--{key}") {
            if let Some(v) = args.get(i + 1).and_then(|s| s.parse().ok()) {
                return v;
            }
        }
    }
    default
}

pub fn arg_usize(key: &str, default: usize) -> usize {
    arg_f64(key, default as f64) as usize
}

/// String-valued `--key value` bench arg (e.g. `--out DIR`).
#[allow(dead_code)] // not every bench binary takes string args
pub fn arg_str(key: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    for i in 0..args.len() {
        if args[i] == format!("--{key}") {
            if let Some(v) = args.get(i + 1) {
                return v.clone();
            }
        }
    }
    default.to_string()
}
