//! Regenerates the paper's **Figures 1, 2 and 3** — rejection-ratio
//! curves (stacked-area charts in the terminal, CSV series on disk).
//!
//! Run: `cargo bench --bench bench_figures [-- --scale 0.25 --points 100]`

#[path = "common/mod.rs"]
mod common;

use dvi_screen::experiments::{self, ExpOptions};

fn main() {
    let scale = common::arg_f64("scale", 0.25);
    let points = common::arg_usize("points", 100);
    // 0 = auto-detect: figure regeneration exploits the sharded ParScan
    // engine by default (results are identical to --threads 1)
    let threads = common::arg_usize("threads", 0);
    let opts = ExpOptions {
        scale,
        points,
        tol: 1e-6,
        out_dir: "results".into(),
        use_pjrt: false,
        validate: false,
        threads,
    };
    println!("# bench_figures: scale {scale}, {points}-point grid, threads {threads} (0 = auto)\n");
    let t = std::time::Instant::now();
    println!("{}", experiments::run("fig1", &opts).unwrap());
    println!("{}", experiments::run("fig2", &opts).unwrap());
    println!("{}", experiments::run("fig3", &opts).unwrap());
    println!("# total {:.1}s; CSVs in results/", t.elapsed().as_secs_f64());
}
