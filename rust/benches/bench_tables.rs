//! Regenerates the paper's **Tables 1, 2 and 3** — end-to-end path
//! runtimes for Solver vs Solver+rule, with the same row structure the
//! paper reports (rule time, init time, total, speedup).
//!
//! Run: `cargo bench --bench bench_tables [-- --scale 0.25 --points 100]`
//! The scale applies to the simulated real sets; toys always run at the
//! paper's full 1000/class. Results also land in `results/*.csv`.

#[path = "common/mod.rs"]
mod common;

use dvi_screen::experiments::{self, ExpOptions};

fn main() {
    let scale = common::arg_f64("scale", 0.25);
    let points = common::arg_usize("points", 100);
    // 0 = auto-detect: table regeneration exploits the sharded ParScan
    // engine by default (results are identical to --threads 1)
    let threads = common::arg_usize("threads", 0);
    let opts = ExpOptions {
        scale,
        points,
        tol: 1e-6,
        out_dir: "results".into(),
        use_pjrt: false,
        validate: false,
        threads,
    };
    println!("# bench_tables: scale {scale}, {points}-point grid, threads {threads} (0 = auto)\n");
    let t = std::time::Instant::now();
    println!("{}", experiments::run("tab1", &opts).unwrap());
    println!("{}", experiments::run("tab2", &opts).unwrap());
    println!("{}", experiments::run("tab3", &opts).unwrap());
    println!("# total {:.1}s; CSVs in results/", t.elapsed().as_secs_f64());
}
