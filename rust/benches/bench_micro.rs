//! Microbenches for the hot paths (§Perf in EXPERIMENTS.md):
//!
//! * the native DVI scan (throughput in GB/s over the instance matrix —
//!   the paper's "scan the data set only once" cost);
//! * scan scaling: the sharded `ParScan` engine at 1/2/4/8 threads over
//!   l ∈ {10k, 100k, 1M} (the paper's "negligible vs solving" claim only
//!   holds if the scan saturates the hardware);
//! * the PJRT/AOT scan (per-call latency incl. u upload + codes download);
//! * CD sweep scaling: the block-synchronous parallel solver at 1/2/4/8
//!   threads over l ∈ {10k, 100k}, dense and CSR, against the full
//!   problem and against a DVI-screened (reduced) free set;
//! * cd-mode: sync vs async wall-clock to convergence at the same tol,
//!   1/2/4/8 threads × l ∈ {10k, 100k} × dense/CSR — written (with the
//!   sweep-scaling and pool-reuse series) to BENCH_solver.json
//!   (`--out DIR`, default `.`), the CI bench-smoke gate's input;
//! * pool reuse: the persistent pinned worker pool vs per-call scoped
//!   spawning, with spawn/dispatch/migration counters;
//! * shard-axis: exact u = Zᵀθ reconstruction on short-and-wide data
//!   (n ∈ {10k, 100k}, small l, dense and CSR), racing the `rows`,
//!   `cols`, and `auto` shard axes — also written to BENCH_solver.json
//!   for the bench-smoke auto-vs-fixed gate;
//! * one dual-CD sweep (gradient-eval rate);
//! * Lemma 20 extremization (SSNSV/ESSNSV inner loop);
//! * w-form vs θ-form DVI ablation (the Gram-matrix crossover).
//!
//! Run: `cargo bench --bench bench_micro [-- --max-l 1000000]`

#[path = "common/mod.rs"]
mod common;

use common::bench;
use dvi_screen::config::{Json, SolverConfig};
use dvi_screen::data::synth;
use dvi_screen::problem::{Instance, Model};
use dvi_screen::screening::dvi::{dvi_scan, dvi_scan_par};
use dvi_screen::screening::ssnsv::lemma20_min;
use dvi_screen::screening::Dvi;
use dvi_screen::solver::CdSolver;

/// One row of BENCH_solver.json's `series` array.
struct SolverSeriesEntry {
    name: String,
    stats: common::BenchStats,
    extra: Vec<(&'static str, Json)>,
}

fn main() {
    println!("# bench_micro\n");
    // accumulates the solver-focused series for BENCH_solver.json
    let mut solver_series: Vec<SolverSeriesEntry> = Vec::new();

    // ---- native DVI scan ------------------------------------------------
    for (l, n) in [(10_000usize, 22usize), (40_000, 54)] {
        let ds = synth::gaussian_classes(1, l, n, 1.0, 1.0, 0.5, 1.0);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let u: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let bytes = (l * n * 8) as f64;
        let s = bench(&format!("native_dvi_scan_{l}x{n}"), 5, 0.5, || {
            dvi_scan(&inst, 1.05, 0.05, &u)
        });
        println!("    -> {:.2} GB/s effective", bytes / s.min_s / 1e9);
    }

    // ---- scan scaling: sharded ParScan across thread counts --------------
    // The acceptance series for the sharded engine: per-(l, threads) scan
    // latency plus the speedup over the single-thread run of the same l.
    // `--max-l` bounds the largest row count (the 1M build allocates
    // ~180 MB for Z).
    {
        println!("\n# scan scaling: sharded ParScan (contiguous shards, std::thread::scope)");
        let max_l = common::arg_usize("max-l", 1_000_000);
        let n = 22usize;
        for l in [10_000usize, 100_000, 1_000_000] {
            if l > max_l {
                println!("par_dvi_scan_{l}x{n} skipped (--max-l {max_l})");
                continue;
            }
            let ds = synth::gaussian_classes(7, l, n, 1.0, 1.0, 0.5, 1.0);
            let inst = Instance::from_dataset(Model::Svm, &ds);
            let u: Vec<f64> = (0..n).map(|i| (i as f64 * 0.11).sin()).collect();
            let bytes = (l * n * 8) as f64;
            let mut single = f64::NAN;
            for threads in [1usize, 2, 4, 8] {
                let s = bench(&format!("par_dvi_scan_{l}x{n}_t{threads}"), 3, 0.3, || {
                    dvi_scan_par(&inst, 1.05, 0.05, &u, threads)
                });
                if threads == 1 {
                    single = s.min_s;
                    println!("    -> {:.2} GB/s effective", bytes / s.min_s / 1e9);
                } else {
                    println!(
                        "    -> {:.2} GB/s effective, {:.2}x vs 1 thread",
                        bytes / s.min_s / 1e9,
                        single / s.min_s
                    );
                }
            }
        }
    }

    // ---- sparse vs dense scan ---------------------------------------------
    // CSR storage pays an index per value but touches only the nonzeros:
    // the acceptance series for the storage-polymorphic data layer. At
    // density 0.01 the CSR scan must beat dense by ≥5× (the win grows
    // with 1/density until the per-row overhead floor).
    {
        use dvi_screen::linalg::Storage;
        println!("\n# sparse vs dense scan: CSR vs dense storage of the same data");
        let max_l = common::arg_usize("max-l", 1_000_000);
        let n = 200usize;
        for l in [10_000usize, 100_000] {
            if l > max_l {
                println!("csr_dvi_scan_{l}x{n} skipped (--max-l {max_l})");
                continue;
            }
            for density in [0.01f64, 0.1, 1.0] {
                let ds = synth::sparse_classes(0xC5A0 + (density * 100.0) as u64, l, n, density);
                let sparse = Instance::from_dataset(Model::Svm, &ds);
                let dense =
                    Instance::from_dataset(Model::Svm, &ds.clone().into_storage(Storage::Dense));
                let u: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();
                let tag = format!("{l}x{n}_d{density}");
                let sd = bench(&format!("dense_dvi_scan_{tag}"), 3, 0.3, || {
                    dvi_scan(&dense, 1.05, 0.05, &u)
                });
                let ss = bench(&format!("csr_dvi_scan_{tag}"), 3, 0.3, || {
                    dvi_scan(&sparse, 1.05, 0.05, &u)
                });
                println!(
                    "    -> csr {:.2}x vs dense (nnz {} of {})",
                    sd.min_s / ss.min_s,
                    ds.nnz(),
                    l * n
                );
            }
        }
    }

    // ---- instance cache: cold construction vs resident hit ----------------
    // The serving-layer amortization series: a cold `get_or_build` pays
    // dataset resolution + z-transform + row norms; a warm one clones an
    // Arc. The gap is what the coordinator's cache saves per request —
    // on CSR data construction costs more than the scan it feeds.
    {
        use dvi_screen::coordinator::{CacheKey, InstanceCache};
        use dvi_screen::linalg::Storage;
        use dvi_screen::metrics::Registry;
        println!("\n# instance cache: cold build vs resident hit (coordinator cache)");
        let max_l = common::arg_usize("max-l", 1_000_000);
        let reg = Registry::default();
        for l in [10_000usize, 100_000] {
            if l > max_l {
                println!("instance_cache_{l} skipped (--max-l {max_l})");
                continue;
            }
            for (name, storage, tag) in [
                (format!("gauss:{l}:50"), Storage::Dense, "dense"),
                (format!("sparse:{l}:200"), Storage::Csr, "csr"),
            ] {
                let key = CacheKey::new(&name, Model::Svm, storage, 1.0);
                // zero-budget cache: every call is a full cold build
                let transient = InstanceCache::new(0);
                let cold = bench(&format!("instance_build_cold_{tag}_{l}"), 3, 0.3, || {
                    transient.get_or_build(&key, &reg).unwrap().len()
                });
                let resident = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
                resident.get_or_build(&key, &reg).unwrap();
                let warm = bench(&format!("instance_cache_hit_{tag}_{l}"), 3, 0.3, || {
                    resident.get_or_build(&key, &reg).unwrap().len()
                });
                println!(
                    "    -> hit is {:.0}x cheaper than cold construction",
                    cold.min_s / warm.min_s.max(1e-12)
                );
            }
        }
    }

    // ---- predict throughput -----------------------------------------------
    // The model-artifact serving series: batch ∈ {1, 64, 4096} rows,
    // dense vs CSR input, full-w vs support-only scoring. Scores are
    // bit-identical across all four cells; the series shows the
    // per-request floor (batch 1), the amortized rate (batch 4096), the
    // CSR bandwidth win, and that support-only's one-time w
    // reconstruction is noise once the batch is non-trivial.
    {
        use dvi_screen::linalg::Storage;
        use dvi_screen::model::{PredictOptions, TrainedModel};
        println!("\n# predict throughput: batch size x storage x scoring path");
        for (storage, density, tag) in
            [(Storage::Dense, 1.0f64, "dense"), (Storage::Csr, 0.05, "csr")]
        {
            let (l, n) = (20_000usize, 100usize);
            let ds = if storage == Storage::Csr {
                synth::sparse_classes(0xBEEF, l, n, density)
            } else {
                synth::gaussian_classes(0xBEEF, l, n, 1.0, 1.0, 0.5, 1.0)
            };
            let inst = Instance::from_dataset(Model::Svm, &ds);
            let solver = CdSolver::new(SolverConfig { tol: 1e-5, ..Default::default() });
            let r = solver.solve(&inst, 0.5, inst.cold_start());
            let tm = TrainedModel::from_solution(&inst, "bench", 1.0, 0.5, 1e-5, &r.theta);
            println!(
                "model[{tag}]: l={l} n={n} support={} active={}",
                tm.support.len(),
                tm.active.len()
            );
            for batch in [1usize, 64, 4096] {
                let idx: Vec<usize> = (0..batch).map(|k| k % l).collect();
                let rows = ds.x.select_rows(&idx);
                let bytes = (rows.nnz() * if storage == Storage::Csr { 12 } else { 8 }) as f64;
                for (path, support_only) in [("full-w", false), ("support", true)] {
                    let opts = PredictOptions { threads: 1, support_only };
                    let s = bench(&format!("predict_{tag}_b{batch}_{path}"), 3, 0.2, || {
                        dvi_screen::model::scores(&tm, &rows, &opts).unwrap().len()
                    });
                    println!(
                        "    -> {:.1} Mrow/s, {:.2} GB/s effective",
                        batch as f64 / s.min_s / 1e6,
                        bytes / s.min_s / 1e9
                    );
                }
            }
        }
    }

    // ---- CD sweep scaling: block-synchronous parallel solver --------------
    // The acceptance series for the sharded CD sweep: fixed sweep budget
    // (max_outer bounds the work so the series measures sweep throughput,
    // not convergence luck), 1/2/4/8 solver threads, dense and CSR, and
    // both arms of the paper's story — the full problem and the reduced
    // problem a DVI screen leaves behind (screening composes with any
    // solver, so the speedups multiply).
    {
        use dvi_screen::linalg::Storage;
        use dvi_screen::screening::Decision;
        println!("\n# cd sweep scaling: block-synchronous parallel dual CD");
        let max_l = common::arg_usize("max-l", 1_000_000);
        for l in [10_000usize, 100_000] {
            if l > max_l {
                println!("cd_sweep_{l} skipped (--max-l {max_l})");
                continue;
            }
            // the csr-wide cell (n = 8192 > the sparse-delta threshold)
            // exercises the sparse delta-u accumulator — the narrow csr
            // cell takes the dense u-clone path like the serial solver
            for (storage, n, density, tag) in [
                (Storage::Dense, 22usize, 1.0f64, "dense"),
                (Storage::Csr, 200, 0.05, "csr"),
                (Storage::Csr, 8192, 0.002, "csr-wide"),
            ] {
                let ds = if storage == Storage::Csr {
                    synth::sparse_classes(0xCD5, l, n, density)
                } else {
                    synth::gaussian_classes(0xCD5, l, n, 1.0, 1.0, 0.5, 1.0)
                };
                let inst = Instance::from_dataset(Model::Svm, &ds);
                let (c_prev, c_next) = (0.5f64, 0.55f64);
                // anchor solve + screen once, outside the timed region
                let anchor = CdSolver::new(SolverConfig {
                    tol: 1e-4,
                    max_outer: 60,
                    ..Default::default()
                })
                .solve(&inst, c_prev, inst.cold_start());
                let u_anchor = inst.u_from_theta(&anchor.theta);
                let report = Dvi::new_w().screen(&inst, c_prev, c_next, &anchor.theta, &u_anchor);
                // snap screened coordinates exactly as the path runner does
                let mut theta_red = anchor.theta.clone();
                let mut u_red = u_anchor.clone();
                for (i, d) in report.decisions.iter().enumerate() {
                    let target = match d {
                        Decision::AtLo => inst.lo[i],
                        Decision::AtHi => inst.hi[i],
                        Decision::Keep => theta_red[i],
                    };
                    let delta = target - theta_red[i];
                    if delta != 0.0 {
                        theta_red[i] = target;
                        inst.z.row(i).axpy_into(delta, &mut u_red);
                    }
                }
                let free_red = report.free_indices();
                let free_all: Vec<usize> = (0..inst.len()).collect();
                for (arm, free, theta0, u0) in [
                    ("full", &free_all, &anchor.theta, &u_anchor),
                    ("screened", &free_red, &theta_red, &u_red),
                ] {
                    let mut single = f64::NAN;
                    for threads in [1usize, 2, 4, 8] {
                        let solver = CdSolver::new(SolverConfig {
                            tol: 1e-12, // unreachable in 24 sweeps: fixed work
                            max_outer: 24,
                            solver_threads: Some(threads),
                            ..Default::default()
                        });
                        let mut evals = 0u64;
                        let s = bench(
                            &format!("cd_sweep_{tag}_{l}_{arm}_t{threads}"),
                            3,
                            0.3,
                            || {
                                let r = solver.solve_free_with_u(
                                    &inst,
                                    c_next,
                                    theta0.clone(),
                                    free,
                                    u0.clone(),
                                );
                                evals = r.stats.grad_evals;
                                r.stats.coord_updates
                            },
                        );
                        let rate = evals as f64 / s.min_s / 1e6;
                        let speedup = if threads == 1 {
                            single = s.min_s;
                            println!("    -> {rate:.1} M grad-evals/s ({} free)", free.len());
                            1.0
                        } else {
                            let x = single / s.min_s;
                            println!("    -> {rate:.1} M grad-evals/s, {x:.2}x vs 1 thread");
                            x
                        };
                        solver_series.push(SolverSeriesEntry {
                            name: s.name.clone(),
                            stats: s,
                            extra: vec![
                                ("series", Json::Str("cd_sweep".into())),
                                ("mode", Json::Str("sync".into())),
                                ("storage", Json::Str(tag.into())),
                                ("l", Json::Int(l as i64)),
                                ("arm", Json::Str(arm.to_string())),
                                ("threads", Json::Int(threads as i64)),
                                ("grad_evals", Json::Int(evals as i64)),
                                ("speedup_vs_serial", Json::Float(speedup)),
                            ],
                        });
                    }
                }
            }
        }
    }

    // ---- cd-mode: sync vs async wall-clock to convergence ------------------
    // The acceptance series for the wild arm: from one shared warm start,
    // time-to-KKT-valid at the same tol for both modes across thread
    // counts. Unlike the fixed-work series above this measures what the
    // async arm is actually for — wall-clock to a converged point — since
    // its wild rounds and confirmation sweeps make per-sweep work
    // incomparable with the block-synchronous arm.
    {
        use dvi_screen::config::CdMode;
        use dvi_screen::linalg::Storage;
        println!("\n# cd mode: sync vs async, wall-clock to convergence at tol 1e-6");
        let max_l = common::arg_usize("max-l", 1_000_000);
        for l in [10_000usize, 100_000] {
            if l > max_l {
                println!("cd_mode_{l} skipped (--max-l {max_l})");
                continue;
            }
            for (storage, n, density, tag) in
                [(Storage::Dense, 22usize, 1.0f64, "dense"), (Storage::Csr, 200, 0.05, "csr")]
            {
                let ds = if storage == Storage::Csr {
                    synth::sparse_classes(0xA51C, l, n, density)
                } else {
                    synth::gaussian_classes(0xA51C, l, n, 1.0, 1.0, 0.5, 1.0)
                };
                let inst = Instance::from_dataset(Model::Svm, &ds);
                // shared warm start so every cell solves the same problem
                let anchor = CdSolver::new(SolverConfig {
                    tol: 1e-3,
                    max_outer: 40,
                    ..Default::default()
                })
                .solve(&inst, 0.5, inst.cold_start());
                let u0 = inst.u_from_theta(&anchor.theta);
                let free: Vec<usize> = (0..inst.len()).collect();
                let mut serial = f64::NAN;
                for mode in [CdMode::Sync, CdMode::Async] {
                    for threads in [1usize, 2, 4, 8] {
                        if mode == CdMode::Async && threads == 1 {
                            continue; // identical to sync/1 by contract
                        }
                        let solver = CdSolver::new(SolverConfig {
                            tol: 1e-6,
                            max_outer: 200_000,
                            solver_threads: Some(threads),
                            cd_mode: mode,
                            ..Default::default()
                        });
                        let mut converged = true;
                        let s = bench(
                            &format!("cd_mode_{}_{tag}_{l}_t{threads}", mode.name()),
                            3,
                            0.3,
                            || {
                                let r = solver.solve_free_with_u(
                                    &inst,
                                    0.55,
                                    anchor.theta.clone(),
                                    &free,
                                    u0.clone(),
                                );
                                converged &= r.stats.converged;
                                r.stats.coord_updates
                            },
                        );
                        assert!(converged, "cd_mode series must converge to be comparable");
                        let speedup = if mode == CdMode::Sync && threads == 1 {
                            serial = s.min_s;
                            1.0
                        } else {
                            let x = serial / s.min_s;
                            println!("    -> {x:.2}x vs sync serial");
                            x
                        };
                        solver_series.push(SolverSeriesEntry {
                            name: s.name.clone(),
                            stats: s,
                            extra: vec![
                                ("series", Json::Str("cd_mode".into())),
                                ("mode", Json::Str(mode.name().into())),
                                ("storage", Json::Str(tag.into())),
                                ("l", Json::Int(l as i64)),
                                ("threads", Json::Int(threads as i64)),
                                ("speedup_vs_serial", Json::Float(speedup)),
                            ],
                        });
                    }
                }
            }
        }
    }

    // ---- pool reuse: persistent pinned workers vs per-call spawning --------
    // The tentpole's accounting: a sharded scan through the routed
    // entries costs channel sends into long-lived workers (pool spawns
    // stay flat after warmup — ≤ 1 spawn per solve amortized, in fact 0
    // here), while the scoped fallback pays t-1 OS thread spawns on
    // EVERY call. Shard→worker affinity is pinned by construction
    // (shard k → worker k-1), measured here as the number of distinct
    // worker threads observed per shard slot across repeat calls.
    {
        use dvi_screen::linalg::par;
        println!("\n# pool reuse: routed (persistent pool) vs scoped (spawn per call)");
        let l = 200_000usize.min(common::arg_usize("max-l", 1_000_000));
        let n = 22usize;
        let shards = 4usize;
        let ds = synth::gaussian_classes(0x9001, l, n, 1.0, 1.0, 0.5, 1.0);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let u: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).sin()).collect();

        let before = par::pool_stats();
        let pooled = bench(&format!("pool_scan_routed_{l}x{n}_t{shards}"), 8, 0.4, || {
            dvi_scan_par(&inst, 1.05, 0.05, &u, shards)
        });
        let after = par::pool_stats();
        let spawned = after.workers_spawned - before.workers_spawned;
        let dispatched = after.jobs_dispatched - before.jobs_dispatched;
        println!(
            "    -> {spawned} workers spawned over {} calls ({dispatched} jobs dispatched); \
             pool reuses its threads",
            pooled.iters
        );
        assert!(
            (spawned as usize) <= shards,
            "pool must spawn at most once per worker slot, ever"
        );

        let scoped_before = par::pool_stats().scoped_spawns;
        let scoped = bench(&format!("pool_scan_scoped_{l}x{n}_t{shards}"), 8, 0.4, || {
            let ranges = inst.balanced_shards(shards);
            par::run_sharded_ranges_scoped(ranges, |r| {
                let mut acc = 0usize;
                for i in r {
                    acc += (inst.z.row(i).dot(&u) > 0.0) as usize;
                }
                acc
            })
        });
        let scoped_spawns = par::pool_stats().scoped_spawns - scoped_before;
        println!(
            "    -> scoped fallback spawned {scoped_spawns} OS threads over {} calls \
             ({:.1} per call)",
            scoped.iters,
            scoped_spawns as f64 / scoped.iters.max(1) as f64
        );

        // shard→worker affinity: each shard slot must land on one stable
        // worker thread across repeated dispatches (shard 0 runs inline)
        let mut migrations = 0usize;
        {
            use std::sync::Mutex;
            let seen: Vec<Mutex<Option<std::thread::ThreadId>>> =
                (0..shards).map(|_| Mutex::new(None)).collect();
            let bounds = inst.balanced_shards(shards);
            for _ in 0..16 {
                let seen_ro = &seen;
                let bounds_c = bounds.clone();
                par::run_sharded_ranges(bounds_c, |r| {
                    let slot = bounds.iter().position(|b| b.start == r.start).unwrap();
                    let me = std::thread::current().id();
                    let mut prev = seen_ro[slot].lock().unwrap();
                    match *prev {
                        Some(p) if p != me => {
                            *prev = Some(me);
                            1usize // migration observed
                        }
                        _ => {
                            *prev = Some(me);
                            0
                        }
                    }
                })
                .into_iter()
                .for_each(|m| migrations += m);
            }
        }
        println!("    -> {migrations} shard->worker migrations across 16 dispatches");
        for (entry, extras) in [
            (
                (&pooled, "routed"),
                vec![
                    ("workers_spawned", Json::Int(spawned as i64)),
                    ("jobs_dispatched", Json::Int(dispatched as i64)),
                    ("shard_migrations", Json::Int(migrations as i64)),
                ],
            ),
            (
                (&scoped, "scoped"),
                vec![("os_threads_spawned", Json::Int(scoped_spawns as i64))],
            ),
        ] {
            let (stats, kind) = entry;
            let mut extra = vec![
                ("series", Json::Str("pool_reuse".into())),
                ("kind", Json::Str(kind.into())),
                ("l", Json::Int(l as i64)),
                ("threads", Json::Int(shards as i64)),
            ];
            extra.extend(extras);
            solver_series.push(SolverSeriesEntry {
                name: stats.name.clone(),
                stats: (*stats).clone(),
                extra,
            });
        }
    }

    // ---- shard-axis reconstruction: rows vs cols vs auto on wide data ------
    // The column-mirror acceptance series: exact u = Zᵀθ reconstruction
    // on short-and-wide instances (n ≫ l), where the `rows` arm is the
    // serial t_matvec (there is nothing to shard along l) and the `cols`
    // arm feature-shards disjoint column slabs over the solver pool.
    // `auto` must track whichever fixed axis wins; the bench-smoke gate
    // holds it to within 10% of the better one on the widest cells. The
    // lazy column mirror is built outside the timed region, and every
    // arm is checked bit-identical to the serial kernel before timing.
    {
        use dvi_screen::linalg::{ShardAxis, Storage};
        println!("\n# shard axis: u = Z^T theta reconstruction, rows vs cols vs auto");
        let threads = 4usize;
        for (l, n, storage, density, tag) in [
            (400usize, 10_000usize, Storage::Dense, 1.0f64, "dense"),
            (400, 10_000, Storage::Csr, 0.05, "csr"),
            (200, 100_000, Storage::Dense, 1.0, "dense"),
            (200, 100_000, Storage::Csr, 0.01, "csr"),
        ] {
            let ds = if storage == Storage::Csr {
                synth::sparse_classes(0x5A1D, l, n, density)
            } else {
                synth::gaussian_classes(0x5A1D, l, n, 1.0, 1.0, 0.5, 1.0)
            };
            let inst = Instance::from_dataset(Model::Svm, &ds);
            let theta: Vec<f64> =
                (0..l).map(|i| 0.5 + 0.4 * (i as f64 * 0.23).sin()).collect();
            let serial = inst.u_from_theta(&theta);
            let t = std::time::Instant::now();
            let first = inst.u_from_theta_axis(&theta, ShardAxis::Cols, threads);
            let mirror_secs = t.elapsed().as_secs_f64();
            assert_eq!(first, serial, "cols reconstruction must be bit-identical");
            println!(
                "shard_axis[{tag}] l={l} n={n}: mirror build + first cols pass \
                 {mirror_secs:.3}s ({} MB charged)",
                inst.mirror_bytes() / (1 << 20)
            );
            for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Auto] {
                let s = bench(
                    &format!("shard_axis_{}_{tag}_{l}x{n}_t{threads}", axis.name()),
                    3,
                    0.3,
                    || inst.u_from_theta_axis(&theta, axis, threads).len(),
                );
                solver_series.push(SolverSeriesEntry {
                    name: s.name.clone(),
                    stats: s,
                    extra: vec![
                        ("series", Json::Str("shard_axis".into())),
                        ("axis", Json::Str(axis.name().into())),
                        ("picked", Json::Str(inst.pick_axis(axis).name().into())),
                        ("storage", Json::Str(tag.into())),
                        ("l", Json::Int(l as i64)),
                        ("n", Json::Int(n as i64)),
                        ("threads", Json::Int(threads as i64)),
                    ],
                });
            }
        }
    }

    // ---- PJRT scan -------------------------------------------------------
    match dvi_screen::runtime::PjrtScreener::from_default_dir() {
        Ok(mut screener) => {
            let ds = synth::gaussian_classes(2, 10_000, 22, 1.0, 1.0, 0.5, 1.0);
            let inst = Instance::from_dataset(Model::Svm, &ds);
            let u: Vec<f64> = (0..22).map(|i| (i as f64 * 0.21).cos()).collect();
            // first call pays compile + upload
            let t = std::time::Instant::now();
            screener.try_scan(&inst, 1.05, 0.05, &u).expect("pjrt");
            println!(
                "{:<44} cold (compile+upload) {:>10.4}s",
                "pjrt_dvi_scan_10000x22", t.elapsed().as_secs_f64()
            );
            bench("pjrt_dvi_scan_10000x22 (warm)", 5, 0.5, || {
                screener.try_scan(&inst, 1.05, 0.05, &u).expect("pjrt")
            });
        }
        Err(e) => println!("pjrt scan skipped: {e}"),
    }

    // ---- solver sweep rate -----------------------------------------------
    {
        let ds = synth::toy_gaussian(9, 5_000, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let solver =
            CdSolver::new(SolverConfig { tol: 1e-7, max_outer: 100_000, ..Default::default() });
        let mut evals = 0u64;
        let s = bench("cd_solve_toy2_l10000_C1", 3, 1.0, || {
            let r = solver.solve(&inst, 1.0, inst.cold_start());
            evals = r.stats.grad_evals;
            r.stats.coord_updates
        });
        println!(
            "    -> {:.1} M grad-evals/s ({} evals/solve)",
            evals as f64 / s.min_s / 1e6,
            evals
        );
    }

    // ---- Lemma 20 --------------------------------------------------------
    {
        let n = 54;
        let v: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let u: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let o: Vec<f64> = vec![0.1; n];
        bench("lemma20_min_n54 (x10000)", 5, 0.5, || {
            let mut acc = 0.0;
            for k in 0..10_000 {
                acc += lemma20_min(&v, &u, 10.0 + k as f64 * 1e-4, &o, 2.0);
            }
            acc
        });
    }

    // ---- w-form vs θ-form ablation ----------------------------------------
    println!("\n# ablation: DVI w-form (O(l·n)) vs θ-form (O(l²) w/ cached Gram)");
    for l in [500usize, 2000, 6000] {
        let n = 22;
        let ds = synth::gaussian_classes(3, l, n, 1.0, 1.0, 0.5, 1.0);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let solver = CdSolver::new(SolverConfig { tol: 1e-6, ..Default::default() });
        let r = solver.solve(&inst, 0.5, inst.cold_start());
        let w_rule = Dvi::new_w();
        bench(&format!("dvi_w_form_{l}x{n}"), 5, 0.3, || {
            w_rule.screen(&inst, 0.5, 0.6, &r.theta, &r.u)
        });
        let t = std::time::Instant::now();
        let t_rule = Dvi::new_theta(&inst);
        let gram_secs = t.elapsed().as_secs_f64();
        let s = bench(&format!("dvi_theta_form_{l}x{n}"), 5, 0.3, || {
            t_rule.screen(&inst, 0.5, 0.6, &r.theta, &r.u)
        });
        println!(
            "    -> Gram precompute {:.3}s amortizes over {:.0} steps vs w-form",
            gram_secs,
            gram_secs / (s.min_s.max(1e-12))
        );
    }

    // ---- BENCH_solver.json -------------------------------------------------
    // Machine-readable record of the solver-focused series (cd_sweep,
    // cd_mode, pool_reuse, shard_axis) for the CI bench-smoke gate and for diffing
    // runs; schema mirrors the gauntlet's BENCH_screening.json.
    {
        use std::collections::BTreeMap;
        let out_dir = std::path::PathBuf::from(common::arg_str("out", "."));
        let mut entries = Vec::with_capacity(solver_series.len());
        for e in &solver_series {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(e.name.clone()));
            o.insert("iters".to_string(), Json::Int(e.stats.iters as i64));
            o.insert("mean_s".to_string(), Json::Float(e.stats.mean_s));
            o.insert("p50_s".to_string(), Json::Float(e.stats.p50_s));
            o.insert("min_s".to_string(), Json::Float(e.stats.min_s));
            for (k, v) in &e.extra {
                o.insert((*k).to_string(), v.clone());
            }
            entries.push(Json::Object(o));
        }
        let mut top = BTreeMap::new();
        top.insert("schema_version".to_string(), Json::Int(1));
        top.insert("bench".to_string(), Json::Str("bench_micro/solver".into()));
        top.insert("series".to_string(), Json::Array(entries));
        let path = out_dir.join("BENCH_solver.json");
        let mut text = Json::Object(top).to_string();
        text.push('\n');
        match std::fs::write(&path, &text) {
            Ok(()) => println!("\nwrote {} solver series to {}", solver_series.len(), path.display()),
            Err(e) => println!("\nfailed to write {}: {e}", path.display()),
        }
    }
}
