//! `dvi` — the launcher CLI.
//!
//! Subcommands:
//! * `path`       — run one regularization path (flags below)
//! * `experiment` — regenerate a paper table/figure by id (tab1..tab3,
//!   fig1..fig3, or `all`)
//! * `serve`      — line-JSON screening service on stdin/stdout
//! * `gen-data`   — write a dataset to a libsvm file
//! * `info`       — print artifact/runtime info
//!
//! Offline build ⇒ no clap; flags are parsed by a small hand-rolled
//! parser (`--key value` / `--flag`).

use dvi_screen::cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = cli::dispatch(&args);
    std::process::exit(code);
}
