//! Report emitters: ASCII tables (paper-table style), CSV files, and
//! terminal stacked-area charts (for the Fig. 1 rejection-rate plots).

pub mod chart;
pub mod csv;
pub mod table;

pub use chart::StackedArea;
pub use csv::CsvWriter;
pub use table::Table;
