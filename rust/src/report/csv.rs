//! Minimal CSV writer (RFC-4180 quoting for the subset we emit).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.iter().map(|h| quote(h)).collect::<Vec<_>>().join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    /// Write one row of already-formatted cells.
    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        assert_eq!(cells.len(), self.cols, "csv row width mismatch");
        writeln!(
            self.w,
            "{}",
            cells.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
        )
    }

    /// Write a row of f64s with full precision.
    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        let v: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&v)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_csv_test_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&p, &["a", "b,c"]).unwrap();
            w.row(&["x".into(), "say \"hi\", ok".into()]).unwrap();
            w.row_f64(&[1.5, 2.0]).unwrap();
            w.flush().unwrap();
        }
        let s = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "a,\"b,c\"");
        assert_eq!(lines[1], "x,\"say \"\"hi\"\", ok\"");
        assert_eq!(lines[2], "1.5,2");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn creates_parent_dirs() {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_csv_dir_{}", std::process::id()));
        p.push("nested/out.csv");
        let mut w = CsvWriter::create(&p, &["x"]).unwrap();
        w.row(&["1".into()]).unwrap();
        w.flush().unwrap();
        assert!(p.exists());
        std::fs::remove_dir_all(p.parent().unwrap().parent().unwrap()).ok();
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_csv_bad_{}.csv", std::process::id()));
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        let _ = w.row(&["only".into()]);
    }
}
