//! ASCII table rendering in the style of the paper's Tables 1–3.

/// A simple column-aligned ASCII table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Table { title: title.into(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert!(
            self.header.is_empty() || cells.len() == self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-ables.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncols = self.header.len().max(self.rows.iter().map(Vec::len).max().unwrap_or(0));
        if ncols == 0 {
            return format!("{}\n(empty)\n", self.title);
        }
        let mut widths = vec![0usize; ncols];
        let measure = |widths: &mut Vec<usize>, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        };
        measure(&mut widths, &self.header);
        for r in &self.rows {
            measure(&mut widths, r);
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = w - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format seconds the way the paper's tables do (2 decimal places).
pub fn secs(t: f64) -> String {
    format!("{t:.2}")
}

/// Format a speedup ("12.34x" / "-" when absent).
pub fn speedup(s: Option<f64>) -> String {
    match s {
        Some(v) => format!("{v:.2}x"),
        None => "-".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Table 1").header(&["set", "solver", "speedup"]);
        t.row(&["Toy1".into(), "11.83".into(), "59.15x".into()]);
        t.row(&["Toy2".into(), "13.68".into(), "26.31x".into()]);
        let s = t.render();
        assert!(s.contains("Table 1"));
        assert!(s.contains("| Toy1"));
        // all data lines equal width
        let widths: Vec<usize> =
            s.lines().filter(|l| l.starts_with('|') || l.starts_with('+')).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn empty_table() {
        let t = Table::new("x");
        assert!(t.render().contains("(empty)"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("t").header(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(secs(1.2345), "1.23");
        assert_eq!(speedup(Some(59.154)), "59.15x");
        assert_eq!(speedup(None), "-");
    }
}
