//! Terminal stacked-area chart — renders the paper's Fig. 1/2/3 rejection
//! curves as unicode block art: for each grid point (x axis = C index),
//! the column is filled bottom-up with the R-fraction (`█`), then the
//! L-fraction (`▒`), remainder blank (unscreened instances).

/// Stacked-area chart of two series (each in [0,1], sum ≤ 1).
pub struct StackedArea {
    title: String,
    r_frac: Vec<f64>,
    l_frac: Vec<f64>,
    height: usize,
}

impl StackedArea {
    pub fn new(title: impl Into<String>, r_frac: Vec<f64>, l_frac: Vec<f64>) -> Self {
        assert_eq!(r_frac.len(), l_frac.len());
        for (r, l) in r_frac.iter().zip(&l_frac) {
            assert!(
                (0.0..=1.0 + 1e-9).contains(r) && (0.0..=1.0 + 1e-9).contains(l),
                "fractions must be in [0,1]"
            );
            assert!(r + l <= 1.0 + 1e-6, "stacked fractions exceed 1: {r}+{l}");
        }
        StackedArea { title: title.into(), r_frac, l_frac, height: 16 }
    }

    pub fn height(mut self, h: usize) -> Self {
        self.height = h.max(4);
        self
    }

    /// Render to a string. Each input point is one column; a y-axis with
    /// 0/50/100% ticks on the left.
    pub fn render(&self) -> String {
        let h = self.height;
        let w = self.r_frac.len();
        let mut grid = vec![vec![' '; w]; h];
        for (c, (&r, &l)) in self.r_frac.iter().zip(&self.l_frac).enumerate() {
            let r_cells = (r * h as f64).round() as usize;
            let l_cells = (l * h as f64).round() as usize;
            for row in 0..r_cells.min(h) {
                grid[h - 1 - row][c] = '█';
            }
            for row in r_cells..(r_cells + l_cells).min(h) {
                grid[h - 1 - row][c] = '▒';
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{}  (█ = R-screened, ▒ = L-screened, blank = kept)\n", self.title));
        for (i, row) in grid.iter().enumerate() {
            let frac = 1.0 - i as f64 / h as f64;
            let label = if i == 0 {
                "100%"
            } else if i == h / 2 {
                " 50%"
            } else if (frac * 100.0).round() == 0.0 {
                "  0%"
            } else {
                "    "
            };
            out.push_str(label);
            out.push('|');
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str("  0%+");
        out.push_str(&"-".repeat(w));
        out.push('\n');
        out.push_str("     C: low -> high\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_expected_shape() {
        let r = vec![1.0, 0.5, 0.0, 0.25];
        let l = vec![0.0, 0.25, 0.5, 0.25];
        let s = StackedArea::new("toy", r, l).height(8).render();
        let lines: Vec<&str> = s.lines().collect();
        // title + 8 rows + axis + caption
        assert_eq!(lines.len(), 1 + 8 + 2);
        // first column fully '█' in all 8 chart rows
        for row in 1..9 {
            let col0 = lines[row].chars().nth(5).unwrap();
            assert_eq!(col0, '█', "row {row}: {}", lines[row]);
        }
        // third column: top half ▒... bottom has ▒ in lower half rows only
        assert!(s.contains('▒'));
    }

    #[test]
    #[should_panic]
    fn rejects_overflow() {
        StackedArea::new("bad", vec![0.8], vec![0.5]);
    }

    #[test]
    #[should_panic]
    fn rejects_length_mismatch() {
        StackedArea::new("bad", vec![0.5, 0.5], vec![0.5]);
    }

    #[test]
    fn zero_series_renders_blank() {
        let s = StackedArea::new("flat", vec![0.0; 10], vec![0.0; 10]).height(4).render();
        // skip the legend line; the chart body must be empty
        let body: String = s.lines().skip(1).collect();
        assert!(!body.contains('█'));
        assert!(!body.contains('▒'));
    }
}
