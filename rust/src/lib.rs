//! # dvi-screen
//!
//! A pathwise-training framework for SVM and Least Absolute Deviations (LAD)
//! regression with **safe exact data reduction**, reproducing
//! *"Scaling SVM and Least Absolute Deviations via Exact Data Reduction"*
//! (Jie Wang, Peter Wonka, Jieping Ye — ICML 2014).
//!
//! The paper's contribution — **DVI** screening rules derived from
//! variational inequalities on the dual boxed QP — is implemented in
//! [`screening`], together with the SSNSV and ESSNSV baselines it compares
//! against. The surrounding framework provides:
//!
//! * [`problem`] — the paper's unified formulation (problem (3)): a loss
//!   spec `(φ, aᵢ, bᵢ)` with conjugate box `[α, β]`, instantiated for SVM
//!   (hinge), LAD (absolute), and weighted SVM (the paper's §8 extension).
//! * [`solver`] — a LIBLINEAR-style dual coordinate-descent solver for the
//!   boxed QP (12)/(15) with shrinking and warm starts.
//! * [`path`] — the regularization-path runner that alternates
//!   screen → reduce (Lemma 4) → solve over the paper's 100-point C-grid.
//! * [`runtime`] — a PJRT client that executes the AOT-compiled JAX/Pallas
//!   screening graph (built once by `python/compile/aot.py`; Python is
//!   never on the request path).
//! * [`model`] — the model artifact subsystem: [`model::TrainedModel`]
//!   extraction from a solved dual point, the versioned `.pallas-model`
//!   binary format (save/load round-trips bit-identically, corrupt files
//!   are rejected with typed errors), and the sharded batch prediction
//!   engine — the layer that closes train → screen → solve → persist →
//!   predict.
//! * [`coordinator`] — a multi-threaded job coordinator and screening
//!   service: the L3 entry point that examples and the CLI drive.
//! * [`obs`] — observability: request-scoped span tracing (Chrome
//!   trace-event export via `--trace-out`) and the Prometheus `/metrics`
//!   exposition behind `dvi serve --metrics-listen`.
//! * [`data`], [`linalg`], [`config`], [`report`], [`validation`],
//!   [`metrics`], [`testutil`] — substrates (dataset generators and IO,
//!   storage-polymorphic dense/CSR kernels, config parsing, table/figure
//!   emitters, safety validation, metrics, property-test helpers).
//!
//! ## Quickstart
//!
//! ```no_run
//! use dvi_screen::data::synth;
//! use dvi_screen::path::{PathConfig, PathRunner};
//! use dvi_screen::problem::Model;
//! use dvi_screen::screening::RuleKind;
//!
//! let ds = synth::toy_gaussian(1, 1000, 1.5, 0.75); // Toy1
//! let cfg = PathConfig::log_grid(1e-2, 10.0, 100);
//! let mut runner = PathRunner::new(Model::Svm, cfg, RuleKind::DviW);
//! let out = runner.run(&ds);
//! println!("mean rejection {:.1}%", 100.0 * out.mean_rejection());
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod path;
pub mod problem;
pub mod report;
pub mod runtime;
pub mod screening;
pub mod serve;
pub mod solver;
pub mod testutil;
pub mod validation;

/// Library version (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
