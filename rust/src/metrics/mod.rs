//! Lightweight metrics: counters, gauges, and duration histograms with
//! percentile queries. Used by the coordinator and the bench harness.
//! Thread-safe via atomics / mutex-guarded histogram buffers.
//!
//! Two histogram shapes:
//!
//! * [`Histogram`] — exact storage, right for low-frequency series
//!   (thousands of path steps). Snapshots pay one sort per histogram,
//!   never per statistic, and sort with [`f64::total_cmp`] so a NaN
//!   sample can never panic a scrape.
//! * [`BoundedHistogram`] — fixed log-spaced buckets with lock-free
//!   recording, for high-frequency serve-path latencies where an exact
//!   sample `Vec` would grow without bound. Percentiles come from
//!   bucket upper bounds (≤ 19% relative error at 4 buckets/octave);
//!   count/sum/min/max stay exact.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (e.g. resident cache bytes).
#[derive(Default, Debug)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Nearest-rank percentile over an ascending-sorted slice; 0 for empty.
fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Duration histogram with exact storage (sample counts here are small —
/// thousands of path steps, not millions of RPCs).
#[derive(Default, Debug)]
pub struct Histogram {
    samples: Mutex<Vec<f64>>,
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        self.record_secs(d.as_secs_f64());
    }
    pub fn record_secs(&self, s: f64) {
        self.samples.lock().unwrap().push(s);
    }
    pub fn count(&self) -> usize {
        self.samples.lock().unwrap().len()
    }
    pub fn sum(&self) -> f64 {
        self.samples.lock().unwrap().iter().sum()
    }
    pub fn mean(&self) -> f64 {
        let g = self.samples.lock().unwrap();
        if g.is_empty() {
            0.0
        } else {
            g.iter().sum::<f64>() / g.len() as f64
        }
    }

    /// One sorted copy of the samples (total order — NaN sorts last
    /// instead of panicking the comparator).
    fn sorted(&self) -> Vec<f64> {
        let mut v = self.samples.lock().unwrap().clone();
        v.sort_by(f64::total_cmp);
        v
    }

    /// Percentile in [0, 100] by nearest-rank; 0 for empty.
    pub fn percentile(&self, p: f64) -> f64 {
        percentile_sorted(&self.sorted(), p)
    }
    pub fn min(&self) -> f64 {
        self.percentile(0.0)
    }
    pub fn max(&self) -> f64 {
        self.percentile(100.0)
    }

    /// Every summary statistic from ONE lock + ONE sort (the snapshot
    /// path used to re-clone + re-sort per percentile).
    pub fn summary(&self, name: &str) -> HistStat {
        let sorted = self.sorted();
        let count = sorted.len() as u64;
        let mean = if sorted.is_empty() { 0.0 } else { sorted.iter().sum::<f64>() / count as f64 };
        HistStat {
            name: name.to_string(),
            count,
            mean,
            p50: percentile_sorted(&sorted, 50.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: percentile_sorted(&sorted, 100.0),
        }
    }
}

/// Buckets per octave (factor-of-two range) in [`BoundedHistogram`].
const BH_PER_OCTAVE: f64 = 4.0;
/// Lowest bucket upper bound: 1µs (serve-path latencies are seconds).
const BH_LO: f64 = 1e-6;
/// Bucket count: 128 quarter-octave buckets span 1µs … ~4800s.
const BH_BUCKETS: usize = 128;

/// Fixed-memory log-bucket histogram: O(1) lock-free recording at any
/// sample rate. Counts land in quarter-octave buckets; sum/min/max are
/// tracked exactly via CAS, so `mean()` is exact and percentiles are
/// bucket-bound approximations.
#[derive(Debug)]
pub struct BoundedHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// f64 bit patterns CAS-updated (Mutex-free float accumulators).
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for BoundedHistogram {
    fn default() -> Self {
        BoundedHistogram {
            buckets: (0..BH_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }
}

impl BoundedHistogram {
    fn bucket_of(v: f64) -> usize {
        if !(v > BH_LO) {
            // NaN, negatives, zero, and sub-µs all land in bucket 0
            return 0;
        }
        let idx = ((v / BH_LO).log2() * BH_PER_OCTAVE).floor() as i64 + 1;
        idx.clamp(0, BH_BUCKETS as i64 - 1) as usize
    }

    /// Upper bound of bucket `i` — the value percentiles report.
    fn bucket_bound(i: usize) -> f64 {
        BH_LO * 2f64.powf((i + 1) as f64 / BH_PER_OCTAVE)
    }

    pub fn record(&self, d: Duration) {
        self.record_secs(d.as_secs_f64());
    }

    pub fn record_secs(&self, s: f64) {
        self.buckets[Self::bucket_of(s)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        if s.is_finite() {
            cas_f64(&self.sum_bits, |cur| cur + s);
            cas_f64(&self.min_bits, |cur| cur.min(s));
            cas_f64(&self.max_bits, |cur| cur.max(s));
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }
    pub fn min(&self) -> f64 {
        let v = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        if v.is_finite() {
            v
        } else {
            0.0
        }
    }
    pub fn max(&self) -> f64 {
        let v = f64::from_bits(self.max_bits.load(Ordering::Relaxed));
        if v.is_finite() {
            v
        } else {
            0.0
        }
    }

    /// Nearest-rank percentile over the bucket counts, reported as the
    /// containing bucket's upper bound (clamped to the exact max).
    pub fn percentile(&self, p: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * (total as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Self::bucket_bound(i).min(self.max());
            }
        }
        self.max()
    }

    pub fn summary(&self, name: &str) -> HistStat {
        HistStat {
            name: name.to_string(),
            count: self.count(),
            mean: self.mean(),
            p50: self.percentile(50.0),
            p99: self.percentile(99.0),
            max: self.max(),
        }
    }
}

/// CAS-update an f64 stored as bits in an `AtomicU64`.
fn cas_f64(bits: &AtomicU64, f: impl Fn(f64) -> f64) {
    let mut cur = bits.load(Ordering::Relaxed);
    loop {
        let next = f(f64::from_bits(cur)).to_bits();
        match bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Scoped timer: records elapsed time into a histogram on drop.
pub struct Timer<'a> {
    hist: &'a Histogram,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(hist: &'a Histogram) -> Self {
        Timer { hist, start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed());
    }
}

/// Point-in-time summary statistics for one named histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistStat {
    pub name: String,
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

/// A registry of named metrics, renderable as a text report.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
    bounded: Mutex<BTreeMap<String, std::sync::Arc<BoundedHistogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// A log-bucket histogram for high-frequency series (serve-path
    /// request latencies). Namespaced with the exact histograms in
    /// snapshots and renders, distinct in storage.
    pub fn bounded_histogram(&self, name: &str) -> std::sync::Arc<BoundedHistogram> {
        self.bounded
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Sorted `(name, value)` snapshot of every counter.
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(name, c)| (name.clone(), c.get()))
            .collect()
    }

    /// Sorted `(name, value)` snapshot of every gauge.
    pub fn gauges_snapshot(&self) -> Vec<(String, u64)> {
        self.gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(name, g)| (name.clone(), g.get()))
            .collect()
    }

    /// Sorted summary-statistics snapshot of every histogram (exact and
    /// bounded), one sort per exact histogram.
    pub fn histograms_snapshot(&self) -> Vec<HistStat> {
        let mut stats: Vec<HistStat> = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(name, h)| h.summary(name))
            .collect();
        stats.extend(self.bounded.lock().unwrap().iter().map(|(name, h)| h.summary(name)));
        stats.sort_by(|a, b| a.name.cmp(&b.name));
        stats
    }

    /// Human-readable dump (sorted by name).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {}\n", g.get()));
        }
        let mut hists = self.histograms_snapshot();
        hists.sort_by(|a, b| a.name.cmp(&b.name));
        for h in hists {
            out.push_str(&format!(
                "{}: n={} mean={:.6}s p50={:.6}s p99={:.6}s max={:.6}s\n",
                h.name, h.count, h.mean, h.p50, h.p99, h.max
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.record_secs(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 100.0);
        let p50 = h.percentile(50.0);
        assert!((49.0..=52.0).contains(&p50));
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
    }

    #[test]
    fn nan_sample_never_panics_a_snapshot() {
        let h = Histogram::default();
        h.record_secs(1.0);
        h.record_secs(f64::NAN);
        h.record_secs(2.0);
        // total_cmp sorts the NaN last; finite statistics stay sensible
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.count(), 3);
        let s = h.summary("lat");
        assert_eq!(s.count, 3);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_matches_individual_statistics() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.record_secs(i as f64);
        }
        let s = h.summary("x");
        assert_eq!(s.count, 100);
        assert_eq!(s.mean, h.mean());
        assert_eq!(s.p50, h.percentile(50.0));
        assert_eq!(s.p99, h.percentile(99.0));
        assert_eq!(s.max, h.max());
    }

    #[test]
    fn timer_records() {
        let h = Histogram::default();
        {
            let _t = Timer::start(&h);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(h.count(), 1);
        assert!(h.sum() >= 0.001);
    }

    #[test]
    fn bounded_histogram_bounds_and_exact_moments() {
        let h = BoundedHistogram::default();
        for i in 1..=1000 {
            h.record_secs(i as f64 * 1e-3); // 1ms … 1s
        }
        assert_eq!(h.count(), 1000);
        assert!((h.sum() - 500.5).abs() < 1e-9, "sum is exact: {}", h.sum());
        assert!((h.mean() - 0.5005).abs() < 1e-12);
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 1.0);
        // quarter-octave buckets: percentile within 19% of the true value
        let p50 = h.percentile(50.0);
        assert!((0.5..=0.6).contains(&p50), "p50 {p50}");
        assert_eq!(h.percentile(100.0), 1.0, "top percentile clamps to the exact max");
    }

    #[test]
    fn bounded_histogram_handles_degenerate_samples() {
        let h = BoundedHistogram::default();
        h.record_secs(0.0);
        h.record_secs(-1.0);
        h.record_secs(f64::NAN);
        h.record_secs(1e12); // beyond the top bucket
        assert_eq!(h.count(), 4);
        assert_eq!(h.max(), 1e12);
        let s = h.summary("edge");
        assert_eq!(s.count, 4);
        assert!(s.p99.is_finite());
    }

    #[test]
    fn bounded_histogram_is_fixed_memory() {
        let h = BoundedHistogram::default();
        for _ in 0..100_000 {
            h.record_secs(0.001);
        }
        assert_eq!(h.count(), 100_000);
        assert_eq!(h.buckets.len(), BH_BUCKETS);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("jobs").inc();
        r.counter("jobs").inc();
        assert_eq!(r.counter("jobs").get(), 2);
        r.histogram("lat").record_secs(0.5);
        let s = r.render();
        assert!(s.contains("jobs = 2"));
        assert!(s.contains("lat: n=1"));
    }

    #[test]
    fn snapshots_are_sorted_and_complete() {
        let r = Registry::default();
        r.counter("b_count").add(3);
        r.counter("a_count").add(1);
        r.gauge("depth").set(7);
        r.histogram("lat").record_secs(0.25);
        assert_eq!(
            r.counters_snapshot(),
            vec![("a_count".to_string(), 1), ("b_count".to_string(), 3)]
        );
        assert_eq!(r.gauges_snapshot(), vec![("depth".to_string(), 7)]);
        let hists = r.histograms_snapshot();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].name, "lat");
        assert_eq!(hists[0].count, 1);
        assert!((hists[0].mean - 0.25).abs() < 1e-12);
        assert!((hists[0].max - 0.25).abs() < 1e-12);
    }

    #[test]
    fn bounded_histograms_join_snapshots_sorted() {
        let r = Registry::default();
        r.histogram("z_exact").record_secs(0.25);
        r.bounded_histogram("a_request_secs").record_secs(0.125);
        let hists = r.histograms_snapshot();
        let names: Vec<&str> = hists.iter().map(|h| h.name.as_str()).collect();
        assert_eq!(names, vec!["a_request_secs", "z_exact"]);
        assert_eq!(hists[0].count, 1);
        assert!(r.render().contains("a_request_secs: n=1"));
    }

    #[test]
    fn gauge_last_write_wins() {
        let r = Registry::default();
        r.gauge("bytes").set(100);
        r.gauge("bytes").set(42);
        assert_eq!(r.gauge("bytes").get(), 42);
        assert!(r.render().contains("bytes = 42"));
    }
}
