//! Lightweight metrics: counters, gauges, and duration histograms with
//! percentile queries. Used by the coordinator and the bench harness.
//! Thread-safe via atomics / mutex-guarded histogram buffers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (e.g. resident cache bytes).
#[derive(Default, Debug)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Duration histogram with exact storage (sample counts here are small —
/// thousands of path steps, not millions of RPCs).
#[derive(Default, Debug)]
pub struct Histogram {
    samples: Mutex<Vec<f64>>,
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        self.record_secs(d.as_secs_f64());
    }
    pub fn record_secs(&self, s: f64) {
        self.samples.lock().unwrap().push(s);
    }
    pub fn count(&self) -> usize {
        self.samples.lock().unwrap().len()
    }
    pub fn sum(&self) -> f64 {
        self.samples.lock().unwrap().iter().sum()
    }
    pub fn mean(&self) -> f64 {
        let g = self.samples.lock().unwrap();
        if g.is_empty() {
            0.0
        } else {
            g.iter().sum::<f64>() / g.len() as f64
        }
    }
    /// Percentile in [0, 100] by nearest-rank; 0 for empty.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut v = self.samples.lock().unwrap().clone();
        if v.is_empty() {
            return 0.0;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[rank.min(v.len() - 1)]
    }
    pub fn min(&self) -> f64 {
        self.percentile(0.0)
    }
    pub fn max(&self) -> f64 {
        self.percentile(100.0)
    }
}

/// Scoped timer: records elapsed time into a histogram on drop.
pub struct Timer<'a> {
    hist: &'a Histogram,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(hist: &'a Histogram) -> Self {
        Timer { hist, start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed());
    }
}

/// Point-in-time summary statistics for one named histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistStat {
    pub name: String,
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

/// A registry of named metrics, renderable as a text report.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Sorted `(name, value)` snapshot of every counter.
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(name, c)| (name.clone(), c.get()))
            .collect()
    }

    /// Sorted `(name, value)` snapshot of every gauge.
    pub fn gauges_snapshot(&self) -> Vec<(String, u64)> {
        self.gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(name, g)| (name.clone(), g.get()))
            .collect()
    }

    /// Sorted summary-statistics snapshot of every histogram.
    pub fn histograms_snapshot(&self) -> Vec<HistStat> {
        self.histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(name, h)| HistStat {
                name: name.clone(),
                count: h.count() as u64,
                mean: h.mean(),
                p50: h.percentile(50.0),
                p99: h.percentile(99.0),
                max: h.max(),
            })
            .collect()
    }

    /// Human-readable dump (sorted by name).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={:.6}s p50={:.6}s p99={:.6}s max={:.6}s\n",
                h.count(),
                h.mean(),
                h.percentile(50.0),
                h.percentile(99.0),
                h.max()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.record_secs(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 100.0);
        let p50 = h.percentile(50.0);
        assert!((49.0..=52.0).contains(&p50));
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
    }

    #[test]
    fn timer_records() {
        let h = Histogram::default();
        {
            let _t = Timer::start(&h);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(h.count(), 1);
        assert!(h.sum() >= 0.001);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("jobs").inc();
        r.counter("jobs").inc();
        assert_eq!(r.counter("jobs").get(), 2);
        r.histogram("lat").record_secs(0.5);
        let s = r.render();
        assert!(s.contains("jobs = 2"));
        assert!(s.contains("lat: n=1"));
    }

    #[test]
    fn snapshots_are_sorted_and_complete() {
        let r = Registry::default();
        r.counter("b_count").add(3);
        r.counter("a_count").add(1);
        r.gauge("depth").set(7);
        r.histogram("lat").record_secs(0.25);
        assert_eq!(
            r.counters_snapshot(),
            vec![("a_count".to_string(), 1), ("b_count".to_string(), 3)]
        );
        assert_eq!(r.gauges_snapshot(), vec![("depth".to_string(), 7)]);
        let hists = r.histograms_snapshot();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].name, "lat");
        assert_eq!(hists[0].count, 1);
        assert!((hists[0].mean - 0.25).abs() < 1e-12);
        assert!((hists[0].max - 0.25).abs() < 1e-12);
    }

    #[test]
    fn gauge_last_write_wins() {
        let r = Registry::default();
        r.gauge("bytes").set(100);
        r.gauge("bytes").set(42);
        assert_eq!(r.gauge("bytes").get(), 42);
        assert!(r.render().contains("bytes = 42"));
    }
}
