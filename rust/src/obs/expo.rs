//! Prometheus text-format exposition + the `/metrics` HTTP listener.
//!
//! [`render_exposition`] turns the serving stack's metric sources into
//! one Prometheus text-format (0.0.4) document:
//!
//! * every family of a service [`Registry`] — counters, gauges, and
//!   histogram summaries (as `summary` with `quantile` labels, `_sum`,
//!   `_count`);
//! * solver-pool activity from [`crate::linalg::par::pool_stats`]
//!   (spawn/dispatch counters) and [`crate::linalg::par::pool_busy`]
//!   (`pool_queue_depth` gauge, per-worker busy seconds);
//! * the cumulative per-rule screening telemetry
//!   ([`super::telemetry::registry`]).
//!
//! Metric names may embed labels Prometheus-style
//! (`screen_rows_scanned_total{rule="dvi"}`); the renderer emits one
//! `# TYPE` line per base name (the part before `{`), so labelled
//! series group under a single family.
//!
//! [`serve_metrics`] binds a TCP listener (the CLI's
//! `--metrics-listen HOST:PORT`) and answers each connection with a
//! single HTTP response: `GET /metrics` → 200 + the rendered document,
//! anything else → 404. One-shot (`Connection: close`), matching how
//! Prometheus scrapes and keeping the responder tiny.

use crate::metrics::Registry;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

/// Append `# TYPE` for `name`'s base family unless already emitted.
fn type_line(out: &mut String, last_base: &mut String, name: &str, kind: &str) {
    let base = name.split('{').next().unwrap_or(name);
    if base != last_base {
        out.push_str(&format!("# TYPE {base} {kind}\n"));
        *last_base = base.to_string();
    }
}

/// A float in Prometheus text syntax (`NaN` / `+Inf` / `-Inf` spelled
/// the way the format requires).
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Render one registry's families (counters → gauges → histograms, each
/// alphabetical — the snapshot order).
pub fn render_registry(reg: &Registry) -> String {
    let mut out = String::new();
    let mut last = String::new();
    for (name, v) in reg.counters_snapshot() {
        type_line(&mut out, &mut last, &name, "counter");
        out.push_str(&format!("{name} {v}\n"));
    }
    for (name, v) in reg.gauges_snapshot() {
        type_line(&mut out, &mut last, &name, "gauge");
        out.push_str(&format!("{name} {v}\n"));
    }
    for h in reg.histograms_snapshot() {
        type_line(&mut out, &mut last, &h.name, "summary");
        out.push_str(&format!("{}{{quantile=\"0.5\"}} {}\n", h.name, fmt_f64(h.p50)));
        out.push_str(&format!("{}{{quantile=\"0.99\"}} {}\n", h.name, fmt_f64(h.p99)));
        out.push_str(&format!("{}_sum {}\n", h.name, fmt_f64(h.mean * h.count as f64)));
        out.push_str(&format!("{}_count {}\n", h.name, h.count));
    }
    out
}

/// The full `/metrics` document: the service registry (when serving has
/// one), solver-pool counters/gauges, and screening telemetry.
pub fn render_exposition(service: Option<&Registry>) -> String {
    let mut out = String::new();
    if let Some(reg) = service {
        out.push_str(&render_registry(reg));
    }

    let stats = crate::linalg::par::pool_stats();
    out.push_str("# TYPE pool_workers_spawned_total counter\n");
    out.push_str(&format!("pool_workers_spawned_total {}\n", stats.workers_spawned));
    out.push_str("# TYPE pool_jobs_dispatched_total counter\n");
    out.push_str(&format!("pool_jobs_dispatched_total {}\n", stats.jobs_dispatched));
    out.push_str("# TYPE pool_scoped_spawns_total counter\n");
    out.push_str(&format!("pool_scoped_spawns_total {}\n", stats.scoped_spawns));

    let busy = crate::linalg::par::pool_busy();
    out.push_str("# TYPE pool_queue_depth gauge\n");
    out.push_str(&format!("pool_queue_depth {}\n", busy.queue_depth));
    out.push_str("# TYPE pool_worker_busy_seconds counter\n");
    for (k, nanos) in busy.busy_nanos.iter().enumerate() {
        out.push_str(&format!(
            "pool_worker_busy_seconds{{worker=\"{k}\"}} {}\n",
            fmt_f64(*nanos as f64 * 1e-9)
        ));
    }

    out.push_str(&render_registry(super::telemetry::registry()));
    out
}

/// Answer one accepted connection: read the request head, route, write a
/// single response, close.
fn answer(mut stream: TcpStream, render: &(dyn Fn() -> String + Send + Sync)) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    // read until end-of-headers (we ignore any body; /metrics is GET)
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < 8192 {
        match stream.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
        }
    }
    let request_line = std::str::from_utf8(&head)
        .unwrap_or("")
        .lines()
        .next()
        .unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let response = if method == "GET" && (path == "/metrics" || path.starts_with("/metrics?")) {
        let body = render();
        format!(
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
    } else {
        let body = "not found; scrape GET /metrics\n";
        format!(
            "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
    };
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Bind `addr` and serve `GET /metrics` forever on a background thread,
/// rendering each scrape with `render`. Returns the bound address (so
/// `HOST:0` callers learn the ephemeral port). The render closure keeps
/// this module free of any coordinator dependency — the CLI decides
/// which registries a scrape sees.
pub fn serve_metrics(
    addr: &str,
    render: Arc<dyn Fn() -> String + Send + Sync>,
) -> std::io::Result<SocketAddr> {
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidInput, "unresolvable metrics address"))?;
    let listener = TcpListener::bind(sock)?;
    let local = listener.local_addr()?;
    std::thread::Builder::new()
        .name("dvi-metrics".into())
        .spawn(move || {
            for stream in listener.incoming().flatten() {
                answer(stream, render.as_ref());
            }
        })
        .expect("spawn metrics listener thread");
    Ok(local)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_renders_every_family_with_type_lines() {
        let reg = Registry::default();
        reg.counter("jobs_total").add(3);
        reg.gauge("cache_bytes").set(640);
        reg.histogram("solve_secs").record_secs(0.5);
        reg.histogram("solve_secs").record_secs(1.5);
        reg.bounded_histogram("request_secs").record_secs(0.01);
        let s = render_registry(&reg);
        assert!(s.contains("# TYPE jobs_total counter\njobs_total 3\n"));
        assert!(s.contains("# TYPE cache_bytes gauge\ncache_bytes 640\n"));
        assert!(s.contains("# TYPE solve_secs summary\n"));
        assert!(s.contains("solve_secs{quantile=\"0.5\"}"));
        assert!(s.contains("solve_secs{quantile=\"0.99\"}"));
        assert!(s.contains("solve_secs_sum 2\n"));
        assert!(s.contains("solve_secs_count 2\n"));
        assert!(s.contains("# TYPE request_secs summary\n"));
        assert!(s.contains("request_secs_count 1\n"));
    }

    #[test]
    fn labelled_series_share_one_type_line() {
        let reg = Registry::default();
        reg.counter("rows_total{rule=\"a\"}").add(1);
        reg.counter("rows_total{rule=\"b\"}").add(2);
        let s = render_registry(&reg);
        assert_eq!(s.matches("# TYPE rows_total counter").count(), 1);
        assert!(s.contains("rows_total{rule=\"a\"} 1\n"));
        assert!(s.contains("rows_total{rule=\"b\"} 2\n"));
    }

    #[test]
    fn exposition_always_includes_pool_families() {
        let s = render_exposition(None);
        assert!(s.contains("# TYPE pool_workers_spawned_total counter"));
        assert!(s.contains("# TYPE pool_jobs_dispatched_total counter"));
        assert!(s.contains("# TYPE pool_scoped_spawns_total counter"));
        assert!(s.contains("# TYPE pool_queue_depth gauge"));
        assert!(s.contains("# TYPE pool_worker_busy_seconds counter"));
    }

    #[test]
    fn metrics_endpoint_scrapes_and_404s() {
        let addr = serve_metrics(
            "127.0.0.1:0",
            Arc::new(|| "# TYPE up gauge\nup 1\n".to_string()),
        )
        .expect("bind metrics listener");

        let scrape = |req: &str| {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(req.as_bytes()).unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        };

        let ok = scrape("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"));
        assert!(ok.ends_with("up 1\n"), "{ok}");

        let missing = scrape("GET /other HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404 Not Found\r\n"), "{missing}");

        // listener survives to answer another scrape
        let again = scrape("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(again.starts_with("HTTP/1.1 200 OK\r\n"));
    }
}
