//! Observability: request-scoped span tracing + scrapeable metrics
//! exposition for the serving stack.
//!
//! Two independent surfaces share this module:
//!
//! * **Span tracing** — explicit begin/end spans with parent ids pushed
//!   into a process-wide lock-free [`ring::EventRing`], exported as
//!   Chrome trace-event JSON ([`chrome`]) via `--trace-out FILE` on
//!   `dvi serve` / `dvi path` / `dvi train`, flushed on exit and on
//!   SIGTERM ([`install_sigterm_flush`]). Spans cover the whole request
//!   lifecycle: connection → parse/admission → pool dispatch (queue
//!   wait) → job body → per-step screening → per-iteration CD sweeps.
//! * **Metrics exposition** — `GET /metrics` in Prometheus text format
//!   ([`expo`]) behind `dvi serve --metrics-listen HOST:PORT`, rendering
//!   every [`crate::metrics::Registry`] family plus solver-pool gauges
//!   and the cumulative per-rule screening telemetry ([`telemetry`]).
//!
//! The determinism contract: observability NEVER writes to the protocol
//! stream. A `"timings": false` session produces byte-identical
//! responses with tracing on or off; everything here goes to the sidecar
//! trace file or the scrape endpoint. The disabled path is one relaxed
//! atomic load per potential span — no allocation, no time syscalls.
//!
//! Span ids: guard spans ([`Span`]) draw from a process counter and
//! parent onto the per-thread current span. Requests cross threads
//! (submitted on a connection reader, finished on a pool worker, retired
//! on the dispatcher), so their span ids are *derived from the pool job
//! id* ([`request_span_id`]/[`queue_span_id`]) — any thread can emit the
//! matching begin or end without coordination.

pub mod chrome;
pub mod expo;
pub mod ring;
#[cfg(unix)]
mod signal;
pub mod telemetry;

pub use ring::{EventRing, RawEvent, MAX_ATTRS};

use std::cell::Cell;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Ring capacity (events). Power of two; the ring keeps the newest
/// window when a long run overflows it.
const RING_CAP: usize = 1 << 16;

/// High bit marks span ids derived from pool job ids (cross-thread
/// request/queue spans) so they can never collide with the sequential
/// guard-span counter.
const DERIVED_BIT: u64 = 1 << 63;

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING: OnceLock<EventRing> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();
static TRACE_OUT: Mutex<Option<PathBuf>> = Mutex::new(None);
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Trace-local thread id (dense small integers; 0 = unassigned).
    static TID: Cell<u64> = const { Cell::new(0) };
    /// The innermost open guard span on this thread (0 = root).
    static CURRENT: Cell<u64> = const { Cell::new(0) };
}

/// Is tracing on? One relaxed load — THE disabled-path cost.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on (idempotent). Allocates the ring and pins the trace
/// epoch on first call.
pub fn enable() {
    RING.get_or_init(|| EventRing::new(RING_CAP));
    EPOCH.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Enable tracing and set the Chrome trace-event JSON flush target
/// (the CLI's `--trace-out FILE`).
pub fn set_trace_out(path: PathBuf) {
    enable();
    *TRACE_OUT.lock().unwrap() = Some(path);
}

/// The configured flush target, if any.
pub fn trace_out() -> Option<PathBuf> {
    TRACE_OUT.lock().unwrap().clone()
}

/// Snapshot every currently-published event (empty when tracing never
/// started).
pub fn snapshot_events() -> Vec<RawEvent> {
    RING.get().map(EventRing::snapshot).unwrap_or_default()
}

/// Write the Chrome trace to the configured `--trace-out` path. Returns
/// the path written, or `None` when no target is configured. Safe to
/// call repeatedly (exit AND signal paths both flush).
pub fn flush() -> std::io::Result<Option<PathBuf>> {
    let Some(path) = trace_out() else { return Ok(None) };
    let json = chrome::render(&snapshot_events());
    std::fs::write(&path, json)?;
    Ok(Some(path))
}

/// Install a SIGTERM handler that flushes the trace and exits 0 (the
/// rolling-restart path for a network server, which otherwise never
/// reaches the end-of-main flush). No-op on non-unix platforms and on
/// repeat calls.
pub fn install_sigterm_flush() {
    #[cfg(unix)]
    signal::install();
}

/// Register a hook the SIGTERM watcher runs *before* the trace flush and
/// exit — the serve layer's graceful drain (stop admitting, wait for
/// in-flight jobs). No-op on non-unix platforms; replaces any previously
/// registered hook.
pub fn set_sigterm_preflush(hook: Box<dyn FnOnce() + Send>) {
    #[cfg(unix)]
    signal::set_preflush_hook(hook);
    #[cfg(not(unix))]
    drop(hook);
}

fn now_ns() -> u64 {
    EPOCH.get().map(|e| e.elapsed().as_nanos() as u64).unwrap_or(0)
}

fn tid() -> u64 {
    TID.with(|t| {
        if t.get() == 0 {
            t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
        }
        t.get()
    })
}

fn push(ev: RawEvent) {
    if let Some(ring) = RING.get() {
        ring.push(ev);
    }
}

/// Span id for the whole request lifetime of pool job `pool_id`
/// (begin at admission/submit, end at outcome dispatch).
pub fn request_span_id(pool_id: u64) -> u64 {
    DERIVED_BIT | (pool_id << 1)
}

/// Span id for pool job `pool_id`'s queue wait (begin at submit, end at
/// worker pickup).
pub fn queue_span_id(pool_id: u64) -> u64 {
    DERIVED_BIT | (pool_id << 1) | 1
}

/// The innermost open guard span on this thread (0 = root). Lets
/// cross-thread begins parent onto the emitting thread's context.
pub fn current_span() -> u64 {
    if !enabled() {
        return 0;
    }
    CURRENT.with(|c| c.get())
}

/// Emit a bare span begin with an explicit id (cross-thread spans; the
/// matching [`event_end`] may come from any thread).
pub fn event_begin(name: &'static str, span_id: u64, parent_id: u64) {
    if !enabled() {
        return;
    }
    push(RawEvent {
        ts_ns: now_ns(),
        span_id,
        parent_id,
        tid: tid(),
        begin: true,
        name,
        ..RawEvent::EMPTY
    });
}

/// Emit a bare span end with an explicit id. `str_attr`/`attrs` ride the
/// end event (they are only known once the work finishes).
pub fn event_end(name: &'static str, span_id: u64) {
    if !enabled() {
        return;
    }
    push(RawEvent { ts_ns: now_ns(), span_id, tid: tid(), begin: false, name, ..RawEvent::EMPTY });
}

/// Intern a dynamic string (e.g. a composed rule name) so events stay
/// `Copy`. Deduplicated; the tiny vocabulary (rule expressions, dataset
/// names) bounds the leak.
pub fn intern(s: &str) -> &'static str {
    static INTERNED: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());
    let mut set = INTERNED.lock().unwrap();
    if let Some(hit) = set.iter().find(|k| **k == s) {
        return hit;
    }
    let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
    set.push(leaked);
    leaked
}

/// A guard span: begin on construction, end on drop, parented onto the
/// thread's innermost open span. Inert (no ids drawn, no events, no
/// clock reads) while tracing is disabled.
pub struct Span {
    id: u64,
    prev: u64,
    name: &'static str,
    str_attr: Option<(&'static str, &'static str)>,
    attrs: [(&'static str, f64); MAX_ATTRS],
    n_attrs: u8,
    active: bool,
}

impl Span {
    const INERT: Span = Span {
        id: 0,
        prev: 0,
        name: "",
        str_attr: None,
        attrs: [("", 0.0); MAX_ATTRS],
        n_attrs: 0,
        active: false,
    };

    /// Open a span under the thread's current span.
    #[inline]
    pub fn enter(name: &'static str) -> Span {
        if !enabled() {
            return Span::INERT;
        }
        Self::open(name, None)
    }

    /// Open a span under an explicit parent (e.g. a job body parenting
    /// onto its cross-thread request span).
    #[inline]
    pub fn enter_under(name: &'static str, parent: u64) -> Span {
        if !enabled() {
            return Span::INERT;
        }
        Self::open(name, Some(parent))
    }

    fn open(name: &'static str, parent: Option<u64>) -> Span {
        let id = NEXT_SPAN.fetch_add(1, Ordering::Relaxed);
        let prev = CURRENT.with(|c| {
            let p = c.get();
            c.set(id);
            p
        });
        let parent_id = parent.unwrap_or(prev);
        push(RawEvent {
            ts_ns: now_ns(),
            span_id: id,
            parent_id,
            tid: tid(),
            begin: true,
            name,
            ..RawEvent::EMPTY
        });
        Span { id, prev, name, str_attr: None, attrs: [("", 0.0); MAX_ATTRS], n_attrs: 0, active: true }
    }

    /// Attach a numeric attribute (emitted with the end event). Silently
    /// dropped past [`MAX_ATTRS`] or on an inert span.
    #[inline]
    pub fn attr(&mut self, key: &'static str, value: f64) {
        if self.active && (self.n_attrs as usize) < MAX_ATTRS {
            self.attrs[self.n_attrs as usize] = (key, value);
            self.n_attrs += 1;
        }
    }

    /// Attach the span's one string attribute (emitted with the end
    /// event).
    #[inline]
    pub fn attr_str(&mut self, key: &'static str, value: &'static str) {
        if self.active {
            self.str_attr = Some((key, value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        CURRENT.with(|c| c.set(self.prev));
        push(RawEvent {
            ts_ns: now_ns(),
            span_id: self.id,
            parent_id: 0,
            tid: tid(),
            begin: false,
            name: self.name,
            str_attr: self.str_attr,
            attrs: self.attrs,
            n_attrs: self.n_attrs,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_inert() {
        // tracing may have been enabled by a sibling test in this
        // process; only assert the inert contract when it is off
        if !enabled() {
            let before = RING.get().map(EventRing::pushed).unwrap_or(0);
            let mut sp = Span::enter("never");
            sp.attr("x", 1.0);
            drop(sp);
            assert_eq!(RING.get().map(EventRing::pushed).unwrap_or(0), before);
            assert_eq!(current_span(), 0);
        }
    }

    #[test]
    fn interning_dedups() {
        let a = intern("dvi+essnsv");
        let b = intern("dvi+essnsv");
        assert!(std::ptr::eq(a, b));
        assert_eq!(a, "dvi+essnsv");
    }

    #[test]
    fn derived_ids_never_collide_with_guard_ids() {
        assert_ne!(request_span_id(0), queue_span_id(0));
        assert_ne!(request_span_id(5), queue_span_id(5));
        // guard ids are sequential from 1 without the high bit
        assert_eq!(request_span_id(7) & DERIVED_BIT, DERIVED_BIT);
        assert_eq!(NEXT_SPAN.load(Ordering::Relaxed) & DERIVED_BIT, 0);
    }
}
