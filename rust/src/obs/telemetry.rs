//! Cumulative screening telemetry, independent of span tracing.
//!
//! Every screening invocation — engine path or the plain-DVI fast path —
//! records how many rows it scanned and how many it rejected, keyed by
//! rule name. The counters live in a process-wide
//! [`crate::metrics::Registry`] with the rule name embedded Prometheus
//! style (`screen_rows_scanned_total{rule="dvi"}`), so the `/metrics`
//! exposition renders them without a separate label mechanism and the
//! cost is two relaxed atomic adds per screen call.

use crate::metrics::Registry;
use std::sync::OnceLock;

static TELEMETRY: OnceLock<Registry> = OnceLock::new();

/// The process-wide telemetry registry (rule-labelled screening
/// counters). Distinct from any per-service registry: screening runs in
/// CLI paths that have no coordinator.
pub fn registry() -> &'static Registry {
    TELEMETRY.get_or_init(Registry::default)
}

/// Record one screening pass for `rule`: `scanned` rows examined,
/// `rejected` of them eliminated. Always on — this is the live-traffic
/// counterpart of the offline `BENCH_screening.json` rates.
pub fn record_screen(rule: &str, scanned: u64, rejected: u64) {
    let reg = registry();
    reg.counter(&format!("screen_rows_scanned_total{{rule=\"{rule}\"}}")).add(scanned);
    reg.counter(&format!("screen_rows_rejected_total{{rule=\"{rule}\"}}")).add(rejected);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn screen_counters_accumulate_per_rule() {
        record_screen("test_rule_a", 100, 40);
        record_screen("test_rule_a", 100, 10);
        record_screen("test_rule_b", 7, 7);
        let snap = registry().counters_snapshot();
        let get = |name: &str| snap.iter().find(|(n, _)| n == name).map(|&(_, v)| v);
        assert_eq!(get("screen_rows_scanned_total{rule=\"test_rule_a\"}"), Some(200));
        assert_eq!(get("screen_rows_rejected_total{rule=\"test_rule_a\"}"), Some(50));
        assert_eq!(get("screen_rows_rejected_total{rule=\"test_rule_b\"}"), Some(7));
    }
}
