//! SIGTERM → trace flush, unix-only, std + raw libc FFI (no crates).
//!
//! A long-running `dvi serve` is normally stopped by SIGTERM (rolling
//! restarts, container runtimes), which would otherwise skip the
//! end-of-main trace flush. The handler itself must stay async-signal
//! safe, so it only writes one byte to a pre-opened self-pipe; a watcher
//! thread blocks on the read end and performs the actual flush + exit
//! from safe Rust.

use std::sync::atomic::{AtomicBool, AtomicI32, Ordering};
use std::sync::Mutex;

const SIGTERM: i32 = 15;

extern "C" {
    fn pipe(fds: *mut i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

static PIPE_WR: AtomicI32 = AtomicI32::new(-1);
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// Hook the watcher runs (from safe Rust, off the signal handler) before
/// flushing the trace and exiting — the serve layer installs its
/// graceful drain here. FnOnce: it runs at most once, on the single
/// SIGTERM that ends the process.
static PRE_FLUSH: Mutex<Option<Box<dyn FnOnce() + Send>>> = Mutex::new(None);

/// Register (or replace) the pre-flush hook.
pub fn set_preflush_hook(hook: Box<dyn FnOnce() + Send>) {
    *PRE_FLUSH.lock().unwrap() = Some(hook);
}

extern "C" fn on_sigterm(_sig: i32) {
    // async-signal-safe: one write(2) to the self-pipe, nothing else
    let fd = PIPE_WR.load(Ordering::Relaxed);
    if fd >= 0 {
        let b = 1u8;
        unsafe { write(fd, &b, 1) };
    }
}

/// Install the handler + watcher (idempotent).
pub fn install() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let mut fds = [-1i32; 2];
    if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
        return; // no pipe, no graceful flush — keep serving
    }
    let (rd, wr) = (fds[0], fds[1]);
    PIPE_WR.store(wr, Ordering::SeqCst);
    unsafe { signal(SIGTERM, on_sigterm) };
    std::thread::Builder::new()
        .name("dvi-obs-signal".into())
        .spawn(move || {
            let mut buf = 0u8;
            loop {
                let n = unsafe { read(rd, &mut buf, 1) };
                if n == 1 {
                    break;
                }
                if n == 0 {
                    return; // pipe closed without a signal
                }
                // n < 0: EINTR etc — retry
            }
            // the drain (or any other registered hook) runs first so
            // in-flight work lands in the trace before it is written
            if let Some(hook) = PRE_FLUSH.lock().unwrap().take() {
                hook();
            }
            if let Ok(Some(path)) = crate::obs::flush() {
                eprintln!("[obs] SIGTERM: trace flushed to {}", path.display());
            }
            std::process::exit(0);
        })
        .ok();
}
