//! SIGTERM → trace flush, unix-only, std + raw libc FFI (no crates).
//!
//! A long-running `dvi serve` is normally stopped by SIGTERM (rolling
//! restarts, container runtimes), which would otherwise skip the
//! end-of-main trace flush. The handler itself must stay async-signal
//! safe, so it only writes one byte to a pre-opened self-pipe; a watcher
//! thread blocks on the read end and performs the actual flush + exit
//! from safe Rust.

use std::sync::atomic::{AtomicBool, AtomicI32, Ordering};

const SIGTERM: i32 = 15;

extern "C" {
    fn pipe(fds: *mut i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

static PIPE_WR: AtomicI32 = AtomicI32::new(-1);
static INSTALLED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_sigterm(_sig: i32) {
    // async-signal-safe: one write(2) to the self-pipe, nothing else
    let fd = PIPE_WR.load(Ordering::Relaxed);
    if fd >= 0 {
        let b = 1u8;
        unsafe { write(fd, &b, 1) };
    }
}

/// Install the handler + watcher (idempotent).
pub fn install() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let mut fds = [-1i32; 2];
    if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
        return; // no pipe, no graceful flush — keep serving
    }
    let (rd, wr) = (fds[0], fds[1]);
    PIPE_WR.store(wr, Ordering::SeqCst);
    unsafe { signal(SIGTERM, on_sigterm) };
    std::thread::Builder::new()
        .name("dvi-obs-signal".into())
        .spawn(move || {
            let mut buf = 0u8;
            loop {
                let n = unsafe { read(rd, &mut buf, 1) };
                if n == 1 {
                    break;
                }
                if n == 0 {
                    return; // pipe closed without a signal
                }
                // n < 0: EINTR etc — retry
            }
            if let Ok(Some(path)) = crate::obs::flush() {
                eprintln!("[obs] SIGTERM: trace flushed to {}", path.display());
            }
            std::process::exit(0);
        })
        .ok();
}
