//! Chrome trace-event JSON export.
//!
//! Renders a [`RawEvent`] snapshot as the Trace Event Format that
//! `chrome://tracing` and Perfetto load directly:
//!
//! * spans whose begin and end landed on the same thread become duration
//!   events (`"ph": "B"` / `"ph": "E"`) on that `tid`;
//! * cross-thread spans (request lifetime, queue wait) become async
//!   events (`"ph": "b"` / `"ph": "e"`) matched by `"id"` — the format's
//!   own representation for work that migrates between threads;
//! * only *paired* spans are exported: a begin whose end was lost to
//!   ring wrap (or is still open at flush) would render as an unmatched
//!   event, so the exporter drops singletons — every end in the file has
//!   its begin, by construction.
//!
//! `ts` is microseconds from the trace epoch (the format's unit), events
//! are sorted by ascending `ts`, every event carries the span id in
//! `args.id`, begins carry `args.parent`, and ends carry the span's
//! recorded attributes. Span ids are hex *strings* (`"0x..."`): derived
//! ids set bit 63, which overflows the i64 integers most JSON parsers
//! (including [`crate::config::json`]) use for number literals.

use super::ring::RawEvent;
use std::collections::HashMap;
use std::fmt::Write as _;

/// Render a snapshot to a complete Chrome trace JSON document.
pub fn render(events: &[RawEvent]) -> String {
    // pair begins/ends by span id, keeping only complete spans
    let mut begins: HashMap<u64, &RawEvent> = HashMap::new();
    let mut ends: HashMap<u64, &RawEvent> = HashMap::new();
    for ev in events {
        if ev.begin {
            begins.insert(ev.span_id, ev);
        } else {
            ends.insert(ev.span_id, ev);
        }
    }

    // (ts_ns, phase_rank, span_id, event, phase); begins sort before
    // ends at equal timestamps so zero-length spans stay well-formed
    let mut out_events: Vec<(u64, u8, u64, &RawEvent, char)> = Vec::new();
    for (id, b) in &begins {
        let Some(e) = ends.get(id) else { continue };
        let (ph_b, ph_e) = if b.tid == e.tid { ('B', 'E') } else { ('b', 'e') };
        out_events.push((b.ts_ns, 0, *id, b, ph_b));
        out_events.push((e.ts_ns.max(b.ts_ns), 1, *id, e, ph_e));
    }
    out_events.sort_by_key(|&(ts, rank, id, _, _)| (ts, rank, id));

    let mut s = String::with_capacity(out_events.len() * 128 + 64);
    s.push_str("{\"displayTimeUnit\": \"ms\", \"traceEvents\": [");
    for (i, &(ts_ns, _, id, ev, ph)) in out_events.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push('{');
        let _ = write!(s, "\"name\": \"{}\", \"ph\": \"{ph}\", ", escape(ev.name));
        if ph == 'b' || ph == 'e' {
            // async events require a category and a matching id
            let _ = write!(s, "\"cat\": \"request\", \"id\": \"0x{id:x}\", ");
        }
        let _ = write!(s, "\"ts\": {}, \"pid\": 1, \"tid\": {}, \"args\": {{", us(ts_ns), ev.tid);
        let _ = write!(s, "\"id\": \"0x{id:x}\"");
        if ev.begin {
            let _ = write!(s, ", \"parent\": \"0x{:x}\"", ev.parent_id);
        }
        for k in 0..ev.n_attrs as usize {
            let (key, v) = ev.attrs[k];
            let _ = write!(s, ", \"{}\": {}", escape(key), num(v));
        }
        if let Some((key, v)) = ev.str_attr {
            let _ = write!(s, ", \"{}\": \"{}\"", escape(key), escape(v));
        }
        s.push_str("}}");
    }
    s.push_str("]}\n");
    s
}

/// Microseconds with nanosecond precision, fixed-point (never scientific
/// notation, always a valid JSON number).
fn us(ts_ns: u64) -> String {
    format!("{}.{:03}", ts_ns / 1000, ts_ns % 1000)
}

/// A finite f64 as a JSON number; non-finite values become null.
fn num(v: f64) -> String {
    if v.is_finite() {
        let s = format!("{v}");
        // `{}` on f64 never emits exponents, but an integral value
        // prints without a dot — fine for JSON either way
        s
    } else {
        "null".to_string()
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_json;
    use crate::obs::ring::RawEvent;

    fn ev(span_id: u64, parent: u64, tid: u64, ts: u64, begin: bool, name: &'static str) -> RawEvent {
        RawEvent { ts_ns: ts, span_id, parent_id: parent, tid, begin, name, ..RawEvent::EMPTY }
    }

    #[test]
    fn paired_spans_export_and_singletons_drop() {
        let mut open = ev(7, 0, 1, 50, true, "lost");
        open.n_attrs = 0;
        let events = vec![
            ev(1, 0, 1, 0, true, "outer"),
            ev(2, 1, 1, 10, true, "inner"),
            ev(2, 0, 1, 20, false, "inner"),
            ev(1, 0, 1, 30, false, "outer"),
            open, // no matching end — must not be exported
        ];
        let json = render(&events);
        let doc = parse_json(&json).expect("exporter emits valid JSON");
        let arr = doc.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 4);
        assert!(!json.contains("lost"));
        // sorted by ts, begins before ends, parents precede children
        let ts: Vec<f64> = arr.iter().map(|e| e.get("ts").unwrap().as_float().unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(arr[0].get("ph").unwrap().as_str(), Some("B"));
        assert_eq!(arr[0].get("args").unwrap().get("parent").unwrap().as_str(), Some("0x0"));
        assert_eq!(arr[1].get("args").unwrap().get("parent").unwrap().as_str(), Some("0x1"));
    }

    #[test]
    fn cross_thread_spans_become_async_pairs() {
        let events = vec![ev(9, 0, 1, 0, true, "request"), ev(9, 0, 3, 100, false, "request")];
        let json = render(&events);
        let doc = parse_json(&json).unwrap();
        let arr = doc.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("ph").unwrap().as_str(), Some("b"));
        assert_eq!(arr[1].get("ph").unwrap().as_str(), Some("e"));
        assert_eq!(arr[0].get("id").unwrap().as_str(), arr[1].get("id").unwrap().as_str());
        assert_eq!(arr[0].get("cat").unwrap().as_str(), Some("request"));
    }

    #[test]
    fn attrs_ride_the_end_event() {
        let mut end = ev(4, 0, 2, 90, false, "sweep");
        end.attrs[0] = ("shards", 4.0);
        end.attrs[1] = ("violation", 0.125);
        end.n_attrs = 2;
        end.str_attr = Some(("cd_mode", "sync"));
        let events = vec![ev(4, 2, 2, 40, true, "sweep"), end];
        let doc = parse_json(&render(&events)).unwrap();
        let arr = doc.get("traceEvents").unwrap().as_array().unwrap();
        let args = arr[1].get("args").unwrap();
        assert_eq!(args.get("shards").unwrap().as_float(), Some(4.0));
        assert_eq!(args.get("violation").unwrap().as_float(), Some(0.125));
        assert_eq!(args.get("cd_mode").unwrap().as_str(), Some("sync"));
    }
}
