//! The lock-free event ring behind span tracing.
//!
//! A fixed-capacity power-of-two ring of [`RawEvent`] slots. Writers
//! claim a ticket with one `fetch_add` and publish through a per-slot
//! seqlock (odd = mid-write, `2·ticket + 2` = published), so concurrent
//! emitters never block each other and never allocate. When the ring
//! wraps, the newest events overwrite the oldest — a bounded trace that
//! keeps the most recent window, never unbounded memory. The reader
//! (trace flush) walks the last `capacity` tickets and drops any slot
//! whose sequence shows a wrap race or an in-flight write, so a snapshot
//! can run concurrently with live traffic and only ever loses the slots
//! actually being overwritten at that instant.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Fixed attribute capacity per event — enough for every span this crate
/// emits, chosen so [`RawEvent`] stays `Copy` and the disabled path never
/// touches the heap.
pub const MAX_ATTRS: usize = 4;

/// One trace event: a span begin or end, fixed-size, no heap.
#[derive(Clone, Copy)]
pub struct RawEvent {
    /// Nanoseconds since the process trace epoch.
    pub ts_ns: u64,
    /// Span this event belongs to (begin/end pairs share it).
    pub span_id: u64,
    /// Enclosing span id (0 = root). Only meaningful on begins.
    pub parent_id: u64,
    /// Trace-local thread id (small dense integers, not OS tids).
    pub tid: u64,
    /// `true` = span begin, `false` = span end.
    pub begin: bool,
    /// Span name. Static (or interned) so events stay `Copy`.
    pub name: &'static str,
    /// One optional string attribute (e.g. `("cd_mode", "sync")`).
    pub str_attr: Option<(&'static str, &'static str)>,
    /// Numeric attributes, `n_attrs` of them valid.
    pub attrs: [(&'static str, f64); MAX_ATTRS],
    pub n_attrs: u8,
}

impl RawEvent {
    pub const EMPTY: RawEvent = RawEvent {
        ts_ns: 0,
        span_id: 0,
        parent_id: 0,
        tid: 0,
        begin: false,
        name: "",
        str_attr: None,
        attrs: [("", 0.0); MAX_ATTRS],
        n_attrs: 0,
    };
}

struct Slot {
    /// Seqlock word: `2·ticket + 1` while the claiming writer is copying,
    /// `2·ticket + 2` once published. A reader accepts a slot only when
    /// it observes the published value for the exact ticket it expects,
    /// before AND after copying the payload out.
    seq: AtomicU64,
    ev: UnsafeCell<RawEvent>,
}

/// Multi-producer bounded event ring. Single logical consumer (the trace
/// flush), which tolerates concurrent producers by seqlock validation.
pub struct EventRing {
    head: AtomicU64,
    mask: u64,
    slots: Vec<Slot>,
}

// Slots are raced deliberately: writers serialize per slot via the
// ticket claim, and the reader validates with the seqlock.
unsafe impl Sync for EventRing {}

impl EventRing {
    /// `capacity` is rounded up to a power of two.
    pub fn new(capacity: usize) -> EventRing {
        let cap = capacity.next_power_of_two().max(2);
        let slots = (0..cap)
            .map(|_| Slot { seq: AtomicU64::new(0), ev: UnsafeCell::new(RawEvent::EMPTY) })
            .collect();
        EventRing { head: AtomicU64::new(0), mask: (cap as u64) - 1, slots }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever pushed (not clamped to capacity).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::SeqCst)
    }

    /// Record one event. Lock-free: one `fetch_add` + two slot stores.
    pub fn push(&self, ev: RawEvent) {
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket & self.mask) as usize];
        slot.seq.store(ticket.wrapping_mul(2).wrapping_add(1), Ordering::SeqCst);
        // Raced only across a full ring wrap (capacity pushes in between);
        // the seqlock check below makes the reader drop a torn slot.
        unsafe { *slot.ev.get() = ev };
        slot.seq.store(ticket.wrapping_mul(2).wrapping_add(2), Ordering::SeqCst);
    }

    /// Copy out every currently-published event, oldest first. Slots that
    /// wrapped or are mid-write are skipped, never torn.
    pub fn snapshot(&self) -> Vec<RawEvent> {
        let head = self.head.load(Ordering::SeqCst);
        let cap = self.slots.len() as u64;
        let start = head.saturating_sub(cap);
        let mut out = Vec::with_capacity((head - start) as usize);
        for ticket in start..head {
            let slot = &self.slots[(ticket & self.mask) as usize];
            let want = ticket.wrapping_mul(2).wrapping_add(2);
            if slot.seq.load(Ordering::SeqCst) != want {
                continue;
            }
            let ev = unsafe { std::ptr::read_volatile(slot.ev.get()) };
            if slot.seq.load(Ordering::SeqCst) != want {
                continue;
            }
            out.push(ev);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64) -> RawEvent {
        RawEvent { span_id: id, ts_ns: id, ..RawEvent::EMPTY }
    }

    #[test]
    fn keeps_the_newest_window_on_wrap() {
        let ring = EventRing::new(4);
        for i in 0..10 {
            ring.push(ev(i));
        }
        let got: Vec<u64> = ring.snapshot().iter().map(|e| e.span_id).collect();
        assert_eq!(got, vec![6, 7, 8, 9]);
        assert_eq!(ring.pushed(), 10);
    }

    #[test]
    fn snapshot_of_partial_ring_is_ordered() {
        let ring = EventRing::new(8);
        for i in 0..3 {
            ring.push(ev(i));
        }
        let got: Vec<u64> = ring.snapshot().iter().map(|e| e.span_id).collect();
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn concurrent_pushers_never_lose_the_latest_window() {
        let ring = std::sync::Arc::new(EventRing::new(1024));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let ring = ring.clone();
                s.spawn(move || {
                    for i in 0..500u64 {
                        ring.push(ev(t * 1000 + i));
                    }
                });
            }
        });
        assert_eq!(ring.pushed(), 2000);
        let snap = ring.snapshot();
        // quiescent snapshot: a full ring, no torn slots
        assert_eq!(snap.len(), 1024);
    }
}
