//! The open screening-rule engine: a trait the path runner (and the
//! coordinator's screen jobs) drive instead of matching on a closed
//! enum, plus the rule-expression syntax (`"dvi+essnsv"`) every config
//! surface parses.
//!
//! A rule splits into two halves:
//!
//! * [`ScreeningRule::prepare`] builds the [`DualRegion`] that provably
//!   contains the dual optimum for the coming step (cheap, O(n) or one
//!   matvec);
//! * [`ScreeningRule::screen_rows`] sweeps the rows against that region
//!   — by default the generic nnz-balanced sharded sweep in
//!   [`super::region`], overridable so the w-form DVI rule keeps its
//!   pluggable [`DviScanBackend`] (native serial / sharded / PJRT).
//!
//! The four pre-refactor rules are re-expressed as trait impls below and
//! reproduce the enum-dispatch decisions bit for bit; composition
//! ([`super::Composite`]) intersects member regions, which is safe
//! because every member region contains the optimum.

use super::dvi::{ball_params, Dvi};
use super::region::{self, DualRegion};
use super::{Decision, RuleKind};
use crate::linalg::{self, ShardAxis};
use crate::path::{DviScanBackend, NativeScan, ParScan};
use crate::problem::{Instance, Model};

/// Everything a rule may need at one path step C_prev → C_next. The
/// runner owns the solved state; rules borrow it.
#[derive(Clone, Copy, Debug)]
pub struct StepContext<'a> {
    pub c_prev: f64,
    pub c_next: f64,
    /// θ*(C_prev) — the most recent solved path point.
    pub theta_prev: &'a [f64],
    /// Zᵀθ*(C_prev) (the solver hands it over for free).
    pub u_prev: &'a [f64],
    /// w*(C_max) — present when the runner solved the far grid end
    /// because some member rule [`ScreeningRule::requires_cmax`].
    pub w_feasible: Option<&'a [f64]>,
}

/// One safe screening rule. Implementations must be *safe*: the region
/// returned by [`Self::prepare`] must contain the dual optimum at
/// `ctx.c_next`, so a non-`Keep` decision is guaranteed exact.
pub trait ScreeningRule {
    /// Display name (e.g. `"dvi"`, `"dvi+essnsv"` for composites).
    fn name(&self) -> String;

    /// Whether the rule needs w*(C_max) in the [`StepContext`] (the
    /// SSNSV family's "Init." solve at the far grid end).
    fn requires_cmax(&self) -> bool {
        false
    }

    /// One-time per-instance precomputation (e.g. the θ-form Gram
    /// matrix), charged to the run's init time.
    fn init(&mut self, _inst: &Instance, _threads: usize) {}

    /// Build the dual region for this step.
    fn prepare(&self, inst: &Instance, ctx: &StepContext) -> DualRegion;

    /// Sweep all rows against the region. The default is the generic
    /// sharded bounds sweep; rules with a specialized kernel override it.
    fn screen_rows(
        &mut self,
        inst: &Instance,
        region: &DualRegion,
        threads: usize,
    ) -> Vec<Decision> {
        region::screen_rows(inst, region, threads)
    }
}

/// DVI_s, w-form (Cor. 9): Theorem-6 ball, O(l·n) streaming sweep. Keeps
/// the pluggable scan backend — inside a composite only its *region* is
/// used (the generic sweep evaluates the intersection), matching the
/// pre-refactor behavior where PJRT only ever served the plain rule.
pub struct DviWRule {
    backend: Box<dyn DviScanBackend>,
}

impl DviWRule {
    /// Same backend policy as the path runner: 1 thread keeps the serial
    /// scan, anything else installs the sharded one (0 = auto).
    pub fn with_threads(threads: usize) -> DviWRule {
        let backend: Box<dyn DviScanBackend> = if threads == 1 {
            Box::new(NativeScan)
        } else {
            Box::new(ParScan::new(threads))
        };
        DviWRule { backend }
    }

    /// Swap the scan backend (e.g. the PJRT AOT executable).
    pub fn with_backend(backend: Box<dyn DviScanBackend>) -> DviWRule {
        DviWRule { backend }
    }
}

impl ScreeningRule for DviWRule {
    fn name(&self) -> String {
        RuleKind::DviW.name().to_string()
    }

    fn prepare(&self, _inst: &Instance, ctx: &StepContext) -> DualRegion {
        let (mid, rad) = ball_params(ctx.c_prev, ctx.c_next);
        DualRegion::BallW {
            mid,
            rad,
            u: ctx.u_prev.to_vec(),
            u_norm: linalg::norm(ctx.u_prev),
        }
    }

    fn screen_rows(
        &mut self,
        inst: &Instance,
        region: &DualRegion,
        threads: usize,
    ) -> Vec<Decision> {
        match region {
            // the backend recomputes ‖u‖ itself — same value, and the
            // kernel stays the single source the PJRT artifact mirrors
            DualRegion::BallW { mid, rad, u, .. } => self.backend.scan(inst, *mid, *rad, u),
            other => region::screen_rows(inst, other, threads),
        }
    }
}

/// DVI_s*, θ-form (Cor. 8): one-time Gram build in [`Self::init`], then
/// a matvec per step.
pub struct DviThetaRule {
    dvi: Option<Dvi>,
    /// ‖zᵢ‖ from the Gram diagonal — the exact `g.get(i,i).max(0).sqrt()`
    /// values the enum path evaluated per row.
    zn: Vec<f64>,
    /// Shard axis for the one-time Gram build (the built matrix is
    /// bit-identical either way; this only picks the parallel schedule).
    axis: ShardAxis,
}

impl DviThetaRule {
    pub fn new() -> DviThetaRule {
        Self::with_axis(ShardAxis::Rows)
    }

    pub fn with_axis(axis: ShardAxis) -> DviThetaRule {
        DviThetaRule { dvi: None, zn: Vec::new(), axis }
    }
}

impl Default for DviThetaRule {
    fn default() -> Self {
        Self::new()
    }
}

impl ScreeningRule for DviThetaRule {
    fn name(&self) -> String {
        RuleKind::DviTheta.name().to_string()
    }

    fn init(&mut self, inst: &Instance, threads: usize) {
        let dvi = Dvi::new_theta_axis(inst, threads, self.axis);
        let g = dvi.gram_matrix().expect("θ-form always builds the Gram matrix");
        self.zn = (0..inst.len()).map(|i| g.get(i, i).max(0.0).sqrt()).collect();
        self.dvi = Some(dvi);
    }

    fn prepare(&self, inst: &Instance, ctx: &StepContext) -> DualRegion {
        let g = self
            .dvi
            .as_ref()
            .and_then(|d| d.gram_matrix())
            .expect("DviThetaRule::prepare before init");
        assert_eq!(g.rows(), inst.len());
        let (mid, rad) = ball_params(ctx.c_prev, ctx.c_next);
        // ‖u‖² = θᵀGθ via one matvec
        let mut gtheta = vec![0.0; inst.len()];
        g.matvec(ctx.theta_prev, &mut gtheta);
        let u_norm = linalg::dot(&gtheta, ctx.theta_prev).max(0.0).sqrt();
        DualRegion::BallTheta { mid, rad, gtheta, u_norm, zn: self.zn.clone() }
    }
}

/// SSNSV (Ogawa et al. 2013) / ESSNSV (§5.2): the cone∩ball region over
/// w-space, extremized row-wise by Lemma 20.
pub struct SsnsvRule {
    pub enhanced: bool,
}

impl SsnsvRule {
    pub fn new(enhanced: bool) -> SsnsvRule {
        SsnsvRule { enhanced }
    }
}

impl ScreeningRule for SsnsvRule {
    fn name(&self) -> String {
        if self.enhanced { RuleKind::Essnsv.name() } else { RuleKind::Ssnsv.name() }
            .to_string()
    }

    fn requires_cmax(&self) -> bool {
        true
    }

    fn prepare(&self, inst: &Instance, ctx: &StepContext) -> DualRegion {
        assert!(
            inst.model != Model::Lad,
            "SSNSV/ESSNSV are derived for SVM only"
        );
        let w_a = inst.w_from_theta(ctx.c_prev, ctx.theta_prev);
        let w_hat = ctx
            .w_feasible
            .expect("SSNSV family needs w*(C_max) in the step context");
        assert_eq!(w_a.len(), inst.dim());
        assert_eq!(w_hat.len(), inst.dim());
        let wa_norm_sq = linalg::norm_sq(&w_a);
        let what_norm = linalg::norm(w_hat);
        // Degenerate anchor (w_a = 0): the half-space is vacuous; fall
        // back to ball-only bounds.
        let cone = if wa_norm_sq > 0.0 {
            Some((w_a.iter().map(|v| -v).collect::<Vec<f64>>(), -wa_norm_sq))
        } else {
            None
        };
        let (center, radius): (Vec<f64>, f64) = if self.enhanced {
            (w_hat.iter().map(|v| 0.5 * v).collect(), 0.5 * what_norm)
        } else {
            (vec![0.0; inst.dim()], what_norm)
        };
        DualRegion::ConeBall { cone, center, radius }
    }
}

/// No screening: the region is all of dual space, every row keeps.
pub struct NoneRule;

impl ScreeningRule for NoneRule {
    fn name(&self) -> String {
        RuleKind::None.name().to_string()
    }

    fn prepare(&self, _inst: &Instance, _ctx: &StepContext) -> DualRegion {
        DualRegion::All
    }

    fn screen_rows(
        &mut self,
        inst: &Instance,
        _region: &DualRegion,
        _threads: usize,
    ) -> Vec<Decision> {
        vec![Decision::Keep; inst.len()]
    }
}

/// Instrumented decorator around any engine: emits `screen_init` /
/// `screen_prepare` / `screen_rows` spans (rule name, rows scanned /
/// rejected, rejection rate) and feeds the cumulative per-rule telemetry
/// counters ([`crate::obs::telemetry`]). Installed by [`RuleExpr::build`]
/// so every config surface gets it for free; decisions pass through
/// untouched, so traced and untraced engines are bit-identical — and the
/// spans themselves are inert unless `--trace-out` enabled tracing.
pub struct Traced {
    inner: Box<dyn ScreeningRule>,
    /// Interned rule name, so span attributes stay `Copy`.
    label: &'static str,
    /// Requested shard axis — resolved against the instance shape per
    /// sweep so `screen_rows` spans report the axis actually in effect.
    axis: ShardAxis,
}

impl Traced {
    pub fn new(inner: Box<dyn ScreeningRule>) -> Traced {
        Self::with_axis(inner, ShardAxis::Rows)
    }

    pub fn with_axis(inner: Box<dyn ScreeningRule>, axis: ShardAxis) -> Traced {
        let label = crate::obs::intern(&inner.name());
        Traced { inner, label, axis }
    }
}

impl ScreeningRule for Traced {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn requires_cmax(&self) -> bool {
        self.inner.requires_cmax()
    }

    fn init(&mut self, inst: &Instance, threads: usize) {
        let mut sp = crate::obs::Span::enter("screen_init");
        sp.attr_str("rule", self.label);
        self.inner.init(inst, threads);
    }

    fn prepare(&self, inst: &Instance, ctx: &StepContext) -> DualRegion {
        let mut sp = crate::obs::Span::enter("screen_prepare");
        sp.attr_str("rule", self.label);
        self.inner.prepare(inst, ctx)
    }

    fn screen_rows(
        &mut self,
        inst: &Instance,
        region: &DualRegion,
        threads: usize,
    ) -> Vec<Decision> {
        let mut sp = crate::obs::Span::enter("screen_rows");
        sp.attr_str("shard_axis", inst.pick_axis(self.axis).name());
        let decisions = self.inner.screen_rows(inst, region, threads);
        let scanned = decisions.len() as u64;
        let rejected =
            decisions.iter().filter(|d| !matches!(d, Decision::Keep)).count() as u64;
        crate::obs::telemetry::record_screen(self.label, scanned, rejected);
        sp.attr_str("rule", self.label);
        sp.attr("rows_scanned", scanned as f64);
        sp.attr("rows_rejected", rejected as f64);
        sp.attr(
            "rejection_rate",
            if scanned == 0 { 0.0 } else { rejected as f64 / scanned as f64 },
        );
        decisions
    }
}

/// The accepted atom names, quoted by every rule-parse error and the CLI
/// usage text.
pub const VALID_RULES: &str = "dvi, dvi-theta, ssnsv, essnsv, none";

/// A parsed rule expression: one atom (`"dvi"`) or a `+`-composition
/// (`"dvi+essnsv"`) whose regions are intersected per step.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RuleExpr {
    atoms: Vec<RuleKind>,
}

impl RuleExpr {
    /// Parse a rule expression. Errors enumerate the accepted names and
    /// the composition syntax (the service and CLI surface them as-is).
    pub fn parse(s: &str) -> Result<RuleExpr, String> {
        let bad = |msg: String| {
            Err(format!(
                "{msg} — valid rules: {VALID_RULES}; compose with `+` (e.g. `dvi+essnsv`)"
            ))
        };
        let s = s.trim();
        if s.is_empty() {
            return bad("empty rule expression".to_string());
        }
        let mut atoms = Vec::new();
        for tok in s.split('+') {
            let tok = tok.trim();
            let Some(kind) = RuleKind::parse(tok) else {
                return bad(format!("unknown rule `{tok}`"));
            };
            if atoms.contains(&kind) {
                return bad(format!("duplicate rule `{tok}` in composition"));
            }
            atoms.push(kind);
        }
        if atoms.len() > 1 && atoms.contains(&RuleKind::None) {
            return bad("`none` cannot be composed".to_string());
        }
        Ok(RuleExpr { atoms })
    }

    /// Wrap a single pre-parsed atom (the legacy enum surface).
    pub fn from_kind(kind: RuleKind) -> RuleExpr {
        RuleExpr { atoms: vec![kind] }
    }

    /// Canonical display/wire name: atom names joined with `+`.
    pub fn name(&self) -> String {
        self.atoms.iter().map(|k| k.name()).collect::<Vec<_>>().join("+")
    }

    /// The member atoms, in expression order.
    pub fn atoms(&self) -> &[RuleKind] {
        &self.atoms
    }

    /// `Some(kind)` iff the expression is a single atom.
    pub fn single(&self) -> Option<RuleKind> {
        match self.atoms.as_slice() {
            [k] => Some(*k),
            _ => None,
        }
    }

    /// The no-screening arm?
    pub fn is_none(&self) -> bool {
        self.single() == Some(RuleKind::None)
    }

    /// Any member needing the C_max init solve (SSNSV family)?
    pub fn requires_cmax(&self) -> bool {
        self.atoms.iter().any(|k| matches!(k, RuleKind::Ssnsv | RuleKind::Essnsv))
    }

    /// Any member derived for SVM only?
    pub fn svm_only(&self) -> bool {
        self.requires_cmax()
    }

    /// Instantiate the engine: a single atom's impl, or a
    /// [`super::Composite`] intersecting the members. `threads` picks
    /// the w-form scan backend (the same policy the path runner uses).
    pub fn build(&self, threads: usize) -> Box<dyn ScreeningRule> {
        self.build_axis(threads, ShardAxis::Rows)
    }

    /// [`RuleExpr::build`] with an explicit shard axis: θ-form members
    /// shard their Gram build along it and the [`Traced`] decorator
    /// stamps the resolved axis on every `screen_rows` span. Decisions
    /// are bit-identical across axes.
    pub fn build_axis(&self, threads: usize, axis: ShardAxis) -> Box<dyn ScreeningRule> {
        let engine: Box<dyn ScreeningRule> = if let [k] = self.atoms.as_slice() {
            build_atom(*k, threads, axis)
        } else {
            Box::new(super::Composite::new(
                self.atoms.iter().map(|&k| build_atom(k, threads, axis)).collect(),
            ))
        };
        // one decorator at the top level — member atoms inside a
        // composite are not individually traced, so telemetry counts
        // each screened row exactly once per expression
        Box::new(Traced::with_axis(engine, axis))
    }
}

fn build_atom(kind: RuleKind, threads: usize, axis: ShardAxis) -> Box<dyn ScreeningRule> {
    match kind {
        RuleKind::DviW => Box::new(DviWRule::with_threads(threads)),
        RuleKind::DviTheta => Box::new(DviThetaRule::with_axis(axis)),
        RuleKind::Ssnsv => Box::new(SsnsvRule::new(false)),
        RuleKind::Essnsv => Box::new(SsnsvRule::new(true)),
        RuleKind::None => Box::new(NoneRule),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expr_parses_atoms_and_compositions() {
        for (s, n) in [
            ("dvi", "dvi"),
            ("dvi-theta", "dvi-theta"),
            ("none", "none"),
            ("dvi+ssnsv", "dvi+ssnsv"),
            (" dvi + essnsv ", "dvi+essnsv"),
            ("dvi+dvi-theta+essnsv", "dvi+dvi-theta+essnsv"),
        ] {
            let e = RuleExpr::parse(s).unwrap_or_else(|err| panic!("{s}: {err}"));
            assert_eq!(e.name(), n);
        }
        assert_eq!(RuleExpr::parse("dvi").unwrap().single(), Some(RuleKind::DviW));
        assert_eq!(RuleExpr::parse("dvi+ssnsv").unwrap().single(), None);
        assert!(RuleExpr::parse("none").unwrap().is_none());
        assert!(RuleExpr::parse("dvi+ssnsv").unwrap().requires_cmax());
        assert!(!RuleExpr::parse("dvi+dvi-theta").unwrap().requires_cmax());
    }

    #[test]
    fn expr_errors_are_actionable() {
        for bad in ["nope", "", "dvi+", "dvi+dvi", "dvi+none", "none+ssnsv"] {
            let err = RuleExpr::parse(bad).unwrap_err();
            assert!(err.contains("valid rules: dvi, dvi-theta, ssnsv, essnsv, none"), "{bad}: {err}");
            assert!(err.contains("compose with `+`"), "{bad}: {err}");
        }
        assert!(RuleExpr::parse("bogus").unwrap_err().contains("unknown rule `bogus`"));
        assert!(RuleExpr::parse("dvi+dvi").unwrap_err().contains("duplicate rule"));
        assert!(RuleExpr::parse("dvi+none").unwrap_err().contains("`none` cannot be composed"));
    }

    #[test]
    fn expr_roundtrips_rulekind_names() {
        for k in [
            RuleKind::DviW,
            RuleKind::DviTheta,
            RuleKind::Ssnsv,
            RuleKind::Essnsv,
            RuleKind::None,
        ] {
            let e = RuleExpr::from_kind(k);
            assert_eq!(RuleExpr::parse(&e.name()).unwrap(), e);
            assert_eq!(e.single(), Some(k));
        }
    }

    #[test]
    fn build_names_match_expressions() {
        for s in ["dvi", "dvi-theta", "ssnsv", "essnsv", "none", "dvi+ssnsv", "dvi+essnsv"] {
            let e = RuleExpr::parse(s).unwrap();
            assert_eq!(e.build(1).name(), e.name(), "{s}");
        }
    }

    #[test]
    fn traced_decorator_passes_decisions_through_and_counts() {
        use crate::data::synth;
        use crate::problem::Instance;

        let ds = synth::toy_gaussian(11, 40, 1.5, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let theta = inst.cold_start();
        let u = inst.u_from_theta(&theta);
        let ctx = StepContext {
            c_prev: 0.5,
            c_next: 0.6,
            theta_prev: &theta,
            u_prev: &u,
            w_feasible: None,
        };

        let mut plain = DviWRule::with_threads(1);
        let mut traced = Traced::new(Box::new(DviWRule::with_threads(1)));
        assert_eq!(traced.name(), plain.name());
        assert!(!traced.requires_cmax());

        let region_p = plain.prepare(&inst, &ctx);
        let region_t = traced.prepare(&inst, &ctx);
        let d_plain = plain.screen_rows(&inst, &region_p, 1);
        let d_traced = traced.screen_rows(&inst, &region_t, 1);
        assert_eq!(d_plain, d_traced, "decorator must not change decisions");

        // the decorator fed the cumulative per-rule telemetry
        let snap = crate::obs::telemetry::registry().counters_snapshot();
        let scanned = snap
            .iter()
            .find(|(n, _)| n == "screen_rows_scanned_total{rule=\"dvi\"}")
            .map(|&(_, v)| v)
            .unwrap_or(0);
        assert!(scanned >= 40, "scanned {scanned}");
    }
}
