//! Safe screening rules — the paper's contribution.
//!
//! Every rule consumes a solved path point and emits, for the next
//! parameter value, a per-instance [`Decision`]: leave the coordinate
//! free, or fix it to the lower (`AtLo`, the paper's R set, θ=α) or upper
//! (`AtHi`, the L set, θ=β) bound. *Safe* means a decision other than
//! `Keep` is guaranteed to match the exact optimum — validated by
//! [`crate::validation`] and the integration test suite.
//!
//! Implemented rules:
//! * [`dvi::Dvi`] — the paper's DVI_s (w-form, Cor. 9/12/15) and DVI_s*
//!   (θ-form with cached Gram matrix, Cor. 8/11/14);
//! * [`ssnsv::Ssnsv`] — the SSNSV baseline (Ogawa et al. 2013, Eq. 27)
//!   and its VI-enhanced variant ESSNSV (Eq. 28 / Theorem 19), sharing
//!   the cone∩ball extremization of Lemma 20;
//! * [`RuleKind::None`] — no screening (the paper's plain "Solver" arm).
//!
//! All of the above are also exposed through the open, composable
//! engine: [`rule::ScreeningRule`] implementations build a
//! [`region::DualRegion`] per step and sweep the rows against it, and a
//! [`composite::Composite`] intersects member regions so `--rule
//! "dvi+essnsv"` screens every row with the tightest available bound.
//! [`RuleKind`] remains the atom vocabulary; [`RuleExpr`] is the parsed
//! `+`-expression every layer now threads through.

pub mod composite;
pub mod dvi;
pub mod region;
pub mod rule;
pub mod ssnsv;

pub use composite::Composite;
pub use dvi::{Dvi, DviForm};
pub use region::{decide_bounds, DualRegion, RowScratch};
pub use rule::{
    DviThetaRule, DviWRule, NoneRule, RuleExpr, ScreeningRule, SsnsvRule, StepContext,
    Traced, VALID_RULES,
};
pub use ssnsv::{Ssnsv, SsnsvContext};

use crate::problem::Instance;

/// Screening decision for one instance at the *next* parameter value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Membership unknown — stays in the reduced optimization.
    Keep,
    /// Guaranteed θᵢ* = α (paper's R set).
    AtLo,
    /// Guaranteed θᵢ* = β (paper's L set).
    AtHi,
}

/// Which rule the path runner applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleKind {
    /// DVI_s, w-form (Cor. 9): O(l·n) per step, streaming.
    DviW,
    /// DVI_s*, θ-form (Cor. 8): O(l²) with a one-time Gram matrix.
    DviTheta,
    /// SSNSV baseline (needs solves at both grid extremes).
    Ssnsv,
    /// Enhanced SSNSV via variational inequalities (§5.2).
    Essnsv,
    /// No screening.
    None,
}

impl RuleKind {
    pub fn parse(s: &str) -> Option<RuleKind> {
        match s {
            "dvi" => Some(RuleKind::DviW),
            "dvi-theta" => Some(RuleKind::DviTheta),
            "ssnsv" => Some(RuleKind::Ssnsv),
            "essnsv" => Some(RuleKind::Essnsv),
            "none" => Some(RuleKind::None),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::DviW => "dvi",
            RuleKind::DviTheta => "dvi-theta",
            RuleKind::Ssnsv => "ssnsv",
            RuleKind::Essnsv => "essnsv",
            RuleKind::None => "none",
        }
    }
}

/// Summary of one screening application.
#[derive(Clone, Debug)]
pub struct ScreenReport {
    pub decisions: Vec<Decision>,
    pub n_lo: usize,
    pub n_hi: usize,
}

impl ScreenReport {
    pub fn from_decisions(decisions: Vec<Decision>) -> Self {
        let n_lo = decisions.iter().filter(|&&d| d == Decision::AtLo).count();
        let n_hi = decisions.iter().filter(|&&d| d == Decision::AtHi).count();
        ScreenReport { decisions, n_lo, n_hi }
    }

    /// All-Keep report (the no-screening arm).
    pub fn keep_all(l: usize) -> Self {
        ScreenReport { decisions: vec![Decision::Keep; l], n_lo: 0, n_hi: 0 }
    }

    /// Fraction of instances screened out (the paper's rejection ratio).
    pub fn rejection(&self) -> f64 {
        if self.decisions.is_empty() {
            return 0.0;
        }
        (self.n_lo + self.n_hi) as f64 / self.decisions.len() as f64
    }

    /// Indices left free.
    pub fn free_indices(&self) -> Vec<usize> {
        self.decisions
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == Decision::Keep)
            .map(|(i, _)| i)
            .collect()
    }

    /// Apply the decisions to a warm-start θ (screened coords snap to
    /// their bound; kept coords are clamped into the box).
    pub fn apply_to_theta(&self, inst: &Instance, theta: &mut [f64]) {
        for (i, d) in self.decisions.iter().enumerate() {
            match d {
                Decision::AtLo => theta[i] = inst.lo[i],
                Decision::AtHi => theta[i] = inst.hi[i],
                Decision::Keep => {
                    theta[i] = crate::linalg::clamp(theta[i], inst.lo[i], inst.hi[i])
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::problem::{Instance, Model};

    #[test]
    fn report_counts_and_rejection() {
        let d = vec![Decision::Keep, Decision::AtLo, Decision::AtHi, Decision::AtLo];
        let r = ScreenReport::from_decisions(d);
        assert_eq!((r.n_lo, r.n_hi), (2, 1));
        assert!((r.rejection() - 0.75).abs() < 1e-12);
        assert_eq!(r.free_indices(), vec![0]);
    }

    #[test]
    fn keep_all_is_empty_rejection() {
        let r = ScreenReport::keep_all(10);
        assert_eq!(r.rejection(), 0.0);
        assert_eq!(r.free_indices().len(), 10);
    }

    #[test]
    fn apply_to_theta_snaps_bounds() {
        let ds = synth::toy_gaussian(1, 2, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = ScreenReport::from_decisions(vec![
            Decision::AtLo,
            Decision::AtHi,
            Decision::Keep,
            Decision::Keep,
        ]);
        let mut theta = vec![0.7, 0.2, 1.5, -0.5];
        r.apply_to_theta(&inst, &mut theta);
        assert_eq!(theta, vec![0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn rulekind_parse_roundtrip() {
        for k in [
            RuleKind::DviW,
            RuleKind::DviTheta,
            RuleKind::Ssnsv,
            RuleKind::Essnsv,
            RuleKind::None,
        ] {
            assert_eq!(RuleKind::parse(k.name()), Some(k));
        }
        assert_eq!(RuleKind::parse("bogus"), None);
    }
}
