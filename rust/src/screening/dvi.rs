//! DVI — the paper's screening rules (Theorem 7, Corollaries 8–15).
//!
//! Given θ*(C_k) solved and C_{k+1} > C_k, Theorem 6 bounds Zᵀθ*(C_{k+1})
//! inside a ball of radius ((C_{k+1}−C_k)/2C_{k+1})·‖Zᵀθ*(C_k)‖ around
//! ((C_k+C_{k+1})/2C_{k+1})·Zᵀθ*(C_k). Pushing that ball through the KKT
//! rules (R1')/(R2') yields, with u = Zᵀθ*(C_k), mid = (C_{k+1}+C_k)/2 and
//! rad = (C_{k+1}−C_k)/2:
//!
//! ```text
//!   mid·⟨u, zᵢ⟩ − rad·‖u‖·‖zᵢ‖ > ȳᵢ  ⇒  θᵢ*(C_{k+1}) = α   (R)
//!   mid·⟨u, zᵢ⟩ + rad·‖u‖·‖zᵢ‖ < ȳᵢ  ⇒  θᵢ*(C_{k+1}) = β   (L)
//! ```
//!
//! The two published forms differ only in how ⟨u, zᵢ⟩ is evaluated:
//!
//! * **w-form (DVI_s, Cor. 9/12/15)** — from w*(C_k) = −C_k·u: an O(l·n)
//!   streaming scan, no extra memory. This is the production form and the
//!   one the Pallas kernel implements.
//! * **θ-form (DVI_s*, Cor. 8/11/14)** — from the Gram matrix G = ZZᵀ:
//!   ⟨u,zᵢ⟩ = gᵢᵀθ, ‖u‖² = θᵀGθ, ‖zᵢ‖² = Gᵢᵢ. O(l²) per step after a
//!   one-time O(l²·n) factorization; only sensible when G fits in memory
//!   (the ablation bench explores the crossover).

use super::{Decision, ScreenReport};
use crate::linalg::{self, par, RowMatrix, ShardAxis};
use crate::problem::Instance;

/// Which evaluation strategy to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DviForm {
    /// Streaming w-form (Corollary 9).
    W,
    /// Gram-matrix θ-form (Corollary 8).
    Theta,
}

/// DVI screening rule. Holds the (optional) cached Gram matrix for the
/// θ-form; construct once per dataset and reuse along the path.
pub struct Dvi {
    pub form: DviForm,
    gram: Option<RowMatrix>,
}

impl Dvi {
    /// w-form: no precomputation.
    pub fn new_w() -> Dvi {
        Dvi { form: DviForm::W, gram: None }
    }

    /// θ-form: precomputes G = ZZᵀ (O(l²·n) once). Panics if l is so large
    /// that G would exceed ~2 GiB — use the w-form there.
    pub fn new_theta(inst: &Instance) -> Dvi {
        Self::new_theta_threads(inst, 1)
    }

    /// θ-form with a sharded Gram build: the upper triangle is split into
    /// contiguous row blocks of near-equal *cost* and computed on
    /// `std::thread::scope` workers. Dense rows cost their area (row i
    /// contributes l−i entries); CSR rows weight entry (i,j) by nnzᵢ+nnzⱼ
    /// via the indptr prefix sums ([`crate::linalg::Rows::gram_triangle_bounds`]),
    /// so a few heavy rows no longer skew the shards. Every entry is the
    /// same `⟨zᵢ, zⱼ⟩` dot the serial build evaluates, so the matrix is
    /// identical for any thread count (0 = auto, 1 = serial) and any
    /// shard boundaries.
    pub fn new_theta_threads(inst: &Instance, threads: usize) -> Dvi {
        let l = inst.len();
        // the l·l product itself can overflow usize on 32-bit targets
        // before a plain `l * l <= budget` assert ever runs
        assert!(
            l.checked_mul(l).map_or(false, |entries| entries <= 256 * 1024 * 1024),
            "Gram matrix for l={l} would exceed the memory budget; use DviForm::W"
        );
        let t = par::effective_threads(threads, l);
        let mut data = vec![0.0f64; l * l];
        if t <= 1 {
            // serial: interleave the symmetric write into the single pass
            // (a separate stride-l mirror sweep would only add traffic)
            for i in 0..l {
                for j in i..l {
                    let v = inst.z.gram(i, j);
                    data[i * l + j] = v;
                    data[j * l + i] = v;
                }
            }
        } else {
            let bounds = inst.z.gram_triangle_bounds(t);
            par::run_sharded_mut(&mut data, l, &bounds, |rows, block| {
                let lo = rows.start;
                for i in rows {
                    let base = (i - lo) * l;
                    for j in i..l {
                        block[base + j] = inst.z.gram(i, j);
                    }
                }
            });
            // mirror the strict upper triangle into the lower one. This
            // stays serial: each lower row reads upper entries owned by
            // other shards, so disjoint &mut blocks can't express it —
            // and it is O(l²) memory traffic vs the O(l²·n) dots above.
            for i in 0..l {
                for j in (i + 1)..l {
                    data[j * l + i] = data[i * l + j];
                }
            }
        }
        Dvi { form: DviForm::Theta, gram: Some(RowMatrix::from_flat(l, l, data)) }
    }

    /// Axis-aware θ-form build. `Rows` (and `Auto` resolving to rows)
    /// delegates to [`Dvi::new_theta_threads`]. `Cols` shards the Gram's
    /// *output* columns instead: shard k owns a contiguous slab of columns
    /// j, balanced by upper-triangle entry count (column j holds j+1
    /// entries), and computes every entry ⟨zᵢ, zⱼ⟩ for i ≤ j as the same
    /// whole dot the serial build evaluates — a single dot is never split
    /// across shards, because the 8-accumulator reduction is not
    /// associative. Shards return packed slabs that the main thread
    /// scatters and mirrors serially, so the matrix is bit-identical to
    /// the row-sharded and serial builds for any thread count.
    pub fn new_theta_axis(inst: &Instance, threads: usize, axis: ShardAxis) -> Dvi {
        let l = inst.len();
        let t = par::effective_threads(threads, l);
        if t <= 1 || inst.pick_axis(axis) != ShardAxis::Cols {
            return Self::new_theta_threads(inst, threads);
        }
        assert!(
            l.checked_mul(l).map_or(false, |entries| entries <= 256 * 1024 * 1024),
            "Gram matrix for l={l} would exceed the memory budget; use DviForm::W"
        );
        let cum = par::cumulative_weights((0..l).map(|j| j + 1));
        let ranges = par::cumulative_ranges(&cum, t);
        let slabs = par::run_sharded_ranges(ranges, |cols| {
            let mut out = Vec::with_capacity(cum[cols.end] - cum[cols.start]);
            for j in cols {
                for i in 0..=j {
                    out.push(inst.z.gram(i, j));
                }
            }
            out
        });
        let mut data = vec![0.0f64; l * l];
        let mut j = 0usize;
        for slab in slabs {
            let mut k = 0usize;
            while k < slab.len() {
                for i in 0..=j {
                    data[i * l + j] = slab[k];
                    k += 1;
                }
                j += 1;
            }
        }
        debug_assert_eq!(j, l, "packed slabs must cover every Gram column");
        for i in 0..l {
            for j in (i + 1)..l {
                data[j * l + i] = data[i * l + j];
            }
        }
        Dvi { form: DviForm::Theta, gram: Some(RowMatrix::from_flat(l, l, data)) }
    }

    /// Screen for C_next given θ*(C_prev). `u_prev` must equal Zᵀθ_prev
    /// (the solver hands it over for free). Requires C_next > C_prev > 0.
    pub fn screen(
        &self,
        inst: &Instance,
        c_prev: f64,
        c_next: f64,
        theta_prev: &[f64],
        u_prev: &[f64],
    ) -> ScreenReport {
        assert_eq!(theta_prev.len(), inst.len());
        let (mid, rad) = ball_params(c_prev, c_next);
        let decisions = match self.form {
            DviForm::W => self.screen_w(inst, mid, rad, u_prev),
            DviForm::Theta => self.screen_theta(inst, mid, rad, theta_prev),
        };
        ScreenReport::from_decisions(decisions)
    }

    fn screen_w(&self, inst: &Instance, mid: f64, rad: f64, u: &[f64]) -> Vec<Decision> {
        dvi_scan(inst, mid, rad, u)
    }

    /// The cached Gram matrix (θ-form only) — read by the trait-based
    /// engine's θ rule so its per-row expressions evaluate the exact
    /// entries the enum-dispatch path did.
    pub(crate) fn gram_matrix(&self) -> Option<&RowMatrix> {
        self.gram.as_ref()
    }

    fn screen_theta(&self, inst: &Instance, mid: f64, rad: f64, theta: &[f64]) -> Vec<Decision> {
        let g = self.gram.as_ref().expect("θ-form requires the Gram matrix");
        assert_eq!(g.rows(), inst.len());
        // ‖u‖² = θᵀGθ via one matvec
        let mut gtheta = vec![0.0; inst.len()];
        g.matvec(theta, &mut gtheta);
        let u_norm = linalg::dot(&gtheta, theta).max(0.0).sqrt();
        let mut out = Vec::with_capacity(inst.len());
        for i in 0..inst.len() {
            let p = gtheta[i]; // gᵢᵀθ = ⟨u, zᵢ⟩
            let zn = g.get(i, i).max(0.0).sqrt();
            let slack = rad * u_norm * zn;
            out.push(decide(mid * p, slack, inst.ybar[i]));
        }
        out
    }
}

/// The Theorem 6 ball in (mid, rad) form — THE screening-safety mapping
/// from a solved C_prev and a target C_next to the scan's parameters:
/// mid = (C_next+C_prev)/2, rad = (C_next−C_prev)/2. Every screening
/// site (the θ/w rule dispatch above, the path runner's backend scan,
/// the coordinator's screen jobs) derives its parameters here, so the
/// formula cannot silently diverge between them.
#[inline]
pub fn ball_params(c_prev: f64, c_next: f64) -> (f64, f64) {
    assert!(c_next > c_prev && c_prev > 0.0, "need C_next > C_prev > 0");
    (0.5 * (c_next + c_prev), 0.5 * (c_next - c_prev))
}

/// w-form screening with the sharded scan: the same `ball_params`
/// mapping as [`Dvi::screen`], evaluated by [`dvi_scan_par`] (`threads`:
/// 0 = auto, 1 = serial; decisions byte-identical throughout). The
/// coordinator's screen jobs call this.
pub fn screen_w_par(
    inst: &Instance,
    c_prev: f64,
    c_next: f64,
    u_prev: &[f64],
    threads: usize,
) -> ScreenReport {
    let (mid, rad) = ball_params(c_prev, c_next);
    ScreenReport::from_decisions(dvi_scan_par(inst, mid, rad, u_prev, threads))
}

/// The streaming DVI scan (w-form, Corollary 9): one O(l·n) pass
/// evaluating both inequalities for every instance. This is the hot path
/// the PJRT/Pallas artifact mirrors; kept as a free function so backends
/// can share it.
pub fn dvi_scan(inst: &Instance, mid: f64, rad: f64, u: &[f64]) -> Vec<Decision> {
    assert_eq!(u.len(), inst.dim());
    dvi_scan_range(inst, mid, rad, u, linalg::norm(u), 0..inst.len())
}

/// Sharded multi-threaded variant of [`dvi_scan`]: the l rows are split
/// into contiguous shards evaluated on the persistent solver pool
/// ([`crate::linalg::par::SolverPool`]) and the per-shard decision
/// vectors are merged in shard order. Shards are area-balanced by
/// *stored-entry* count ([`crate::problem::Instance::balanced_shards`],
/// served from the instance's cached nnz prefix):
/// row-count splits on CSR data with uneven row lengths would starve some
/// workers, since a shard's cost is its nonzero count, not its row count.
/// `‖u‖` is computed once and every per-row expression is identical to
/// the serial scan, so the result is byte-identical to [`dvi_scan`] for
/// any thread count and either storage (`threads`: 0 = auto-detect,
/// 1 = serial).
pub fn dvi_scan_par(inst: &Instance, mid: f64, rad: f64, u: &[f64], threads: usize) -> Vec<Decision> {
    assert_eq!(u.len(), inst.dim());
    let u_norm = linalg::norm(u);
    let t = par::effective_threads(threads, inst.len());
    let shards = par::run_sharded_ranges(inst.balanced_shards(t), |r| {
        dvi_scan_range(inst, mid, rad, u, u_norm, r)
    });
    let mut out = Vec::with_capacity(inst.len());
    for mut s in shards {
        out.append(&mut s);
    }
    out
}

/// The scan kernel over one contiguous row range — the single source of
/// truth both the serial and the sharded scans evaluate.
fn dvi_scan_range(
    inst: &Instance,
    mid: f64,
    rad: f64,
    u: &[f64],
    u_norm: f64,
    rows: std::ops::Range<usize>,
) -> Vec<Decision> {
    let mut out = Vec::with_capacity(rows.end - rows.start);
    for i in rows {
        let p = inst.z.row(i).dot(u); // ⟨u, zᵢ⟩
        let zn = inst.z_norms_sq[i].sqrt();
        let slack = rad * u_norm * zn;
        out.push(decide(mid * p, slack, inst.ybar[i]));
    }
    out
}

/// Shared decision core: score ± slack vs ȳᵢ.
#[inline]
fn decide(score: f64, slack: f64, ybar: f64) -> Decision {
    if score - slack > ybar {
        Decision::AtLo
    } else if score + slack < ybar {
        Decision::AtHi
    } else {
        Decision::Keep
    }
}

/// Theorem 6 ball check (used by property tests): returns the distance of
/// Zᵀθ_next from the ball center, and the ball radius.
pub fn theorem6_ball(
    inst: &Instance,
    c_prev: f64,
    c_next: f64,
    theta_prev: &[f64],
    theta_next: &[f64],
) -> (f64, f64) {
    let u_prev = inst.u_from_theta(theta_prev);
    let u_next = inst.u_from_theta(theta_next);
    let scale = (c_prev + c_next) / (2.0 * c_next);
    let center: Vec<f64> = u_prev.iter().map(|v| v * scale).collect();
    let dist = u_next
        .iter()
        .zip(&center)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let radius = (c_next - c_prev) / (2.0 * c_next) * linalg::norm(&u_prev);
    (dist, radius)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverConfig;
    use crate::data::{synth, Rng};
    use crate::problem::{classify_kkt, Instance, KktClass, Model};
    use crate::solver::CdSolver;

    fn solve(inst: &Instance, c: f64) -> crate::solver::SolveResult {
        CdSolver::new(SolverConfig { tol: 1e-9, ..Default::default() })
            .solve(inst, c, inst.cold_start())
    }

    #[test]
    fn w_and_theta_forms_agree() {
        let ds = synth::toy_gaussian(31, 60, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = solve(&inst, 0.5);
        let w_rule = Dvi::new_w();
        let t_rule = Dvi::new_theta(&inst);
        let a = w_rule.screen(&inst, 0.5, 0.8, &r.theta, &r.u);
        let b = t_rule.screen(&inst, 0.5, 0.8, &r.theta, &r.u);
        assert_eq!(a.decisions, b.decisions);
        assert!(a.rejection() > 0.0, "expected some screening on a separable toy");
    }

    #[test]
    fn dvi_is_safe_on_svm() {
        let ds = synth::toy_gaussian(32, 80, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let (c0, c1) = (0.3, 0.6);
        let r0 = solve(&inst, c0);
        let rep = Dvi::new_w().screen(&inst, c0, c1, &r0.theta, &r0.u);
        // ground truth at c1
        let r1 = solve(&inst, c1);
        let w1 = inst.w_from_theta(c1, &r1.theta);
        let truth = classify_kkt(&inst, &w1, 1e-7);
        for (i, d) in rep.decisions.iter().enumerate() {
            match d {
                Decision::AtLo => assert_eq!(truth.classes[i], KktClass::R, "i={i}"),
                Decision::AtHi => assert_eq!(truth.classes[i], KktClass::L, "i={i}"),
                Decision::Keep => {}
            }
        }
    }

    #[test]
    fn dvi_is_safe_on_lad() {
        let mut rng = Rng::new(8);
        let ds = synth::random_regression(&mut rng, 100, 6);
        let inst = Instance::from_dataset(Model::Lad, &ds);
        let (c0, c1) = (0.2, 0.5);
        let r0 = solve(&inst, c0);
        let rep = Dvi::new_w().screen(&inst, c0, c1, &r0.theta, &r0.u);
        let r1 = solve(&inst, c1);
        let w1 = inst.w_from_theta(c1, &r1.theta);
        let truth = classify_kkt(&inst, &w1, 1e-7);
        let mut screened = 0;
        for (i, d) in rep.decisions.iter().enumerate() {
            match d {
                Decision::AtLo => {
                    screened += 1;
                    assert_eq!(truth.classes[i], KktClass::R, "i={i}");
                }
                Decision::AtHi => {
                    screened += 1;
                    assert_eq!(truth.classes[i], KktClass::L, "i={i}");
                }
                Decision::Keep => {}
            }
        }
        assert!(screened > 0, "LAD screening found nothing");
    }

    #[test]
    fn closer_parameters_screen_more() {
        let ds = synth::toy_gaussian(33, 100, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let c0 = 1.0;
        let r0 = solve(&inst, c0);
        let rule = Dvi::new_w();
        let near = rule.screen(&inst, c0, 1.05, &r0.theta, &r0.u);
        let far = rule.screen(&inst, c0, 5.0, &r0.theta, &r0.u);
        assert!(
            near.rejection() >= far.rejection(),
            "near {} < far {}",
            near.rejection(),
            far.rejection()
        );
    }

    #[test]
    fn theorem6_ball_contains_next_solution() {
        let ds = synth::toy_gaussian(34, 60, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        for (c0, c1) in [(0.1, 0.2), (0.5, 2.0), (1.0, 1.01)] {
            let t0 = solve(&inst, c0).theta;
            let t1 = solve(&inst, c1).theta;
            let (dist, radius) = theorem6_ball(&inst, c0, c1, &t0, &t1);
            assert!(
                dist <= radius + 1e-6,
                "C {c0}->{c1}: dist {dist} > radius {radius}"
            );
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_increasing_c() {
        let ds = synth::toy_gaussian(35, 10, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = solve(&inst, 1.0);
        Dvi::new_w().screen(&inst, 1.0, 1.0, &r.theta, &r.u);
    }

    #[test]
    fn screen_w_par_matches_rule_screen() {
        let ds = synth::toy_gaussian(37, 60, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = solve(&inst, 0.5);
        let u = inst.u_from_theta(&r.theta);
        let want = Dvi::new_w().screen(&inst, 0.5, 0.8, &r.theta, &u);
        for threads in [1usize, 3, 0] {
            let got = screen_w_par(&inst, 0.5, 0.8, &u, threads);
            assert_eq!(got.decisions, want.decisions, "threads={threads}");
        }
        let (mid, rad) = ball_params(0.5, 0.8);
        assert_eq!(mid, 0.5 * (0.8 + 0.5));
        assert_eq!(rad, 0.5 * (0.8 - 0.5));
    }

    #[test]
    fn par_scan_matches_serial_scan_exactly() {
        // l = 103 is prime, so no thread count divides it evenly
        let ds = synth::gaussian_classes(40, 103, 5, 1.0, 1.0, 0.5, 1.0);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = solve(&inst, 0.4);
        let want = dvi_scan(&inst, 0.55, 0.15, &r.u);
        for threads in [1usize, 2, 4, 7, 0] {
            let got = dvi_scan_par(&inst, 0.55, 0.15, &r.u, threads);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn sparse_scan_matches_dense_scan_exactly() {
        use crate::linalg::Storage;
        let ds = synth::sparse_classes(17, 151, 33, 0.12); // prime l, uneven rows
        let dense_ds = ds.clone().into_storage(Storage::Dense);
        let sp = Instance::from_dataset(Model::Svm, &ds);
        let de = Instance::from_dataset(Model::Svm, &dense_ds);
        let r = solve(&de, 0.4);
        let want = dvi_scan(&de, 0.55, 0.15, &r.u);
        assert_eq!(dvi_scan(&sp, 0.55, 0.15, &r.u), want, "serial sparse scan");
        for threads in [1usize, 2, 4, 7, 0] {
            assert_eq!(
                dvi_scan_par(&sp, 0.55, 0.15, &r.u, threads),
                want,
                "sparse threads={threads}"
            );
        }
        // θ-form over a sparse Gram build agrees too
        let a = Dvi::new_theta(&de).screen(&de, 0.4, 0.7, &r.theta, &r.u);
        let b = Dvi::new_theta_threads(&sp, 3).screen(&sp, 0.4, 0.7, &r.theta, &r.u);
        assert_eq!(a.decisions, b.decisions);
    }

    #[test]
    fn parallel_gram_build_matches_serial() {
        let ds = synth::toy_gaussian(41, 30, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = solve(&inst, 0.5);
        let serial = Dvi::new_theta(&inst);
        for threads in [2usize, 3, 7, 0] {
            let par_rule = Dvi::new_theta_threads(&inst, threads);
            assert_eq!(
                serial.gram.as_ref().unwrap().flat(),
                par_rule.gram.as_ref().unwrap().flat(),
                "threads={threads}"
            );
            let a = serial.screen(&inst, 0.5, 0.8, &r.theta, &r.u);
            let b = par_rule.screen(&inst, 0.5, 0.8, &r.theta, &r.u);
            assert_eq!(a.decisions, b.decisions);
        }
    }

    #[test]
    fn sparse_parallel_gram_build_matches_serial() {
        // prime l and random row lengths: the nnz-weighted triangle
        // bounds differ from the area bounds, the built matrix must not
        let ds = synth::sparse_classes(21, 97, 30, 0.15);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        assert!(inst.z.is_sparse());
        let serial = Dvi::new_theta(&inst);
        for threads in [2usize, 3, 7, 0] {
            let par_rule = Dvi::new_theta_threads(&inst, threads);
            assert_eq!(
                serial.gram.as_ref().unwrap().flat(),
                par_rule.gram.as_ref().unwrap().flat(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn cols_axis_gram_build_matches_serial() {
        use crate::linalg::Storage;
        // prime l so no shard count divides the column slabs evenly
        for ds in [
            synth::toy_gaussian(43, 53, 1.0, 0.75),
            synth::sparse_classes(44, 61, 24, 0.2).into_storage(Storage::Csr),
        ] {
            let inst = Instance::from_dataset(Model::Svm, &ds);
            let serial = Dvi::new_theta(&inst);
            for threads in [1usize, 2, 4, 7, 0] {
                for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Auto] {
                    let rule = Dvi::new_theta_axis(&inst, threads, axis);
                    assert_eq!(
                        serial.gram.as_ref().unwrap().flat(),
                        rule.gram.as_ref().unwrap().flat(),
                        "threads={threads} axis={}",
                        axis.name()
                    );
                }
            }
        }
    }

    #[test]
    fn weighted_svm_screening_safe() {
        let ds = synth::gaussian_classes(36, 120, 4, 1.5, 1.0, 0.3, 1.0);
        let inst = Instance::from_dataset(Model::WeightedSvm, &ds);
        let (c0, c1) = (0.2, 0.35);
        let r0 = solve(&inst, c0);
        let rep = Dvi::new_w().screen(&inst, c0, c1, &r0.theta, &r0.u);
        let r1 = solve(&inst, c1);
        let w1 = inst.w_from_theta(c1, &r1.theta);
        let truth = classify_kkt(&inst, &w1, 1e-7);
        for (i, d) in rep.decisions.iter().enumerate() {
            match d {
                Decision::AtLo => assert_eq!(truth.classes[i], KktClass::R, "i={i}"),
                Decision::AtHi => assert_eq!(truth.classes[i], KktClass::L, "i={i}"),
                Decision::Keep => {}
            }
        }
    }
}
