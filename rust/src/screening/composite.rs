//! Rule composition: intersect member regions, keep the tightest per-row
//! bounds.
//!
//! Safety: every member's region contains the dual optimum at the next
//! parameter value (that is each rule's contract), so their intersection
//! does too — screening against the intersection is exactly as safe as
//! against any member, and at least as tight. Per row the intersection's
//! interval is lo = max over members, hi = min over members
//! ([`DualRegion::Intersect`]), so any row a member rejects, the
//! composite rejects: a composed rule's rejection rate dominates every
//! member's *by construction*, on the same solved context. The `dvi
//! gauntlet` bench records that dominance and
//! `tests/integration_screening_rules.rs` locks it.

use super::region::{self, DualRegion};
use super::rule::{ScreeningRule, StepContext};
use super::Decision;
use crate::problem::Instance;

/// Intersection of member rules (built from `"a+b"` expressions by
/// [`super::RuleExpr::build`]).
pub struct Composite {
    members: Vec<Box<dyn ScreeningRule>>,
}

impl Composite {
    pub fn new(members: Vec<Box<dyn ScreeningRule>>) -> Composite {
        assert!(members.len() >= 2, "a composite needs at least two members");
        Composite { members }
    }
}

impl ScreeningRule for Composite {
    fn name(&self) -> String {
        self.members.iter().map(|m| m.name()).collect::<Vec<_>>().join("+")
    }

    fn requires_cmax(&self) -> bool {
        self.members.iter().any(|m| m.requires_cmax())
    }

    fn init(&mut self, inst: &Instance, threads: usize) {
        for m in &mut self.members {
            m.init(inst, threads);
        }
    }

    fn prepare(&self, inst: &Instance, ctx: &StepContext) -> DualRegion {
        DualRegion::Intersect(self.members.iter().map(|m| m.prepare(inst, ctx)).collect())
    }

    // Member kernels (e.g. the PJRT scan) are deliberately not consulted
    // here, matching the pre-refactor behavior where specialized backends
    // only ever served the plain dvi rule.
    fn screen_rows(
        &mut self,
        inst: &Instance,
        region: &DualRegion,
        threads: usize,
    ) -> Vec<Decision> {
        // fused single-pass intersection sweep — decisions byte-identical
        // to the trait's generic sweep (locked by
        // `tests/integration_screening_rules.rs` and region::tests)
        region::screen_rows_fused(inst, region, threads)
    }
}
