//! Dual-feasible regions — the geometric half of the composable engine.
//!
//! Every safe rule in this repo works the same way: it constructs a
//! region that provably contains the (transformed) dual optimum at the
//! next parameter value, then bounds the per-row score sᵢ over that
//! region. DVI bounds Zᵀθ*(C_next) in Theorem 6's ball and evaluates
//! mid·⟨u, zᵢ⟩ ± rad·‖u‖·‖zᵢ‖; SSNSV/ESSNSV bound w*(C) in a
//! half-space-intersected ball (Ogawa et al. §IV) and extremize ⟨w, x̄ᵢ⟩
//! via Lemma 20. Either way the output is a per-row interval
//! [loᵢ, hiᵢ] compared against ȳᵢ:
//!
//! ```text
//!   loᵢ > ȳᵢ  ⇒  AtLo (paper's R set)
//!   hiᵢ < ȳᵢ  ⇒  AtHi (paper's L set)
//! ```
//!
//! Because every region contains the optimum, *intersecting* regions is
//! also safe: the intersection still contains the optimum, and the
//! tightest per-row bounds are simply lo = max over members, hi = min
//! over members ([`DualRegion::Intersect`]). That max/min construction
//! is what makes a composed rule dominate each member by construction —
//! any row a member rejects, the composite rejects.
//!
//! The per-row expressions below are kept *textually identical* to the
//! pre-refactor kernels in [`super::dvi`] and [`super::ssnsv`], so the
//! trait-based rules reproduce the enum-dispatch decisions bit for bit
//! (locked by `tests/integration_screening_rules.rs`).

use super::ssnsv::{ball_min, lemma20_min};
use super::Decision;
use crate::linalg::{par, RowView};
use crate::problem::Instance;

/// A region guaranteed to contain the dual optimum at the next parameter
/// value, in whichever space the owning rule screens.
#[derive(Clone, Debug)]
pub enum DualRegion {
    /// No information — every row stays free (the `none` rule).
    All,
    /// Theorem-6 ball screened in w-form (DVI_s, Cor. 9): per-row score
    /// mid·⟨u, zᵢ⟩ with slack rad·‖u‖·‖zᵢ‖.
    BallW { mid: f64, rad: f64, u: Vec<f64>, u_norm: f64 },
    /// Theorem-6 ball screened in θ-form (DVI_s*, Cor. 8): ⟨u, zᵢ⟩ read
    /// from the cached Gram matvec, ‖zᵢ‖ from its diagonal.
    BallTheta { mid: f64, rad: f64, gtheta: Vec<f64>, u_norm: f64, zn: Vec<f64> },
    /// SSNSV/ESSNSV region: ball ‖w − center‖ ≤ radius intersected with
    /// the variational-inequality half-space uᵀw ≤ d (`cone = (u, d)`;
    /// `None` when the anchor is degenerate and the half-space vacuous).
    ConeBall { cone: Option<(Vec<f64>, f64)>, center: Vec<f64>, radius: f64 },
    /// Intersection of member regions: per-row lo = max, hi = min.
    Intersect(Vec<DualRegion>),
}

/// Reusable per-shard buffers for rules that materialize x̄ᵢ per row.
pub struct RowScratch {
    xbar: Vec<f64>,
    neg: Vec<f64>,
}

impl RowScratch {
    pub fn new(dim: usize) -> RowScratch {
        RowScratch { xbar: vec![0.0; dim], neg: vec![0.0; dim] }
    }
}

impl DualRegion {
    /// The tightest [lo, hi] interval this region implies for row `i`'s
    /// score. `ybar` is passed so the cone∩ball case can skip the upper
    /// extremization once the lower bound alone rejects the row — the
    /// exact short-circuit the pre-refactor SSNSV loop performs.
    pub fn row_bounds(
        &self,
        inst: &Instance,
        i: usize,
        ybar: f64,
        scratch: &mut RowScratch,
    ) -> (f64, f64) {
        match self {
            DualRegion::All => (f64::NEG_INFINITY, f64::INFINITY),
            DualRegion::BallW { mid, rad, u, u_norm } => {
                let p = inst.z.row(i).dot(u); // ⟨u, zᵢ⟩
                let zn = inst.z_norms_sq[i].sqrt();
                let slack = rad * u_norm * zn;
                let score = mid * p;
                (score - slack, score + slack)
            }
            DualRegion::BallTheta { mid, rad, gtheta, u_norm, zn } => {
                let p = gtheta[i]; // gᵢᵀθ = ⟨u, zᵢ⟩
                let slack = rad * u_norm * zn[i];
                let score = mid * p;
                (score - slack, score + slack)
            }
            DualRegion::ConeBall { cone, center, radius } => {
                // x̄ᵢ = yᵢxᵢ = −zᵢ for (weighted) SVM. Dense rows overwrite
                // every position directly; sparse rows reset then scatter.
                match inst.z.row(i) {
                    RowView::Dense(r) => {
                        for (x, z) in scratch.xbar.iter_mut().zip(r) {
                            *x = -z;
                        }
                    }
                    sparse => {
                        scratch.xbar.iter_mut().for_each(|x| *x = 0.0);
                        for (j, z) in sparse.iter() {
                            scratch.xbar[j] = -z;
                        }
                    }
                }
                let lower = match cone {
                    Some((u, d)) => lemma20_min(&scratch.xbar, u, *d, center, *radius),
                    None => ball_min(&scratch.xbar, center, *radius),
                };
                if lower > ybar {
                    // the lower bound already rejects; the upper
                    // extremization is never evaluated (and can't matter:
                    // the decision logic tests lo first)
                    return (lower, f64::INFINITY);
                }
                // max⟨w,x̄⟩ = −min⟨w,−x̄⟩
                for (n, x) in scratch.neg.iter_mut().zip(&scratch.xbar) {
                    *n = -x;
                }
                let upper = -match cone {
                    Some((u, d)) => lemma20_min(&scratch.neg, u, *d, center, *radius),
                    None => ball_min(&scratch.neg, center, *radius),
                };
                (lower, upper)
            }
            DualRegion::Intersect(members) => {
                let mut lo = f64::NEG_INFINITY;
                let mut hi = f64::INFINITY;
                for m in members {
                    let (ml, mh) = m.row_bounds(inst, i, ybar, scratch);
                    lo = lo.max(ml);
                    hi = hi.min(mh);
                }
                (lo, hi)
            }
        }
    }
}

/// Shared decision core over an interval: lo > ȳᵢ fixes the row at the
/// lower bound, hi < ȳᵢ at the upper — the exact comparison order of the
/// pre-refactor `dvi::decide` (score ± slack) and SSNSV loops.
#[inline]
pub fn decide_bounds(lo: f64, hi: f64, ybar: f64) -> Decision {
    if lo > ybar {
        Decision::AtLo
    } else if hi < ybar {
        Decision::AtHi
    } else {
        Decision::Keep
    }
}

/// Decide row `i` against an intersection in one fused member walk,
/// without materializing the combined interval. Members are consulted in
/// order and the walk stops at the first member whose *lower* bound alone
/// rejects the row — for ConeBall members that also skips their upper
/// extremization (a second `lemma20_min`) *and* every later member's
/// bounds entirely.
///
/// Byte-identity with `decide_bounds(row_bounds(Intersect(..)))`:
/// [`decide_bounds`] tests `lo > ȳᵢ` FIRST, and the intersection's lo is
/// the max over members, so "some member's ml > ȳᵢ" ⟺ "lo > ȳᵢ" ⟺ AtLo —
/// which member trips it cannot change the decision. The AtHi side takes
/// no shortcut: hi must be the min over *all* members before comparing,
/// exactly as the unfused walk computes it.
#[inline]
pub(super) fn fused_row_decision(
    inst: &Instance,
    members: &[DualRegion],
    i: usize,
    ybar: f64,
    scratch: &mut RowScratch,
) -> Decision {
    let mut hi = f64::INFINITY;
    for m in members {
        let (ml, mh) = m.row_bounds(inst, i, ybar, scratch);
        if ml > ybar {
            return Decision::AtLo;
        }
        hi = hi.min(mh);
    }
    if hi < ybar {
        Decision::AtHi
    } else {
        Decision::Keep
    }
}

/// Evaluate a region over one contiguous row range.
fn scan_range(
    inst: &Instance,
    region: &DualRegion,
    rows: std::ops::Range<usize>,
    scratch: &mut RowScratch,
) -> Vec<Decision> {
    let mut out = Vec::with_capacity(rows.end - rows.start);
    for i in rows {
        let ybar = inst.ybar[i];
        let (lo, hi) = region.row_bounds(inst, i, ybar, scratch);
        out.push(decide_bounds(lo, hi, ybar));
    }
    out
}

/// The generic row sweep behind [`super::ScreeningRule::screen_rows`]:
/// nnz-balanced contiguous shards on `std::thread::scope` workers
/// (`threads`: 0 = auto, 1 = serial), merged in shard order. Per-row
/// bounds are independent of sharding, so decisions are byte-identical
/// for any thread count and either storage — the same contract
/// [`super::dvi::dvi_scan_par`] keeps.
pub fn screen_rows(inst: &Instance, region: &DualRegion, threads: usize) -> Vec<Decision> {
    let l = inst.len();
    let t = par::effective_threads(threads, l);
    if t <= 1 {
        let mut scratch = RowScratch::new(inst.dim());
        return scan_range(inst, region, 0..l, &mut scratch);
    }
    let shards = par::run_sharded_ranges(inst.balanced_shards(t), |r| {
        let mut scratch = RowScratch::new(inst.dim());
        scan_range(inst, region, r, &mut scratch)
    });
    let mut out = Vec::with_capacity(l);
    for mut s in shards {
        out.append(&mut s);
    }
    out
}

/// [`screen_rows`] specialized for intersections: each row makes ONE
/// member walk through [`fused_row_decision`] instead of materializing
/// the combined [lo, hi] and deciding afterwards. Decisions are
/// byte-identical to the generic sweep for any thread count (same
/// shards, same per-member arithmetic, same comparison order — only
/// provably-irrelevant work is skipped); `tests` lock this. Non-intersect
/// regions fall through to the generic sweep unchanged.
pub fn screen_rows_fused(inst: &Instance, region: &DualRegion, threads: usize) -> Vec<Decision> {
    let members = match region {
        DualRegion::Intersect(ms) => ms.as_slice(),
        _ => return screen_rows(inst, region, threads),
    };
    let l = inst.len();
    let t = par::effective_threads(threads, l);
    let scan = |rows: std::ops::Range<usize>, scratch: &mut RowScratch| {
        let mut out = Vec::with_capacity(rows.end - rows.start);
        for i in rows {
            out.push(fused_row_decision(inst, members, i, inst.ybar[i], scratch));
        }
        out
    };
    if t <= 1 {
        let mut scratch = RowScratch::new(inst.dim());
        return scan(0..l, &mut scratch);
    }
    let shards = par::run_sharded_ranges(inst.balanced_shards(t), |r| {
        let mut scratch = RowScratch::new(inst.dim());
        scan(r, &mut scratch)
    });
    let mut out = Vec::with_capacity(l);
    for mut s in shards {
        out.append(&mut s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decide_bounds_matches_interval_logic() {
        assert_eq!(decide_bounds(1.5, 2.0, 1.0), Decision::AtLo);
        assert_eq!(decide_bounds(-2.0, 0.5, 1.0), Decision::AtHi);
        assert_eq!(decide_bounds(0.5, 1.5, 1.0), Decision::Keep);
        // boundary: strict inequalities, ties keep
        assert_eq!(decide_bounds(1.0, 1.0, 1.0), Decision::Keep);
        assert_eq!(decide_bounds(f64::NEG_INFINITY, f64::INFINITY, 0.0), Decision::Keep);
    }

    #[test]
    fn intersect_takes_tightest_bounds() {
        use crate::data::synth;
        use crate::problem::Model;
        let ds = synth::toy_gaussian(3, 12, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let u = vec![0.3, -0.2];
        let u_norm = crate::linalg::norm(&u);
        let wide =
            DualRegion::BallW { mid: 1.0, rad: 2.0, u: u.clone(), u_norm };
        let tight = DualRegion::BallW { mid: 1.0, rad: 0.1, u, u_norm };
        let both = DualRegion::Intersect(vec![wide.clone(), tight.clone()]);
        let mut s = RowScratch::new(inst.dim());
        for i in 0..inst.len() {
            let y = inst.ybar[i];
            let (wl, wh) = wide.row_bounds(&inst, i, y, &mut s);
            let (tl, th) = tight.row_bounds(&inst, i, y, &mut s);
            let (bl, bh) = both.row_bounds(&inst, i, y, &mut s);
            assert_eq!(bl, wl.max(tl), "i={i}");
            assert_eq!(bh, wh.min(th), "i={i}");
        }
    }

    #[test]
    fn fused_intersection_is_byte_identical() {
        use crate::data::synth;
        use crate::problem::Model;
        let ds = synth::gaussian_classes(23, 97, 3, 1.0, 1.0, 0.5, 1.0);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let u: Vec<f64> = (0..inst.dim()).map(|j| (j as f64 * 0.9).cos()).collect();
        let u_norm = crate::linalg::norm(&u);
        let center: Vec<f64> = u.iter().map(|v| 0.4 * v).collect();
        // three members of different kinds, including a ConeBall whose
        // upper extremization the fusion skips on lower-bound rejections
        let region = DualRegion::Intersect(vec![
            DualRegion::BallW { mid: 0.8, rad: 0.3, u: u.clone(), u_norm },
            DualRegion::ConeBall { cone: Some((u.clone(), 0.2)), center, radius: 0.5 },
            DualRegion::BallW { mid: 0.5, rad: 1.5, u, u_norm },
        ]);
        for threads in [1usize, 2, 3, 7, 0] {
            let generic = screen_rows(&inst, &region, threads);
            let fused = screen_rows_fused(&inst, &region, threads);
            assert_eq!(generic, fused, "threads={threads}");
        }
        // non-intersect regions fall through unchanged
        let ball = DualRegion::All;
        assert_eq!(screen_rows_fused(&inst, &ball, 2), screen_rows(&inst, &ball, 2));
    }

    #[test]
    fn sweep_is_thread_invariant() {
        use crate::data::synth;
        use crate::problem::Model;
        // prime l so no thread count divides it evenly
        let ds = synth::gaussian_classes(19, 101, 4, 1.0, 1.0, 0.5, 1.0);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let u: Vec<f64> = (0..inst.dim()).map(|j| (j as f64 * 0.7).sin()).collect();
        let u_norm = crate::linalg::norm(&u);
        let region = DualRegion::BallW { mid: 0.6, rad: 0.2, u, u_norm };
        let want = screen_rows(&inst, &region, 1);
        for threads in [2usize, 3, 4, 7, 0] {
            assert_eq!(screen_rows(&inst, &region, threads), want, "threads={threads}");
        }
    }
}
