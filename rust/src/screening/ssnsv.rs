//! SSNSV (Ogawa et al., ICML 2013) and the paper's VI-enhanced variant
//! ESSNSV (§5.2, Theorem 19) — the baselines DVI is compared against.
//!
//! Both bound w*(C) inside a region Ω and apply (R1″)/(R2″):
//!
//! ```text
//!   min_{w∈Ω} ⟨w, x̄ᵢ⟩ > 1  ⇒  i ∈ R (θᵢ = 0)
//!   max_{w∈Ω} ⟨w, x̄ᵢ⟩ < 1  ⇒  i ∈ L (θᵢ = 1)
//! ```
//!
//! with x̄ᵢ = yᵢxᵢ. The region is the intersection of
//!
//! * a half-space from the variational inequality at the solved point
//!   w_a := w*(C_k):  ⟨w_a, w − w_a⟩ ≥ 0, and
//! * a ball from a feasible point ŵ := w*(C_max) of the loss-constrained
//!   formulation (26):
//!   * SSNSV (Eq. 27): ‖w‖ ≤ ‖ŵ‖ (center 0, radius ‖ŵ‖);
//!   * ESSNSV (Eq. 28): ‖w − ŵ/2‖ ≤ ‖ŵ‖/2 — *half* the radius, obtained
//!     by applying the same VI trick DVI uses; Ω′ ⊂ Ω, so ESSNSV
//!     dominates SSNSV pointwise.
//!
//! The extremization over cone∩ball is Lemma 20's closed form,
//! implemented in [`lemma20_min`].
//!
//! Path protocol (paper Table 2 "Init."): requires solving at *both* grid
//! extremes — ŵ comes from C_max (feasible for every smaller C's loss
//! level since the loss s(C) decreases as C grows... s(C_max) ≤ s(C) for
//! C ≤ C_max), while the half-space anchor is the most recent solved point
//! w*(C_k), valid for all C ≥ C_k.
//!
//! SSNSV is defined for SVM only (the 2013 paper and this paper's
//! experiments); the constructor rejects LAD instances.

use super::{Decision, ScreenReport};
use crate::linalg::{self, RowView};
use crate::problem::{Instance, Model};

/// Inputs for one SSNSV/ESSNSV screening application.
#[derive(Clone, Debug)]
pub struct SsnsvContext<'a> {
    /// w*(C_k) — optimal at the most recent solved path point (the
    /// half-space anchor; the paper's w*(s_a)).
    pub w_anchor: &'a [f64],
    /// ŵ — a feasible point for the target loss level; along a C-path,
    /// w*(C_max) (the paper's ŵ(s_b)).
    pub w_feasible: &'a [f64],
}

/// SSNSV baseline rule; `enhanced = true` gives ESSNSV.
#[derive(Clone, Copy, Debug)]
pub struct Ssnsv {
    pub enhanced: bool,
}

impl Ssnsv {
    pub fn new(enhanced: bool) -> Self {
        Ssnsv { enhanced }
    }

    /// Screen all instances. Panics on LAD instances (rule is SVM-only).
    pub fn screen(&self, inst: &Instance, ctx: &SsnsvContext) -> ScreenReport {
        assert!(
            inst.model != Model::Lad,
            "SSNSV/ESSNSV are derived for SVM only"
        );
        let w_a = ctx.w_anchor;
        let w_hat = ctx.w_feasible;
        assert_eq!(w_a.len(), inst.dim());
        assert_eq!(w_hat.len(), inst.dim());

        let wa_norm_sq = linalg::norm_sq(w_a);
        let what_norm = linalg::norm(w_hat);
        // Degenerate anchor (w_a = 0): the half-space is vacuous; fall
        // back to ball-only bounds (Cauchy–Schwarz on the ball).
        let cone = if wa_norm_sq > 0.0 {
            Some(Cone { u: w_a.iter().map(|v| -v).collect::<Vec<f64>>(), d: -wa_norm_sq })
        } else {
            None
        };
        let (o, r): (Vec<f64>, f64) = if self.enhanced {
            (w_hat.iter().map(|v| 0.5 * v).collect(), 0.5 * what_norm)
        } else {
            (vec![0.0; inst.dim()], what_norm)
        };

        let l = inst.len();
        let mut decisions = Vec::with_capacity(l);
        let mut xbar = vec![0.0; inst.dim()];
        for i in 0..l {
            // x̄ᵢ = yᵢxᵢ = −zᵢ for (weighted) SVM. Dense rows overwrite
            // every position directly (no reset pass); sparse rows reset
            // then scatter their stored entries, never densifying.
            match inst.z.row(i) {
                RowView::Dense(r) => {
                    for (x, z) in xbar.iter_mut().zip(r) {
                        *x = -z;
                    }
                }
                sparse => {
                    xbar.iter_mut().for_each(|x| *x = 0.0);
                    for (j, z) in sparse.iter() {
                        xbar[j] = -z;
                    }
                }
            }
            let lower = match &cone {
                Some(c) => lemma20_min(&xbar, &c.u, c.d, &o, r),
                None => ball_min(&xbar, &o, r),
            };
            if lower > inst.ybar[i] {
                decisions.push(Decision::AtLo);
                continue;
            }
            // max⟨w,x̄⟩ = −min⟨w,−x̄⟩
            let neg: Vec<f64> = xbar.iter().map(|v| -v).collect();
            let upper = -match &cone {
                Some(c) => lemma20_min(&neg, &c.u, c.d, &o, r),
                None => ball_min(&neg, &o, r),
            };
            if upper < inst.ybar[i] {
                decisions.push(Decision::AtHi);
            } else {
                decisions.push(Decision::Keep);
            }
        }
        ScreenReport::from_decisions(decisions)
    }
}

struct Cone {
    u: Vec<f64>,
    d: f64,
}

/// min ⟨v, w⟩ over ‖w − o‖ ≤ r (no half-space): vᵀo − r‖v‖.
pub(crate) fn ball_min(v: &[f64], o: &[f64], r: f64) -> f64 {
    linalg::dot(v, o) - r * linalg::norm(v)
}

/// Lemma 20: minimize vᵀw subject to uᵀw ≤ d and ‖w − o‖ ≤ r (r > 0).
///
/// With d′ = d − uᵀo:
/// * if vᵀu + ‖v‖·d′/r ≥ 0 the ball constraint alone is active:
///   f* = vᵀo − r‖v‖;
/// * otherwise both are active:
///   f* = vᵀo − ‖v⊥‖·√(r² − d′²/‖u‖²) + vᵀu·d′/‖u‖²,
///   v⊥ = v − (vᵀu/‖u‖²)·u.
pub fn lemma20_min(v: &[f64], u: &[f64], d: f64, o: &[f64], r: f64) -> f64 {
    debug_assert!(r > 0.0);
    let v_norm = linalg::norm(v);
    if v_norm == 0.0 {
        return linalg::dot(v, o); // constant objective
    }
    let u_norm_sq = linalg::norm_sq(u);
    if u_norm_sq == 0.0 {
        // half-space 0 ≤ d: vacuous if d ≥ 0, infeasible otherwise —
        // treat as ball-only (callers guarantee feasibility).
        return ball_min(v, o, r);
    }
    let d_prime = d - linalg::dot(u, o);
    let vu = linalg::dot(v, u);
    if vu + v_norm * d_prime / r >= 0.0 {
        return linalg::dot(v, o) - r * v_norm;
    }
    // both constraints active
    let scale = vu / u_norm_sq;
    let vperp_sq = (linalg::norm_sq(v) - scale * vu).max(0.0);
    let inside = (r * r - d_prime * d_prime / u_norm_sq).max(0.0);
    linalg::dot(v, o) - vperp_sq.sqrt() * inside.sqrt() + vu * d_prime / u_norm_sq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverConfig;
    use crate::data::synth;
    use crate::data::Rng;
    use crate::problem::{classify_kkt, KktClass};
    use crate::solver::CdSolver;

    fn solve(inst: &Instance, c: f64) -> crate::solver::SolveResult {
        CdSolver::new(SolverConfig { tol: 1e-9, ..Default::default() })
            .solve(inst, c, inst.cold_start())
    }

    /// Monte-Carlo check of Lemma 20 against random feasible points.
    #[test]
    fn lemma20_lower_bounds_feasible_points() {
        let mut rng = Rng::new(77);
        for trial in 0..200 {
            let n = 2 + (trial % 5);
            let v: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
            let u: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
            let o: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
            let r = rng.uniform_in(0.3, 2.0);
            // pick d so the problem is feasible: require the center obeys
            // the half-space with slack
            let d = linalg::dot(&u, &o) + rng.uniform_in(0.0, r * linalg::norm(&u));
            let fstar = lemma20_min(&v, &u, d, &o, r);
            // sample random points in the ball, project to half-space by
            // rejection
            let mut checked = 0;
            for _ in 0..500 {
                let dir: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
                let nn = linalg::norm(&dir);
                if nn == 0.0 {
                    continue;
                }
                let rad = r * rng.uniform().powf(1.0 / n as f64);
                let w: Vec<f64> = o
                    .iter()
                    .zip(&dir)
                    .map(|(oi, di)| oi + rad * di / nn)
                    .collect();
                if linalg::dot(&u, &w) <= d {
                    checked += 1;
                    let val = linalg::dot(&v, &w);
                    assert!(
                        val >= fstar - 1e-9,
                        "trial {trial}: feasible value {val} < f* {fstar}"
                    );
                }
            }
            assert!(checked > 0, "no feasible samples in trial {trial}");
        }
    }

    #[test]
    fn lemma20_ball_only_case() {
        // u pointing away from v so the half-space is inactive
        let v = vec![1.0, 0.0];
        let u = vec![1.0, 0.0];
        let o = vec![0.0, 0.0];
        // d large ⇒ half-space vacuous in the ball
        let f = lemma20_min(&v, &u, 100.0, &o, 2.0);
        assert!((f - (-2.0)).abs() < 1e-12);
    }

    #[test]
    fn lemma20_both_active_case() {
        // minimize w_x over ‖w‖≤1 intersect w_x ≥ 0 (uᵀw ≤ 0 with
        // u = (−1, 0)): optimum 0 at the boundary circle∩line... the
        // minimum of v=(1,0) over {w_x ≥ 0, ‖w‖ ≤ 1} is 0.
        let v = vec![1.0, 0.0];
        let u = vec![-1.0, 0.0];
        let f = lemma20_min(&v, &u, 0.0, &[0.0, 0.0], 1.0);
        assert!(f.abs() < 1e-12, "{f}");
    }

    fn setup_path(ds_seed: u32) -> (Instance, Vec<f64>, Vec<f64>, f64, f64) {
        let ds = synth::toy_gaussian(ds_seed, 80, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let (c_min, c_max) = (0.1, 2.0);
        let w_min = {
            let r = solve(&inst, c_min);
            inst.w_from_theta(c_min, &r.theta)
        };
        let w_max = {
            let r = solve(&inst, c_max);
            inst.w_from_theta(c_max, &r.theta)
        };
        (inst, w_min, w_max, c_min, c_max)
    }

    #[test]
    fn ssnsv_safe_and_essnsv_dominates() {
        let (inst, w_min, w_max, _c_min, c_max) = setup_path(41);
        let ctx = SsnsvContext { w_anchor: &w_min, w_feasible: &w_max };
        let base = Ssnsv::new(false).screen(&inst, &ctx);
        let enh = Ssnsv::new(true).screen(&inst, &ctx);

        // ESSNSV's region is a subset ⇒ every decision SSNSV makes,
        // ESSNSV makes too (pointwise dominance).
        for (b, e) in base.decisions.iter().zip(&enh.decisions) {
            if *b != Decision::Keep {
                assert_eq!(b, e, "ESSNSV lost a decision SSNSV made");
            }
        }
        assert!(enh.rejection() >= base.rejection());

        // safety vs the true membership at an interior C
        let c_mid = 0.7;
        let r_mid = solve(&inst, c_mid);
        let w_mid = inst.w_from_theta(c_mid, &r_mid.theta);
        let truth = classify_kkt(&inst, &w_mid, 1e-7);
        for (i, d) in enh.decisions.iter().enumerate() {
            match d {
                Decision::AtLo => assert_eq!(truth.classes[i], KktClass::R, "i={i}"),
                Decision::AtHi => assert_eq!(truth.classes[i], KktClass::L, "i={i}"),
                Decision::Keep => {}
            }
        }
        // also safe at the far end of the interval
        let r_end = solve(&inst, c_max);
        let w_end = inst.w_from_theta(c_max, &r_end.theta);
        let truth_end = classify_kkt(&inst, &w_end, 1e-7);
        for (i, d) in enh.decisions.iter().enumerate() {
            match d {
                Decision::AtLo => assert_eq!(truth_end.classes[i], KktClass::R, "i={i}"),
                Decision::AtHi => assert_eq!(truth_end.classes[i], KktClass::L, "i={i}"),
                Decision::Keep => {}
            }
        }
    }

    #[test]
    fn zero_anchor_falls_back_to_ball() {
        let ds = synth::toy_gaussian(42, 20, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let zeros = vec![0.0; 2];
        let r = solve(&inst, 1.0);
        let w_max = inst.w_from_theta(1.0, &r.theta);
        let ctx = SsnsvContext { w_anchor: &zeros, w_feasible: &w_max };
        // must not panic; ball-only bounds are valid (w*(C) ∈ ball)
        let rep = Ssnsv::new(true).screen(&inst, &ctx);
        assert_eq!(rep.decisions.len(), inst.len());
    }

    #[test]
    #[should_panic(expected = "SVM only")]
    fn rejects_lad() {
        let mut rng = Rng::new(9);
        let ds = synth::random_regression(&mut rng, 10, 2);
        let inst = Instance::from_dataset(Model::Lad, &ds);
        let w = vec![0.0; 2];
        let ctx = SsnsvContext { w_anchor: &w, w_feasible: &w };
        Ssnsv::new(false).screen(&inst, &ctx);
    }
}
