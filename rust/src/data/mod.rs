//! Datasets: the container type, deterministic synthetic generators for
//! the paper's toy experiments, simulated analogs of the paper's six real
//! data sets, and libsvm-format IO.

pub mod dataset;
pub mod io;
pub mod registry;
pub mod rng;
pub mod simreal;
pub mod synth;

pub use dataset::{Dataset, Task};
pub use rng::Rng;
