//! Name-based dataset resolution used by configs, the CLI, and the
//! coordinator: `toy1..toy3`, the six simulated real sets, parameterized
//! synthetics, or `file:<path>` (libsvm format).

use super::dataset::{Dataset, Task};
use super::{io, simreal, synth};
use crate::linalg::Storage;
use std::path::Path;

/// Resolve a dataset name with automatic storage selection (sparse libsvm
/// files load as CSR, dense synthetics stay dense).
///
/// * `toy1`/`toy2`/`toy3` — the paper's §7.1 synthetics (1000/class);
/// * `ijcnn1`, `wine`, `covertype`, `magic`, `computer`, `houses` — the
///   simulated analogs of the paper's real sets (scaled by `scale`);
/// * `gauss:<l>:<n>` / `linreg:<l>:<n>` / `sparse:<l>:<n>` /
///   `sparsereg:<l>:<n>` — parameterized synthetics (the sparse pair
///   generates 5%-density CSR data);
/// * `file:<path>` — libsvm file; task from `task` hint.
pub fn resolve(name: &str, scale: f64, task_hint: Task) -> Result<Dataset, String> {
    resolve_storage(name, scale, task_hint, Storage::Auto)
}

/// [`resolve`] with explicit storage selection: the resolved dataset is
/// converted to the requested storage (generated sets included, so
/// `--storage csr` can drive the whole pipeline through the sparse path
/// on any dataset). libsvm files parse straight into CSR and are only
/// densified when `storage` resolves to dense.
///
/// NOTE: when adding a name here (or to [`simreal::by_name`]), add it to
/// [`NAMED_DATASETS`] too — `peek_task_matches_resolution` replays that
/// table against this resolver, so a missing entry fails tests.
pub fn resolve_storage(
    name: &str,
    scale: f64,
    task_hint: Task,
    storage: Storage,
) -> Result<Dataset, String> {
    let ds = match name {
        "toy1" => synth::toy_gaussian(1, scaled_per_class(scale), 1.5, 0.75),
        "toy2" => synth::toy_gaussian(2, scaled_per_class(scale), 0.75, 0.75),
        "toy3" => synth::toy_gaussian(3, scaled_per_class(scale), 0.5, 0.75),
        _ => {
            if let Some(ds) = simreal::by_name(name, scale) {
                ds
            } else if let Some(rest) = name.strip_prefix("gauss:") {
                let (l, n) = parse_l_n(rest)?;
                synth::gaussian_classes(0xA11CE, l, n, 1.0, 1.0, 0.5, 1.0)
            } else if let Some(rest) = name.strip_prefix("linreg:") {
                let (l, n) = parse_l_n(rest)?;
                synth::linear_regression(0xB0B, l, n, 0.2, 0.05, 10.0)
            } else if let Some(rest) = name.strip_prefix("sparse:") {
                let (l, n) = parse_l_n(rest)?;
                synth::sparse_classes(0x5BA5E, l, n, 0.05)
            } else if let Some(rest) = name.strip_prefix("sparsereg:") {
                let (l, n) = parse_l_n(rest)?;
                synth::sparse_regression(0x5BA5F, l, n, 0.05, 0.2)
            } else if let Some(path) = name.strip_prefix("file:") {
                return io::read_libsvm_storage(Path::new(path), task_hint, 0, storage)
                    .map_err(|e| format!("read {path}: {e}"));
            } else {
                return Err(format!("unknown dataset `{name}`"));
            }
        }
    };
    Ok(ds.into_storage(storage))
}

/// Every concrete (non-parameterized, non-`file:`) registry name with
/// its task — the single table [`peek_task`] consults and
/// `peek_task_matches_resolution` replays against [`resolve`], so a name
/// added here without a resolver arm (or vice versa once the test list
/// of parameterized prefixes is consulted) fails tests instead of
/// silently diverging.
pub const NAMED_DATASETS: &[(&str, Task)] = &[
    ("toy1", Task::Classification),
    ("toy2", Task::Classification),
    ("toy3", Task::Classification),
    ("ijcnn1", Task::Classification),
    ("wine", Task::Classification),
    ("covertype", Task::Classification),
    ("magic", Task::Regression),
    ("computer", Task::Regression),
    ("houses", Task::Regression),
];

/// The task a registry name will resolve to, WITHOUT building the
/// dataset — `None` when the name is unknown or the task depends on
/// external content (`file:` paths take a caller hint). Lets callers
/// like `serve --preload` pick the matching model up front instead of
/// paying (and mis-counting) a failed trial construction.
pub fn peek_task(name: &str) -> Option<Task> {
    if let Some((_, task)) = NAMED_DATASETS.iter().find(|(n, _)| *n == name) {
        return Some(*task);
    }
    if name.starts_with("gauss:") || name.starts_with("sparse:") {
        Some(Task::Classification)
    } else if name.starts_with("linreg:") || name.starts_with("sparsereg:") {
        Some(Task::Regression)
    } else {
        None
    }
}

fn scaled_per_class(scale: f64) -> usize {
    ((1000.0 * scale).round() as usize).max(8)
}

fn parse_l_n(s: &str) -> Result<(usize, usize), String> {
    let (l, n) = s.split_once(':').ok_or_else(|| format!("expected <l>:<n>, got `{s}`"))?;
    let l: usize = l.parse().map_err(|e| format!("bad l: {e}"))?;
    let n: usize = n.parse().map_err(|e| format!("bad n: {e}"))?;
    if l == 0 || n == 0 {
        return Err("l and n must be positive".into());
    }
    Ok((l, n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toys_resolve() {
        let d = resolve("toy1", 0.1, Task::Classification).unwrap();
        assert_eq!(d.len(), 200);
        assert_eq!(d.dim(), 2);
        assert!(resolve("toy3", 0.05, Task::Classification).is_ok());
    }

    #[test]
    fn simreal_resolve() {
        let d = resolve("wine", 0.01, Task::Classification).unwrap();
        assert_eq!(d.dim(), 12);
    }

    #[test]
    fn parameterized_resolve() {
        let d = resolve("gauss:50:7", 1.0, Task::Classification).unwrap();
        assert_eq!((d.len(), d.dim()), (50, 7));
        let r = resolve("linreg:30:4", 1.0, Task::Regression).unwrap();
        assert_eq!((r.len(), r.dim()), (30, 4));
    }

    #[test]
    fn file_resolve_roundtrip() {
        let ds = synth::toy_gaussian(1, 10, 1.5, 0.75);
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_registry_{}.svm", std::process::id()));
        io::write_libsvm(&ds, &p).unwrap();
        let name = format!("file:{}", p.display());
        let back = resolve(&name, 1.0, Task::Classification).unwrap();
        assert_eq!(back.len(), 20);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn sparse_parameterized_resolve() {
        let d = resolve("sparse:60:40", 1.0, Task::Classification).unwrap();
        assert_eq!((d.len(), d.dim()), (60, 40));
        assert!(d.x.is_sparse());
        let r = resolve("sparsereg:30:20", 1.0, Task::Regression).unwrap();
        assert_eq!(r.task, Task::Regression);
        assert!(r.x.is_sparse());
    }

    #[test]
    fn storage_override_applies_to_generated_sets() {
        let csr = resolve_storage("toy1", 0.05, Task::Classification, Storage::Csr).unwrap();
        assert!(csr.x.is_sparse());
        let dense =
            resolve_storage("sparse:40:30", 1.0, Task::Classification, Storage::Dense).unwrap();
        assert!(!dense.x.is_sparse());
    }

    #[test]
    fn file_resolve_respects_storage() {
        let ds = synth::sparse_classes(9, 30, 50, 0.05);
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_registry_sparse_{}.svm", std::process::id()));
        io::write_libsvm(&ds, &p).unwrap();
        let name = format!("file:{}", p.display());
        let auto = resolve(&name, 1.0, Task::Classification).unwrap();
        assert!(auto.x.is_sparse(), "5% density file must auto-load as CSR");
        let dense = resolve_storage(&name, 1.0, Task::Classification, Storage::Dense).unwrap();
        assert!(!dense.x.is_sparse());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn peek_task_matches_resolution() {
        // every named dataset, driven from the shared NAMED_DATASETS
        // table peek_task consults, plus one of each parameterized
        // prefix: the peeked task must match what resolution produces
        let mut probes: Vec<(String, f64)> = NAMED_DATASETS
            .iter()
            .map(|(n, _)| (n.to_string(), if n.starts_with("toy") { 0.05 } else { 0.005 }))
            .collect();
        for p in ["gauss:20:3", "sparse:20:10", "linreg:20:3", "sparsereg:20:10"] {
            probes.push((p.to_string(), 1.0));
        }
        for (name, scale) in probes {
            let task = peek_task(&name).expect(&name);
            let ds = resolve(&name, scale, task).unwrap();
            assert_eq!(ds.task, task, "{name}");
        }
        assert_eq!(peek_task("no-such-set"), None);
        assert_eq!(peek_task("file:/tmp/x.svm"), None, "file content decides");
    }

    #[test]
    fn errors() {
        assert!(resolve("nope", 1.0, Task::Classification).is_err());
        assert!(resolve("gauss:xx:3", 1.0, Task::Classification).is_err());
        assert!(resolve("gauss:0:3", 1.0, Task::Classification).is_err());
        assert!(resolve("file:/does/not/exist", 1.0, Task::Regression).is_err());
    }
}
