//! Name-based dataset resolution used by configs, the CLI, and the
//! coordinator: `toy1..toy3`, the six simulated real sets, parameterized
//! synthetics, or `file:<path>` (libsvm format).

use super::dataset::{Dataset, Task};
use super::{io, simreal, synth};
use std::path::Path;

/// Resolve a dataset name.
///
/// * `toy1`/`toy2`/`toy3` — the paper's §7.1 synthetics (1000/class);
/// * `ijcnn1`, `wine`, `covertype`, `magic`, `computer`, `houses` — the
///   simulated analogs of the paper's real sets (scaled by `scale`);
/// * `gauss:<l>:<n>` / `linreg:<l>:<n>` — parameterized synthetics;
/// * `file:<path>` — libsvm file; task from `task` hint.
pub fn resolve(name: &str, scale: f64, task_hint: Task) -> Result<Dataset, String> {
    match name {
        "toy1" => Ok(synth::toy_gaussian(1, scaled_per_class(scale), 1.5, 0.75)),
        "toy2" => Ok(synth::toy_gaussian(2, scaled_per_class(scale), 0.75, 0.75)),
        "toy3" => Ok(synth::toy_gaussian(3, scaled_per_class(scale), 0.5, 0.75)),
        _ => {
            if let Some(ds) = simreal::by_name(name, scale) {
                return Ok(ds);
            }
            if let Some(rest) = name.strip_prefix("gauss:") {
                let (l, n) = parse_l_n(rest)?;
                return Ok(synth::gaussian_classes(0xA11CE, l, n, 1.0, 1.0, 0.5, 1.0));
            }
            if let Some(rest) = name.strip_prefix("linreg:") {
                let (l, n) = parse_l_n(rest)?;
                return Ok(synth::linear_regression(0xB0B, l, n, 0.2, 0.05, 10.0));
            }
            if let Some(path) = name.strip_prefix("file:") {
                return io::read_libsvm(Path::new(path), task_hint, 0)
                    .map_err(|e| format!("read {path}: {e}"));
            }
            Err(format!("unknown dataset `{name}`"))
        }
    }
}

fn scaled_per_class(scale: f64) -> usize {
    ((1000.0 * scale).round() as usize).max(8)
}

fn parse_l_n(s: &str) -> Result<(usize, usize), String> {
    let (l, n) = s.split_once(':').ok_or_else(|| format!("expected <l>:<n>, got `{s}`"))?;
    let l: usize = l.parse().map_err(|e| format!("bad l: {e}"))?;
    let n: usize = n.parse().map_err(|e| format!("bad n: {e}"))?;
    if l == 0 || n == 0 {
        return Err("l and n must be positive".into());
    }
    Ok((l, n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toys_resolve() {
        let d = resolve("toy1", 0.1, Task::Classification).unwrap();
        assert_eq!(d.len(), 200);
        assert_eq!(d.dim(), 2);
        assert!(resolve("toy3", 0.05, Task::Classification).is_ok());
    }

    #[test]
    fn simreal_resolve() {
        let d = resolve("wine", 0.01, Task::Classification).unwrap();
        assert_eq!(d.dim(), 12);
    }

    #[test]
    fn parameterized_resolve() {
        let d = resolve("gauss:50:7", 1.0, Task::Classification).unwrap();
        assert_eq!((d.len(), d.dim()), (50, 7));
        let r = resolve("linreg:30:4", 1.0, Task::Regression).unwrap();
        assert_eq!((r.len(), r.dim()), (30, 4));
    }

    #[test]
    fn file_resolve_roundtrip() {
        let ds = synth::toy_gaussian(1, 10, 1.5, 0.75);
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_registry_{}.svm", std::process::id()));
        io::write_libsvm(&ds, &p).unwrap();
        let name = format!("file:{}", p.display());
        let back = resolve(&name, 1.0, Task::Classification).unwrap();
        assert_eq!(back.len(), 20);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn errors() {
        assert!(resolve("nope", 1.0, Task::Classification).is_err());
        assert!(resolve("gauss:xx:3", 1.0, Task::Classification).is_err());
        assert!(resolve("gauss:0:3", 1.0, Task::Classification).is_err());
        assert!(resolve("file:/does/not/exist", 1.0, Task::Regression).is_err());
    }
}
