//! Synthetic data generators for the paper's §7.1 toy experiments and for
//! randomized tests.
//!
//! The paper's Toy1/Toy2/Toy3 are two 1000-point classes drawn from
//! N((±μ, ±μ)ᵀ, 0.75²·I) with μ = 1.5, 0.75, 0.5 — increasingly
//! overlapping. `toy_gaussian` reproduces exactly that family; the other
//! generators cover regression (LAD) workloads with controllable outlier
//! contamination.

use super::dataset::{Dataset, Task};
use super::rng::Rng;
use crate::linalg::{CsrMatrix, RowMatrix};

/// The paper's 2-D two-gaussian toys. `toy_id` only names the set
/// (Toy1/2/3); pass `mu` = 1.5 / 0.75 / 0.5 and `sigma` = 0.75 for the
/// paper's versions. Each class gets `per_class` points; seeds are fixed
/// per toy so datasets are reproducible.
pub fn toy_gaussian(toy_id: u32, per_class: usize, mu: f64, sigma: f64) -> Dataset {
    let mut rng = Rng::new(0xD5C0 + toy_id as u64);
    let l = 2 * per_class;
    let mut x = RowMatrix::zeros(l, 2);
    let mut y = vec![0.0; l];
    for i in 0..per_class {
        // positive class at (+mu, +mu)
        x.set(i, 0, rng.normal(mu, sigma));
        x.set(i, 1, rng.normal(mu, sigma));
        y[i] = 1.0;
        // negative class at (−mu, −mu)
        let k = per_class + i;
        x.set(k, 0, rng.normal(-mu, sigma));
        x.set(k, 1, rng.normal(-mu, sigma));
        y[k] = -1.0;
    }
    Dataset::new(format!("toy{toy_id}"), Task::Classification, x, y)
}

/// The three paper toys at their published parameters.
pub fn paper_toys(per_class: usize) -> Vec<Dataset> {
    vec![
        toy_gaussian(1, per_class, 1.5, 0.75),
        toy_gaussian(2, per_class, 0.75, 0.75),
        toy_gaussian(3, per_class, 0.5, 0.75),
    ]
}

/// General gaussian-mixture classification set in n dimensions: class
/// centers at ±μ·1/√n (so the center separation is 2μ regardless of n),
/// optional anisotropy (per-coordinate scale ramp) and class imbalance.
pub fn gaussian_classes(
    seed: u64,
    l: usize,
    n: usize,
    mu: f64,
    sigma: f64,
    positive_fraction: f64,
    anisotropy: f64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = RowMatrix::zeros(l, n);
    let mut y = vec![0.0; l];
    let shift = mu / (n as f64).sqrt();
    for i in 0..l {
        let label = if rng.bernoulli(positive_fraction) { 1.0 } else { -1.0 };
        y[i] = label;
        for j in 0..n {
            // scale ramps linearly from 1 to `anisotropy` across coords
            let s = 1.0 + (anisotropy - 1.0) * j as f64 / (n.max(2) - 1) as f64;
            x.set(i, j, label * shift + rng.normal(0.0, sigma * s));
        }
    }
    Dataset::new(format!("gauss{seed}"), Task::Classification, x, y)
}

/// Linear-model regression data y = ⟨w°, x⟩ + ε with gaussian noise and a
/// fraction of gross outliers (the LAD motivation): outliers get noise
/// amplified by `outlier_scale`.
pub fn linear_regression(
    seed: u64,
    l: usize,
    n: usize,
    noise: f64,
    outlier_fraction: f64,
    outlier_scale: f64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let w0: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
    let mut x = RowMatrix::zeros(l, n);
    let mut y = vec![0.0; l];
    for i in 0..l {
        for j in 0..n {
            x.set(i, j, rng.normal(0.0, 1.0));
        }
        let clean = crate::linalg::dot(x.row(i), &w0);
        let eps = if rng.bernoulli(outlier_fraction) {
            rng.normal(0.0, noise * outlier_scale)
        } else {
            rng.normal(0.0, noise)
        };
        y[i] = clean + eps;
    }
    Dataset::new(format!("linreg{seed}"), Task::Regression, x, y)
}

/// Per-row nonzero entries for a random sparse design: each of the `n`
/// features is present with probability `density`, values N(0, 1).
fn sparse_design(rng: &mut Rng, l: usize, n: usize, density: f64) -> Vec<Vec<(usize, f64)>> {
    let mut rows = Vec::with_capacity(l);
    for _ in 0..l {
        let mut feats = Vec::new();
        for j in 0..n {
            if rng.bernoulli(density) {
                feats.push((j, rng.normal(0.0, 1.0)));
            }
        }
        rows.push(feats);
    }
    rows
}

/// Randomized sparse two-class set in CSR storage (the shape of the
/// paper's real libsvm benchmarks): features present with probability
/// `density`, labels from a dense random hyperplane with a noise margin
/// so both classes occur and the problem is learnable but not separable.
pub fn sparse_classes(seed: u64, l: usize, n: usize, density: f64) -> Dataset {
    let mut rng = Rng::new(seed);
    let w0: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
    let rows = sparse_design(&mut rng, l, n, density);
    let x = CsrMatrix::from_rows(rows, n);
    let y: Vec<f64> = (0..l)
        .map(|i| {
            let (idx, val) = x.row(i);
            let s: f64 = idx.iter().zip(val).map(|(&j, &v)| v * w0[j as usize]).sum();
            let noisy = s + rng.normal(0.0, 0.3);
            if noisy >= 0.0 {
                1.0
            } else {
                -1.0
            }
        })
        .collect();
    Dataset::new(format!("sparse{seed}"), Task::Classification, x, y)
}

/// Randomized sparse regression set in CSR storage: y = ⟨w°, x⟩ + ε over
/// a `density`-sparse design.
pub fn sparse_regression(seed: u64, l: usize, n: usize, density: f64, noise: f64) -> Dataset {
    let mut rng = Rng::new(seed);
    let w0: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
    let rows = sparse_design(&mut rng, l, n, density);
    let x = CsrMatrix::from_rows(rows, n);
    let y: Vec<f64> = (0..l)
        .map(|i| {
            let (idx, val) = x.row(i);
            let s: f64 = idx.iter().zip(val).map(|(&j, &v)| v * w0[j as usize]).sum();
            s + rng.normal(0.0, noise)
        })
        .collect();
    Dataset::new(format!("sparsereg{seed}"), Task::Regression, x, y)
}

/// Small random classification problem for unit/property tests.
pub fn random_classification(rng: &mut Rng, l: usize, n: usize) -> Dataset {
    let mu = rng.uniform_in(0.2, 2.0);
    let seed = rng.next_u64();
    gaussian_classes(seed, l, n, mu, 1.0, 0.5, 1.0)
}

/// Small random regression problem for unit/property tests.
pub fn random_regression(rng: &mut Rng, l: usize, n: usize) -> Dataset {
    let noise = rng.uniform_in(0.05, 0.5);
    let seed = rng.next_u64();
    linear_regression(seed, l, n, noise, 0.1, 10.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toy_matches_paper_spec() {
        let d = toy_gaussian(1, 1000, 1.5, 0.75);
        assert_eq!(d.len(), 2000);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.positive_fraction(), 0.5);
        // class means near (±1.5, ±1.5)
        let (mut px, mut nx) = (0.0, 0.0);
        for i in 0..d.len() {
            if d.y[i] > 0.0 {
                px += d.x.get(i, 0);
            } else {
                nx += d.x.get(i, 0);
            }
        }
        assert!((px / 1000.0 - 1.5).abs() < 0.1);
        assert!((nx / 1000.0 + 1.5).abs() < 0.1);
    }

    #[test]
    fn toys_are_reproducible() {
        let a = toy_gaussian(2, 100, 0.75, 0.75);
        let b = toy_gaussian(2, 100, 0.75, 0.75);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn sparse_generators_shapes_and_storage() {
        let c = sparse_classes(5, 200, 40, 0.1);
        assert!(c.x.is_sparse());
        assert_eq!((c.len(), c.dim()), (200, 40));
        // expected nnz ≈ 200·40·0.1 = 800
        assert!((c.nnz() as f64 - 800.0).abs() < 200.0, "nnz {}", c.nnz());
        let pf = c.positive_fraction();
        assert!(pf > 0.1 && pf < 0.9, "degenerate label balance {pf}");
        // reproducible
        assert_eq!(sparse_classes(5, 200, 40, 0.1).x, c.x);

        let r = sparse_regression(6, 100, 30, 0.2, 0.1);
        assert!(r.x.is_sparse());
        assert_eq!(r.task, Task::Regression);
        assert_eq!((r.len(), r.dim()), (100, 30));
    }

    #[test]
    fn paper_toys_overlap_ordering() {
        // smaller mu ⇒ more class overlap ⇒ more hinge violations at a
        // fixed w. Use w = (1,1)/√2 direction as a proxy.
        let toys = paper_toys(500);
        let violation = |d: &Dataset| {
            (0..d.len())
                .filter(|&i| {
                    let m = d.y[i] * (d.x.get(i, 0) + d.x.get(i, 1)) / 2f64.sqrt();
                    m < 1.0
                })
                .count()
        };
        let v: Vec<usize> = toys.iter().map(violation).collect();
        assert!(v[0] < v[1] && v[1] < v[2], "violations {v:?}");
    }

    #[test]
    fn gaussian_classes_imbalance() {
        let d = gaussian_classes(7, 4000, 10, 1.0, 1.0, 0.9, 2.0);
        assert!((d.positive_fraction() - 0.9).abs() < 0.03);
        assert_eq!(d.dim(), 10);
    }

    #[test]
    fn linear_regression_outliers_increase_spread() {
        let clean = linear_regression(3, 2000, 5, 0.1, 0.0, 1.0);
        let dirty = linear_regression(3, 2000, 5, 0.1, 0.2, 50.0);
        let spread = |d: &Dataset| crate::linalg::std_dev(&d.y);
        assert!(spread(&dirty) > spread(&clean));
    }

    #[test]
    fn random_generators_shapes() {
        let mut rng = Rng::new(1);
        let c = random_classification(&mut rng, 64, 5);
        assert_eq!((c.len(), c.dim()), (64, 5));
        let r = random_regression(&mut rng, 32, 3);
        assert_eq!((r.len(), r.dim()), (32, 3));
        assert_eq!(r.task, Task::Regression);
    }
}
