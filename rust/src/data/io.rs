//! libsvm-format dataset IO.
//!
//! Format: one instance per line, `label idx:val idx:val ...` with 1-based
//! feature indices. This is the interchange format of the solvers the
//! paper benchmarks against (LIBSVM/LIBLINEAR), so datasets generated here
//! can be cross-checked against external tools, and users can feed their
//! own data to the CLI.

use super::dataset::{Dataset, Task};
use crate::linalg::{CsrMatrix, Storage};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Errors for dataset IO.
#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
    Empty,
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "io: {e}"),
            IoError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            IoError::Empty => write!(f, "empty data set"),
        }
    }
}

impl std::error::Error for IoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            IoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        IoError::Io(e)
    }
}

/// Parse a libsvm file with [`Storage::Auto`] selection: the parsed
/// nonzeros become CSR when the density is at or below the auto
/// threshold, dense otherwise. Feature dimension is the max index seen
/// (or `min_dim` if larger). `task` controls label validation.
pub fn read_libsvm(path: &Path, task: Task, min_dim: usize) -> Result<Dataset, IoError> {
    read_libsvm_storage(path, task, min_dim, Storage::Auto)
}

/// [`read_libsvm`] with explicit storage selection. The file is parsed
/// straight into per-row index/value lists and assembled as CSR — a dense
/// l×n buffer is only ever materialized when `storage` resolves to
/// dense (explicitly, or by `auto` on a dense-enough file).
pub fn read_libsvm_storage(
    path: &Path,
    task: Task,
    min_dim: usize,
    storage: Storage,
) -> Result<Dataset, IoError> {
    let f = File::open(path)?;
    let reader = BufReader::new(f);
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let lab: f64 = parts
            .next()
            .ok_or_else(|| IoError::Parse { line: lineno + 1, msg: "missing label".into() })?
            .parse()
            .map_err(|e| IoError::Parse { line: lineno + 1, msg: format!("label: {e}") })?;
        // non-finite labels would panic in label normalization's sort;
        // reject them with a located error instead
        if !lab.is_finite() {
            return Err(IoError::Parse {
                line: lineno + 1,
                msg: format!("non-finite label {lab}"),
            });
        }
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok.split_once(':').ok_or_else(|| IoError::Parse {
                line: lineno + 1,
                msg: format!("bad feature token `{tok}`"),
            })?;
            let i: usize = i
                .parse()
                .map_err(|e| IoError::Parse { line: lineno + 1, msg: format!("index: {e}") })?;
            if i == 0 {
                return Err(IoError::Parse {
                    line: lineno + 1,
                    msg: "libsvm indices are 1-based".into(),
                });
            }
            let v: f64 = v
                .parse()
                .map_err(|e| IoError::Parse { line: lineno + 1, msg: format!("value: {e}") })?;
            // non-finite values poison dense kernels (0·inf = NaN) while
            // sparse intersection kernels skip them — rejecting here keeps
            // the dense↔CSR equivalence guarantee honest
            if !v.is_finite() {
                return Err(IoError::Parse {
                    line: lineno + 1,
                    msg: format!("non-finite value {v} at index {i}"),
                });
            }
            max_idx = max_idx.max(i);
            feats.push((i - 1, v));
        }
        labels.push(lab);
        rows.push(feats);
    }
    if rows.is_empty() {
        return Err(IoError::Empty);
    }
    let n = max_idx.max(min_dim);
    // assemble straight into CSR (the parse already is index/value pairs);
    // densify only if the requested storage resolves to dense
    let x = CsrMatrix::from_rows(rows, n);
    if task == Task::Classification {
        normalize_two_class_labels(&mut labels)?;
    }
    Ok(Dataset::new(
        path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        task,
        x,
        labels,
    )
    .into_storage(storage))
}

/// Map arbitrary two-class labels onto ±1 in place (common encodings:
/// 0/1, 1/2). Accepts: labels already in {−1, +1} (including a single
/// class — degenerate but well-formed), or exactly two distinct values
/// (the smaller becomes −1). Anything else — a single class not encoded
/// ±1, or three or more classes — is rejected.
fn normalize_two_class_labels(labels: &mut [f64]) -> Result<(), IoError> {
    let mut uniq: Vec<f64> = labels.to_vec();
    uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
    uniq.dedup();
    match uniq.len() {
        1 if uniq[0] == 1.0 || uniq[0] == -1.0 => Ok(()),
        2 => {
            if uniq != [-1.0, 1.0] {
                let lo = uniq[0];
                for l in labels {
                    *l = if *l == lo { -1.0 } else { 1.0 };
                }
            }
            Ok(())
        }
        _ => Err(IoError::Parse {
            line: 0,
            msg: format!("expected 2 classes, got {uniq:?}"),
        }),
    }
}

/// Write a dataset in libsvm format. Only nonzeros are emitted: CSR rows
/// stream their stored entries directly, dense rows filter zeros — both
/// storages produce identical files for the same data.
pub fn write_libsvm(ds: &Dataset, path: &Path) -> Result<(), IoError> {
    let f = File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.len() {
        write!(w, "{}", format_num(ds.y[i]))?;
        for (j, v) in ds.x.row(i).iter() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, format_num(v))?;
            }
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

fn format_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.12}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use std::path::PathBuf;

    fn tmpfile(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_classification() {
        let ds = synth::toy_gaussian(1, 20, 1.5, 0.75);
        let p = tmpfile("cls.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, Task::Classification, ds.dim()).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.dim(), ds.dim());
        assert_eq!(back.y, ds.y);
        for i in 0..ds.len() {
            for j in 0..ds.dim() {
                assert!((back.x.get(i, j) - ds.x.get(i, j)).abs() < 1e-9);
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_regression() {
        let mut rng = crate::data::Rng::new(4);
        let ds = synth::random_regression(&mut rng, 15, 4);
        let p = tmpfile("reg.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, Task::Regression, 4).unwrap();
        assert_eq!(back.len(), 15);
        for i in 0..15 {
            assert!((back.y[i] - ds.y[i]).abs() < 1e-9);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn parses_alt_labels_and_comments() {
        let p = tmpfile("alt.svm");
        std::fs::write(&p, "# comment\n0 1:1.0\n1 2:2.0\n\n0 1:-1\n").unwrap();
        let ds = read_libsvm(&p, Task::Classification, 0).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.y, vec![-1.0, 1.0, -1.0]);
        assert_eq!(ds.dim(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn storage_selection_on_read() {
        // 3 rows × 10 cols, 1 nonzero each → density 0.1 ≤ auto threshold
        let p = tmpfile("storage.svm");
        std::fs::write(&p, "1 10:1.0\n-1 3:2.0\n1 7:0.5\n").unwrap();
        let auto = read_libsvm(&p, Task::Classification, 0).unwrap();
        assert!(auto.x.is_sparse(), "auto must pick CSR at density 0.1");
        assert_eq!(auto.nnz(), 3);
        let dense = read_libsvm_storage(&p, Task::Classification, 0, Storage::Dense).unwrap();
        assert!(!dense.x.is_sparse());
        let csr = read_libsvm_storage(&p, Task::Classification, 0, Storage::Csr).unwrap();
        assert!(csr.x.is_sparse());
        for i in 0..3 {
            for j in 0..10 {
                assert_eq!(dense.x.get(i, j), csr.x.get(i, j));
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn sparse_write_matches_dense_write() {
        let ds = synth::sparse_classes(11, 20, 12, 0.2);
        let dense = ds.clone().into_storage(Storage::Dense);
        let (p1, p2) = (tmpfile("w_sparse.svm"), tmpfile("w_dense.svm"));
        write_libsvm(&ds, &p1).unwrap();
        write_libsvm(&dense, &p2).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn label_normalization_cases() {
        // single-class ±1: accepted as-is
        let mut l = vec![1.0, 1.0];
        assert!(normalize_two_class_labels(&mut l).is_ok());
        assert_eq!(l, vec![1.0, 1.0]);
        let mut l = vec![-1.0];
        assert!(normalize_two_class_labels(&mut l).is_ok());
        // single-class not ±1: rejected
        let mut l = vec![0.0, 0.0];
        assert!(normalize_two_class_labels(&mut l).is_err());
        // 0/1 → ±1
        let mut l = vec![0.0, 1.0, 0.0];
        assert!(normalize_two_class_labels(&mut l).is_ok());
        assert_eq!(l, vec![-1.0, 1.0, -1.0]);
        // 1/2 → ±1
        let mut l = vec![2.0, 1.0];
        assert!(normalize_two_class_labels(&mut l).is_ok());
        assert_eq!(l, vec![1.0, -1.0]);
        // already ±1 untouched
        let mut l = vec![1.0, -1.0];
        assert!(normalize_two_class_labels(&mut l).is_ok());
        assert_eq!(l, vec![1.0, -1.0]);
        // 3 classes: rejected
        let mut l = vec![0.0, 1.0, 2.0];
        assert!(normalize_two_class_labels(&mut l).is_err());
    }

    #[test]
    fn three_class_file_rejected() {
        let p = tmpfile("3cls.svm");
        std::fs::write(&p, "0 1:1\n1 1:2\n2 1:3\n").unwrap();
        assert!(matches!(
            read_libsvm(&p, Task::Classification, 0),
            Err(IoError::Parse { .. })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_zero_index() {
        let p = tmpfile("zero.svm");
        std::fs::write(&p, "1 0:1.0\n").unwrap();
        assert!(matches!(
            read_libsvm(&p, Task::Regression, 0),
            Err(IoError::Parse { .. })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_empty() {
        let p = tmpfile("empty.svm");
        std::fs::write(&p, "\n# nothing\n").unwrap();
        assert!(matches!(read_libsvm(&p, Task::Regression, 0), Err(IoError::Empty)));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_non_finite_labels_and_values() {
        let p = tmpfile("nonfinite.svm");
        for contents in ["nan 1:1.0\n1 1:2.0\n", "1 5:inf\n-1 1:1.0\n", "1 2:-inf\n"] {
            std::fs::write(&p, contents).unwrap();
            assert!(
                matches!(
                    read_libsvm(&p, Task::Classification, 0),
                    Err(IoError::Parse { .. })
                ),
                "accepted {contents:?}"
            );
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_token() {
        let p = tmpfile("bad.svm");
        std::fs::write(&p, "1 nonsense\n").unwrap();
        assert!(read_libsvm(&p, Task::Regression, 0).is_err());
        std::fs::remove_file(&p).ok();
    }
}
