//! libsvm-format dataset IO.
//!
//! Format: one instance per line, `label idx:val idx:val ...` with 1-based
//! feature indices. This is the interchange format of the solvers the
//! paper benchmarks against (LIBSVM/LIBLINEAR), so datasets generated here
//! can be cross-checked against external tools, and users can feed their
//! own data to the CLI.

use super::dataset::{Dataset, Task};
use crate::linalg::RowMatrix;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Errors for dataset IO.
#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
    Empty,
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "io: {e}"),
            IoError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            IoError::Empty => write!(f, "empty data set"),
        }
    }
}

impl std::error::Error for IoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            IoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        IoError::Io(e)
    }
}

/// Parse a libsvm file. Feature dimension is the max index seen (or
/// `min_dim` if larger). `task` controls label validation.
pub fn read_libsvm(path: &Path, task: Task, min_dim: usize) -> Result<Dataset, IoError> {
    let f = File::open(path)?;
    let reader = BufReader::new(f);
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let lab: f64 = parts
            .next()
            .ok_or_else(|| IoError::Parse { line: lineno + 1, msg: "missing label".into() })?
            .parse()
            .map_err(|e| IoError::Parse { line: lineno + 1, msg: format!("label: {e}") })?;
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok.split_once(':').ok_or_else(|| IoError::Parse {
                line: lineno + 1,
                msg: format!("bad feature token `{tok}`"),
            })?;
            let i: usize = i
                .parse()
                .map_err(|e| IoError::Parse { line: lineno + 1, msg: format!("index: {e}") })?;
            if i == 0 {
                return Err(IoError::Parse {
                    line: lineno + 1,
                    msg: "libsvm indices are 1-based".into(),
                });
            }
            let v: f64 = v
                .parse()
                .map_err(|e| IoError::Parse { line: lineno + 1, msg: format!("value: {e}") })?;
            max_idx = max_idx.max(i);
            feats.push((i - 1, v));
        }
        labels.push(lab);
        rows.push(feats);
    }
    if rows.is_empty() {
        return Err(IoError::Empty);
    }
    let n = max_idx.max(min_dim);
    let mut x = RowMatrix::zeros(rows.len(), n);
    for (r, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            x.set(r, j, v);
        }
    }
    if task == Task::Classification {
        // map arbitrary two-class labels onto ±1 (common: 0/1, 1/2)
        let mut uniq: Vec<f64> = labels.clone();
        uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
        uniq.dedup();
        if uniq.len() != 2 && !(uniq.len() == 1 && (uniq[0] == 1.0 || uniq[0] == -1.0)) {
            if uniq != vec![-1.0, 1.0] {
                return Err(IoError::Parse {
                    line: 0,
                    msg: format!("expected 2 classes, got {:?}", uniq),
                });
            }
        }
        if uniq.len() == 2 && uniq != vec![-1.0, 1.0] {
            let lo = uniq[0];
            for l in &mut labels {
                *l = if *l == lo { -1.0 } else { 1.0 };
            }
        }
    }
    Ok(Dataset::new(
        path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        task,
        x,
        labels,
    ))
}

/// Write a dataset in libsvm format (dense — all features emitted; zeros
/// skipped to keep files small).
pub fn write_libsvm(ds: &Dataset, path: &Path) -> Result<(), IoError> {
    let f = File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.len() {
        write!(w, "{}", format_num(ds.y[i]))?;
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, format_num(v))?;
            }
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

fn format_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.12}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use std::path::PathBuf;

    fn tmpfile(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_classification() {
        let ds = synth::toy_gaussian(1, 20, 1.5, 0.75);
        let p = tmpfile("cls.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, Task::Classification, ds.dim()).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.dim(), ds.dim());
        assert_eq!(back.y, ds.y);
        for i in 0..ds.len() {
            for j in 0..ds.dim() {
                assert!((back.x.get(i, j) - ds.x.get(i, j)).abs() < 1e-9);
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_regression() {
        let mut rng = crate::data::Rng::new(4);
        let ds = synth::random_regression(&mut rng, 15, 4);
        let p = tmpfile("reg.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, Task::Regression, 4).unwrap();
        assert_eq!(back.len(), 15);
        for i in 0..15 {
            assert!((back.y[i] - ds.y[i]).abs() < 1e-9);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn parses_alt_labels_and_comments() {
        let p = tmpfile("alt.svm");
        std::fs::write(&p, "# comment\n0 1:1.0\n1 2:2.0\n\n0 1:-1\n").unwrap();
        let ds = read_libsvm(&p, Task::Classification, 0).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.y, vec![-1.0, 1.0, -1.0]);
        assert_eq!(ds.dim(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_zero_index() {
        let p = tmpfile("zero.svm");
        std::fs::write(&p, "1 0:1.0\n").unwrap();
        assert!(matches!(
            read_libsvm(&p, Task::Regression, 0),
            Err(IoError::Parse { .. })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_empty() {
        let p = tmpfile("empty.svm");
        std::fs::write(&p, "\n# nothing\n").unwrap();
        assert!(matches!(read_libsvm(&p, Task::Regression, 0), Err(IoError::Empty)));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_token() {
        let p = tmpfile("bad.svm");
        std::fs::write(&p, "1 nonsense\n").unwrap();
        assert!(read_libsvm(&p, Task::Regression, 0).is_err());
        std::fs::remove_file(&p).ok();
    }
}
