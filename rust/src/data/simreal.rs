//! Simulated analogs of the paper's six evaluation data sets.
//!
//! The originals (UCI / LIBSVM mirrors) are external downloads; this build
//! is offline, so each set is replaced by a *seeded synthetic analog*
//! matched on size (l, n), feature scaling, class balance, and the margin /
//! residual geometry that actually drives screening behaviour. See
//! `DESIGN.md §Substitutions` for the paper→analog mapping and the
//! argument for why this preserves the experiments' shape: every screening
//! rule consumes the data only through ⟨w, x̄ᵢ⟩, ‖x̄ᵢ‖ and ‖w‖.
//!
//! All generators accept a `scale` in (0, 1] that shrinks l (tests use
//! small scales; the benchmark harness uses 1.0).

use super::dataset::{Dataset, Task};
use super::rng::Rng;
use crate::linalg::RowMatrix;

fn scaled(l: usize, scale: f64) -> usize {
    ((l as f64 * scale).round() as usize).max(16)
}

/// IJCNN1 analog: 49,990 × 22, ~9:1 negative:positive imbalance (the real
/// set is ~90% negative), moderate overlap so that roughly 10–25% of the
/// instances end up on or inside the margin at mid-path C.
pub fn ijcnn1(scale: f64) -> Dataset {
    let l = scaled(49_990, scale);
    let n = 22;
    let mut rng = Rng::new(0x11C4);
    let mut x = RowMatrix::zeros(l, n);
    let mut y = vec![0.0; l];
    for i in 0..l {
        let label = if rng.bernoulli(0.10) { 1.0 } else { -1.0 };
        y[i] = label;
        // anisotropic covariance: first 6 coords carry most of the signal
        for j in 0..n {
            let (shift, sig) = if j < 6 {
                (label * 0.9, 1.0)
            } else {
                (label * 0.12, 1.4)
            };
            x.set(i, j, shift + rng.normal(0.0, sig));
        }
    }
    let mut d = Dataset::new("ijcnn1-sim", Task::Classification, x, y);
    d.standardize();
    d
}

/// Wine Quality analog: 6,497 × 12; labels derived from a noisy linear
/// score over correlated physico-chemical-style features (quality ≥ 6),
/// giving heavily overlapping classes.
pub fn wine(scale: f64) -> Dataset {
    let l = scaled(6_497, scale);
    let n = 12;
    let mut rng = Rng::new(0x3142);
    let w0: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
    let mut x = RowMatrix::zeros(l, n);
    let mut y = vec![0.0; l];
    // latent factor to correlate features (wine chemistry is collinear)
    for i in 0..l {
        let f = rng.gaussian();
        let mut score = 0.0;
        for j in 0..n {
            let v = 0.6 * f + 0.8 * rng.gaussian();
            x.set(i, j, v);
            score += w0[j] * v;
        }
        score += rng.normal(0.0, 2.0); // heavy label noise ⇒ overlap
        y[i] = if score > 0.0 { 1.0 } else { -1.0 };
    }
    let mut d = Dataset::new("wine-sim", Task::Classification, x, y);
    d.standardize();
    d
}

/// Forest Covertype (2-class subset) analog: 37,877 × 54 with 40 of the 54
/// columns binary one-hot-ish (soil/wilderness indicators in the real set)
/// and well-separated continuous clusters ⇒ near-complete screening.
pub fn covertype(scale: f64) -> Dataset {
    let l = scaled(37_877, scale);
    let n = 54;
    let n_cont = 14;
    let mut rng = Rng::new(0xC0Fe as u64);
    let mut x = RowMatrix::zeros(l, n);
    let mut y = vec![0.0; l];
    for i in 0..l {
        let label = if rng.bernoulli(0.45) { 1.0 } else { -1.0 };
        y[i] = label;
        for j in 0..n_cont {
            // strong separation on continuous block
            x.set(i, j, label * 1.6 + rng.normal(0.0, 1.0));
        }
        // binary block: class-dependent activation probabilities
        for j in n_cont..n {
            let p = if label > 0.0 { 0.12 } else { 0.05 };
            x.set(i, j, if rng.bernoulli(p) { 1.0 } else { 0.0 });
        }
    }
    let mut d = Dataset::new("covertype-sim", Task::Classification, x, y);
    d.standardize();
    d
}

/// Normalize a weight vector to a target norm.
fn unit_w(rng: &mut Rng, n: usize, norm: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.0)).collect();
    let s = norm / crate::linalg::norm(&w).max(1e-12);
    for v in &mut w {
        *v *= s;
    }
    w
}

// The LAD analogs are tuned so the *residual-to-fit ratio* matches what
// the paper's rejection curves imply. DVI keeps instance i only when its
// residual is inside a band of width ≈ (rad/mid)·‖w*‖·‖xᵢ‖ around zero
// (rad/mid ≈ 0.035 on the paper's 100-point grid); the real Magic /
// Computer / Houses targets are poorly fit by a linear model on
// standardized features (large irreducible residuals, modest ‖w*‖),
// which is exactly what drives their 90%/~100%/~100% rejection. The
// generators therefore use a weak linear signal plus dominant residual
// noise, ordered houses > computer > magic in residual/band ratio.

/// Magic Gamma Telescope analog: 19,020 × 10 — long-tailed features,
/// weak linear fit with heavy residual spread ⇒ rejection ≈ 90%.
pub fn magic(scale: f64) -> Dataset {
    let l = scaled(19_020, scale);
    let n = 10;
    let mut rng = Rng::new(0x3a61c);
    let w0 = unit_w(&mut rng, n, 1.0);
    let mut x = RowMatrix::zeros(l, n);
    let mut y = vec![0.0; l];
    for i in 0..l {
        let mut t = 0.0;
        for j in 0..n {
            let v = rng.lognormal(0.0, 0.6) - 1.0; // long tail, ~zero mode
            x.set(i, j, v);
            t += w0[j] * v;
        }
        y[i] = t + rng.normal(0.0, 1.2);
    }
    let mut d = Dataset::new("magic-sim", Task::Regression, x, y);
    d.standardize();
    d.center_targets();
    d
}

/// Computer (comp-activ) analog: 8,192 × 21 — system-activity regression
/// with a weak linear component, wide residuals and a few percent gross
/// outliers ⇒ rejection approaching 100%.
pub fn computer(scale: f64) -> Dataset {
    let l = scaled(8_192, scale);
    let n = 21;
    let mut rng = Rng::new(0xC09);
    let w0 = unit_w(&mut rng, n, 0.4);
    let mut x = RowMatrix::zeros(l, n);
    let mut y = vec![0.0; l];
    for i in 0..l {
        // system-activity counters are strongly collinear (load factor):
        // a shared latent keeps the effective dimension low, as in the
        // real comp-activ set
        let f = rng.gaussian();
        for j in 0..n {
            x.set(i, j, 0.8 * f + 0.6 * rng.gaussian());
        }
        let noise = if rng.bernoulli(0.03) {
            rng.normal(0.0, 15.0) // bursty outliers (the LAD motivation)
        } else {
            rng.normal(0.0, 1.5)
        };
        y[i] = crate::linalg::dot(x.row(i), &w0) + noise;
    }
    let mut d = Dataset::new("computer-sim", Task::Regression, x, y);
    d.standardize();
    d.center_targets();
    d
}

/// Houses (California housing) analog: 20,640 × 8 — weakest linear
/// signal of the three relative to the residual spread ⇒ the highest
/// rejection; the paper reports ~115× speedup here.
pub fn houses(scale: f64) -> Dataset {
    let l = scaled(20_640, scale);
    let n = 8;
    let mut rng = Rng::new(0x40e5);
    let w0 = unit_w(&mut rng, n, 0.3);
    let mut x = RowMatrix::zeros(l, n);
    let mut y = vec![0.0; l];
    for i in 0..l {
        for j in 0..n {
            x.set(i, j, rng.normal(0.0, 1.0));
        }
        let r = x.row(i);
        let inter = 0.2 * r[0] * r[1] - 0.15 * r[2] * r[3];
        y[i] = crate::linalg::dot(r, &w0) + inter + rng.normal(0.0, 1.5);
    }
    let mut d = Dataset::new("houses-sim", Task::Regression, x, y);
    d.standardize();
    d.center_targets();
    d
}

/// Registry lookup by name (used by the CLI and the experiment configs).
pub fn by_name(name: &str, scale: f64) -> Option<Dataset> {
    match name {
        "ijcnn1" => Some(ijcnn1(scale)),
        "wine" => Some(wine(scale)),
        "covertype" => Some(covertype(scale)),
        "magic" => Some(magic(scale)),
        "computer" => Some(computer(scale)),
        "houses" => Some(houses(scale)),
        _ => None,
    }
}

/// Names of the three SVM evaluation sets (paper Fig. 2 / Table 2).
pub const SVM_SETS: [&str; 3] = ["ijcnn1", "wine", "covertype"];
/// Names of the three LAD evaluation sets (paper Fig. 3 / Table 3).
pub const LAD_SETS: [&str; 3] = ["magic", "computer", "houses"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        assert_eq!(ijcnn1(1e-3).dim(), 22);
        assert_eq!(wine(1e-2).dim(), 12);
        assert_eq!(covertype(1e-3).dim(), 54);
        assert_eq!(magic(1e-3).dim(), 10);
        assert_eq!(computer(1e-2).dim(), 21);
        assert_eq!(houses(1e-3).dim(), 8);
    }

    #[test]
    fn full_scale_sizes() {
        // construct cheap small versions but check the scaling arithmetic
        assert_eq!(super::scaled(49_990, 1.0), 49_990);
        assert_eq!(super::scaled(20_640, 0.5), 10_320);
        assert_eq!(super::scaled(100, 1e-9), 16); // floor
    }

    #[test]
    fn ijcnn1_imbalance() {
        let d = ijcnn1(0.05);
        let pf = d.positive_fraction();
        assert!(pf > 0.05 && pf < 0.18, "positive fraction {pf}");
    }

    #[test]
    fn tasks_correct() {
        assert_eq!(wine(0.01).task, Task::Classification);
        assert_eq!(covertype(0.002).task, Task::Classification);
        assert_eq!(magic(0.005).task, Task::Regression);
        assert_eq!(houses(0.005).task, Task::Regression);
    }

    #[test]
    fn registry_roundtrip() {
        for name in SVM_SETS.iter().chain(LAD_SETS.iter()) {
            let d = by_name(name, 0.002).expect(name);
            assert!(d.len() >= 16);
        }
        assert!(by_name("nope", 1.0).is_none());
    }

    #[test]
    fn regression_targets_centered() {
        for name in LAD_SETS {
            let d = by_name(name, 0.01).unwrap();
            let mu = d.y.iter().sum::<f64>() / d.len() as f64;
            assert!(mu.abs() < 1e-9, "{name} target mean {mu}");
        }
    }

    #[test]
    fn deterministic() {
        let a = wine(0.01);
        let b = wine(0.01);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
