//! The dataset container shared by every layer of the system. Instances
//! live in storage-polymorphic [`Rows`] — dense for the synthetic
//! generators, CSR for sparse libsvm loads — and every consumer works
//! through that interface.

use crate::linalg::{Rows, Storage};

/// What the responses mean.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Binary classification, labels in {−1, +1} (SVM).
    Classification,
    /// Real-valued regression targets (LAD).
    Regression,
}

/// A supervised data set: l instances × n features plus responses.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable identifier, used in reports and the artifact cache.
    pub name: String,
    pub task: Task,
    /// l × n instance matrix X (rows are instances), dense or CSR.
    pub x: Rows,
    /// Responses: labels (±1) for classification, targets for regression.
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, task: Task, x: impl Into<Rows>, y: Vec<f64>) -> Self {
        let x = x.into();
        assert_eq!(x.rows(), y.len(), "instances and responses disagree");
        if task == Task::Classification {
            assert!(
                y.iter().all(|&v| v == 1.0 || v == -1.0),
                "classification labels must be ±1"
            );
        }
        Dataset { name: name.into(), task, x, y }
    }

    /// Number of instances l.
    #[inline]
    pub fn len(&self) -> usize {
        self.x.rows()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimension n.
    #[inline]
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Standardize features in place. Dense storage gets the full z-score
    /// (center + scale, guarding zero-variance columns) the paper's
    /// experiments use. CSR storage gets *scale-only* standardization
    /// (divide by the exact column std, computed over zeros too, without
    /// centering) — centering would shift every structural zero to
    /// −μ/σ and densify the matrix, so sparse pipelines follow the
    /// standard sparse practice (scikit-learn's `with_mean=False`).
    pub fn standardize(&mut self) {
        let (l, n) = (self.len(), self.dim());
        if l == 0 {
            return;
        }
        match &mut self.x {
            Rows::Dense(x) => {
                for j in 0..n {
                    let mut s = 0.0;
                    for i in 0..l {
                        s += x.get(i, j);
                    }
                    let mu = s / l as f64;
                    let mut v = 0.0;
                    for i in 0..l {
                        let d = x.get(i, j) - mu;
                        v += d * d;
                    }
                    let sd = (v / l as f64).sqrt();
                    let inv = if sd > 1e-12 { 1.0 / sd } else { 1.0 };
                    for i in 0..l {
                        let val = (x.get(i, j) - mu) * inv;
                        x.set(i, j, val);
                    }
                }
            }
            Rows::Sparse(x) => {
                // per-column Σv and Σv² over stored entries; zeros
                // contribute 0 to both, so the population moments are
                // exact: μ = Σv/l, var = Σv²/l − μ²
                let mut sum = vec![0.0f64; n];
                let mut sum_sq = vec![0.0f64; n];
                for i in 0..l {
                    let (idx, val) = x.row(i);
                    for (&j, &v) in idx.iter().zip(val) {
                        sum[j as usize] += v;
                        sum_sq[j as usize] += v * v;
                    }
                }
                let factors: Vec<f64> = (0..n)
                    .map(|j| {
                        let mu = sum[j] / l as f64;
                        let var = (sum_sq[j] / l as f64 - mu * mu).max(0.0);
                        let sd = var.sqrt();
                        if sd > 1e-12 {
                            1.0 / sd
                        } else {
                            1.0
                        }
                    })
                    .collect();
                x.scale_cols(&factors);
            }
        }
    }

    /// Stored entries in X (l·n for dense).
    pub fn nnz(&self) -> usize {
        self.x.nnz()
    }

    /// Stored-entry fraction of X.
    pub fn density(&self) -> f64 {
        self.x.density()
    }

    /// Convert X to the requested storage (no-op when already there;
    /// `auto` picks CSR at or below the density threshold).
    pub fn into_storage(mut self, storage: Storage) -> Dataset {
        self.x = self.x.into_storage(storage);
        self
    }

    /// Center regression targets (LAD has no intercept in problem (29);
    /// centering y plays that role).
    pub fn center_targets(&mut self) {
        if self.task != Task::Regression || self.y.is_empty() {
            return;
        }
        let mu = self.y.iter().sum::<f64>() / self.y.len() as f64;
        for v in &mut self.y {
            *v -= mu;
        }
    }

    /// Subset by row indices (copies).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        Dataset {
            name: format!("{}[{}]", self.name, idx.len()),
            task: self.task,
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }

    /// Class balance (positive fraction) for classification sets.
    pub fn positive_fraction(&self) -> f64 {
        if self.task != Task::Classification || self.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::RowMatrix;

    fn tiny() -> Dataset {
        let x = RowMatrix::from_flat(4, 2, vec![0.0, 10.0, 2.0, 10.0, 4.0, 30.0, 6.0, 30.0]);
        Dataset::new("tiny", Task::Classification, x, vec![1.0, 1.0, -1.0, -1.0])
    }

    #[test]
    fn basic_shape() {
        let d = tiny();
        assert_eq!(d.len(), 4);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.positive_fraction(), 0.5);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_labels() {
        let x = RowMatrix::zeros(1, 1);
        Dataset::new("bad", Task::Classification, x, vec![0.5]);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut d = tiny();
        d.standardize();
        for j in 0..d.dim() {
            let col: Vec<f64> = (0..d.len()).map(|i| d.x.get(i, j)).collect();
            let mu = col.iter().sum::<f64>() / col.len() as f64;
            let var = col.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / col.len() as f64;
            assert!(mu.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn standardize_constant_column_is_noop_scale() {
        let x = RowMatrix::from_flat(3, 1, vec![5.0, 5.0, 5.0]);
        let mut d = Dataset::new("c", Task::Regression, x, vec![1.0, 2.0, 3.0]);
        d.standardize();
        for i in 0..3 {
            assert_eq!(d.x.get(i, 0), 0.0); // centered, scale guarded
        }
    }

    #[test]
    fn center_targets_regression_only() {
        let x = RowMatrix::zeros(3, 1);
        let mut d = Dataset::new("r", Task::Regression, x, vec![1.0, 2.0, 3.0]);
        d.center_targets();
        assert!((d.y.iter().sum::<f64>()).abs() < 1e-12);
    }

    #[test]
    fn sparse_standardize_is_scale_only() {
        use crate::linalg::Storage;
        let x = RowMatrix::from_flat(4, 2, vec![2.0, 0.0, 0.0, 0.0, -2.0, 0.0, 0.0, 4.0]);
        let mut d = Dataset::new("sp", Task::Regression, x, vec![0.0; 4]).into_storage(Storage::Csr);
        assert!(d.x.is_sparse());
        let nnz_before = d.nnz();
        d.standardize();
        // sparsity pattern unchanged, columns divided by their exact std
        assert_eq!(d.nnz(), nnz_before);
        let sd0 = (2.0f64).sqrt(); // col0: {2,0,-2,0} → var 2
        let sd1 = (3.0f64).sqrt(); // col1: {0,0,0,4} → var 3
        assert!((d.x.get(0, 0) - 2.0 / sd0).abs() < 1e-12);
        assert!((d.x.get(2, 0) + 2.0 / sd0).abs() < 1e-12);
        assert!((d.x.get(3, 1) - 4.0 / sd1).abs() < 1e-12);
        assert_eq!(d.x.get(1, 0), 0.0);
    }

    #[test]
    fn storage_conversion_roundtrip() {
        use crate::linalg::Storage;
        let d = tiny();
        let sparse = d.clone().into_storage(Storage::Csr);
        assert!(sparse.x.is_sparse());
        assert!(sparse.density() < 1.0); // tiny() has a structural zero
        let back = sparse.into_storage(Storage::Dense);
        for i in 0..d.len() {
            for j in 0..d.dim() {
                assert_eq!(back.x.get(i, j), d.x.get(i, j));
            }
        }
    }

    #[test]
    fn select_subsets() {
        let d = tiny();
        let s = d.select(&[0, 3]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.y, vec![1.0, -1.0]);
        assert_eq!(s.x.row(1), d.x.row(3));
    }
}
