//! Deterministic pseudo-random number generation.
//!
//! All dataset generators and property tests in this crate must be exactly
//! reproducible across runs and platforms, so we implement a small,
//! well-understood generator (xoshiro256**) rather than depending on an
//! external crate (the build is fully offline). Gaussian variates use the
//! Box–Muller transform with cached second draw.

/// xoshiro256** by Blackman & Vigna — public domain reference algorithm.
///
/// 256 bits of state, period 2^256−1, passes BigCrush. Plenty for data
/// generation; NOT cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// splitmix64 — used to expand a single seed into the 256-bit state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single 64-bit value (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[1].wrapping_mul(5), 7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method
    /// with a widening multiply; bias is negligible for n ≪ 2^64 but we
    /// reject to be exact.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism
    /// of call counts; the trig form consumes exactly two uniforms per
    /// pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let phi = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * phi.sin());
        r * phi.cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Log-normal with underlying normal(mu, sigma).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.uniform();
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            s += g;
            s2 += g * g;
            s3 += g * g * g;
        }
        let mean = s / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
        assert!((s3 / n as f64).abs() < 0.05, "skew");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20);
        assert!(u.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(99);
        let mut c1 = base.fork();
        let mut c2 = base.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 1.0) > 0.0);
        }
    }
}
