//! Safety validation — machine-checking the paper's central claim that
//! DVI (and SSNSV/ESSNSV) are *safe*: no screened instance is a true
//! support vector.
//!
//! [`check_safety`] solves the next path point exactly (no screening) and
//! compares every non-`Keep` decision against the KKT ground truth;
//! [`check_exactness`] verifies the reduced solve reproduces the full
//! optimum. Both are used by the integration suite and by
//! `PathConfig::validate` in spot-check form.

use crate::config::SolverConfig;
use crate::problem::{classify_kkt, Instance, KktClass};
use crate::screening::{Decision, ScreenReport};
use crate::solver::CdSolver;

/// Violation found by [`check_safety`].
#[derive(Clone, Debug)]
pub struct SafetyViolation {
    pub index: usize,
    pub decided: Decision,
    pub truth: KktClass,
    pub margin_gap: f64,
}

/// Result of a safety check.
#[derive(Clone, Debug)]
pub struct SafetyReport {
    pub violations: Vec<SafetyViolation>,
    pub n_checked: usize,
    pub n_screened: usize,
}

impl SafetyReport {
    pub fn is_safe(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Solve at `c` exactly and verify every screening decision. `kkt_tol` is
/// the dead-band treated as "support vector" in the ground truth (a
/// screened instance inside the dead-band counts as a violation — we are
/// strict).
pub fn check_safety(
    inst: &Instance,
    c: f64,
    report: &ScreenReport,
    solver_cfg: &SolverConfig,
    kkt_tol: f64,
) -> SafetyReport {
    let solver = CdSolver::new(solver_cfg.clone());
    let full = solver.solve(inst, c, inst.cold_start());
    let w = inst.w_from_theta(c, &full.theta);
    let truth = classify_kkt(inst, &w, kkt_tol);

    let mut violations = Vec::new();
    let mut n_screened = 0;
    for (i, d) in report.decisions.iter().enumerate() {
        let expected = match d {
            Decision::Keep => continue,
            Decision::AtLo => KktClass::R,
            Decision::AtHi => KktClass::L,
        };
        n_screened += 1;
        if truth.classes[i] != expected {
            let s = -inst.z.row(i).dot(&w);
            violations.push(SafetyViolation {
                index: i,
                decided: *d,
                truth: truth.classes[i],
                margin_gap: s - inst.ybar[i],
            });
        }
    }
    SafetyReport { violations, n_checked: report.decisions.len(), n_screened }
}

/// Verify a reduced solve equals the full solve: dual objectives agree to
/// `tol` and u vectors agree in ℓ∞. Returns Err with a description on
/// mismatch.
pub fn check_exactness(
    inst: &Instance,
    c: f64,
    reduced_theta: &[f64],
    solver_cfg: &SolverConfig,
    tol: f64,
) -> Result<(), String> {
    let solver = CdSolver::new(solver_cfg.clone());
    let full = solver.solve(inst, c, inst.cold_start());
    let g_red = inst.dual_objective(c, reduced_theta);
    let g_full = inst.dual_objective(c, &full.theta);
    if (g_red - g_full).abs() > tol * g_full.abs().max(1.0) {
        return Err(format!(
            "objective mismatch at C={c}: reduced {g_red} vs full {g_full}"
        ));
    }
    let u_red = inst.u_from_theta(reduced_theta);
    let diff = crate::linalg::max_abs_diff(&u_red, &full.u);
    // u is unique (strong convexity in u); θ need not be
    let scale = crate::linalg::norm(&full.u).max(1.0);
    if diff > 1e3 * tol * scale {
        return Err(format!("u mismatch at C={c}: ℓ∞ diff {diff}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::problem::Model;
    use crate::screening::Dvi;

    #[test]
    fn dvi_screening_passes_safety() {
        let ds = synth::toy_gaussian(51, 100, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let cfg = SolverConfig { tol: 1e-9, ..Default::default() };
        let solver = CdSolver::new(cfg.clone());
        let r = solver.solve(&inst, 0.5, inst.cold_start());
        let rep = Dvi::new_w().screen(&inst, 0.5, 1.0, &r.theta, &r.u);
        let safety = check_safety(&inst, 1.0, &rep, &cfg, 1e-7);
        assert!(safety.is_safe(), "{:?}", safety.violations);
        assert!(safety.n_screened > 0);
        assert_eq!(safety.n_checked, 200);
    }

    #[test]
    fn fabricated_bad_decision_is_caught() {
        let ds = synth::toy_gaussian(52, 50, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let cfg = SolverConfig { tol: 1e-9, ..Default::default() };
        // claim everything is AtLo — certainly unsafe on an overlapping toy
        let rep = crate::screening::ScreenReport::from_decisions(vec![
            Decision::AtLo;
            inst.len()
        ]);
        let safety = check_safety(&inst, 1.0, &rep, &cfg, 1e-7);
        assert!(!safety.is_safe());
    }

    #[test]
    fn exactness_detects_wrong_theta() {
        let ds = synth::toy_gaussian(53, 40, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let cfg = SolverConfig { tol: 1e-9, ..Default::default() };
        let solver = CdSolver::new(cfg.clone());
        let good = solver.solve(&inst, 1.0, inst.cold_start());
        assert!(check_exactness(&inst, 1.0, &good.theta, &cfg, 1e-6).is_ok());
        let bad = vec![0.5; inst.len()];
        assert!(check_exactness(&inst, 1.0, &bad, &cfg, 1e-6).is_err());
    }
}
