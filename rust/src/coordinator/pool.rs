//! Thread worker pool executing [`JobSpec`]s.
//!
//! std-only (no tokio offline): a bounded mpsc work queue feeding N worker
//! threads, results collected on a shared channel. Jobs that panic are
//! caught (`catch_unwind`) and surfaced as failed outcomes — one bad run
//! must not take down an experiment sweep. A per-message guard backstops
//! even panics outside the job body (metrics, channel plumbing): every
//! accepted job produces exactly one [`JobOutcome`], so the service never
//! loses a response line. The pool owns the resident [`InstanceCache`]
//! workers resolve instances through, so jobs naming the same dataset
//! share one `Arc<Instance>` instead of rebuilding per request.
//!
//! Shutdown is deterministic: dropping the pool (or calling
//! [`WorkerPool::shutdown`]) enqueues one shutdown message per worker
//! *behind* any queued jobs — FIFO order means workers drain the queue
//! first — then joins every worker thread.

use super::cache::{InstanceCache, ModelCache};
use super::job::{run_job_cached, JobOutcome, JobSpec};
use crate::metrics::{Counter, Registry};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum Msg {
    Job(JobSpec),
    Shutdown,
}

/// Dependency bookkeeping for [`JobSpec::after`]: jobs naming a dep that
/// has not completed yet are parked here; the worker that delivers the
/// dep's outcome re-enqueues them. `done` grows by one u64 per finished
/// job for the pool's lifetime — the service's per-session job counts
/// make that a non-issue, and correctness needs the full history (a dep
/// may complete long before its dependent is submitted).
struct DepState {
    /// Every job id ever accepted by `submit` — the membership check
    /// that lets a dangling `after` fail fast instead of parking a job
    /// (and a caller blocked in `recv`) forever.
    submitted: HashSet<u64>,
    done: HashSet<u64>,
    waiting: HashMap<u64, Vec<JobSpec>>,
}

/// Mark `id` complete and hand any parked dependents back to the queue.
/// Called on every completion path (normal outcomes and the result
/// guard's unwind cleanup), so a failed or panicked dep still releases
/// its dependents — they run and fail on their own terms (e.g. "model
/// not resident") instead of hanging the session.
fn release_dependents(id: u64, deps: &Mutex<DepState>, tx: &Sender<Msg>) {
    let freed = {
        let mut st = deps.lock().unwrap();
        st.done.insert(id);
        st.waiting.remove(&id)
    };
    if let Some(specs) = freed {
        for spec in specs {
            // receiver may be gone during shutdown; the drop path then
            // fails these jobs out of the waiting map
            let _ = tx.send(Msg::Job(spec));
        }
    }
}

/// Fixed-size worker pool with a shared resident instance cache.
pub struct WorkerPool {
    tx: Sender<Msg>,
    /// Mutex-wrapped so the pool is `Sync`: the serve subsystem shares
    /// one pool behind an `Arc` and drains results from a dispatcher
    /// thread. There is exactly one consumer at a time, so the lock is
    /// uncontended in practice.
    results_rx: Mutex<Receiver<JobOutcome>>,
    /// A sender the pool keeps for itself so the drop path can fail out
    /// parked jobs whose dependency never ran (workers hold clones).
    results_tx: Sender<JobOutcome>,
    /// The pool's own handle on the work queue receiver, used only at
    /// drop: jobs released into the queue after the shutdown messages
    /// (a dependency finishing during the drain) are recovered from it
    /// and failed out instead of vanishing with the channel.
    rx: Arc<Mutex<Receiver<Msg>>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicU64>,
    deps: Arc<Mutex<DepState>>,
    pub metrics: Arc<Registry>,
    pub cache: Arc<InstanceCache>,
    /// Resident trained-model cache (train inserts, predict resolves).
    pub models: Arc<ModelCache>,
}

/// Guarantees exactly one outcome — delivered AND counted — per accepted
/// job: if the worker unwinds anywhere in the processing block (even
/// outside the `catch_unwind` around the job body), the guard's drop
/// still delivers a failure outcome, bumps the jobs_done/jobs_failed
/// counters, and releases the pending slot before the thread dies. The
/// counters are pre-resolved `Arc<Counter>` handles so the drop path
/// only touches atomics — it cannot trip over a registry mutex poisoned
/// by the very panic it is cleaning up after.
struct ResultGuard<'a> {
    id: u64,
    results_tx: &'a Sender<JobOutcome>,
    pending: &'a AtomicU64,
    jobs_done: &'a Counter,
    jobs_failed: &'a Counter,
    deps: &'a Mutex<DepState>,
    job_tx: &'a Sender<Msg>,
    done: bool,
}

impl ResultGuard<'_> {
    fn complete(mut self, outcome: JobOutcome) {
        self.done = true;
        self.jobs_done.inc();
        if outcome.result.is_err() {
            self.jobs_failed.inc();
        }
        self.pending.fetch_sub(1, Ordering::SeqCst);
        // receiver may be gone during shutdown
        let _ = self.results_tx.send(outcome);
        release_dependents(self.id, self.deps, self.job_tx);
    }
}

impl Drop for ResultGuard<'_> {
    fn drop(&mut self) {
        if !self.done {
            self.jobs_done.inc();
            self.jobs_failed.inc();
            self.pending.fetch_sub(1, Ordering::SeqCst);
            let _ = self.results_tx.send(JobOutcome {
                id: self.id,
                timings: true,
                result: Err("worker crashed while finalizing the job".into()),
            });
            release_dependents(self.id, self.deps, self.job_tx);
        }
    }
}

impl WorkerPool {
    /// Spawn `n_workers` threads (≥1) with the default cache budget.
    pub fn new(n_workers: usize) -> WorkerPool {
        Self::with_cache(n_workers, InstanceCache::DEFAULT_BUDGET_BYTES)
    }

    /// Spawn `n_workers` threads sharing an instance cache of
    /// `cache_bytes` (0 disables residency) and a default-budget model
    /// cache.
    pub fn with_cache(n_workers: usize, cache_bytes: usize) -> WorkerPool {
        Self::with_caches(n_workers, cache_bytes, ModelCache::DEFAULT_BUDGET_BYTES)
    }

    /// Spawn `n_workers` threads with explicit byte budgets for both the
    /// instance cache and the trained-model cache (0 disables either).
    pub fn with_caches(n_workers: usize, cache_bytes: usize, model_bytes: usize) -> WorkerPool {
        let n = n_workers.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let (results_tx, results_rx) = channel::<JobOutcome>();
        let pending = Arc::new(AtomicU64::new(0));
        let metrics = Arc::new(Registry::default());
        let cache = Arc::new(InstanceCache::new(cache_bytes));
        let models = Arc::new(ModelCache::new(model_bytes));
        let deps = Arc::new(Mutex::new(DepState {
            submitted: HashSet::new(),
            done: HashSet::new(),
            waiting: HashMap::new(),
        }));

        let mut workers = Vec::with_capacity(n);
        for wid in 0..n {
            let rx = rx.clone();
            let results_tx = results_tx.clone();
            let pending = pending.clone();
            let metrics = metrics.clone();
            let cache = cache.clone();
            let models = models.clone();
            let deps = deps.clone();
            let job_tx = tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dvi-worker-{wid}"))
                    .spawn(move || {
                        // resolve the shared metric handles once, up
                        // front: the per-job path (and the guard's drop)
                        // then only touches atomics
                        let hist = metrics.histogram("job_secs");
                        let jobs_done = metrics.counter("jobs_done");
                        let jobs_failed = metrics.counter("jobs_failed");
                        loop {
                            let msg = {
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            match msg {
                                Ok(Msg::Job(spec)) => {
                                    // close the queue-wait span opened at
                                    // submit (any worker may emit it)
                                    crate::obs::event_end(
                                        "queue_wait",
                                        crate::obs::queue_span_id(spec.id),
                                    );
                                    let guard = ResultGuard {
                                        id: spec.id,
                                        results_tx: &results_tx,
                                        pending: &pending,
                                        jobs_done: &jobs_done,
                                        jobs_failed: &jobs_failed,
                                        deps: &deps,
                                        job_tx: &job_tx,
                                        done: false,
                                    };
                                    let t = std::time::Instant::now();
                                    let outcome = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(|| {
                                            run_job_cached(&spec, &cache, &models, &metrics)
                                        }),
                                    )
                                    .unwrap_or_else(|p| JobOutcome {
                                        id: spec.id,
                                        timings: spec.timings,
                                        result: Err(panic_msg(p)),
                                    });
                                    hist.record(t.elapsed());
                                    guard.complete(outcome);
                                }
                                Ok(Msg::Shutdown) | Err(_) => break,
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        WorkerPool {
            tx,
            results_rx: Mutex::new(results_rx),
            results_tx,
            rx,
            workers,
            pending,
            deps,
            metrics,
            cache,
            models,
        }
    }

    /// Enqueue a job. A job carrying [`JobSpec::after`] is parked until
    /// that dependency's outcome has been delivered. The dependency must
    /// name an *already-submitted* job — a dangling or self-referential
    /// id is failed out immediately (an error outcome, never a park),
    /// because a forever-parked job would deadlock a caller blocked in
    /// [`WorkerPool::recv`].
    pub fn submit(&self, spec: JobSpec) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        if let Some(dep) = spec.after {
            let mut st = self.deps.lock().unwrap();
            // membership is checked BEFORE this id registers, so a
            // self-dependency is dangling by construction
            if !st.submitted.contains(&dep) {
                st.submitted.insert(spec.id);
                drop(st);
                self.metrics.counter("jobs_done").inc();
                self.metrics.counter("jobs_failed").inc();
                self.pending.fetch_sub(1, Ordering::SeqCst);
                let _ = self.results_tx.send(JobOutcome {
                    id: spec.id,
                    timings: spec.timings,
                    result: Err(format!(
                        "after: {dep} does not name an already-submitted job"
                    )),
                });
                // a fail-fast is still a completion: anything gated on
                // THIS id must release (and fail on its own terms), not
                // park forever
                release_dependents(spec.id, &self.deps, &self.tx);
                return;
            }
            st.submitted.insert(spec.id);
            if !st.done.contains(&dep) {
                // parked time counts as queue wait: the span opened below
                // closes at worker pickup regardless of the park
                crate::obs::event_begin(
                    "queue_wait",
                    crate::obs::queue_span_id(spec.id),
                    crate::obs::request_span_id(spec.id),
                );
                st.waiting.entry(dep).or_default().push(spec);
                return;
            }
        } else {
            self.deps.lock().unwrap().submitted.insert(spec.id);
        }
        crate::obs::event_begin(
            "queue_wait",
            crate::obs::queue_span_id(spec.id),
            crate::obs::request_span_id(spec.id),
        );
        self.tx.send(Msg::Job(spec)).expect("pool closed");
    }

    /// Number of submitted-but-unfinished jobs.
    pub fn pending(&self) -> u64 {
        self.pending.load(Ordering::SeqCst)
    }

    /// Block for the next finished job.
    pub fn recv(&self) -> Option<JobOutcome> {
        self.results_rx.lock().unwrap().recv().ok()
    }

    /// Block for the next finished job, giving up after `timeout` — the
    /// serve dispatcher uses this to interleave result routing with
    /// shutdown checks without busy-waiting.
    pub fn recv_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Result<JobOutcome, RecvTimeoutError> {
        self.results_rx.lock().unwrap().recv_timeout(timeout)
    }

    /// Submit a batch and wait for all results (order by job id).
    pub fn run_all(&self, specs: Vec<JobSpec>) -> Vec<JobOutcome> {
        let n = specs.len();
        for s in specs {
            self.submit(s);
        }
        let mut out: Vec<JobOutcome> = (0..n).filter_map(|_| self.recv()).collect();
        out.sort_by_key(|o| o.id);
        out
    }

    /// Graceful shutdown: drains queued jobs and joins every worker
    /// (equivalent to dropping the pool — see [`Drop`]).
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for WorkerPool {
    /// Deterministic teardown even when the pool is dropped early (e.g. a
    /// panicking test): shutdown messages queue *behind* in-flight jobs,
    /// so workers finish and report every accepted job, then exit, then
    /// the drop joins them. The results receiver stays alive (it is a
    /// field of `self`) for the whole drain, so no worker ever blocks on
    /// a closed channel.
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Jobs a finishing dependency released into the queue *behind*
        // the shutdown messages: their dep DID complete, and the pool's
        // contract is that drop drains queued jobs — so run them inline
        // here (their completions may release further dependents into
        // the queue, hence the loop until dry).
        if let Ok(rx) = self.rx.lock() {
            while let Ok(msg) = rx.try_recv() {
                let Msg::Job(spec) = msg else { continue };
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_job_cached(&spec, &self.cache, &self.models, &self.metrics)
                }))
                .unwrap_or_else(|p| JobOutcome {
                    id: spec.id,
                    timings: spec.timings,
                    result: Err(panic_msg(p)),
                });
                self.metrics.counter("jobs_done").inc();
                if outcome.result.is_err() {
                    self.metrics.counter("jobs_failed").inc();
                }
                self.pending.fetch_sub(1, Ordering::SeqCst);
                let _ = self.results_tx.send(outcome);
                release_dependents(spec.id, &self.deps, &self.tx);
            }
        }
        // Anything still parked has a dependency that never ran at all
        // (a dangling id): fail it out so every accepted job still
        // yields exactly one outcome.
        let stragglers: Vec<JobSpec> = {
            let mut st = self.deps.lock().unwrap();
            st.waiting.drain().flat_map(|(_, specs)| specs).collect()
        };
        for spec in stragglers {
            self.metrics.counter("jobs_done").inc();
            self.metrics.counter("jobs_failed").inc();
            self.pending.fetch_sub(1, Ordering::SeqCst);
            let _ = self.results_tx.send(JobOutcome {
                id: spec.id,
                timings: spec.timings,
                result: Err("pool shut down before the job's dependency completed".into()),
            });
        }
    }
}

fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("job panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("job panicked: {s}")
    } else {
        "job panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GridConfig, RunConfig, SolverConfig};

    fn spec(id: u64, dataset: &str) -> JobSpec {
        JobSpec::path(
            id,
            RunConfig {
                model: "svm".into(),
                dataset: dataset.into(),
                scale: 0.03,
                rule: "dvi".into(),
                storage: "auto".into(),
                grid: GridConfig { c_min: 0.01, c_max: 10.0, points: 4 },
                solver: SolverConfig { tol: 1e-5, ..Default::default() },
                use_pjrt: false,
                validate: false,
            },
        )
    }

    #[test]
    fn runs_batch_in_parallel() {
        let pool = WorkerPool::new(3);
        let outcomes = pool.run_all(vec![spec(0, "toy1"), spec(1, "toy2"), spec(2, "toy3")]);
        assert_eq!(outcomes.len(), 3);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.id, i as u64);
            assert!(o.result.is_ok(), "{:?}", o.result);
        }
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.metrics.counter("jobs_done").get(), 3);
        pool.shutdown();
    }

    #[test]
    fn failed_jobs_are_data() {
        let pool = WorkerPool::new(1);
        let outcomes = pool.run_all(vec![spec(0, "missing-set")]);
        assert!(outcomes[0].result.is_err());
        assert_eq!(pool.metrics.counter("jobs_failed").get(), 1);
        pool.shutdown();
    }

    #[test]
    fn mixed_batch_keeps_going_after_failure() {
        let pool = WorkerPool::new(2);
        let outcomes =
            pool.run_all(vec![spec(0, "missing"), spec(1, "toy1"), spec(2, "missing2")]);
        assert!(outcomes[0].result.is_err());
        assert!(outcomes[1].result.is_ok());
        assert!(outcomes[2].result.is_err());
        pool.shutdown();
    }

    #[test]
    fn same_dataset_jobs_build_instance_once() {
        let pool = WorkerPool::new(4);
        let outcomes =
            pool.run_all(vec![spec(0, "toy1"), spec(1, "toy1"), spec(2, "toy1"), spec(3, "toy1")]);
        assert!(outcomes.iter().all(|o| o.result.is_ok()));
        assert_eq!(pool.metrics.counter("instance_cache_misses").get(), 1);
        assert_eq!(pool.metrics.counter("instance_cache_hits").get(), 3);
        assert_eq!(pool.cache.len(), 1);
        pool.shutdown();
    }

    #[test]
    fn train_then_predict_share_the_model_cache_across_workers() {
        use super::super::job::{ModelRef, PredictInput, PredictSpec, TrainSpec};
        use crate::linalg::Storage;
        use crate::problem::Model;
        let pool = WorkerPool::new(2);
        // train must complete before predict-by-id is submitted: jobs on
        // the pool run concurrently, so the client sequences them
        pool.submit(JobSpec::train(
            0,
            TrainSpec {
                dataset: "toy1".into(),
                model: Model::Svm,
                scale: 0.03,
                storage: Storage::Auto,
                c: 0.5,
                solver: SolverConfig { tol: 1e-6, ..Default::default() },
                save: None,
                persist_dir: None,
                report_support: false,
            },
        ));
        let trained = pool.recv().unwrap().result.unwrap();
        let id = trained.as_train().unwrap().model_id.clone();
        assert_eq!(pool.models.len(), 1);

        pool.submit(JobSpec::predict(
            1,
            PredictSpec {
                model: ModelRef::Id(id),
                input: PredictInput::Rows { flat: vec![1.0, 1.0], width: 2 },
                threads: 1,
                support_only: false,
            },
        ));
        let out = pool.recv().unwrap().result.unwrap();
        assert_eq!(out.as_predict().unwrap().scores.len(), 1);
        assert_eq!(pool.metrics.counter("model_cache_hits").get(), 1);
        pool.shutdown();
    }

    #[test]
    fn after_edge_orders_train_before_predict() {
        use super::super::job::{ModelRef, PredictInput, PredictSpec, TrainSpec};
        use crate::linalg::Storage;
        use crate::problem::Model;
        // learn the deterministic model id up front (content digest)
        let probe = super::super::job::run_job(&JobSpec::train(
            0,
            TrainSpec {
                dataset: "toy1".into(),
                model: Model::Svm,
                scale: 0.03,
                storage: Storage::Auto,
                c: 0.5,
                solver: SolverConfig { tol: 1e-6, ..Default::default() },
                save: None,
                persist_dir: None,
                report_support: false,
            },
        ));
        let id = probe.result.unwrap().as_train().unwrap().model_id.clone();

        // submit train + dependent predict TOGETHER on a multi-worker
        // pool: without the edge the predict could run first and miss
        let pool = WorkerPool::new(3);
        pool.submit(JobSpec::train(
            0,
            TrainSpec {
                dataset: "toy1".into(),
                model: Model::Svm,
                scale: 0.03,
                storage: Storage::Auto,
                c: 0.5,
                solver: SolverConfig { tol: 1e-6, ..Default::default() },
                save: None,
                persist_dir: None,
                report_support: false,
            },
        ));
        pool.submit(
            JobSpec::predict(
                1,
                PredictSpec {
                    model: ModelRef::Id(id),
                    input: PredictInput::Rows { flat: vec![1.0, 1.0], width: 2 },
                    threads: 1,
                    support_only: false,
                },
            )
            .after(0),
        );
        let mut outcomes = vec![pool.recv().unwrap(), pool.recv().unwrap()];
        outcomes.sort_by_key(|o| o.id);
        assert!(outcomes[0].result.is_ok(), "{:?}", outcomes[0].result);
        assert!(
            outcomes[1].result.is_ok(),
            "predict must run after its train dep: {:?}",
            outcomes[1].result
        );
        assert_eq!(pool.pending(), 0);
        pool.shutdown();
    }

    #[test]
    fn after_edge_on_completed_dep_runs_immediately_and_failures_release() {
        let pool = WorkerPool::new(1);
        // dep fails (unknown dataset) — the dependent must still run
        pool.submit(spec(0, "missing-set"));
        pool.submit(spec(1, "toy1").after(0));
        let mut outcomes = vec![pool.recv().unwrap(), pool.recv().unwrap()];
        outcomes.sort_by_key(|o| o.id);
        assert!(outcomes[0].result.is_err());
        assert!(outcomes[1].result.is_ok(), "failed dep must still release");
        // a dep that already completed gates nothing
        pool.submit(spec(2, "toy1").after(1));
        assert!(pool.recv().unwrap().result.is_ok());
        pool.shutdown();
    }

    #[test]
    fn dangling_or_self_after_fails_fast() {
        let pool = WorkerPool::new(1);
        pool.submit(spec(0, "toy1").after(99)); // 99 never submitted
        let out = pool.recv().unwrap();
        assert_eq!(out.id, 0);
        assert!(out.result.is_err(), "dangling dep must not park forever");
        // self-dependency is dangling by construction (membership is
        // checked before the id registers)
        pool.submit(spec(1, "toy1").after(1));
        let out = pool.recv().unwrap();
        assert!(out.result.is_err());
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.metrics.counter("jobs_failed").get(), 2);
        // a fail-fast still counts as completion: a job gated on the
        // failed id runs (and succeeds on its own terms)
        pool.submit(spec(2, "toy1").after(0));
        assert!(pool.recv().unwrap().result.is_ok());
        pool.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_workers_and_drains_queue() {
        let pool = WorkerPool::new(2);
        for i in 0..4 {
            pool.submit(spec(i, "toy1"));
        }
        // drop immediately: queued jobs still run to completion before the
        // workers see their shutdown messages (FIFO queue), and the drop
        // blocks until every worker has exited
        drop(pool);
    }

    #[test]
    fn panicked_job_still_yields_its_response() {
        // a degenerate grid (c_min == c_max) trips the GridConfig assert
        // inside the worker; catch_unwind must turn it into an error
        // outcome while the next queued job still completes
        let mut bad = spec(0, "toy1");
        if let super::super::job::JobKind::Path(run) = &mut bad.kind {
            run.grid = GridConfig { c_min: 1.0, c_max: 1.0, points: 2 };
        }
        let pool = WorkerPool::new(1);
        let outcomes = pool.run_all(vec![bad, spec(1, "toy1")]);
        assert_eq!(outcomes.len(), 2, "no response line may be lost");
        assert!(outcomes[0].result.is_err(), "panic must surface as an error outcome");
        assert!(outcomes[1].result.is_ok());
        assert_eq!(pool.metrics.counter("jobs_failed").get(), 1);
        assert_eq!(pool.pending(), 0);
        pool.shutdown();
    }
}
