//! Thread worker pool executing [`JobSpec`]s.
//!
//! std-only (no tokio offline): a bounded mpsc work queue feeding N worker
//! threads, results collected on a shared channel. Jobs that panic are
//! caught (`catch_unwind`) and surfaced as failed outcomes — one bad run
//! must not take down an experiment sweep.

use super::job::{run_job, JobOutcome, JobSpec};
use crate::metrics::Registry;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum Msg {
    Job(JobSpec),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct WorkerPool {
    tx: Sender<Msg>,
    results_rx: Receiver<JobOutcome>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicU64>,
    pub metrics: Arc<Registry>,
}

impl WorkerPool {
    /// Spawn `n_workers` threads (≥1).
    pub fn new(n_workers: usize) -> WorkerPool {
        let n = n_workers.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let (results_tx, results_rx) = channel::<JobOutcome>();
        let pending = Arc::new(AtomicU64::new(0));
        let metrics = Arc::new(Registry::default());

        let mut workers = Vec::with_capacity(n);
        for wid in 0..n {
            let rx = rx.clone();
            let results_tx = results_tx.clone();
            let pending = pending.clone();
            let metrics = metrics.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dvi-worker-{wid}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match msg {
                            Ok(Msg::Job(spec)) => {
                                let hist = metrics.histogram("job_secs");
                                let t = std::time::Instant::now();
                                let outcome = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| run_job(&spec)),
                                )
                                .unwrap_or_else(|p| JobOutcome {
                                    id: spec.id,
                                    result: Err(panic_msg(p)),
                                });
                                hist.record(t.elapsed());
                                metrics.counter("jobs_done").inc();
                                if outcome.result.is_err() {
                                    metrics.counter("jobs_failed").inc();
                                }
                                pending.fetch_sub(1, Ordering::SeqCst);
                                // receiver may be gone during shutdown
                                let _ = results_tx.send(outcome);
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        WorkerPool { tx, results_rx, workers, pending, metrics }
    }

    /// Enqueue a job.
    pub fn submit(&self, spec: JobSpec) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Job(spec)).expect("pool closed");
    }

    /// Number of submitted-but-unfinished jobs.
    pub fn pending(&self) -> u64 {
        self.pending.load(Ordering::SeqCst)
    }

    /// Block for the next finished job.
    pub fn recv(&self) -> Option<JobOutcome> {
        self.results_rx.recv().ok()
    }

    /// Submit a batch and wait for all results (order by job id).
    pub fn run_all(&self, specs: Vec<JobSpec>) -> Vec<JobOutcome> {
        let n = specs.len();
        for s in specs {
            self.submit(s);
        }
        let mut out: Vec<JobOutcome> = (0..n).filter_map(|_| self.recv()).collect();
        out.sort_by_key(|o| o.id);
        out
    }

    /// Graceful shutdown (waits for workers to exit).
    pub fn shutdown(mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("job panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("job panicked: {s}")
    } else {
        "job panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GridConfig, RunConfig, SolverConfig};

    fn spec(id: u64, dataset: &str) -> JobSpec {
        JobSpec {
            id,
            run: RunConfig {
                model: "svm".into(),
                dataset: dataset.into(),
                scale: 0.03,
                rule: "dvi".into(),
                storage: "auto".into(),
                grid: GridConfig { c_min: 0.01, c_max: 10.0, points: 4 },
                solver: SolverConfig { tol: 1e-5, ..Default::default() },
                use_pjrt: false,
                validate: false,
            },
        }
    }

    #[test]
    fn runs_batch_in_parallel() {
        let pool = WorkerPool::new(3);
        let outcomes = pool.run_all(vec![spec(0, "toy1"), spec(1, "toy2"), spec(2, "toy3")]);
        assert_eq!(outcomes.len(), 3);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.id, i as u64);
            assert!(o.result.is_ok(), "{:?}", o.result);
        }
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.metrics.counter("jobs_done").get(), 3);
        pool.shutdown();
    }

    #[test]
    fn failed_jobs_are_data() {
        let pool = WorkerPool::new(1);
        let outcomes = pool.run_all(vec![spec(0, "missing-set")]);
        assert!(outcomes[0].result.is_err());
        assert_eq!(pool.metrics.counter("jobs_failed").get(), 1);
        pool.shutdown();
    }

    #[test]
    fn mixed_batch_keeps_going_after_failure() {
        let pool = WorkerPool::new(2);
        let outcomes =
            pool.run_all(vec![spec(0, "missing"), spec(1, "toy1"), spec(2, "missing2")]);
        assert!(outcomes[0].result.is_err());
        assert!(outcomes[1].result.is_ok());
        assert!(outcomes[2].result.is_err());
        pool.shutdown();
    }
}
