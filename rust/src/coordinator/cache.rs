//! Resident instance cache: the amortization layer that turns the
//! per-request experiment harness into a screening server.
//!
//! The paper's selling point is that a DVI screening pass costs one scan —
//! negligible next to the solve — but a service that re-parses the dataset
//! and re-builds the [`Instance`] (the z-transform, row norms, box) on
//! every request pays more for construction than for the scan, especially
//! on CSR data where the scan is cheap but the parse/convert is not. The
//! cache keeps built instances resident, keyed by everything construction
//! depends on — `(dataset, model, storage, scale)` — and hands out
//! `Arc<Instance>` so concurrent jobs share one copy.
//!
//! Properties:
//!
//! * **Exactly-once construction.** Concurrent requests for the same key
//!   serialize on a per-key build slot: the first locker builds, the rest
//!   block and receive the same `Arc`. A batch of B same-dataset requests
//!   fanned across the worker pool constructs the instance once (asserted
//!   by the batch integration tests via the hit/miss counters).
//! * **LRU eviction under a byte budget.** Entries are charged
//!   [`Instance::approx_bytes`] (dense `l·n·8`, CSR `nnz·12 + indptr`).
//!   When an insert pushes the resident total over the budget, least-
//!   recently-used entries are evicted until it fits; the entry just
//!   inserted is never evicted by its own insert, so one oversized
//!   instance stays resident (and becomes evictable by the next insert).
//!   Evicted `Arc`s stay alive until in-flight jobs drop them. A zero
//!   budget disables caching entirely (every call builds transiently).
//! * **Metrics.** `instance_cache_hits` / `instance_cache_misses` (=
//!   successful constructions) / `instance_cache_errors` /
//!   `instance_cache_evictions` counters plus `instance_cache_bytes` /
//!   `instance_cache_entries` gauges in the pool's [`Registry`].
//! * **Errors are not cached.** A failed resolve (unknown dataset,
//!   task/model mismatch, unreadable file) is reported to every waiter
//!   and retried on the next request.

use crate::data::registry;
use crate::linalg::Storage;
use crate::metrics::Registry;
use crate::problem::{Instance, Model};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Everything [`Instance`] construction depends on. `scale` participates
/// as its bit pattern so the key stays `Eq + Hash` (requests are parsed
/// from text, so two requests meaning the same scale carry identical
/// bits).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub dataset: String,
    pub model: Model,
    pub storage: Storage,
    scale_bits: u64,
}

impl CacheKey {
    pub fn new(dataset: &str, model: Model, storage: Storage, scale: f64) -> CacheKey {
        CacheKey { dataset: dataset.to_string(), model, storage, scale_bits: scale.to_bits() }
    }

    pub fn scale(&self) -> f64 {
        f64::from_bits(self.scale_bits)
    }
}

/// Per-key build slot: the mutex serializes construction, the option
/// holds the built instance.
struct Slot {
    built: Mutex<Option<Arc<Instance>>>,
}

struct Entry {
    slot: Arc<Slot>,
    /// Recency tick of the last `get_or_build` touch.
    last_used: u64,
    /// [`Instance::approx_bytes`] once built; 0 while building (unbuilt
    /// entries are never evicted — they hold no bytes yet).
    bytes: usize,
}

struct CacheState {
    entries: HashMap<CacheKey, Entry>,
    tick: u64,
    resident_bytes: usize,
}

/// `(dataset, model, storage, scale)`-keyed LRU cache of built
/// [`Instance`]s, shared by every worker in a pool.
pub struct InstanceCache {
    budget_bytes: usize,
    state: Mutex<CacheState>,
}

impl InstanceCache {
    /// Default byte budget for pools that don't configure one
    /// (`dvi serve --cache-mb` overrides): 256 MiB holds e.g. a dense
    /// 1M×32 instance or a ~20M-nonzero CSR one with room to spare.
    pub const DEFAULT_BUDGET_BYTES: usize = 256 * 1024 * 1024;

    /// `budget_bytes = 0` disables residency: every call constructs a
    /// transient instance (still counted as a miss).
    pub fn new(budget_bytes: usize) -> InstanceCache {
        InstanceCache {
            budget_bytes,
            state: Mutex::new(CacheState {
                entries: HashMap::new(),
                tick: 0,
                resident_bytes: 0,
            }),
        }
    }

    /// Number of resident (built) entries.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().entries.values().filter(|e| e.bytes > 0).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes charged against the budget.
    pub fn resident_bytes(&self) -> usize {
        self.state.lock().unwrap().resident_bytes
    }

    /// Fetch the instance for `key`, constructing it if absent. Counts a
    /// hit when the built instance is already resident and a miss when
    /// this call had to construct one — so `instance_cache_misses` equals
    /// the number of instances ever built and the batch acceptance test
    /// can assert "B same-dataset requests, exactly one construction".
    /// Failed builds count `instance_cache_errors` instead. Concurrent
    /// misses on one key build exactly once: the builder counts the miss,
    /// the waiters blocked on the slot count hits once the instance
    /// appears.
    pub fn get_or_build(&self, key: &CacheKey, metrics: &Registry) -> Result<Arc<Instance>, String> {
        if self.budget_bytes == 0 {
            return match build_instance(key) {
                Ok(inst) => {
                    metrics.counter("instance_cache_misses").inc();
                    Ok(Arc::new(inst))
                }
                Err(e) => {
                    metrics.counter("instance_cache_errors").inc();
                    Err(e)
                }
            };
        }
        let slot = {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            match st.entries.get_mut(key) {
                Some(e) => {
                    e.last_used = tick;
                    e.slot.clone()
                }
                None => {
                    let slot = Arc::new(Slot { built: Mutex::new(None) });
                    st.entries.insert(
                        key.clone(),
                        Entry { slot: slot.clone(), last_used: tick, bytes: 0 },
                    );
                    slot
                }
            }
        };
        let mut built = slot.built.lock().unwrap();
        if let Some(inst) = built.as_ref() {
            metrics.counter("instance_cache_hits").inc();
            return Ok(inst.clone());
        }
        match build_instance(key) {
            Ok(inst) => {
                metrics.counter("instance_cache_misses").inc();
                let inst = Arc::new(inst);
                *built = Some(inst.clone());
                drop(built);
                self.charge_and_evict(key, &slot, inst.approx_bytes(), metrics);
                Ok(inst)
            }
            Err(e) => {
                metrics.counter("instance_cache_errors").inc();
                drop(built);
                self.forget_failed(key, &slot);
                Err(e)
            }
        }
    }

    /// Record the built entry's size, then evict LRU entries until the
    /// resident total fits the budget again. The entry just inserted is
    /// exempt from its own eviction pass; unbuilt entries (a concurrent
    /// build mid-flight) hold no bytes and are skipped.
    fn charge_and_evict(&self, key: &CacheKey, slot: &Arc<Slot>, bytes: usize, metrics: &Registry) {
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.entries.get_mut(key) {
            // only charge if this is still our entry (a failed build may
            // have been forgotten and re-created by another thread)
            if Arc::ptr_eq(&e.slot, slot) && e.bytes == 0 {
                e.bytes = bytes;
                st.resident_bytes += bytes;
            }
        }
        while st.resident_bytes > self.budget_bytes {
            let victim = st
                .entries
                .iter()
                .filter(|(k, e)| e.bytes > 0 && *k != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some(e) = st.entries.remove(&k) {
                        st.resident_bytes -= e.bytes;
                        metrics.counter("instance_cache_evictions").inc();
                    }
                }
                None => break, // only the fresh entry remains; keep it
            }
        }
        metrics.gauge("instance_cache_bytes").set(st.resident_bytes as u64);
        metrics
            .gauge("instance_cache_entries")
            .set(st.entries.values().filter(|e| e.bytes > 0).count() as u64);
    }

    /// Drop the placeholder entry for a failed build (only if it is still
    /// ours — a concurrent retry may have replaced it).
    fn forget_failed(&self, key: &CacheKey, slot: &Arc<Slot>) {
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.entries.get(key) {
            if Arc::ptr_eq(&e.slot, slot) && e.bytes == 0 {
                st.entries.remove(key);
            }
        }
    }
}

/// Resolve the dataset and build the instance — the single construction
/// path the cache guards. Mirrors what a per-request job used to do
/// inline.
fn build_instance(key: &CacheKey) -> Result<Instance, String> {
    let ds = registry::resolve_storage(
        &key.dataset,
        key.scale(),
        key.model.expected_task(),
        key.storage,
    )?;
    if ds.task != key.model.expected_task() {
        return Err(format!(
            "dataset `{}` is a {:?} set but model {:?} expects {:?}",
            key.dataset,
            ds.task,
            key.model,
            key.model.expected_task()
        ));
    }
    Ok(Instance::from_dataset(key.model, &ds))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(dataset: &str, scale: f64) -> CacheKey {
        CacheKey::new(dataset, Model::Svm, Storage::Auto, scale)
    }

    #[test]
    fn hit_after_miss_shares_one_arc() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        let a = cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        let b = cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(m.counter("instance_cache_misses").get(), 1);
        assert_eq!(m.counter("instance_cache_hits").get(), 1);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), a.approx_bytes());
    }

    #[test]
    fn key_fields_separate_entries() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        cache.get_or_build(&key("toy1", 0.06), &m).unwrap();
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        cache
            .get_or_build(&CacheKey::new("toy1", Model::Svm, Storage::Csr, 0.05), &m)
            .unwrap();
        cache
            .get_or_build(&CacheKey::new("toy1", Model::WeightedSvm, Storage::Auto, 0.05), &m)
            .unwrap();
        assert_eq!(m.counter("instance_cache_misses").get(), 5);
        assert_eq!(m.counter("instance_cache_hits").get(), 0);
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn zero_budget_disables_residency() {
        let cache = InstanceCache::new(0);
        let m = Registry::default();
        let a = cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        let b = cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(m.counter("instance_cache_misses").get(), 2);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let m = Registry::default();
        // size the budget to hold exactly two toy instances
        let probe = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let one = probe.get_or_build(&key("toy1", 0.05), &m).unwrap().approx_bytes();
        let cache = InstanceCache::new(2 * one + one / 2);
        let m = Registry::default();
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        // touch toy1 so toy2 is the LRU
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        cache.get_or_build(&key("toy3", 0.05), &m).unwrap();
        assert_eq!(m.counter("instance_cache_evictions").get(), 1);
        assert_eq!(cache.len(), 2);
        // toy1 survived (recently used), toy2 was evicted
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        assert_eq!(m.counter("instance_cache_hits").get(), 2);
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        assert_eq!(m.counter("instance_cache_misses").get(), 4, "toy2 must rebuild");
    }

    #[test]
    fn oversized_entry_stays_until_next_insert() {
        let m = Registry::default();
        let cache = InstanceCache::new(1); // smaller than any instance
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        assert_eq!(cache.len(), 1, "fresh entry is never evicted by its own insert");
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        // the toy2 insert evicts toy1, then toy2 itself stays
        assert_eq!(cache.len(), 1);
        assert_eq!(m.counter("instance_cache_evictions").get(), 1);
    }

    #[test]
    fn errors_are_not_cached() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        assert!(cache.get_or_build(&key("no-such-set", 0.05), &m).is_err());
        assert!(cache.get_or_build(&key("no-such-set", 0.05), &m).is_err());
        assert_eq!(m.counter("instance_cache_errors").get(), 2, "errors retry");
        assert_eq!(m.counter("instance_cache_misses").get(), 0, "a miss means a build");
        assert_eq!(cache.len(), 0);
        // task mismatch is an error, not a panic
        let bad = CacheKey::new("houses", Model::Svm, Storage::Auto, 0.05);
        let e = cache.get_or_build(&bad, &m);
        assert!(e.is_err(), "houses is a regression set");
    }

    #[test]
    fn concurrent_same_key_builds_once() {
        let cache = Arc::new(InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES));
        let m = Arc::new(Registry::default());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cache = cache.clone();
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                cache.get_or_build(&key("toy2", 0.05), &m).unwrap().len()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("instance_cache_misses").get(), 1, "exactly one build");
        assert_eq!(m.counter("instance_cache_hits").get(), 7);
    }
}
