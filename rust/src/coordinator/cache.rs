//! Resident instance cache: the amortization layer that turns the
//! per-request experiment harness into a screening server.
//!
//! The paper's selling point is that a DVI screening pass costs one scan —
//! negligible next to the solve — but a service that re-parses the dataset
//! and re-builds the [`Instance`] (the z-transform, row norms, box) on
//! every request pays more for construction than for the scan, especially
//! on CSR data where the scan is cheap but the parse/convert is not. The
//! cache keeps built instances resident, keyed by everything construction
//! depends on — `(dataset, model, storage, scale)` — and hands out
//! `Arc<Instance>` so concurrent jobs share one copy.
//!
//! Properties:
//!
//! * **Exactly-once construction.** Concurrent requests for the same key
//!   serialize on a per-key build slot: the first locker builds, the rest
//!   block and receive the same `Arc`. A batch of B same-dataset requests
//!   fanned across the worker pool constructs the instance once (asserted
//!   by the batch integration tests via the hit/miss counters).
//! * **LRU eviction under a byte budget.** Entries are charged
//!   [`Instance::approx_bytes`] (dense `l·n·8`, CSR `nnz·12 + indptr`).
//!   When an insert pushes the resident total over the budget, least-
//!   recently-used entries are evicted until it fits; the entry just
//!   inserted is never evicted by its own insert, so one oversized
//!   instance stays resident (and becomes evictable by the next insert).
//!   Evicted `Arc`s stay alive until in-flight jobs drop them. A zero
//!   budget disables caching entirely (every call builds transiently).
//! * **Metrics.** `instance_cache_hits` / `instance_cache_misses` (=
//!   successful constructions) / `instance_cache_errors` /
//!   `instance_cache_evictions` counters plus `instance_cache_bytes` /
//!   `instance_cache_entries` gauges in the pool's [`Registry`].
//! * **Errors are not cached.** A failed resolve (unknown dataset,
//!   task/model mismatch, unreadable file) is reported to every waiter
//!   and retried on the next request.
//!
//! The recency/byte bookkeeping itself — tick clock, charge/uncharge,
//! evict-until-fit with the fresh-entry exemption, the gauge pair — is
//! one generic [`LruCore`] shared with the sibling [`ModelCache`]
//! (deferred from PR 4; previously each cache carried its own copy of
//! the eviction loop).

use crate::data::registry;
use crate::linalg::Storage;
use crate::metrics::Registry;
use crate::model::{format, TrainedModel};
use crate::problem::{Instance, Model};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Everything [`Instance`] construction depends on. `scale` participates
/// as its bit pattern so the key stays `Eq + Hash` (requests are parsed
/// from text, so two requests meaning the same scale carry identical
/// bits).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub dataset: String,
    pub model: Model,
    pub storage: Storage,
    scale_bits: u64,
}

impl CacheKey {
    pub fn new(dataset: &str, model: Model, storage: Storage, scale: f64) -> CacheKey {
        CacheKey { dataset: dataset.to_string(), model, storage, scale_bits: scale.to_bits() }
    }

    pub fn scale(&self) -> f64 {
        f64::from_bits(self.scale_bits)
    }
}

/// Per-key build slot: the mutex serializes construction, the option
/// holds the built instance.
struct Slot {
    built: Mutex<Option<Arc<Instance>>>,
}

/// One entry of the shared LRU core: a value plus the recency/size/hit
/// bookkeeping both caches used to duplicate. `bytes == 0` means "not
/// resident yet" (an instance placeholder still building) — such entries
/// are never eviction victims and don't count toward the gauges.
struct LruEntry<V> {
    value: V,
    /// Recency tick of the last touch (strictly increasing per core, so
    /// LRU victim selection is deterministic).
    last_used: u64,
    bytes: usize,
    /// Resident-hit count (the `"kind": "cache"` introspection surface).
    hits: u64,
}

/// The byte-budget LRU core [`InstanceCache`] and [`ModelCache`] share:
/// tick/recency bookkeeping, byte charging, evict-until-fit with the
/// fresh-entry exemption, and the `{prefix}_bytes`/`{prefix}_entries`
/// gauge pair. Wrappers hold it behind their own mutex and keep their
/// policy differences (build slots and deferred charging for instances;
/// replace-keeps-hits inserts and file loads for models) on top of these
/// primitives — one eviction loop instead of the two copies PR 3/PR 4
/// shipped.
struct LruCore<K, V> {
    entries: HashMap<K, LruEntry<V>>,
    tick: u64,
    resident_bytes: usize,
}

impl<K: Eq + std::hash::Hash + Clone, V> LruCore<K, V> {
    fn new() -> LruCore<K, V> {
        LruCore { entries: HashMap::new(), tick: 0, resident_bytes: 0 }
    }

    /// Advance and return the recency clock.
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn get_mut<Q>(&mut self, k: &Q) -> Option<&mut LruEntry<V>>
    where
        K: std::borrow::Borrow<Q>,
        Q: Eq + std::hash::Hash + ?Sized,
    {
        self.entries.get_mut(k)
    }

    /// Insert at a fresh tick, charging `bytes`. Any existing entry under
    /// the key is removed (and uncharged) first and returned; its hit
    /// count carries over to the new entry — for both caches, same key
    /// means same logical object (content-digest model ids, full
    /// construction-input instance keys), so a refresh keeps its history.
    fn insert(&mut self, k: K, value: V, bytes: usize) -> Option<LruEntry<V>> {
        let tick = self.next_tick();
        let displaced = self.entries.remove(&k);
        if let Some(old) = &displaced {
            self.resident_bytes -= old.bytes;
        }
        let hits = displaced.as_ref().map_or(0, |old| old.hits);
        self.resident_bytes += bytes;
        self.entries.insert(k, LruEntry { value, last_used: tick, bytes, hits });
        displaced
    }

    /// Charge a so-far-unresident entry (a build slot whose construction
    /// just finished). No-op if the entry is gone or already charged.
    fn charge(&mut self, k: &K, bytes: usize) {
        if let Some(e) = self.entries.get_mut(k) {
            if e.bytes == 0 {
                e.bytes = bytes;
                self.resident_bytes += bytes;
            }
        }
    }

    /// Remove and uncharge an entry.
    fn remove<Q>(&mut self, k: &Q) -> Option<LruEntry<V>>
    where
        K: std::borrow::Borrow<Q>,
        Q: Eq + std::hash::Hash + ?Sized,
    {
        let e = self.entries.remove(k)?;
        self.resident_bytes -= e.bytes;
        Some(e)
    }

    /// Number of resident (charged) entries.
    fn resident_len(&self) -> usize {
        self.entries.values().filter(|e| e.bytes > 0).count()
    }

    /// Evict least-recently-used resident entries until `resident_bytes`
    /// fits the budget. The `protect` key — the entry whose insert
    /// triggered this pass — is exempt, so one oversized entry stays
    /// resident (and becomes evictable by the next insert); unresident
    /// placeholders hold no bytes and are skipped.
    fn evict_until_fit(&mut self, budget: usize, protect: &K, evictions: &crate::metrics::Counter) {
        while self.resident_bytes > budget {
            let victim = self
                .entries
                .iter()
                .filter(|(k, e)| e.bytes > 0 && *k != protect)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if self.remove(&k).is_some() {
                        evictions.inc();
                    }
                }
                None => break, // only the fresh entry remains; keep it
            }
        }
    }

    /// Refresh the `{prefix}_bytes` / `{prefix}_entries` gauge pair.
    fn publish(&self, metrics: &Registry, prefix: &str) {
        metrics.gauge(&format!("{prefix}_bytes")).set(self.resident_bytes as u64);
        metrics
            .gauge(&format!("{prefix}_entries"))
            .set(self.resident_len() as u64);
    }
}

/// One resident instance entry, as reported by the `"kind": "cache"`
/// introspection request.
#[derive(Clone, Debug)]
pub struct InstanceEntryInfo {
    pub dataset: String,
    pub model: Model,
    pub storage: Storage,
    pub scale: f64,
    pub bytes: usize,
    pub hits: u64,
}

/// `(dataset, model, storage, scale)`-keyed LRU cache of built
/// [`Instance`]s, shared by every worker in a pool.
pub struct InstanceCache {
    budget_bytes: usize,
    state: Mutex<LruCore<CacheKey, Arc<Slot>>>,
}

impl InstanceCache {
    /// Default byte budget for pools that don't configure one
    /// (`dvi serve --cache-mb` overrides): 256 MiB holds e.g. a dense
    /// 1M×32 instance or a ~20M-nonzero CSR one with room to spare.
    pub const DEFAULT_BUDGET_BYTES: usize = 256 * 1024 * 1024;

    /// `budget_bytes = 0` disables residency: every call constructs a
    /// transient instance (still counted as a miss).
    pub fn new(budget_bytes: usize) -> InstanceCache {
        InstanceCache { budget_bytes, state: Mutex::new(LruCore::new()) }
    }

    /// Configured byte budget (0 = residency disabled).
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Number of resident (built) entries.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().resident_len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes charged against the budget.
    pub fn resident_bytes(&self) -> usize {
        self.state.lock().unwrap().resident_bytes
    }

    /// Fetch the instance for `key`, constructing it if absent. Counts a
    /// hit when the built instance is already resident and a miss when
    /// this call had to construct one — so `instance_cache_misses` equals
    /// the number of instances ever built and the batch acceptance test
    /// can assert "B same-dataset requests, exactly one construction".
    /// Failed builds count `instance_cache_errors` instead. Concurrent
    /// misses on one key build exactly once: the builder counts the miss,
    /// the waiters blocked on the slot count hits once the instance
    /// appears.
    pub fn get_or_build(&self, key: &CacheKey, metrics: &Registry) -> Result<Arc<Instance>, String> {
        if self.budget_bytes == 0 {
            return match build_instance(key) {
                Ok(inst) => {
                    metrics.counter("instance_cache_misses").inc();
                    Ok(Arc::new(inst))
                }
                Err(e) => {
                    metrics.counter("instance_cache_errors").inc();
                    Err(e)
                }
            };
        }
        let slot = {
            let mut st = self.state.lock().unwrap();
            let tick = st.next_tick();
            match st.get_mut(key) {
                Some(e) => {
                    e.last_used = tick;
                    // resident-hit bookkeeping rides the lock we already
                    // hold: bytes > 0 means built, so this touch WILL hit
                    // on the slot below. (Waiters that arrive mid-build —
                    // bytes still 0 — count a metrics hit once the slot
                    // yields but not an entry hit; the introspection
                    // counter may undercount by those rare waiters, which
                    // is the price of not re-taking the global lock on
                    // the hot hit path.)
                    if e.bytes > 0 {
                        e.hits += 1;
                    }
                    e.value.clone()
                }
                None => {
                    let slot = Arc::new(Slot { built: Mutex::new(None) });
                    // a placeholder: 0 bytes until the build charges it
                    let _ = st.insert(key.clone(), slot.clone(), 0);
                    slot
                }
            }
        };
        let mut built = slot.built.lock().unwrap();
        if let Some(inst) = built.as_ref() {
            metrics.counter("instance_cache_hits").inc();
            return Ok(inst.clone());
        }
        match build_instance(key) {
            Ok(inst) => {
                metrics.counter("instance_cache_misses").inc();
                let inst = Arc::new(inst);
                *built = Some(inst.clone());
                drop(built);
                self.charge_and_evict(key, &slot, inst.approx_bytes(), metrics);
                Ok(inst)
            }
            Err(e) => {
                metrics.counter("instance_cache_errors").inc();
                drop(built);
                self.forget_failed(key, &slot);
                Err(e)
            }
        }
    }

    /// Record the built entry's size, then evict LRU entries until the
    /// resident total fits the budget again (the core's evict-until-fit:
    /// the entry just inserted is exempt from its own pass; unbuilt
    /// entries hold no bytes and are skipped).
    fn charge_and_evict(&self, key: &CacheKey, slot: &Arc<Slot>, bytes: usize, metrics: &Registry) {
        let mut st = self.state.lock().unwrap();
        // only charge if this is still our entry (a failed build may
        // have been forgotten and re-created by another thread)
        let ours = st
            .get_mut(key)
            .map_or(false, |e| Arc::ptr_eq(&e.value, slot) && e.bytes == 0);
        if ours {
            st.charge(key, bytes);
        }
        st.evict_until_fit(
            self.budget_bytes,
            key,
            &metrics.counter("instance_cache_evictions"),
        );
        st.publish(metrics, "instance_cache");
    }

    /// Drop the placeholder entry for a failed build (only if it is still
    /// ours — a concurrent retry may have replaced it).
    fn forget_failed(&self, key: &CacheKey, slot: &Arc<Slot>) {
        let mut st = self.state.lock().unwrap();
        let ours = st
            .get_mut(key)
            .map_or(false, |e| Arc::ptr_eq(&e.value, slot) && e.bytes == 0);
        if ours {
            st.remove(key);
        }
    }

    /// Snapshot of the resident (built) entries, deterministically sorted
    /// by key — the `"kind": "cache"` list surface.
    pub fn snapshot(&self) -> Vec<InstanceEntryInfo> {
        let st = self.state.lock().unwrap();
        let mut out: Vec<InstanceEntryInfo> = st
            .entries
            .iter()
            .filter(|(_, e)| e.bytes > 0)
            .map(|(k, e)| InstanceEntryInfo {
                dataset: k.dataset.clone(),
                model: k.model,
                storage: k.storage,
                scale: k.scale(),
                bytes: e.bytes,
                hits: e.hits,
            })
            .collect();
        out.sort_by(|a, b| {
            (&a.dataset, a.model.name(), a.storage.name(), a.scale.to_bits()).cmp(&(
                &b.dataset,
                b.model.name(),
                b.storage.name(),
                b.scale.to_bits(),
            ))
        });
        out
    }

    /// Explicitly evict one built entry (the `"kind": "cache"` evict
    /// surface). Returns whether an entry was removed; entries still
    /// building are left alone (their builder will charge them, and a
    /// follow-up evict can then remove them).
    pub fn evict_key(&self, key: &CacheKey, metrics: &Registry) -> bool {
        let mut st = self.state.lock().unwrap();
        let evictable = st.entries.get(key).map_or(false, |e| e.bytes > 0);
        if !evictable {
            return false;
        }
        st.remove(key).expect("checked above");
        metrics.counter("instance_cache_evictions").inc();
        st.publish(metrics, "instance_cache");
        true
    }
}

/// Resolve the dataset and build the instance — the single construction
/// path the cache guards. Mirrors what a per-request job used to do
/// inline.
fn build_instance(key: &CacheKey) -> Result<Instance, String> {
    let ds = registry::resolve_storage(
        &key.dataset,
        key.scale(),
        key.model.expected_task(),
        key.storage,
    )?;
    if ds.task != key.model.expected_task() {
        return Err(format!(
            "dataset `{}` is a {:?} set but model {:?} expects {:?}",
            key.dataset,
            ds.task,
            key.model,
            key.model.expected_task()
        ));
    }
    Ok(Instance::from_dataset(key.model, &ds))
}

/// One resident model entry, as reported by `"kind": "cache"`.
#[derive(Clone, Debug)]
pub struct ModelEntryInfo {
    pub id: String,
    pub bytes: usize,
    pub hits: u64,
}

/// Resident cache of [`TrainedModel`]s keyed by their deterministic id —
/// the instance cache's sibling on the serving side of the train →
/// predict loop, built over the same [`LruCore`] (one eviction loop, one
/// gauge pair, shared fresh-entry exemption):
/// [`TrainedModel::approx_bytes`] per entry, `model_cache_{hits,misses,
/// loads,evictions,errors}` counters plus `model_cache_{bytes,entries}`
/// gauges, zero budget disables residency. Unlike instances, models
/// enter by *insertion* (a train job) or by *loading* an artifact file —
/// there is no per-key build slot because neither path has the instance
/// cache's expensive-concurrent-rebuild problem: inserts are cheap, and
/// a rare duplicate concurrent file load is just a second read.
pub struct ModelCache {
    budget_bytes: usize,
    state: Mutex<LruCore<String, Arc<TrainedModel>>>,
}

impl ModelCache {
    /// Default byte budget (models are far smaller than instances: w plus
    /// the active rows).
    pub const DEFAULT_BUDGET_BYTES: usize = 64 * 1024 * 1024;

    /// `budget_bytes = 0` disables residency: inserts are dropped and
    /// every file reference loads transiently.
    pub fn new(budget_bytes: usize) -> ModelCache {
        ModelCache { budget_bytes, state: Mutex::new(LruCore::new()) }
    }

    pub fn len(&self) -> usize {
        // every model entry is charged on insert, so resident = all
        self.state.lock().unwrap().resident_len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn resident_bytes(&self) -> usize {
        self.state.lock().unwrap().resident_bytes
    }

    /// Insert (or refresh) a model under its deterministic id; returns
    /// the id. Then evicts LRU entries until the budget fits again — the
    /// entry just inserted is exempt from its own pass (the core's
    /// fresh-entry exemption).
    pub fn insert(&self, model: Arc<TrainedModel>, metrics: &Registry) -> String {
        let id = model.id();
        if self.budget_bytes == 0 {
            return id;
        }
        let bytes = model.approx_bytes();
        let mut st = self.state.lock().unwrap();
        // a refresh (re-train, predict-by-file reload) keeps the entry's
        // hit history (the core's carry-over) — ids are content digests,
        // so same id ⇒ same model
        let _ = st.insert(id.clone(), model, bytes);
        st.evict_until_fit(self.budget_bytes, &id, &metrics.counter("model_cache_evictions"));
        st.publish(metrics, "model_cache");
        id
    }

    /// Fetch a resident model by id (hit/miss counted).
    pub fn get(&self, id: &str, metrics: &Registry) -> Option<Arc<TrainedModel>> {
        let mut st = self.state.lock().unwrap();
        let tick = st.next_tick();
        match st.get_mut(id) {
            Some(e) => {
                e.last_used = tick;
                e.hits += 1;
                metrics.counter("model_cache_hits").inc();
                Some(e.value.clone())
            }
            None => {
                metrics.counter("model_cache_misses").inc();
                None
            }
        }
    }

    /// Load a `.pallas-model` artifact from disk and make it resident
    /// (every call reads the file — `model_cache_loads` counts them; a
    /// client that wants the cached path should address the model by the
    /// id a train/load response reported). Load failures count
    /// `model_cache_errors` and are never cached.
    pub fn get_or_load(&self, path: &Path, metrics: &Registry) -> Result<Arc<TrainedModel>, String> {
        match format::load(path) {
            Ok(m) => {
                metrics.counter("model_cache_loads").inc();
                let m = Arc::new(m);
                self.insert(m.clone(), metrics);
                Ok(m)
            }
            Err(e) => {
                metrics.counter("model_cache_errors").inc();
                Err(format!("load {}: {e}", path.display()))
            }
        }
    }

    /// Explicitly evict one model (the `"kind": "cache"` evict surface).
    pub fn evict(&self, id: &str, metrics: &Registry) -> bool {
        let mut st = self.state.lock().unwrap();
        match st.remove(id) {
            Some(_) => {
                metrics.counter("model_cache_evictions").inc();
                st.publish(metrics, "model_cache");
                true
            }
            None => false,
        }
    }

    /// Snapshot of resident models, sorted by id.
    pub fn snapshot(&self) -> Vec<ModelEntryInfo> {
        let st = self.state.lock().unwrap();
        let mut out: Vec<ModelEntryInfo> = st
            .entries
            .iter()
            .map(|(k, e)| ModelEntryInfo { id: k.clone(), bytes: e.bytes, hits: e.hits })
            .collect();
        out.sort_by(|a, b| a.id.cmp(&b.id));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(dataset: &str, scale: f64) -> CacheKey {
        CacheKey::new(dataset, Model::Svm, Storage::Auto, scale)
    }

    #[test]
    fn hit_after_miss_shares_one_arc() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        let a = cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        let b = cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(m.counter("instance_cache_misses").get(), 1);
        assert_eq!(m.counter("instance_cache_hits").get(), 1);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), a.approx_bytes());
    }

    #[test]
    fn key_fields_separate_entries() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        cache.get_or_build(&key("toy1", 0.06), &m).unwrap();
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        cache
            .get_or_build(&CacheKey::new("toy1", Model::Svm, Storage::Csr, 0.05), &m)
            .unwrap();
        cache
            .get_or_build(&CacheKey::new("toy1", Model::WeightedSvm, Storage::Auto, 0.05), &m)
            .unwrap();
        assert_eq!(m.counter("instance_cache_misses").get(), 5);
        assert_eq!(m.counter("instance_cache_hits").get(), 0);
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn zero_budget_disables_residency() {
        let cache = InstanceCache::new(0);
        let m = Registry::default();
        let a = cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        let b = cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(m.counter("instance_cache_misses").get(), 2);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let m = Registry::default();
        // size the budget to hold exactly two toy instances
        let probe = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let one = probe.get_or_build(&key("toy1", 0.05), &m).unwrap().approx_bytes();
        let cache = InstanceCache::new(2 * one + one / 2);
        let m = Registry::default();
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        // touch toy1 so toy2 is the LRU
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        cache.get_or_build(&key("toy3", 0.05), &m).unwrap();
        assert_eq!(m.counter("instance_cache_evictions").get(), 1);
        assert_eq!(cache.len(), 2);
        // toy1 survived (recently used), toy2 was evicted
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        assert_eq!(m.counter("instance_cache_hits").get(), 2);
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        assert_eq!(m.counter("instance_cache_misses").get(), 4, "toy2 must rebuild");
    }

    #[test]
    fn oversized_entry_stays_until_next_insert() {
        let m = Registry::default();
        let cache = InstanceCache::new(1); // smaller than any instance
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        assert_eq!(cache.len(), 1, "fresh entry is never evicted by its own insert");
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        // the toy2 insert evicts toy1, then toy2 itself stays
        assert_eq!(cache.len(), 1);
        assert_eq!(m.counter("instance_cache_evictions").get(), 1);
    }

    #[test]
    fn errors_are_not_cached() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        assert!(cache.get_or_build(&key("no-such-set", 0.05), &m).is_err());
        assert!(cache.get_or_build(&key("no-such-set", 0.05), &m).is_err());
        assert_eq!(m.counter("instance_cache_errors").get(), 2, "errors retry");
        assert_eq!(m.counter("instance_cache_misses").get(), 0, "a miss means a build");
        assert_eq!(cache.len(), 0);
        // task mismatch is an error, not a panic
        let bad = CacheKey::new("houses", Model::Svm, Storage::Auto, 0.05);
        let e = cache.get_or_build(&bad, &m);
        assert!(e.is_err(), "houses is a regression set");
    }

    #[test]
    fn snapshot_and_evict_key() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap();
        cache.get_or_build(&key("toy2", 0.05), &m).unwrap();
        cache.get_or_build(&key("toy1", 0.05), &m).unwrap(); // hit
        let snap = cache.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].dataset, "toy1");
        assert_eq!(snap[0].hits, 1);
        assert_eq!(snap[1].dataset, "toy2");
        assert_eq!(snap[1].hits, 0);
        assert!(snap.iter().all(|e| e.bytes > 0));

        assert!(cache.evict_key(&key("toy1", 0.05), &m));
        assert!(!cache.evict_key(&key("toy1", 0.05), &m), "already gone");
        assert!(!cache.evict_key(&key("no-such", 0.05), &m));
        assert_eq!(cache.len(), 1);
        assert_eq!(m.counter("instance_cache_evictions").get(), 1);
        assert_eq!(
            m.gauge("instance_cache_bytes").get() as usize,
            cache.resident_bytes()
        );
    }

    fn toy_model(c: f64) -> Arc<crate::model::TrainedModel> {
        let mut m = crate::model::trained::trained_toy(crate::linalg::Storage::Dense);
        m.c = c; // distinct c ⇒ distinct id
        Arc::new(m)
    }

    #[test]
    fn model_cache_insert_get_hit_miss() {
        let cache = ModelCache::new(ModelCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        let model = toy_model(0.5);
        let id = cache.insert(model.clone(), &m);
        assert_eq!(id, model.id());
        let got = cache.get(&id, &m).expect("resident");
        assert!(Arc::ptr_eq(&got, &model));
        assert!(cache.get("nope", &m).is_none());
        assert_eq!(m.counter("model_cache_hits").get(), 1);
        assert_eq!(m.counter("model_cache_misses").get(), 1);
        let snap = cache.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].id, id);
        assert_eq!(snap[0].hits, 1);
        assert_eq!(cache.resident_bytes(), model.approx_bytes());
        // re-inserting the same id replaces, never double-charges
        cache.insert(model.clone(), &m);
        assert_eq!(cache.resident_bytes(), model.approx_bytes());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn model_cache_lru_eviction_and_explicit_evict() {
        let a = toy_model(0.3);
        let b = toy_model(0.5);
        let c = toy_model(0.9);
        let one = a.approx_bytes();
        let cache = ModelCache::new(2 * one + one / 2);
        let m = Registry::default();
        cache.insert(a.clone(), &m);
        cache.insert(b.clone(), &m);
        cache.get(&a.id(), &m); // touch a so b is LRU
        cache.insert(c.clone(), &m);
        assert_eq!(cache.len(), 2);
        assert_eq!(m.counter("model_cache_evictions").get(), 1);
        assert!(cache.get(&b.id(), &m).is_none(), "b was the LRU victim");
        assert!(cache.get(&a.id(), &m).is_some());

        assert!(cache.evict(&a.id(), &m));
        assert!(!cache.evict(&a.id(), &m));
        assert_eq!(cache.len(), 1);
        assert_eq!(m.gauge("model_cache_entries").get(), 1);
    }

    #[test]
    fn model_cache_zero_budget_and_file_load() {
        let cache = ModelCache::new(0);
        let m = Registry::default();
        let model = toy_model(0.4);
        cache.insert(model.clone(), &m);
        assert_eq!(cache.len(), 0, "zero budget stores nothing");

        let mut p = std::env::temp_dir();
        p.push(format!("dvi_model_cache_{}.pallas-model", std::process::id()));
        crate::model::format::save(&model, &p).unwrap();
        let loaded = cache.get_or_load(&p, &m).unwrap();
        assert_eq!(loaded.id(), model.id());
        assert_eq!(m.counter("model_cache_loads").get(), 1);
        assert_eq!(cache.len(), 0);

        // a resident cache makes the load resident
        let resident = ModelCache::new(ModelCache::DEFAULT_BUDGET_BYTES);
        resident.get_or_load(&p, &m).unwrap();
        assert!(resident.get(&model.id(), &m).is_some());
        std::fs::remove_file(&p).ok();
        assert!(cache.get_or_load(Path::new("/no/such/file"), &m).is_err());
        assert_eq!(m.counter("model_cache_errors").get(), 1);
    }

    #[test]
    fn lru_core_charge_evict_and_publish() {
        let m = Registry::default();
        let ev = m.counter("test_evictions");
        let mut core: LruCore<&'static str, u32> = LruCore::new();
        assert!(core.insert("a", 1, 10).is_none());
        assert!(core.insert("b", 2, 10).is_none());
        assert_eq!(core.resident_bytes, 20);
        assert_eq!(core.resident_len(), 2);

        // placeholder: unresident until charged, never a victim
        let _ = core.insert("building", 3, 0);
        assert_eq!(core.resident_len(), 2);
        core.evict_until_fit(5, &"b", &ev);
        assert!(core.get_mut("building").is_some(), "placeholders survive eviction");
        assert!(core.get_mut("a").is_none(), "LRU resident entry evicted");
        assert!(core.get_mut("b").is_some(), "protected entry survives over-budget");
        assert_eq!(ev.get(), 1);

        core.charge(&"building", 7);
        assert_eq!(core.resident_bytes, 17);
        core.charge(&"building", 99); // double charge is a no-op
        assert_eq!(core.resident_bytes, 17);

        // touching refreshes recency: "b" touched last, "building" evicts
        let t = core.next_tick();
        core.get_mut("b").unwrap().last_used = t;
        core.evict_until_fit(10, &"b", &ev);
        assert!(core.get_mut("building").is_none());
        assert_eq!(core.resident_bytes, 10);

        // remove uncharges; publish reflects the final state
        assert!(core.remove("b").is_some());
        assert!(core.remove("b").is_none());
        assert_eq!(core.resident_bytes, 0);
        core.publish(&m, "test_core");
        assert_eq!(m.gauge("test_core_bytes").get(), 0);
        assert_eq!(m.gauge("test_core_entries").get(), 0);
    }

    #[test]
    fn lru_core_insert_replaces_without_double_charge() {
        let mut core: LruCore<u8, u8> = LruCore::new();
        let _ = core.insert(1, 10, 100);
        core.get_mut(&1).unwrap().hits = 5;
        let displaced = core.insert(1, 11, 40).expect("old entry displaced");
        assert_eq!((displaced.value, displaced.hits), (10, 5));
        assert_eq!(core.resident_bytes, 40, "replacement uncharges the old entry");
        let e = core.get_mut(&1).unwrap();
        assert_eq!((e.value, e.bytes, e.hits), (11, 40, 5), "hit history carries over");
        // ticks strictly increase across operations
        let a = core.next_tick();
        let b = core.next_tick();
        assert!(b > a);
    }

    #[test]
    fn concurrent_same_key_builds_once() {
        let cache = Arc::new(InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES));
        let m = Arc::new(Registry::default());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cache = cache.clone();
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                cache.get_or_build(&key("toy2", 0.05), &m).unwrap().len()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("instance_cache_misses").get(), 1, "exactly one build");
        assert_eq!(m.counter("instance_cache_hits").get(), 7);
    }
}
