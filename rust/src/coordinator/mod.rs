//! L3 coordinator: turns [`crate::config::RunConfig`]s into scheduled
//! path-run jobs on a thread worker pool, tracks metrics, and exposes a
//! line-oriented JSON service (the "screening service" the examples and
//! the CLI drive).

pub mod job;
pub mod pool;
pub mod service;

pub use job::{run_job, JobOutcome, JobSpec};
pub use pool::WorkerPool;
pub use service::ScreeningService;
