//! L3 coordinator: turns [`crate::config::RunConfig`]s into scheduled
//! path-run, batch-screening, train, predict, or cache-introspection
//! jobs on a thread worker pool backed by a resident
//! [`cache::InstanceCache`] and a sibling [`cache::ModelCache`] of
//! trained models, tracks metrics, and exposes a line-oriented JSON
//! service with single, screen, train, predict, cache, stats, and batch
//! request kinds (the "screening service" the examples and the CLI
//! drive). The network front-end over this lives in [`crate::serve`].

pub mod cache;
pub mod job;
pub mod pool;
pub mod service;

pub use cache::{CacheKey, InstanceCache, InstanceEntryInfo, ModelCache, ModelEntryInfo};
pub use job::{
    run_job, run_job_cached, CacheOp, CacheSpec, CacheSummary, JobKind, JobOutcome, JobReply,
    JobSpec, JobSummary, ModelRef, PredictInput, PredictSpec, PredictSummary, ScreenSpec,
    ScreenSummary, StatsSummary, TrainSpec, TrainSummary,
};
pub use pool::WorkerPool;
pub use service::{ParsedRequest, ScreeningService};
