//! L3 coordinator: turns [`crate::config::RunConfig`]s into scheduled
//! path-run or batch-screening jobs on a thread worker pool backed by a
//! resident [`cache::InstanceCache`], tracks metrics, and exposes a
//! line-oriented JSON service with single, screen, and batch request
//! kinds (the "screening service" the examples and the CLI drive).

pub mod cache;
pub mod job;
pub mod pool;
pub mod service;

pub use cache::{CacheKey, InstanceCache};
pub use job::{
    run_job, run_job_cached, JobKind, JobOutcome, JobReply, JobSpec, JobSummary, ScreenSpec,
    ScreenSummary,
};
pub use pool::WorkerPool;
pub use service::ScreeningService;
