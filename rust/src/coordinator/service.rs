//! The screening service: a line-oriented JSON front-end over the worker
//! pool. Each request line is a JSON object describing a run; each
//! response line is the job summary (or error). This is the long-running
//! L3 process the `screening_service` example drives end-to-end.
//!
//! Request schema (all fields optional except dataset):
//! ```json
//! {"dataset": "toy1", "model": "svm", "rule": "dvi",
//!  "scale": 0.1, "points": 20, "c_min": 0.01, "c_max": 10.0,
//!  "threads": 4, "storage": "auto", "validate": true}
//! ```
//!
//! `threads` selects the sharded scan/validation engine for the job
//! (1 = serial, 0 = auto-detect); decisions are byte-identical either way.
//! Numeric fields are validated here so malformed requests produce an
//! error response line instead of a worker panic.

use super::job::{JobOutcome, JobSpec};
use super::pool::WorkerPool;
use crate::config::json::{parse_json, Json};
use crate::config::RunConfig;
use std::collections::BTreeMap;
use std::io::{BufRead, Write};

/// Service wrapping a pool with JSON request/response framing.
pub struct ScreeningService {
    pool: WorkerPool,
    next_id: u64,
}

impl ScreeningService {
    pub fn new(workers: usize) -> ScreeningService {
        ScreeningService { pool: WorkerPool::new(workers), next_id: 0 }
    }

    /// Parse one request line into a RunConfig. Numeric fields are
    /// range-checked here: a negative `points` cast straight to `usize`
    /// would wrap to a gigantic grid, and non-finite/non-positive C bounds
    /// would panic inside the worker instead of producing an error line.
    pub fn parse_request(line: &str) -> Result<RunConfig, String> {
        let j = parse_json(line).map_err(|e| e.to_string())?;
        let obj = j.as_object().ok_or("request must be a JSON object")?;
        let mut cfg = RunConfig::default();
        for (k, v) in obj {
            match k.as_str() {
                "dataset" => cfg.dataset = v.as_str().ok_or("dataset: string")?.to_string(),
                "model" => cfg.model = v.as_str().ok_or("model: string")?.to_string(),
                "rule" => cfg.rule = v.as_str().ok_or("rule: string")?.to_string(),
                "scale" => cfg.scale = v.as_float().ok_or("scale: number")?,
                "points" => {
                    let p = v.as_int().ok_or("points: int")?;
                    // lower bound: the grid needs two points; upper bound:
                    // a huge request must not OOM the worker allocating the
                    // grid (the paper's protocol is 100 points)
                    if !(2..=1_000_000).contains(&p) {
                        return Err(format!("points must be in [2, 1000000], got {p}"));
                    }
                    cfg.grid.points = p as usize;
                }
                "c_min" => {
                    let x = v.as_float().ok_or("c_min: number")?;
                    if !x.is_finite() || x <= 0.0 {
                        return Err(format!("c_min must be finite and > 0, got {x}"));
                    }
                    cfg.grid.c_min = x;
                }
                "c_max" => {
                    let x = v.as_float().ok_or("c_max: number")?;
                    if !x.is_finite() || x <= 0.0 {
                        return Err(format!("c_max must be finite and > 0, got {x}"));
                    }
                    cfg.grid.c_max = x;
                }
                "tol" => cfg.solver.tol = v.as_float().ok_or("tol: number")?,
                "threads" => {
                    let t = v.as_int().ok_or("threads: int")?;
                    if t < 0 {
                        return Err(format!("threads must be >= 0 (0 = auto), got {t}"));
                    }
                    cfg.solver.threads = t as usize;
                }
                "storage" => {
                    let s = v.as_str().ok_or("storage: string")?;
                    if crate::linalg::Storage::parse(s).is_none() {
                        return Err(format!("storage must be dense|csr|auto, got `{s}`"));
                    }
                    cfg.storage = s.to_string();
                }
                "validate" => cfg.validate = v.as_bool().ok_or("validate: bool")?,
                "use_pjrt" => cfg.use_pjrt = v.as_bool().ok_or("use_pjrt: bool")?,
                other => return Err(format!("unknown request field `{other}`")),
            }
        }
        // shared semantic validation (model/rule/storage vocabulary, grid
        // ordering, scale ∈ (0,1], tol > 0) — without the scale bound a
        // request like {"scale": 1e18} would reach the worker and abort
        // it inside the dataset generator's allocation
        cfg.validate_semantics().map_err(|e| e.to_string())?;
        Ok(cfg)
    }

    /// Submit a run; returns its job id.
    pub fn submit(&mut self, run: RunConfig) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.pool.submit(JobSpec { id, run });
        id
    }

    /// Block for the next result.
    pub fn recv(&self) -> Option<JobOutcome> {
        self.pool.recv()
    }

    /// Encode an outcome as a JSON response line.
    pub fn encode_response(outcome: &JobOutcome) -> String {
        let mut o = BTreeMap::new();
        o.insert("id".to_string(), Json::Int(outcome.id as i64));
        match &outcome.result {
            Err(e) => {
                o.insert("ok".into(), Json::Bool(false));
                o.insert("error".into(), Json::Str(e.clone()));
            }
            Ok(s) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("dataset".into(), Json::Str(s.dataset.clone()));
                o.insert("model".into(), Json::Str(s.model.clone()));
                o.insert("rule".into(), Json::Str(s.rule.clone()));
                o.insert("l".into(), Json::Int(s.l as i64));
                o.insert("steps".into(), Json::Int(s.steps as i64));
                o.insert("mean_rejection".into(), Json::Float(s.mean_rejection));
                o.insert("init_secs".into(), Json::Float(s.init_secs));
                o.insert("screen_secs".into(), Json::Float(s.screen_secs));
                o.insert("total_secs".into(), Json::Float(s.total_secs));
                o.insert("total_updates".into(), Json::Int(s.total_updates as i64));
                if let Some(v) = s.worst_violation {
                    o.insert("worst_violation".into(), Json::Float(v));
                }
                o.insert(
                    "rejection_lo".into(),
                    Json::Array(s.rejection_lo.iter().map(|&v| Json::Float(v)).collect()),
                );
                o.insert(
                    "rejection_hi".into(),
                    Json::Array(s.rejection_hi.iter().map(|&v| Json::Float(v)).collect()),
                );
            }
        }
        Json::Object(o).to_string()
    }

    /// Serve until EOF: one JSON request per line in, one JSON response
    /// per line out. Responses are written in completion order with ids.
    pub fn serve<R: BufRead, W: Write>(&mut self, input: R, mut output: W) -> std::io::Result<()> {
        let mut submitted = 0u64;
        for line in input.lines() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            match Self::parse_request(line) {
                Ok(cfg) => {
                    self.submit(cfg);
                    submitted += 1;
                }
                Err(e) => {
                    let mut o = BTreeMap::new();
                    o.insert("ok".to_string(), Json::Bool(false));
                    o.insert("error".to_string(), Json::Str(e));
                    writeln!(output, "{}", Json::Object(o).to_string())?;
                }
            }
        }
        for _ in 0..submitted {
            if let Some(outcome) = self.recv() {
                writeln!(output, "{}", Self::encode_response(&outcome))?;
                output.flush()?;
            }
        }
        Ok(())
    }

    /// Shut the pool down.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }

    /// Metrics registry (jobs_done, jobs_failed, job_secs).
    pub fn metrics(&self) -> &crate::metrics::Registry {
        &self.pool.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full_and_defaults() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy2", "model": "svm", "rule": "essnsv",
                "scale": 0.5, "points": 12, "c_min": 0.1, "c_max": 2.0,
                "tol": 1e-7, "validate": true}"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, "toy2");
        assert_eq!(cfg.rule, "essnsv");
        assert_eq!(cfg.grid.points, 12);
        assert!(cfg.validate);

        let d = ScreeningService::parse_request(r#"{"dataset": "toy1"}"#).unwrap();
        assert_eq!(d.grid.points, 100);
    }

    #[test]
    fn parse_request_rejects_unknown() {
        assert!(ScreeningService::parse_request(r#"{"datafoo": 1}"#).is_err());
        assert!(ScreeningService::parse_request("not json").is_err());
        assert!(ScreeningService::parse_request(r#"{"scale": "big"}"#).is_err());
    }

    #[test]
    fn parse_request_rejects_bad_numerics() {
        // a negative points value must not wrap to a huge usize grid
        for bad in [
            r#"{"dataset": "toy1", "points": -5}"#,
            r#"{"dataset": "toy1", "points": 0}"#,
            r#"{"dataset": "toy1", "points": 1}"#,
            r#"{"dataset": "toy1", "points": 4000000000000000000}"#,
            r#"{"dataset": "toy1", "c_min": -1.0}"#,
            r#"{"dataset": "toy1", "c_min": 0.0}"#,
            r#"{"dataset": "toy1", "c_max": -2.5}"#,
            r#"{"dataset": "toy1", "c_min": 5.0, "c_max": 0.5}"#,
            r#"{"dataset": "toy1", "threads": -1}"#,
            // scale outside (0,1] must not reach the worker's dataset
            // generator (an absurd scale aborts it inside the allocation)
            r#"{"dataset": "toy1", "scale": 1e18}"#,
            r#"{"dataset": "toy1", "scale": 0.0}"#,
            r#"{"dataset": "toy1", "scale": -0.5}"#,
            r#"{"dataset": "toy1", "model": "nope"}"#,
            r#"{"dataset": "toy1", "rule": "nope"}"#,
        ] {
            let e = ScreeningService::parse_request(bad);
            assert!(e.is_err(), "accepted `{bad}`");
        }
        // boundary-legal values still parse
        let ok = ScreeningService::parse_request(
            r#"{"dataset": "toy1", "points": 2, "c_min": 0.5, "c_max": 0.6, "threads": 0}"#,
        )
        .unwrap();
        assert_eq!(ok.grid.points, 2);
        assert_eq!(ok.solver.threads, 0);
    }

    #[test]
    fn parse_request_storage() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy1", "storage": "csr"}"#,
        )
        .unwrap();
        assert_eq!(cfg.storage, "csr");
        assert!(ScreeningService::parse_request(
            r#"{"dataset": "toy1", "storage": "sparse"}"#
        )
        .is_err());
        assert_eq!(
            ScreeningService::parse_request(r#"{"dataset": "toy1"}"#).unwrap().storage,
            "auto"
        );
    }

    #[test]
    fn parse_request_threads_flows_to_solver() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy2", "threads": 4, "points": 8}"#,
        )
        .unwrap();
        assert_eq!(cfg.solver.threads, 4);
    }

    #[test]
    fn serve_round_trip() {
        let mut svc = ScreeningService::new(2);
        let input = br#"
# a comment line
{"dataset": "toy1", "scale": 0.03, "points": 4, "tol": 1e-5}
{"dataset": "no-such", "points": 4}
"#;
        let mut out = Vec::new();
        svc.serve(&input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let ok_count = lines
            .iter()
            .filter(|l| parse_json(l).unwrap().get("ok").unwrap().as_bool() == Some(true))
            .count();
        assert_eq!(ok_count, 1, "{text}");
        assert_eq!(svc.metrics().counter("jobs_done").get(), 2);
        svc.shutdown();
    }

    #[test]
    fn encode_response_contains_series() {
        let outcome = JobOutcome {
            id: 7,
            result: Ok(super::super::job::JobSummary {
                dataset: "d".into(),
                model: "svm".into(),
                rule: "dvi".into(),
                l: 10,
                steps: 2,
                mean_rejection: 0.5,
                rejection_lo: vec![0.0, 0.4],
                rejection_hi: vec![0.0, 0.1],
                grid: vec![0.1, 1.0],
                init_secs: 0.01,
                screen_secs: 0.001,
                total_secs: 0.05,
                total_updates: 123,
                worst_violation: Some(1e-9),
            }),
        };
        let s = ScreeningService::encode_response(&outcome);
        let j = parse_json(&s).unwrap();
        assert_eq!(j.get("id").unwrap().as_int(), Some(7));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("rejection_lo").unwrap().as_array().unwrap().len(), 2);
    }
}
