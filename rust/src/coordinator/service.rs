//! The screening service: a line-oriented JSON front-end over the worker
//! pool. Each request line is a JSON object describing a job (or a batch
//! of jobs); each response line answers it. This is the long-running L3
//! process the `screening_service` example drives end-to-end.
//!
//! ## Path requests (the default kind)
//!
//! ```json
//! {"dataset": "toy1", "model": "svm", "rule": "dvi",
//!  "scale": 0.1, "points": 20, "c_min": 0.01, "c_max": 10.0,
//!  "threads": 4, "storage": "auto", "validate": true, "timings": false}
//! ```
//!
//! `threads` selects the sharded scan/validation engine for the job
//! (1 = serial, 0 = auto-detect); decisions are byte-identical either way.
//! `timings` (default true) controls whether wall-clock fields appear in
//! the response; turning it off makes responses byte-for-byte
//! deterministic.
//!
//! ## Screen requests
//!
//! ```json
//! {"kind": "screen", "dataset": "toy1", "model": "svm", "scale": 0.1,
//!  "pairs": [[0.1, 0.2], [0.2, 0.4]], "theta": [0.0, 1.0],
//!  "tol": 1e-6, "threads": 0, "return_theta": true}
//! ```
//!
//! A screen job runs the w-form DVI scan for each `(c_prev, c_next)` pair
//! against ONE resident instance. The anchor θ*(c_prev) is the supplied
//! `theta` (valid for the first pair's `c_prev`) or is solved on demand
//! and memoized across pairs. This is the protocol for amortizing one
//! prepared problem over many screening queries.
//!
//! ## Batch requests
//!
//! ```json
//! {"batch": [{...}, {...}, {...}]}
//! ```
//!
//! Entries are any mix of path/screen requests; they fan out across the
//! worker pool (sharing the instance cache — B entries naming the same
//! dataset build it once) and come back as ONE response line,
//! `{"batch": [...]}`, in entry order. Errors are isolated per entry: a
//! malformed or failed entry yields its error object in place, and with
//! `"timings": false` each entry's object is byte-identical to what the
//! same request would produce as its own line.
//!
//! Responses are written in *input order* once EOF is reached (jobs still
//! execute concurrently in between), so a scripted session's output is
//! reproducible. Numeric fields are validated at parse so malformed
//! requests produce an error response line instead of a worker panic.

use super::cache::InstanceCache;
use super::job::{JobKind, JobOutcome, JobReply, JobSpec, ScreenSpec};
use super::pool::WorkerPool;
use crate::config::json::{parse_json, Json};
use crate::config::{RunConfig, SolverConfig};
use crate::problem::Model;
use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, Write};

/// Cap on batch entries per line and screen pairs per job: a huge request
/// must degrade to an error line, not an OOM.
const MAX_BATCH: usize = 10_000;
const MAX_PAIRS: usize = 100_000;

/// One parsed request object: the job plus its response options.
#[derive(Clone, Debug)]
pub struct ParsedRequest {
    pub kind: JobKind,
    pub timings: bool,
}

/// Service wrapping a pool with JSON request/response framing.
pub struct ScreeningService {
    pool: WorkerPool,
    next_id: u64,
}

/// A response owed for one input line (or one batch entry).
enum Pending {
    /// Already answerable (parse/validation error).
    Ready(Json),
    /// Awaiting the outcome of job `id`.
    Job(u64),
}

enum LineSlot {
    Single(Pending),
    Batch(Vec<Pending>),
}

impl ScreeningService {
    /// `workers` threads over the default-size instance cache.
    pub fn new(workers: usize) -> ScreeningService {
        Self::with_cache(workers, InstanceCache::DEFAULT_BUDGET_BYTES)
    }

    /// `workers` threads sharing a `cache_bytes`-budget instance cache
    /// (0 disables residency — every job rebuilds, like the pre-cache
    /// service).
    pub fn with_cache(workers: usize, cache_bytes: usize) -> ScreeningService {
        ScreeningService { pool: WorkerPool::with_cache(workers, cache_bytes), next_id: 0 }
    }

    /// Parse one request line into a path-run config (legacy surface;
    /// screen/batch lines are handled by [`Self::serve`]). Numeric fields
    /// are range-checked here: a negative `points` cast straight to
    /// `usize` would wrap to a gigantic grid, and non-finite/non-positive
    /// C bounds would panic inside the worker instead of producing an
    /// error line.
    pub fn parse_request(line: &str) -> Result<RunConfig, String> {
        let j = parse_json(line).map_err(|e| e.to_string())?;
        let obj = j.as_object().ok_or("request must be a JSON object")?;
        match Self::parse_object(obj)? {
            ParsedRequest { kind: JobKind::Path(cfg), .. } => Ok(cfg),
            _ => Err("not a path request (use serve() for screen/batch lines)".into()),
        }
    }

    /// Parse one request object (path or screen kind — batch nesting is
    /// handled a level up by [`Self::serve`]).
    pub fn parse_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        if obj.contains_key("batch") {
            return Err("batch requests cannot nest".into());
        }
        let kind = match obj.get("kind") {
            None => "path",
            Some(v) => v.as_str().ok_or("kind: string")?,
        };
        match kind {
            "path" => Self::parse_path_object(obj),
            "screen" => Self::parse_screen_object(obj),
            other => Err(format!("unknown request kind `{other}` (path | screen)")),
        }
    }

    fn parse_path_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        let mut cfg = RunConfig::default();
        let mut timings = true;
        for (k, v) in obj {
            match k.as_str() {
                "kind" => {} // dispatched by the caller
                "timings" => timings = v.as_bool().ok_or("timings: bool")?,
                "dataset" => cfg.dataset = v.as_str().ok_or("dataset: string")?.to_string(),
                "model" => cfg.model = v.as_str().ok_or("model: string")?.to_string(),
                "rule" => cfg.rule = v.as_str().ok_or("rule: string")?.to_string(),
                "scale" => cfg.scale = v.as_float().ok_or("scale: number")?,
                "points" => {
                    let p = v.as_int().ok_or("points: int")?;
                    // lower bound: the grid needs two points; upper bound:
                    // a huge request must not OOM the worker allocating the
                    // grid (the paper's protocol is 100 points)
                    if !(2..=1_000_000).contains(&p) {
                        return Err(format!("points must be in [2, 1000000], got {p}"));
                    }
                    cfg.grid.points = p as usize;
                }
                "c_min" => {
                    let x = v.as_float().ok_or("c_min: number")?;
                    if !x.is_finite() || x <= 0.0 {
                        return Err(format!("c_min must be finite and > 0, got {x}"));
                    }
                    cfg.grid.c_min = x;
                }
                "c_max" => {
                    let x = v.as_float().ok_or("c_max: number")?;
                    if !x.is_finite() || x <= 0.0 {
                        return Err(format!("c_max must be finite and > 0, got {x}"));
                    }
                    cfg.grid.c_max = x;
                }
                "tol" => cfg.solver.tol = v.as_float().ok_or("tol: number")?,
                "threads" => cfg.solver.threads = parse_threads(v)?,
                "storage" => {
                    let s = v.as_str().ok_or("storage: string")?;
                    if crate::linalg::Storage::parse(s).is_none() {
                        return Err(format!("storage must be dense|csr|auto, got `{s}`"));
                    }
                    cfg.storage = s.to_string();
                }
                "validate" => cfg.validate = v.as_bool().ok_or("validate: bool")?,
                "use_pjrt" => cfg.use_pjrt = v.as_bool().ok_or("use_pjrt: bool")?,
                other => return Err(format!("unknown request field `{other}`")),
            }
        }
        // shared semantic validation (model/rule/storage vocabulary, grid
        // ordering, scale ∈ (0,1], tol > 0) — without the scale bound a
        // request like {"scale": 1e18} would reach the worker and abort
        // it inside the dataset generator's allocation
        cfg.validate_semantics().map_err(|e| e.to_string())?;
        Ok(ParsedRequest { kind: JobKind::Path(cfg), timings })
    }

    fn parse_screen_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        let mut spec = ScreenSpec {
            dataset: String::new(),
            model: Model::Svm,
            scale: 1.0,
            storage: crate::linalg::Storage::Auto,
            pairs: Vec::new(),
            theta: None,
            solver: SolverConfig::default(),
            return_theta: false,
        };
        let mut timings = true;
        for (k, v) in obj {
            match k.as_str() {
                "kind" => {}
                "timings" => timings = v.as_bool().ok_or("timings: bool")?,
                "dataset" => spec.dataset = v.as_str().ok_or("dataset: string")?.to_string(),
                "model" => {
                    let s = v.as_str().ok_or("model: string")?;
                    spec.model =
                        Model::parse(s).ok_or_else(|| format!("unknown model `{s}`"))?;
                }
                "scale" => {
                    let x = v.as_float().ok_or("scale: number")?;
                    if !(x > 0.0 && x <= 1.0) {
                        return Err(format!("scale must be in (0, 1], got {x}"));
                    }
                    spec.scale = x;
                }
                "storage" => {
                    let s = v.as_str().ok_or("storage: string")?;
                    spec.storage = crate::linalg::Storage::parse(s)
                        .ok_or_else(|| format!("storage must be dense|csr|auto, got `{s}`"))?;
                }
                "tol" => {
                    let x = v.as_float().ok_or("tol: number")?;
                    if !(x > 0.0) {
                        return Err(format!("tol must be positive, got {x}"));
                    }
                    spec.solver.tol = x;
                }
                "threads" => spec.solver.threads = parse_threads(v)?,
                "pairs" => {
                    let arr = v.as_array().ok_or("pairs: array of [c_prev, c_next]")?;
                    if arr.len() > MAX_PAIRS {
                        return Err(format!("pairs is capped at {MAX_PAIRS} entries"));
                    }
                    let mut pairs = Vec::with_capacity(arr.len());
                    for p in arr {
                        let pp = p.as_array().filter(|a| a.len() == 2).ok_or_else(|| {
                            "each pair must be a [c_prev, c_next] array".to_string()
                        })?;
                        let a = pp[0].as_float().ok_or("c_prev: number")?;
                        let b = pp[1].as_float().ok_or("c_next: number")?;
                        if !(a.is_finite() && b.is_finite() && a > 0.0 && b > a) {
                            return Err(format!(
                                "pair ({a}, {b}) must satisfy 0 < c_prev < c_next"
                            ));
                        }
                        pairs.push((a, b));
                    }
                    spec.pairs = pairs;
                }
                "theta" => {
                    let arr = v.as_array().ok_or("theta: array of numbers")?;
                    let mut t = Vec::with_capacity(arr.len());
                    for x in arr {
                        let f = x.as_float().ok_or("theta entries must be numbers")?;
                        if !f.is_finite() {
                            return Err("theta must be finite".into());
                        }
                        t.push(f);
                    }
                    spec.theta = Some(t);
                }
                "return_theta" => {
                    spec.return_theta = v.as_bool().ok_or("return_theta: bool")?
                }
                other => return Err(format!("unknown screen field `{other}`")),
            }
        }
        if spec.dataset.is_empty() {
            return Err("screen: `dataset` is required".into());
        }
        if spec.pairs.is_empty() {
            return Err("screen: `pairs` must be a non-empty array".into());
        }
        Ok(ParsedRequest { kind: JobKind::Screen(spec), timings })
    }

    /// Submit a path run; returns its job id.
    pub fn submit(&mut self, run: RunConfig) -> u64 {
        self.submit_kind(JobKind::Path(run), true)
    }

    /// Submit any job kind; returns its job id.
    pub fn submit_kind(&mut self, kind: JobKind, timings: bool) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.pool.submit(JobSpec { id, kind, timings });
        id
    }

    /// Block for the next result.
    pub fn recv(&self) -> Option<JobOutcome> {
        self.pool.recv()
    }

    /// Encode an outcome as a JSON response line.
    pub fn encode_response(outcome: &JobOutcome) -> String {
        Self::encode_response_json(outcome).to_string()
    }

    /// Encode an outcome as a JSON value (batch entries embed these).
    pub fn encode_response_json(outcome: &JobOutcome) -> Json {
        let mut o = BTreeMap::new();
        o.insert("id".to_string(), Json::Int(outcome.id as i64));
        match &outcome.result {
            Err(e) => {
                o.insert("ok".into(), Json::Bool(false));
                o.insert("error".into(), Json::Str(e.clone()));
            }
            Ok(JobReply::Path(s)) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("dataset".into(), Json::Str(s.dataset.clone()));
                o.insert("model".into(), Json::Str(s.model.clone()));
                o.insert("rule".into(), Json::Str(s.rule.clone()));
                o.insert("l".into(), Json::Int(s.l as i64));
                o.insert("steps".into(), Json::Int(s.steps as i64));
                o.insert("mean_rejection".into(), Json::Float(s.mean_rejection));
                if outcome.timings {
                    o.insert("init_secs".into(), Json::Float(s.init_secs));
                    o.insert("screen_secs".into(), Json::Float(s.screen_secs));
                    o.insert("total_secs".into(), Json::Float(s.total_secs));
                }
                o.insert("total_updates".into(), Json::Int(s.total_updates as i64));
                if let Some(v) = s.worst_violation {
                    o.insert("worst_violation".into(), Json::Float(v));
                }
                o.insert(
                    "rejection_lo".into(),
                    Json::Array(s.rejection_lo.iter().map(|&v| Json::Float(v)).collect()),
                );
                o.insert(
                    "rejection_hi".into(),
                    Json::Array(s.rejection_hi.iter().map(|&v| Json::Float(v)).collect()),
                );
            }
            Ok(JobReply::Screen(s)) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("screen".into()));
                o.insert("dataset".into(), Json::Str(s.dataset.clone()));
                o.insert("model".into(), Json::Str(s.model.clone()));
                o.insert("l".into(), Json::Int(s.l as i64));
                o.insert("mean_rejection".into(), Json::Float(s.mean_rejection()));
                o.insert("anchor_solves".into(), Json::Int(s.anchor_solves as i64));
                if outcome.timings {
                    o.insert("solve_secs".into(), Json::Float(s.solve_secs));
                    o.insert("screen_secs".into(), Json::Float(s.screen_secs));
                }
                let pairs: Vec<Json> = s
                    .pairs
                    .iter()
                    .map(|p| {
                        let mut m = BTreeMap::new();
                        m.insert("c".to_string(), Json::Float(p.c_next));
                        m.insert("c_prev".to_string(), Json::Float(p.c_prev));
                        m.insert("n_lo".to_string(), Json::Int(p.n_lo as i64));
                        m.insert("n_hi".to_string(), Json::Int(p.n_hi as i64));
                        m.insert("free".to_string(), Json::Int(p.free as i64));
                        Json::Object(m)
                    })
                    .collect();
                o.insert("pairs".into(), Json::Array(pairs));
                if let Some(t) = &s.theta {
                    o.insert(
                        "theta".into(),
                        Json::Array(t.iter().map(|&v| Json::Float(v)).collect()),
                    );
                    o.insert("theta_c".into(), Json::Float(s.theta_c.unwrap_or(0.0)));
                }
            }
        }
        Json::Object(o)
    }

    /// Serve until EOF: one JSON request (or batch) per line in, one JSON
    /// response per line out, *in input order* — jobs run concurrently on
    /// the pool in between, but the emitted session is reproducible.
    pub fn serve<R: BufRead, W: Write>(&mut self, input: R, mut output: W) -> std::io::Result<()> {
        let mut slots: Vec<LineSlot> = Vec::new();
        let mut submitted = 0u64;
        for line in input.lines() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            slots.push(self.accept_line(line, &mut submitted));
        }
        // drain every accepted job, then answer in input order
        let mut results: HashMap<u64, Json> = HashMap::new();
        for _ in 0..submitted {
            if let Some(outcome) = self.recv() {
                results.insert(outcome.id, Self::encode_response_json(&outcome));
            }
        }
        for slot in slots {
            let json = match slot {
                LineSlot::Single(p) => resolve_pending(p, &mut results),
                LineSlot::Batch(ps) => {
                    let entries: Vec<Json> = ps
                        .into_iter()
                        .map(|p| resolve_pending(p, &mut results))
                        .collect();
                    let mut o = BTreeMap::new();
                    o.insert("batch".to_string(), Json::Array(entries));
                    Json::Object(o)
                }
            };
            writeln!(output, "{}", json.to_string())?;
            output.flush()?;
        }
        Ok(())
    }

    /// Parse one input line into its response slot, submitting any jobs
    /// it contains.
    fn accept_line(&mut self, line: &str, submitted: &mut u64) -> LineSlot {
        let j = match parse_json(line) {
            Ok(j) => j,
            Err(e) => return LineSlot::Single(Pending::Ready(error_json(e.to_string()))),
        };
        let Some(obj) = j.as_object() else {
            return LineSlot::Single(Pending::Ready(error_json(
                "request must be a JSON object".into(),
            )));
        };
        if let Some(batch) = obj.get("batch") {
            if obj.len() != 1 {
                return LineSlot::Single(Pending::Ready(error_json(
                    "a batch request must contain only the `batch` field".into(),
                )));
            }
            let Some(entries) = batch.as_array() else {
                return LineSlot::Single(Pending::Ready(error_json(
                    "batch must be an array of request objects".into(),
                )));
            };
            if entries.len() > MAX_BATCH {
                return LineSlot::Single(Pending::Ready(error_json(format!(
                    "batch is capped at {MAX_BATCH} entries"
                ))));
            }
            self.pool.metrics.counter("service_batches").inc();
            let pending = entries
                .iter()
                .map(|e| {
                    let parsed = e
                        .as_object()
                        .ok_or("batch entry must be a request object".to_string())
                        .and_then(Self::parse_object);
                    match parsed {
                        Ok(req) => {
                            *submitted += 1;
                            self.pool.metrics.counter("service_requests").inc();
                            Pending::Job(self.submit_kind(req.kind, req.timings))
                        }
                        Err(msg) => Pending::Ready(error_json(msg)),
                    }
                })
                .collect();
            LineSlot::Batch(pending)
        } else {
            match Self::parse_object(obj) {
                Ok(req) => {
                    *submitted += 1;
                    self.pool.metrics.counter("service_requests").inc();
                    LineSlot::Single(Pending::Job(self.submit_kind(req.kind, req.timings)))
                }
                Err(msg) => LineSlot::Single(Pending::Ready(error_json(msg))),
            }
        }
    }

    /// Shut the pool down (drains queued jobs, joins workers).
    pub fn shutdown(self) {
        self.pool.shutdown();
    }

    /// Metrics registry (jobs_done, jobs_failed, job_secs,
    /// instance_cache_hits/misses/evictions/bytes, service_*).
    pub fn metrics(&self) -> &crate::metrics::Registry {
        &self.pool.metrics
    }

    /// The pool's resident instance cache.
    pub fn cache(&self) -> &InstanceCache {
        &self.pool.cache
    }
}

fn parse_threads(v: &Json) -> Result<usize, String> {
    let t = v.as_int().ok_or("threads: int")?;
    if t < 0 {
        return Err(format!("threads must be >= 0 (0 = auto), got {t}"));
    }
    Ok(t as usize)
}

fn error_json(msg: String) -> Json {
    let mut o = BTreeMap::new();
    o.insert("ok".to_string(), Json::Bool(false));
    o.insert("error".to_string(), Json::Str(msg));
    Json::Object(o)
}

/// Answer one pending slot from the drained results. A job whose worker
/// died without reporting (the guard makes this near-impossible) still
/// yields an error object instead of a hole in the session.
fn resolve_pending(p: Pending, results: &mut HashMap<u64, Json>) -> Json {
    match p {
        Pending::Ready(j) => j,
        Pending::Job(id) => results.remove(&id).unwrap_or_else(|| {
            let mut o = BTreeMap::new();
            o.insert("id".to_string(), Json::Int(id as i64));
            o.insert("ok".to_string(), Json::Bool(false));
            o.insert("error".to_string(), Json::Str("job result lost".into()));
            Json::Object(o)
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full_and_defaults() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy2", "model": "svm", "rule": "essnsv",
                "scale": 0.5, "points": 12, "c_min": 0.1, "c_max": 2.0,
                "tol": 1e-7, "validate": true}"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, "toy2");
        assert_eq!(cfg.rule, "essnsv");
        assert_eq!(cfg.grid.points, 12);
        assert!(cfg.validate);

        let d = ScreeningService::parse_request(r#"{"dataset": "toy1"}"#).unwrap();
        assert_eq!(d.grid.points, 100);
    }

    #[test]
    fn parse_request_rejects_unknown() {
        assert!(ScreeningService::parse_request(r#"{"datafoo": 1}"#).is_err());
        assert!(ScreeningService::parse_request("not json").is_err());
        assert!(ScreeningService::parse_request(r#"{"scale": "big"}"#).is_err());
        assert!(ScreeningService::parse_request(r#"{"kind": "nope", "dataset": "toy1"}"#)
            .is_err());
    }

    #[test]
    fn parse_request_rejects_bad_numerics() {
        // a negative points value must not wrap to a huge usize grid
        for bad in [
            r#"{"dataset": "toy1", "points": -5}"#,
            r#"{"dataset": "toy1", "points": 0}"#,
            r#"{"dataset": "toy1", "points": 1}"#,
            r#"{"dataset": "toy1", "points": 4000000000000000000}"#,
            r#"{"dataset": "toy1", "c_min": -1.0}"#,
            r#"{"dataset": "toy1", "c_min": 0.0}"#,
            r#"{"dataset": "toy1", "c_max": -2.5}"#,
            r#"{"dataset": "toy1", "c_min": 5.0, "c_max": 0.5}"#,
            r#"{"dataset": "toy1", "threads": -1}"#,
            // scale outside (0,1] must not reach the worker's dataset
            // generator (an absurd scale aborts it inside the allocation)
            r#"{"dataset": "toy1", "scale": 1e18}"#,
            r#"{"dataset": "toy1", "scale": 0.0}"#,
            r#"{"dataset": "toy1", "scale": -0.5}"#,
            r#"{"dataset": "toy1", "model": "nope"}"#,
            r#"{"dataset": "toy1", "rule": "nope"}"#,
        ] {
            let e = ScreeningService::parse_request(bad);
            assert!(e.is_err(), "accepted `{bad}`");
        }
        // boundary-legal values still parse
        let ok = ScreeningService::parse_request(
            r#"{"dataset": "toy1", "points": 2, "c_min": 0.5, "c_max": 0.6, "threads": 0}"#,
        )
        .unwrap();
        assert_eq!(ok.grid.points, 2);
        assert_eq!(ok.solver.threads, 0);
    }

    #[test]
    fn parse_request_storage() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy1", "storage": "csr"}"#,
        )
        .unwrap();
        assert_eq!(cfg.storage, "csr");
        assert!(ScreeningService::parse_request(
            r#"{"dataset": "toy1", "storage": "sparse"}"#
        )
        .is_err());
        assert_eq!(
            ScreeningService::parse_request(r#"{"dataset": "toy1"}"#).unwrap().storage,
            "auto"
        );
    }

    #[test]
    fn parse_request_threads_flows_to_solver() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy2", "threads": 4, "points": 8}"#,
        )
        .unwrap();
        assert_eq!(cfg.solver.threads, 4);
    }

    fn parse_line(line: &str) -> Result<ParsedRequest, String> {
        let j = parse_json(line).map_err(|e| e.to_string())?;
        let obj = j.as_object().ok_or("not an object")?;
        ScreeningService::parse_object(obj)
    }

    #[test]
    fn parse_screen_request() {
        let r = parse_line(
            r#"{"kind": "screen", "dataset": "toy1", "scale": 0.1,
                "pairs": [[0.1, 0.2], [0.2, 0.4]], "tol": 1e-7,
                "threads": 2, "return_theta": true, "timings": false}"#,
        )
        .unwrap();
        assert!(!r.timings);
        let JobKind::Screen(s) = r.kind else { panic!("expected screen kind") };
        assert_eq!(s.dataset, "toy1");
        assert_eq!(s.pairs, vec![(0.1, 0.2), (0.2, 0.4)]);
        assert_eq!(s.solver.threads, 2);
        assert!(s.return_theta);
        assert!(s.theta.is_none());
    }

    #[test]
    fn parse_screen_rejects_bad_input() {
        for bad in [
            // no dataset
            r#"{"kind": "screen", "pairs": [[0.1, 0.2]]}"#,
            // no pairs
            r#"{"kind": "screen", "dataset": "toy1"}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": []}"#,
            // malformed pairs
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1]]}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.2, 0.1]]}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.0, 0.1]]}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [["a", "b"]]}"#,
            // screen jobs have no grid fields
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1, 0.2]], "points": 5}"#,
            // bad theta
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1, 0.2]], "theta": ["x"]}"#,
        ] {
            assert!(parse_line(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn parse_object_rejects_nested_batch() {
        assert!(parse_line(r#"{"batch": []}"#).is_err());
    }

    #[test]
    fn serve_round_trip() {
        let mut svc = ScreeningService::new(2);
        let input = br#"
# a comment line
{"dataset": "toy1", "scale": 0.03, "points": 4, "tol": 1e-5}
{"dataset": "no-such", "points": 4}
"#;
        let mut out = Vec::new();
        svc.serve(&input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let ok_count = lines
            .iter()
            .filter(|l| parse_json(l).unwrap().get("ok").unwrap().as_bool() == Some(true))
            .count();
        assert_eq!(ok_count, 1, "{text}");
        assert_eq!(svc.metrics().counter("jobs_done").get(), 2);
        svc.shutdown();
    }

    #[test]
    fn serve_answers_in_input_order() {
        let mut svc = ScreeningService::new(3);
        // a heavyweight first job and featherweight later ones: with
        // completion-order framing the cheap jobs would answer first
        let input = br#"
{"dataset": "toy1", "scale": 0.2, "points": 12, "tol": 1e-7, "timings": false}
{"dataset": "toy2", "scale": 0.03, "points": 4, "tol": 1e-4, "timings": false}
{"not json
{"dataset": "toy3", "scale": 0.03, "points": 4, "tol": 1e-4, "timings": false}
"#;
        let mut out = Vec::new();
        svc.serve(&input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "{text}");
        let ds = |l: &str| {
            parse_json(l)
                .unwrap()
                .get("dataset")
                .and_then(|v| v.as_str().map(str::to_string))
        };
        assert_eq!(ds(lines[0]).as_deref(), Some("toy1"));
        assert_eq!(ds(lines[1]).as_deref(), Some("toy2"));
        assert_eq!(ds(lines[2]), None, "parse error line");
        assert_eq!(ds(lines[3]).as_deref(), Some("toy3"));
        svc.shutdown();
    }

    #[test]
    fn encode_response_contains_series() {
        let outcome = JobOutcome {
            id: 7,
            timings: true,
            result: Ok(JobReply::Path(super::super::job::JobSummary {
                dataset: "d".into(),
                model: "svm".into(),
                rule: "dvi".into(),
                l: 10,
                steps: 2,
                mean_rejection: 0.5,
                rejection_lo: vec![0.0, 0.4],
                rejection_hi: vec![0.0, 0.1],
                grid: vec![0.1, 1.0],
                init_secs: 0.01,
                screen_secs: 0.001,
                total_secs: 0.05,
                total_updates: 123,
                worst_violation: Some(1e-9),
            })),
        };
        let s = ScreeningService::encode_response(&outcome);
        let j = parse_json(&s).unwrap();
        assert_eq!(j.get("id").unwrap().as_int(), Some(7));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("rejection_lo").unwrap().as_array().unwrap().len(), 2);
        assert!(j.get("total_secs").is_some());

        // timings off strips every wall-clock field
        let mut quiet = outcome.clone();
        quiet.timings = false;
        let j = parse_json(&ScreeningService::encode_response(&quiet)).unwrap();
        assert!(j.get("total_secs").is_none());
        assert!(j.get("init_secs").is_none());
        assert!(j.get("screen_secs").is_none());
        assert!(j.get("mean_rejection").is_some());
    }
}
