//! The screening service: a line-oriented JSON front-end over the worker
//! pool. Each request line is a JSON object describing a job (or a batch
//! of jobs); each response line answers it. This is the long-running L3
//! process the `screening_service` example drives end-to-end.
//!
//! ## Path requests (the default kind)
//!
//! ```json
//! {"dataset": "toy1", "model": "svm", "rule": "dvi",
//!  "scale": 0.1, "points": 20, "c_min": 0.01, "c_max": 10.0,
//!  "threads": 4, "storage": "auto", "validate": true, "timings": false}
//! ```
//!
//! `threads` selects the sharded scan/validation engine for the job
//! (1 = serial, 0 = auto-detect); decisions are byte-identical either way.
//! `timings` (default true) controls whether wall-clock fields appear in
//! the response; turning it off makes responses byte-for-byte
//! deterministic.
//!
//! ## Screen requests
//!
//! ```json
//! {"kind": "screen", "dataset": "toy1", "model": "svm", "scale": 0.1,
//!  "rule": "dvi+essnsv",
//!  "pairs": [[0.1, 0.2], [0.2, 0.4]], "theta": [0.0, 1.0],
//!  "tol": 1e-6, "threads": 0, "return_theta": true}
//! ```
//!
//! A screen job screens each `(c_prev, c_next)` pair against ONE resident
//! instance with the requested `rule` expression — any path-rule name or
//! a `+`-composition (e.g. `"dvi+essnsv"`); it defaults to `"dvi"`, whose
//! sharded w-form scan keeps the pre-`rule` wire behavior bit-for-bit.
//! SSNSV-family members cost one extra feasible solve at the batch's
//! largest `c_next`. The anchor θ*(c_prev) is the supplied `theta` (valid
//! for the first pair's `c_prev`) or is solved on demand and memoized
//! across pairs. This is the protocol for amortizing one prepared problem
//! over many screening queries.
//!
//! ## Batch requests
//!
//! ```json
//! {"batch": [{...}, {...}, {...}]}
//! ```
//!
//! Entries are any mix of path/screen requests; they fan out across the
//! worker pool (sharing the instance cache — B entries naming the same
//! dataset build it once) and come back as ONE response line,
//! `{"batch": [...]}`, in entry order. Errors are isolated per entry: a
//! malformed or failed entry yields its error object in place, and with
//! `"timings": false` each entry's object is byte-identical to what the
//! same request would produce as its own line.
//!
//! ## Train and predict requests
//!
//! ```json
//! {"kind": "train", "dataset": "toy1", "model": "svm", "scale": 0.1,
//!  "c": 0.5, "tol": 1e-6, "save": "toy1.pallas-model", "timings": false}
//! {"kind": "predict", "model_id": "svm-…", "rows": [[0.5, -1.0]]}
//! {"kind": "predict", "model_file": "toy1.pallas-model",
//!  "dataset": "toy2", "scale": 0.1, "support_only": true}
//! ```
//!
//! A train job solves the boxed QP at ONE C against the cached instance,
//! extracts the trained-model artifact (w, support set, θ-form active
//! rows), makes it resident in the pool's model cache, optionally
//! persists the `.pallas-model` file, and reports the deterministic
//! `model_id`. A predict job scores inline rows or a registry dataset
//! against a model addressed by `model_id` (resident) or `model_file`
//! (loaded from disk, then resident); scores are byte-deterministic for
//! any `threads`/storage/`support_only` setting.
//!
//! Jobs on one session line-set run concurrently; a request that depends
//! on an earlier one declares `"after": <id>` (any kind accepts it) and
//! the pool holds it until that job's outcome is delivered — so a
//! predict-by-id can follow its train in the same session at any worker
//! count:
//!
//! ```json
//! {"kind": "train", "dataset": "toy1", "c": 0.5}
//! {"kind": "predict", "model_id": "svm-…", "rows": [[0.5, -1.0]], "after": 0}
//! ```
//!
//! Ids are assigned in submission order from 0 (parse-failed lines
//! consume no id); `after` must name an already-submitted id. The edge
//! fires on completion, success or failure — a failed dependency lets
//! the dependent run and fail on its own terms. `"kind": "cache"`
//! introspection still races whatever jobs are in flight unless gated
//! the same way (or run with `--workers 1`).
//!
//! Path, screen, and train requests accept `"solver_threads"` (0 = auto)
//! to shard their CD solves independently of the scan-side `"threads"`;
//! unset, the solver inherits `"threads"`. They also accept
//! `"cd_mode": "sync"|"async"` (default `sync`): sync solves are
//! deterministic per (seed, solver_threads); async solves are KKT-valid
//! at the same tolerance but nondeterministic run to run — see README
//! §Solver for the contract before diffing session outputs that vary
//! either knob. `"shard_axis": "rows"|"cols"|"auto"` (default `rows`)
//! picks the parallel schedule for the n-dimensional reconstruction and
//! Gram-build passes; results are bit-identical across axes, so it is a
//! pure performance knob (`auto` resolves per instance from the cached
//! shape, emitted on the `sweep`/`screen_rows` spans).
//!
//! ## Cache requests
//!
//! ```json
//! {"kind": "cache"}
//! {"kind": "cache", "op": "evict", "target": "model", "model_id": "svm-…"}
//! {"kind": "cache", "op": "evict", "target": "instance",
//!  "dataset": "toy1", "model": "svm", "scale": 0.1, "storage": "auto"}
//! ```
//!
//! Lists both resident caches (key, bytes, hits per entry); the evict op
//! removes one entry and reports whether it existed.
//!
//! ## Stats requests
//!
//! ```json
//! {"kind": "stats", "timings": false}
//! ```
//!
//! One point-in-time snapshot of every metrics family — all counters and
//! gauges in the pool's registry plus the process-wide solver-pool
//! spawn/dispatch counters; histogram summaries are timing-derived and
//! only appear under `"timings": true`. This is the scrape endpoint for
//! a live server (no log parsing, no stderr). Like `"kind": "cache"` it
//! races whatever jobs are in flight.
//!
//! Responses are written in *input order* once EOF is reached (jobs still
//! execute concurrently in between), so a scripted session's output is
//! reproducible. Numeric fields are validated at parse so malformed
//! requests produce an error response line instead of a worker panic.
//! The serve subsystem ([`crate::serve`]) runs this same per-connection
//! protocol over TCP/unix sockets, adds `"stream": true` per-entry
//! framing and admission control, and maps `"persist": true` train
//! requests into its `--model-dir` registry; [`ScreeningService::serve`]
//! is a thin stdin/stdout adapter over that handler, byte-identical to
//! the historical loop.

use super::cache::{CacheKey, InstanceCache, ModelCache};
use super::job::{
    CacheOp, CacheSpec, JobKind, JobOutcome, JobReply, JobSpec, ModelRef, PredictInput,
    PredictSpec, ScreenSpec, TrainSpec,
};
use super::pool::WorkerPool;
use crate::config::json::{parse_json, Json};
use crate::config::{RunConfig, SolverConfig};
use crate::problem::Model;
use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::sync::Arc;

/// Cap on batch entries per line and screen pairs per job: a huge request
/// must degrade to an error line, not an OOM.
pub(crate) const MAX_BATCH: usize = 10_000;
const MAX_PAIRS: usize = 100_000;
/// Caps on inline predict batches (rows and total floats).
const MAX_PREDICT_ROWS: usize = 100_000;
const MAX_PREDICT_FLOATS: usize = 8_000_000;

/// One parsed request object: the job plus its response options.
#[derive(Clone, Debug)]
pub struct ParsedRequest {
    pub kind: JobKind,
    pub timings: bool,
    /// `"after": <id>` — run only once that (already-submitted) job of
    /// this session has completed. Lets e.g. a predict depend on a
    /// same-session train with `--workers` > 1.
    pub after: Option<u64>,
    /// `"stream": true` — emit this request's response(s) as each job
    /// completes instead of buffering for input-order replay. Honored by
    /// the serve-layer connection handler; the buffered default keeps the
    /// historical determinism contract.
    pub stream: bool,
    /// `"persist": true` on a train request — persist the artifact into
    /// the server's `--model-dir` registry. The serve layer resolves the
    /// directory (and rejects the flag when no registry is configured).
    pub persist: bool,
}

/// Service wrapping a pool with JSON request/response framing. The pool
/// is behind an `Arc` so the serve subsystem can multiplex many network
/// connections onto the same workers/caches ([`Self::pool_handle`]).
pub struct ScreeningService {
    pool: Arc<WorkerPool>,
    next_id: u64,
    /// Admission/registry options for [`Self::serve`] sessions. Defaults
    /// to fully open — the historical stdin-loop behavior.
    serve_opts: crate::serve::ServeOptions,
}

impl ScreeningService {
    /// `workers` threads over the default-size instance cache.
    pub fn new(workers: usize) -> ScreeningService {
        Self::with_cache(workers, InstanceCache::DEFAULT_BUDGET_BYTES)
    }

    /// `workers` threads sharing a `cache_bytes`-budget instance cache
    /// (0 disables residency — every job rebuilds, like the pre-cache
    /// service).
    pub fn with_cache(workers: usize, cache_bytes: usize) -> ScreeningService {
        ScreeningService {
            pool: Arc::new(WorkerPool::with_cache(workers, cache_bytes)),
            next_id: 0,
            serve_opts: Default::default(),
        }
    }

    /// Explicit byte budgets for both the instance cache and the
    /// trained-model cache (`dvi serve --cache-mb/--model-cache-mb`).
    pub fn with_caches(workers: usize, cache_bytes: usize, model_bytes: usize) -> ScreeningService {
        ScreeningService {
            pool: Arc::new(WorkerPool::with_caches(workers, cache_bytes, model_bytes)),
            next_id: 0,
            serve_opts: Default::default(),
        }
    }

    /// Apply admission-control / model-registry options to later
    /// [`Self::serve`] sessions (`dvi serve --max-inflight/--queue-cost/
    /// --model-dir` in stdin mode).
    pub fn set_serve_options(&mut self, opts: crate::serve::ServeOptions) {
        self.serve_opts = opts;
    }

    /// A shared handle on the underlying pool — what [`crate::serve::Server`]
    /// multiplexes network connections onto.
    pub fn pool_handle(&self) -> Arc<WorkerPool> {
        self.pool.clone()
    }

    /// Warm the instance cache before serving (`dvi serve --preload`):
    /// resolve and build each named registry dataset into the resident
    /// cache at `scale`. The model for the cache key comes from
    /// [`crate::data::registry::peek_task`] — classification sets warm under the SVM
    /// key, regression sets under LAD, and unknown names (including
    /// `file:` paths, whose task the content decides) default to SVM —
    /// so a preload never pays (or mis-counts as `instance_cache_errors`)
    /// a trial construction under the wrong model. Returns per-dataset
    /// `(name, Ok((model, secs, bytes)) | Err)` for the caller to log.
    pub fn preload(
        &self,
        names: &[&str],
        scale: f64,
    ) -> Vec<(String, Result<(Model, f64, usize), String>)> {
        use crate::data::{registry, Task};
        let mut out = Vec::with_capacity(names.len());
        for &name in names {
            let name = name.trim();
            if name.is_empty() {
                continue;
            }
            // with residency disabled a build would be paid and instantly
            // dropped — logging "preloaded" would be a lie
            if self.pool.cache.budget_bytes() == 0 {
                out.push((
                    name.to_string(),
                    Err("instance cache is disabled (--cache-mb 0); preload skipped".into()),
                ));
                continue;
            }
            let model = match registry::peek_task(name) {
                Some(Task::Regression) => Model::Lad,
                _ => Model::Svm,
            };
            let key = CacheKey::new(name, model, crate::linalg::Storage::Auto, scale);
            let t = std::time::Instant::now();
            // a panicking dataset generator (degenerate shape assert, OOM
            // guard) must log-and-continue like any failed build — preload
            // is best-effort warm-up, never a startup abort
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.pool.cache.get_or_build(&key, &self.pool.metrics)
            }))
            .unwrap_or_else(|p| {
                let msg = if let Some(s) = p.downcast_ref::<&str>() {
                    (*s).to_string()
                } else if let Some(s) = p.downcast_ref::<String>() {
                    s.clone()
                } else {
                    "unknown panic".to_string()
                };
                Err(format!("preload panicked: {msg}"))
            })
            .map(|inst| (model, t.elapsed().as_secs_f64(), inst.approx_bytes()));
            out.push((name.to_string(), result));
        }
        out
    }

    /// Parse one request line into a path-run config (legacy surface;
    /// screen/batch lines are handled by [`Self::serve`]). Numeric fields
    /// are range-checked here: a negative `points` cast straight to
    /// `usize` would wrap to a gigantic grid, and non-finite/non-positive
    /// C bounds would panic inside the worker instead of producing an
    /// error line.
    pub fn parse_request(line: &str) -> Result<RunConfig, String> {
        let j = parse_json(line).map_err(|e| e.to_string())?;
        let obj = j.as_object().ok_or("request must be a JSON object")?;
        match Self::parse_object(obj)? {
            ParsedRequest { kind: JobKind::Path(cfg), .. } => Ok(cfg),
            _ => Err("not a path request (use serve() for screen/batch lines)".into()),
        }
    }

    /// Parse one request object (path or screen kind — batch nesting is
    /// handled a level up by [`Self::serve`]).
    pub fn parse_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        if obj.contains_key("batch") {
            return Err("batch requests cannot nest".into());
        }
        let kind = match obj.get("kind") {
            None => "path",
            Some(v) => v.as_str().ok_or("kind: string")?,
        };
        // the dependency edge is common to every kind; the per-kind
        // parsers skip the key and this level attaches it
        let after = match obj.get("after") {
            None => None,
            Some(v) => {
                let a = v.as_int().ok_or("after: int (an earlier job id)")?;
                if a < 0 {
                    return Err(format!("after must be a job id >= 0, got {a}"));
                }
                Some(a as u64)
            }
        };
        // stream framing is likewise kind-agnostic; the per-kind parsers
        // skip the key the same way
        let stream = match obj.get("stream") {
            None => false,
            Some(v) => v.as_bool().ok_or("stream: bool")?,
        };
        let mut req = match kind {
            "path" => Self::parse_path_object(obj),
            "screen" => Self::parse_screen_object(obj),
            "train" => Self::parse_train_object(obj),
            "predict" => Self::parse_predict_object(obj),
            "cache" => Self::parse_cache_object(obj),
            "stats" => Self::parse_stats_object(obj),
            other => Err(format!(
                "unknown request kind `{other}` (path | screen | train | predict | cache | stats)"
            )),
        }?;
        req.after = after;
        req.stream = stream;
        Ok(req)
    }

    fn parse_path_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        let mut cfg = RunConfig::default();
        let mut timings = true;
        for (k, v) in obj {
            match k.as_str() {
                "kind" | "after" | "stream" => {} // dispatched by the caller
                "timings" => timings = v.as_bool().ok_or("timings: bool")?,
                "dataset" => cfg.dataset = v.as_str().ok_or("dataset: string")?.to_string(),
                "model" => cfg.model = v.as_str().ok_or("model: string")?.to_string(),
                "rule" => cfg.rule = v.as_str().ok_or("rule: string")?.to_string(),
                "scale" => cfg.scale = v.as_float().ok_or("scale: number")?,
                "points" => {
                    let p = v.as_int().ok_or("points: int")?;
                    // lower bound: the grid needs two points; upper bound:
                    // a huge request must not OOM the worker allocating the
                    // grid (the paper's protocol is 100 points)
                    if !(2..=1_000_000).contains(&p) {
                        return Err(format!("points must be in [2, 1000000], got {p}"));
                    }
                    cfg.grid.points = p as usize;
                }
                "c_min" => {
                    let x = v.as_float().ok_or("c_min: number")?;
                    if !x.is_finite() || x <= 0.0 {
                        return Err(format!("c_min must be finite and > 0, got {x}"));
                    }
                    cfg.grid.c_min = x;
                }
                "c_max" => {
                    let x = v.as_float().ok_or("c_max: number")?;
                    if !x.is_finite() || x <= 0.0 {
                        return Err(format!("c_max must be finite and > 0, got {x}"));
                    }
                    cfg.grid.c_max = x;
                }
                "tol" => cfg.solver.tol = v.as_float().ok_or("tol: number")?,
                "threads" => cfg.solver.threads = parse_threads(v)?,
                "solver_threads" => cfg.solver.solver_threads = Some(parse_threads(v)?),
                "cd_mode" => cfg.solver.cd_mode = parse_cd_mode(v)?,
                "shard_axis" => cfg.solver.shard_axis = parse_shard_axis(v)?,
                "storage" => {
                    let s = v.as_str().ok_or("storage: string")?;
                    if crate::linalg::Storage::parse(s).is_none() {
                        return Err(format!("storage must be dense|csr|auto, got `{s}`"));
                    }
                    cfg.storage = s.to_string();
                }
                "validate" => cfg.validate = v.as_bool().ok_or("validate: bool")?,
                "use_pjrt" => cfg.use_pjrt = v.as_bool().ok_or("use_pjrt: bool")?,
                other => return Err(format!("unknown request field `{other}`")),
            }
        }
        // shared semantic validation (model/rule/storage vocabulary, grid
        // ordering, scale ∈ (0,1], tol > 0) — without the scale bound a
        // request like {"scale": 1e18} would reach the worker and abort
        // it inside the dataset generator's allocation
        cfg.validate_semantics().map_err(|e| e.to_string())?;
        Ok(ParsedRequest {
            kind: JobKind::Path(cfg),
            timings,
            after: None,
            stream: false,
            persist: false,
        })
    }

    fn parse_screen_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        let mut spec = ScreenSpec {
            dataset: String::new(),
            model: Model::Svm,
            scale: 1.0,
            storage: crate::linalg::Storage::Auto,
            rule: "dvi".to_string(),
            pairs: Vec::new(),
            theta: None,
            solver: SolverConfig::default(),
            return_theta: false,
        };
        let mut timings = true;
        for (k, v) in obj {
            match k.as_str() {
                "kind" | "after" | "stream" => {}
                "timings" => timings = v.as_bool().ok_or("timings: bool")?,
                "dataset" => spec.dataset = v.as_str().ok_or("dataset: string")?.to_string(),
                "model" => {
                    let s = v.as_str().ok_or("model: string")?;
                    spec.model =
                        Model::parse(s).ok_or_else(|| format!("unknown model `{s}`"))?;
                }
                "scale" => {
                    let x = v.as_float().ok_or("scale: number")?;
                    if !(x > 0.0 && x <= 1.0) {
                        return Err(format!("scale must be in (0, 1], got {x}"));
                    }
                    spec.scale = x;
                }
                "storage" => {
                    let s = v.as_str().ok_or("storage: string")?;
                    spec.storage = crate::linalg::Storage::parse(s)
                        .ok_or_else(|| format!("storage must be dense|csr|auto, got `{s}`"))?;
                }
                "rule" => {
                    let s = v.as_str().ok_or("rule: string")?;
                    // validate the expression at parse so a typo answers
                    // with the accepted vocabulary instead of a worker error
                    crate::screening::RuleExpr::parse(s)?;
                    spec.rule = s.to_string();
                }
                "tol" => {
                    let x = v.as_float().ok_or("tol: number")?;
                    if !(x.is_finite() && x > 0.0) {
                        return Err(format!("tol must be finite and positive, got {x}"));
                    }
                    spec.solver.tol = x;
                }
                "threads" => spec.solver.threads = parse_threads(v)?,
                "solver_threads" => spec.solver.solver_threads = Some(parse_threads(v)?),
                "cd_mode" => spec.solver.cd_mode = parse_cd_mode(v)?,
                "shard_axis" => spec.solver.shard_axis = parse_shard_axis(v)?,
                "pairs" => {
                    let arr = v.as_array().ok_or("pairs: array of [c_prev, c_next]")?;
                    if arr.len() > MAX_PAIRS {
                        return Err(format!("pairs is capped at {MAX_PAIRS} entries"));
                    }
                    let mut pairs = Vec::with_capacity(arr.len());
                    for p in arr {
                        let pp = p.as_array().filter(|a| a.len() == 2).ok_or_else(|| {
                            "each pair must be a [c_prev, c_next] array".to_string()
                        })?;
                        let a = pp[0].as_float().ok_or("c_prev: number")?;
                        let b = pp[1].as_float().ok_or("c_next: number")?;
                        if !(a.is_finite() && b.is_finite() && a > 0.0 && b > a) {
                            return Err(format!(
                                "pair ({a}, {b}) must satisfy 0 < c_prev < c_next"
                            ));
                        }
                        pairs.push((a, b));
                    }
                    spec.pairs = pairs;
                }
                "theta" => {
                    let arr = v.as_array().ok_or("theta: array of numbers")?;
                    let mut t = Vec::with_capacity(arr.len());
                    for x in arr {
                        let f = x.as_float().ok_or("theta entries must be numbers")?;
                        if !f.is_finite() {
                            return Err("theta must be finite".into());
                        }
                        t.push(f);
                    }
                    spec.theta = Some(t);
                }
                "return_theta" => {
                    spec.return_theta = v.as_bool().ok_or("return_theta: bool")?
                }
                other => return Err(format!("unknown screen field `{other}`")),
            }
        }
        if spec.dataset.is_empty() {
            return Err("screen: `dataset` is required".into());
        }
        if spec.pairs.is_empty() {
            return Err("screen: `pairs` must be a non-empty array".into());
        }
        Ok(ParsedRequest {
            kind: JobKind::Screen(spec),
            timings,
            after: None,
            stream: false,
            persist: false,
        })
    }

    fn parse_train_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        let mut spec = TrainSpec {
            dataset: String::new(),
            model: Model::Svm,
            scale: 1.0,
            storage: crate::linalg::Storage::Auto,
            c: f64::NAN,
            solver: SolverConfig::default(),
            save: None,
            persist_dir: None,
            report_support: false,
        };
        let mut timings = true;
        let mut persist = false;
        for (k, v) in obj {
            match k.as_str() {
                "kind" | "after" | "stream" => {}
                "timings" => timings = v.as_bool().ok_or("timings: bool")?,
                "dataset" => spec.dataset = v.as_str().ok_or("dataset: string")?.to_string(),
                "model" => {
                    let s = v.as_str().ok_or("model: string")?;
                    spec.model =
                        Model::parse(s).ok_or_else(|| format!("unknown model `{s}`"))?;
                }
                "scale" => {
                    let x = v.as_float().ok_or("scale: number")?;
                    if !(x > 0.0 && x <= 1.0) {
                        return Err(format!("scale must be in (0, 1], got {x}"));
                    }
                    spec.scale = x;
                }
                "storage" => {
                    let s = v.as_str().ok_or("storage: string")?;
                    spec.storage = crate::linalg::Storage::parse(s)
                        .ok_or_else(|| format!("storage must be dense|csr|auto, got `{s}`"))?;
                }
                "c" => {
                    let x = v.as_float().ok_or("c: number")?;
                    if !(x.is_finite() && x > 0.0) {
                        return Err(format!("c must be finite and > 0, got {x}"));
                    }
                    spec.c = x;
                }
                "tol" => {
                    let x = v.as_float().ok_or("tol: number")?;
                    if !(x.is_finite() && x > 0.0) {
                        // an infinite tol "converges" instantly and
                        // would persist a garbage artifact with ok:true
                        return Err(format!("tol must be finite and positive, got {x}"));
                    }
                    spec.solver.tol = x;
                }
                "threads" => spec.solver.threads = parse_threads(v)?,
                "solver_threads" => spec.solver.solver_threads = Some(parse_threads(v)?),
                "cd_mode" => spec.solver.cd_mode = parse_cd_mode(v)?,
                "shard_axis" => spec.solver.shard_axis = parse_shard_axis(v)?,
                "save" => spec.save = Some(v.as_str().ok_or("save: string")?.to_string()),
                // the serve layer rewrites this into `persist_dir` once it
                // knows the server's --model-dir; here it only flags intent
                "persist" => persist = v.as_bool().ok_or("persist: bool")?,
                other => return Err(format!("unknown train field `{other}`")),
            }
        }
        if spec.dataset.is_empty() {
            return Err("train: `dataset` is required".into());
        }
        if spec.c.is_nan() {
            return Err("train: `c` is required".into());
        }
        Ok(ParsedRequest {
            kind: JobKind::Train(spec),
            timings,
            after: None,
            stream: false,
            persist,
        })
    }

    fn parse_predict_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        let mut model_id: Option<String> = None;
        let mut model_file: Option<String> = None;
        let mut rows: Option<(Vec<f64>, usize)> = None; // (flat, width)
        let mut dataset: Option<String> = None;
        let mut scale = 1.0f64;
        let mut storage = crate::linalg::Storage::Auto;
        let mut dataset_fields = false; // scale/storage seen explicitly
        let mut threads = 1usize;
        let mut support_only = false;
        let mut timings = true;
        for (k, v) in obj {
            match k.as_str() {
                "kind" | "after" | "stream" => {}
                "timings" => timings = v.as_bool().ok_or("timings: bool")?,
                "model_id" => model_id = Some(v.as_str().ok_or("model_id: string")?.to_string()),
                "model_file" => {
                    model_file = Some(v.as_str().ok_or("model_file: string")?.to_string())
                }
                "dataset" => dataset = Some(v.as_str().ok_or("dataset: string")?.to_string()),
                "scale" => {
                    let x = v.as_float().ok_or("scale: number")?;
                    if !(x > 0.0 && x <= 1.0) {
                        return Err(format!("scale must be in (0, 1], got {x}"));
                    }
                    scale = x;
                    dataset_fields = true;
                }
                "storage" => {
                    let s = v.as_str().ok_or("storage: string")?;
                    storage = crate::linalg::Storage::parse(s)
                        .ok_or_else(|| format!("storage must be dense|csr|auto, got `{s}`"))?;
                    dataset_fields = true;
                }
                "threads" => threads = parse_threads(v)?,
                "support_only" => support_only = v.as_bool().ok_or("support_only: bool")?,
                "rows" => {
                    let arr = v.as_array().ok_or("rows: array of number arrays")?;
                    if arr.is_empty() {
                        return Err("rows must be non-empty".into());
                    }
                    if arr.len() > MAX_PREDICT_ROWS {
                        return Err(format!("rows is capped at {MAX_PREDICT_ROWS} entries"));
                    }
                    // parse straight into the flat row-major buffer the
                    // scoring engine wants — no per-row Vec allocations
                    let width = arr[0].as_array().ok_or("each row must be a number array")?.len();
                    if width == 0 {
                        return Err("rows must have at least one feature".into());
                    }
                    if arr.len().saturating_mul(width) > MAX_PREDICT_FLOATS {
                        return Err(format!(
                            "rows payload is capped at {MAX_PREDICT_FLOATS} numbers"
                        ));
                    }
                    let mut flat = Vec::with_capacity(arr.len() * width);
                    for (i, r) in arr.iter().enumerate() {
                        let rr = r.as_array().ok_or("each row must be a number array")?;
                        if rr.len() != width {
                            return Err(format!(
                                "row {i} has {} entries but row 0 has {width} (rows must be rectangular)",
                                rr.len()
                            ));
                        }
                        for x in rr {
                            let f = x.as_float().ok_or("row entries must be numbers")?;
                            if !f.is_finite() {
                                return Err("row entries must be finite".into());
                            }
                            flat.push(f);
                        }
                    }
                    rows = Some((flat, width));
                }
                other => return Err(format!("unknown predict field `{other}`")),
            }
        }
        let model = match (model_id, model_file) {
            (Some(id), None) => ModelRef::Id(id),
            (None, Some(f)) => ModelRef::File(f),
            (Some(_), Some(_)) => {
                return Err("predict: supply model_id or model_file, not both".into())
            }
            (None, None) => return Err("predict: model_id or model_file is required".into()),
        };
        let input = match (rows, dataset) {
            (Some((flat, width)), None) => {
                // silently ignoring these would make the scores differ
                // from what the client asked for
                if dataset_fields {
                    return Err(
                        "predict: scale/storage apply to dataset inputs, not inline rows".into(),
                    );
                }
                PredictInput::Rows { flat, width }
            }
            (None, Some(name)) => PredictInput::Dataset { name, scale, storage },
            (Some(_), Some(_)) => {
                return Err("predict: supply rows or dataset, not both".into())
            }
            (None, None) => return Err("predict: rows or dataset is required".into()),
        };
        Ok(ParsedRequest {
            kind: JobKind::Predict(PredictSpec { model, input, threads, support_only }),
            timings,
            after: None,
            stream: false,
            persist: false,
        })
    }

    fn parse_cache_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        let mut op = "list".to_string();
        let mut target: Option<String> = None;
        let mut model_id: Option<String> = None;
        let mut dataset: Option<String> = None;
        let mut model = Model::Svm;
        let mut storage = crate::linalg::Storage::Auto;
        let mut scale = 1.0f64;
        let mut instance_fields = false; // model/storage/scale seen
        let mut timings = true;
        for (k, v) in obj {
            match k.as_str() {
                "kind" | "after" | "stream" => {}
                "timings" => timings = v.as_bool().ok_or("timings: bool")?,
                "op" => op = v.as_str().ok_or("op: string")?.to_string(),
                "target" => target = Some(v.as_str().ok_or("target: string")?.to_string()),
                "model_id" => model_id = Some(v.as_str().ok_or("model_id: string")?.to_string()),
                "dataset" => dataset = Some(v.as_str().ok_or("dataset: string")?.to_string()),
                "model" => {
                    let s = v.as_str().ok_or("model: string")?;
                    model = Model::parse(s).ok_or_else(|| format!("unknown model `{s}`"))?;
                    instance_fields = true;
                }
                "storage" => {
                    let s = v.as_str().ok_or("storage: string")?;
                    storage = crate::linalg::Storage::parse(s)
                        .ok_or_else(|| format!("storage must be dense|csr|auto, got `{s}`"))?;
                    instance_fields = true;
                }
                "scale" => {
                    scale = v.as_float().ok_or("scale: number")?;
                    instance_fields = true;
                }
                other => return Err(format!("unknown cache field `{other}`")),
            }
        }
        // every selector must belong to the chosen op — a typo'd evict
        // (e.g. a bare `model_id` with no "op") must NOT silently degrade
        // to a list that reports ok:true while doing nothing
        let op = match op.as_str() {
            "list" => {
                if target.is_some()
                    || model_id.is_some()
                    || dataset.is_some()
                    || instance_fields
                {
                    return Err(
                        "cache list takes no selector fields (did you mean \"op\": \"evict\"?)"
                            .into(),
                    );
                }
                CacheOp::List
            }
            "evict" => match target.as_deref() {
                Some("model") => {
                    if dataset.is_some() || instance_fields {
                        return Err(
                            "cache evict model: dataset/model/storage/scale do not apply".into(),
                        );
                    }
                    CacheOp::EvictModel(
                        model_id.ok_or("cache evict model: `model_id` is required")?,
                    )
                }
                Some("instance") => {
                    if model_id.is_some() {
                        return Err("cache evict instance: `model_id` does not apply".into());
                    }
                    let ds = dataset.ok_or("cache evict instance: `dataset` is required")?;
                    CacheOp::EvictInstance(CacheKey::new(&ds, model, storage, scale))
                }
                _ => return Err("cache evict: `target` must be instance | model".into()),
            },
            other => return Err(format!("unknown cache op `{other}` (list | evict)")),
        };
        Ok(ParsedRequest {
            kind: JobKind::Cache(CacheSpec { op }),
            timings,
            after: None,
            stream: false,
            persist: false,
        })
    }

    /// `{"kind": "stats"}` — no fields beyond the kind-agnostic ones; a
    /// selector typo must answer with an error, not a silent full dump.
    fn parse_stats_object(obj: &BTreeMap<String, Json>) -> Result<ParsedRequest, String> {
        let mut timings = true;
        for (k, v) in obj {
            match k.as_str() {
                "kind" | "after" | "stream" => {}
                "timings" => timings = v.as_bool().ok_or("timings: bool")?,
                other => return Err(format!("unknown stats field `{other}`")),
            }
        }
        Ok(ParsedRequest {
            kind: JobKind::Stats,
            timings,
            after: None,
            stream: false,
            persist: false,
        })
    }

    /// Submit a path run; returns its job id.
    pub fn submit(&mut self, run: RunConfig) -> u64 {
        self.submit_kind(JobKind::Path(run), true)
    }

    /// Submit any job kind; returns its job id.
    pub fn submit_kind(&mut self, kind: JobKind, timings: bool) -> u64 {
        self.submit_gated(kind, timings, None)
    }

    /// Submit a job, optionally gated on an earlier job's completion
    /// (`"after"`; the caller has validated the id exists).
    fn submit_gated(&mut self, kind: JobKind, timings: bool, after: Option<u64>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.pool.submit(JobSpec { id, kind, timings, after });
        id
    }

    /// Block for the next result.
    pub fn recv(&self) -> Option<JobOutcome> {
        self.pool.recv()
    }

    /// Encode an outcome as a JSON response line.
    pub fn encode_response(outcome: &JobOutcome) -> String {
        Self::encode_response_json(outcome).to_string()
    }

    /// Encode an outcome as a JSON value (batch entries embed these).
    pub fn encode_response_json(outcome: &JobOutcome) -> Json {
        let mut o = BTreeMap::new();
        o.insert("id".to_string(), Json::Int(outcome.id as i64));
        match &outcome.result {
            Err(e) => {
                o.insert("ok".into(), Json::Bool(false));
                o.insert("error".into(), Json::Str(e.clone()));
            }
            Ok(JobReply::Path(s)) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("dataset".into(), Json::Str(s.dataset.clone()));
                o.insert("model".into(), Json::Str(s.model.clone()));
                o.insert("rule".into(), Json::Str(s.rule.clone()));
                o.insert("l".into(), Json::Int(s.l as i64));
                o.insert("steps".into(), Json::Int(s.steps as i64));
                o.insert("mean_rejection".into(), Json::Float(s.mean_rejection));
                if outcome.timings {
                    o.insert("init_secs".into(), Json::Float(s.init_secs));
                    o.insert("screen_secs".into(), Json::Float(s.screen_secs));
                    o.insert("total_secs".into(), Json::Float(s.total_secs));
                }
                o.insert("total_updates".into(), Json::Int(s.total_updates as i64));
                if let Some(v) = s.worst_violation {
                    o.insert("worst_violation".into(), Json::Float(v));
                }
                o.insert(
                    "rejection_lo".into(),
                    Json::Array(s.rejection_lo.iter().map(|&v| Json::Float(v)).collect()),
                );
                o.insert(
                    "rejection_hi".into(),
                    Json::Array(s.rejection_hi.iter().map(|&v| Json::Float(v)).collect()),
                );
            }
            Ok(JobReply::Screen(s)) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("screen".into()));
                o.insert("dataset".into(), Json::Str(s.dataset.clone()));
                o.insert("model".into(), Json::Str(s.model.clone()));
                o.insert("rule".into(), Json::Str(s.rule.clone()));
                o.insert("l".into(), Json::Int(s.l as i64));
                o.insert("mean_rejection".into(), Json::Float(s.mean_rejection()));
                o.insert("anchor_solves".into(), Json::Int(s.anchor_solves as i64));
                if outcome.timings {
                    o.insert("solve_secs".into(), Json::Float(s.solve_secs));
                    o.insert("screen_secs".into(), Json::Float(s.screen_secs));
                }
                let pairs: Vec<Json> = s
                    .pairs
                    .iter()
                    .map(|p| {
                        let mut m = BTreeMap::new();
                        m.insert("c".to_string(), Json::Float(p.c_next));
                        m.insert("c_prev".to_string(), Json::Float(p.c_prev));
                        m.insert("n_lo".to_string(), Json::Int(p.n_lo as i64));
                        m.insert("n_hi".to_string(), Json::Int(p.n_hi as i64));
                        m.insert("free".to_string(), Json::Int(p.free as i64));
                        Json::Object(m)
                    })
                    .collect();
                o.insert("pairs".into(), Json::Array(pairs));
                if let Some(t) = &s.theta {
                    o.insert(
                        "theta".into(),
                        Json::Array(t.iter().map(|&v| Json::Float(v)).collect()),
                    );
                    o.insert("theta_c".into(), Json::Float(s.theta_c.unwrap_or(0.0)));
                }
            }
            Ok(JobReply::Train(s)) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("train".into()));
                o.insert("model_id".into(), Json::Str(s.model_id.clone()));
                o.insert("dataset".into(), Json::Str(s.dataset.clone()));
                o.insert("model".into(), Json::Str(s.model.wire_name()));
                o.insert("storage".into(), Json::Str(s.storage.name().into()));
                o.insert("c".into(), Json::Float(s.c));
                o.insert("l".into(), Json::Int(s.l as i64));
                o.insert("n".into(), Json::Int(s.n as i64));
                o.insert("support".into(), Json::Int(s.support as i64));
                o.insert("active".into(), Json::Int(s.active as i64));
                o.insert("artifact_bytes".into(), Json::Int(s.artifact_bytes as i64));
                if let Some(p) = &s.saved {
                    o.insert("saved".into(), Json::Str(p.clone()));
                }
                if let Some(p) = &s.persisted {
                    o.insert("persisted".into(), Json::Str(p.clone()));
                }
                if outcome.timings {
                    o.insert("solve_secs".into(), Json::Float(s.solve_secs));
                }
            }
            Ok(JobReply::Predict(s)) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("predict".into()));
                o.insert("model_id".into(), Json::Str(s.model_id.clone()));
                o.insert("model".into(), Json::Str(s.model.wire_name()));
                o.insert("rows".into(), Json::Int(s.rows as i64));
                o.insert("support_only".into(), Json::Bool(s.support_only));
                o.insert(
                    "scores".into(),
                    Json::Array(s.scores.iter().map(|&v| Json::Float(v)).collect()),
                );
                if let Some(labels) = &s.labels {
                    o.insert(
                        "labels".into(),
                        Json::Array(labels.iter().map(|&v| Json::Int(v as i64)).collect()),
                    );
                }
                if outcome.timings {
                    o.insert("predict_secs".into(), Json::Float(s.predict_secs));
                }
            }
            Ok(JobReply::Cache(s)) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("cache".into()));
                let instances: Vec<Json> = s
                    .instances
                    .iter()
                    .map(|e| {
                        let mut m = BTreeMap::new();
                        m.insert("dataset".to_string(), Json::Str(e.dataset.clone()));
                        m.insert("model".to_string(), Json::Str(e.model.wire_name()));
                        m.insert("storage".to_string(), Json::Str(e.storage.name().into()));
                        m.insert("scale".to_string(), Json::Float(e.scale));
                        m.insert("bytes".to_string(), Json::Int(e.bytes as i64));
                        m.insert("hits".to_string(), Json::Int(e.hits as i64));
                        Json::Object(m)
                    })
                    .collect();
                o.insert("instances".into(), Json::Array(instances));
                let models: Vec<Json> = s
                    .models
                    .iter()
                    .map(|e| {
                        let mut m = BTreeMap::new();
                        m.insert("id".to_string(), Json::Str(e.id.clone()));
                        m.insert("bytes".to_string(), Json::Int(e.bytes as i64));
                        m.insert("hits".to_string(), Json::Int(e.hits as i64));
                        Json::Object(m)
                    })
                    .collect();
                o.insert("models".into(), Json::Array(models));
                if let Some(e) = s.evicted {
                    o.insert("evicted".into(), Json::Bool(e));
                }
            }
            Ok(JobReply::Stats(s)) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("stats".into()));
                let counters: BTreeMap<String, Json> = s
                    .counters
                    .iter()
                    .map(|(n, v)| (n.clone(), Json::Int(*v as i64)))
                    .collect();
                o.insert("counters".into(), Json::Object(counters));
                let gauges: BTreeMap<String, Json> = s
                    .gauges
                    .iter()
                    .map(|(n, v)| (n.clone(), Json::Int(*v as i64)))
                    .collect();
                o.insert("gauges".into(), Json::Object(gauges));
                let mut pool = BTreeMap::new();
                pool.insert(
                    "workers_spawned".to_string(),
                    Json::Int(s.pool.workers_spawned as i64),
                );
                pool.insert(
                    "jobs_dispatched".to_string(),
                    Json::Int(s.pool.jobs_dispatched as i64),
                );
                pool.insert("scoped_spawns".to_string(), Json::Int(s.pool.scoped_spawns as i64));
                o.insert("pool".into(), Json::Object(pool));
                // histogram summaries are wall-clock derived — emitting
                // them under the determinism contract would break
                // byte-identical session diffs
                if outcome.timings {
                    let hists: Vec<Json> = s
                        .histograms
                        .iter()
                        .map(|h| {
                            let mut m = BTreeMap::new();
                            m.insert("name".to_string(), Json::Str(h.name.clone()));
                            m.insert("n".to_string(), Json::Int(h.count as i64));
                            m.insert("mean".to_string(), Json::Float(h.mean));
                            m.insert("p50".to_string(), Json::Float(h.p50));
                            m.insert("p99".to_string(), Json::Float(h.p99));
                            m.insert("max".to_string(), Json::Float(h.max));
                            Json::Object(m)
                        })
                        .collect();
                    o.insert("histograms".into(), Json::Array(hists));
                }
            }
        }
        Json::Object(o)
    }

    /// Serve until EOF: one JSON request (or batch) per line in, one JSON
    /// response per line out, *in input order* — jobs run concurrently on
    /// the pool in between, but the emitted session is reproducible.
    ///
    /// This is a thin adapter over the serve subsystem's connection
    /// handler ([`crate::serve::Server::serve_session`]) with admission
    /// control defaulting to unlimited (see [`Self::set_serve_options`]),
    /// so the emitted bytes match the historical stdin/stdout loop
    /// exactly — the TCP/unix listeners run the very same handler per
    /// connection.
    pub fn serve<R: BufRead, W: Write + Send>(
        &mut self,
        input: R,
        output: W,
    ) -> std::io::Result<()> {
        let mut server = crate::serve::Server::with_start(
            self.pool.clone(),
            self.serve_opts.clone(),
            self.next_id,
        );
        let result = server.serve_session(input, output, self.next_id);
        // join the dispatcher before returning so a later direct recv()
        // on this service sees the results channel uncontended
        server.stop();
        self.next_id = result?;
        Ok(())
    }

    /// Shut the service down: this drops the service's handle on the
    /// shared pool; the workers drain queued jobs and join when the last
    /// `Arc` holder (e.g. a still-running [`crate::serve::Server`])
    /// releases it.
    pub fn shutdown(self) {
        drop(self);
    }

    /// Metrics registry (jobs_done, jobs_failed, job_secs,
    /// instance_cache_hits/misses/evictions/bytes, service_*).
    pub fn metrics(&self) -> &crate::metrics::Registry {
        &self.pool.metrics
    }

    /// The pool's resident instance cache.
    pub fn cache(&self) -> &InstanceCache {
        &self.pool.cache
    }

    /// The pool's resident trained-model cache.
    pub fn models(&self) -> &ModelCache {
        &self.pool.models
    }
}

fn parse_threads(v: &Json) -> Result<usize, String> {
    let t = v.as_int().ok_or("threads: int")?;
    if t < 0 {
        return Err(format!("threads must be >= 0 (0 = auto), got {t}"));
    }
    Ok(t as usize)
}

fn parse_cd_mode(v: &Json) -> Result<crate::config::CdMode, String> {
    let s = v.as_str().ok_or("cd_mode: string")?;
    crate::config::CdMode::parse(s)
        .ok_or_else(|| format!("cd_mode must be sync|async, got `{s}`"))
}

fn parse_shard_axis(v: &Json) -> Result<crate::config::ShardAxis, String> {
    let s = v.as_str().ok_or("shard_axis: string")?;
    crate::config::ShardAxis::parse(s)
        .ok_or_else(|| format!("shard_axis must be rows|cols|auto, got `{s}`"))
}

/// An id-less error object (parse failures — no job was submitted). The
/// serve-layer connection handler shares this shape so a request is
/// answered identically whether it fails over stdin or over a socket.
pub(crate) fn error_json(msg: String) -> Json {
    let mut o = BTreeMap::new();
    o.insert("ok".to_string(), Json::Bool(false));
    o.insert("error".to_string(), Json::Str(msg));
    Json::Object(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full_and_defaults() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy2", "model": "svm", "rule": "essnsv",
                "scale": 0.5, "points": 12, "c_min": 0.1, "c_max": 2.0,
                "tol": 1e-7, "validate": true}"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, "toy2");
        assert_eq!(cfg.rule, "essnsv");
        assert_eq!(cfg.grid.points, 12);
        assert!(cfg.validate);

        let d = ScreeningService::parse_request(r#"{"dataset": "toy1"}"#).unwrap();
        assert_eq!(d.grid.points, 100);
    }

    #[test]
    fn parse_request_rejects_unknown() {
        assert!(ScreeningService::parse_request(r#"{"datafoo": 1}"#).is_err());
        assert!(ScreeningService::parse_request("not json").is_err());
        assert!(ScreeningService::parse_request(r#"{"scale": "big"}"#).is_err());
        assert!(ScreeningService::parse_request(r#"{"kind": "nope", "dataset": "toy1"}"#)
            .is_err());
    }

    #[test]
    fn parse_request_rejects_bad_numerics() {
        // a negative points value must not wrap to a huge usize grid
        for bad in [
            r#"{"dataset": "toy1", "points": -5}"#,
            r#"{"dataset": "toy1", "points": 0}"#,
            r#"{"dataset": "toy1", "points": 1}"#,
            r#"{"dataset": "toy1", "points": 4000000000000000000}"#,
            r#"{"dataset": "toy1", "c_min": -1.0}"#,
            r#"{"dataset": "toy1", "c_min": 0.0}"#,
            r#"{"dataset": "toy1", "c_max": -2.5}"#,
            r#"{"dataset": "toy1", "c_min": 5.0, "c_max": 0.5}"#,
            r#"{"dataset": "toy1", "threads": -1}"#,
            // scale outside (0,1] must not reach the worker's dataset
            // generator (an absurd scale aborts it inside the allocation)
            r#"{"dataset": "toy1", "scale": 1e18}"#,
            r#"{"dataset": "toy1", "scale": 0.0}"#,
            r#"{"dataset": "toy1", "scale": -0.5}"#,
            r#"{"dataset": "toy1", "model": "nope"}"#,
            r#"{"dataset": "toy1", "rule": "nope"}"#,
            r#"{"dataset": "toy1", "tol": 1e400}"#,
        ] {
            let e = ScreeningService::parse_request(bad);
            assert!(e.is_err(), "accepted `{bad}`");
        }
        // boundary-legal values still parse
        let ok = ScreeningService::parse_request(
            r#"{"dataset": "toy1", "points": 2, "c_min": 0.5, "c_max": 0.6, "threads": 0}"#,
        )
        .unwrap();
        assert_eq!(ok.grid.points, 2);
        assert_eq!(ok.solver.threads, 0);
    }

    #[test]
    fn parse_request_storage() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy1", "storage": "csr"}"#,
        )
        .unwrap();
        assert_eq!(cfg.storage, "csr");
        assert!(ScreeningService::parse_request(
            r#"{"dataset": "toy1", "storage": "sparse"}"#
        )
        .is_err());
        assert_eq!(
            ScreeningService::parse_request(r#"{"dataset": "toy1"}"#).unwrap().storage,
            "auto"
        );
    }

    #[test]
    fn parse_request_threads_flows_to_solver() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy2", "threads": 4, "points": 8}"#,
        )
        .unwrap();
        assert_eq!(cfg.solver.threads, 4);
        assert_eq!(cfg.solver.solver_threads, None, "solver inherits threads by default");
        assert_eq!(cfg.solver.cd_threads(), 4);
    }

    #[test]
    fn parse_solver_threads_overrides_inheritance() {
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy2", "threads": 4, "solver_threads": 1, "points": 8}"#,
        )
        .unwrap();
        assert_eq!(cfg.solver.threads, 4);
        assert_eq!(cfg.solver.cd_threads(), 1);
        assert!(ScreeningService::parse_request(
            r#"{"dataset": "toy2", "solver_threads": -2}"#
        )
        .is_err());
        // screen and train kinds take it too
        let r = parse_line(
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1, 0.2]],
                "solver_threads": 2}"#,
        )
        .unwrap();
        let JobKind::Screen(s) = r.kind else { panic!("expected screen kind") };
        assert_eq!(s.solver.cd_threads(), 2);
        let r = parse_line(
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "solver_threads": 0}"#,
        )
        .unwrap();
        let JobKind::Train(s) = r.kind else { panic!("expected train kind") };
        assert_eq!(s.solver.solver_threads, Some(0), "0 = auto is legal");
    }

    #[test]
    fn parse_cd_mode_on_path_screen_train() {
        use crate::config::CdMode;
        // default is sync; explicit async sticks on every solver-bearing kind
        let cfg = ScreeningService::parse_request(r#"{"dataset": "toy1"}"#).unwrap();
        assert_eq!(cfg.solver.cd_mode, CdMode::Sync);
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy1", "cd_mode": "async", "solver_threads": 4}"#,
        )
        .unwrap();
        assert_eq!(cfg.solver.cd_mode, CdMode::Async);
        let r = parse_line(
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1, 0.2]],
                "cd_mode": "async"}"#,
        )
        .unwrap();
        let JobKind::Screen(s) = r.kind else { panic!("expected screen kind") };
        assert_eq!(s.solver.cd_mode, CdMode::Async);
        let r = parse_line(
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "cd_mode": "sync"}"#,
        )
        .unwrap();
        let JobKind::Train(s) = r.kind else { panic!("expected train kind") };
        assert_eq!(s.solver.cd_mode, CdMode::Sync);
        // vocabulary and type errors answer at parse, not in the worker
        for bad in [
            r#"{"dataset": "toy1", "cd_mode": "wild"}"#,
            r#"{"dataset": "toy1", "cd_mode": 2}"#,
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "cd_mode": "Async"}"#,
        ] {
            let e = parse_line(bad);
            assert!(e.is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn parse_shard_axis_on_path_screen_train() {
        use crate::config::ShardAxis;
        // default is rows; explicit values stick on every solver-bearing kind
        let cfg = ScreeningService::parse_request(r#"{"dataset": "toy1"}"#).unwrap();
        assert_eq!(cfg.solver.shard_axis, ShardAxis::Rows);
        let cfg = ScreeningService::parse_request(
            r#"{"dataset": "toy1", "shard_axis": "cols"}"#,
        )
        .unwrap();
        assert_eq!(cfg.solver.shard_axis, ShardAxis::Cols);
        let r = parse_line(
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1, 0.2]],
                "shard_axis": "auto"}"#,
        )
        .unwrap();
        let JobKind::Screen(s) = r.kind else { panic!("expected screen kind") };
        assert_eq!(s.solver.shard_axis, ShardAxis::Auto);
        let r = parse_line(
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "shard_axis": "cols"}"#,
        )
        .unwrap();
        let JobKind::Train(s) = r.kind else { panic!("expected train kind") };
        assert_eq!(s.solver.shard_axis, ShardAxis::Cols);
        // vocabulary and type errors answer at parse, not in the worker
        for bad in [
            r#"{"dataset": "toy1", "shard_axis": "columns"}"#,
            r#"{"dataset": "toy1", "shard_axis": 1}"#,
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "shard_axis": "Cols"}"#,
        ] {
            let e = parse_line(bad);
            assert!(e.is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn parse_after_on_any_kind() {
        for line in [
            r#"{"dataset": "toy1", "after": 3}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1, 0.2]], "after": 0}"#,
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "after": 1}"#,
            r#"{"kind": "predict", "model_id": "m", "rows": [[1.0]], "after": 2}"#,
            r#"{"kind": "cache", "after": 0}"#,
        ] {
            let r = parse_line(line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert!(r.after.is_some(), "{line}");
        }
        assert_eq!(parse_line(r#"{"dataset": "toy1"}"#).unwrap().after, None);
        assert!(parse_line(r#"{"dataset": "toy1", "after": -1}"#).is_err());
        assert!(parse_line(r#"{"dataset": "toy1", "after": "zero"}"#).is_err());
    }

    #[test]
    fn serve_after_orders_in_session_train_predict() {
        use super::super::job::{JobSpec, TrainSpec};
        // learn the deterministic model id (content digest) up front
        let probe = super::super::job::run_job(&JobSpec::train(
            0,
            TrainSpec {
                dataset: "toy1".into(),
                model: Model::Svm,
                scale: 0.03,
                storage: crate::linalg::Storage::Auto,
                c: 0.5,
                solver: SolverConfig { tol: 1e-6, ..Default::default() },
                save: None,
                persist_dir: None,
                report_support: false,
            },
        ));
        let id = probe.result.unwrap().as_train().unwrap().model_id.clone();

        // 3 workers: without the edge the predict would race the train
        let mut svc = ScreeningService::new(3);
        let input = format!(
            concat!(
                r#"{{"kind": "train", "dataset": "toy1", "scale": 0.03, "c": 0.5, "tol": 1e-6, "timings": false}}"#,
                "\n",
                r#"{{"kind": "predict", "model_id": "{}", "rows": [[1.0, 1.0]], "after": 0, "timings": false}}"#,
                "\n",
                // an edge past the last submitted id is an error line
                r#"{{"kind": "cache", "after": 7}}"#,
                "\n"
            ),
            id
        );
        let mut out = Vec::new();
        svc.serve(input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert_eq!(
            parse_json(lines[0]).unwrap().get("ok").unwrap().as_bool(),
            Some(true),
            "{text}"
        );
        let predict = parse_json(lines[1]).unwrap();
        assert_eq!(predict.get("ok").unwrap().as_bool(), Some(true), "{text}");
        assert_eq!(predict.get("kind").unwrap().as_str(), Some("predict"));
        let bad = parse_json(lines[2]).unwrap();
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            bad.get("error").unwrap().as_str().unwrap().contains("already-submitted"),
            "{text}"
        );
        svc.shutdown();
    }

    fn parse_line(line: &str) -> Result<ParsedRequest, String> {
        let j = parse_json(line).map_err(|e| e.to_string())?;
        let obj = j.as_object().ok_or("not an object")?;
        ScreeningService::parse_object(obj)
    }

    #[test]
    fn parse_screen_request() {
        let r = parse_line(
            r#"{"kind": "screen", "dataset": "toy1", "scale": 0.1,
                "pairs": [[0.1, 0.2], [0.2, 0.4]], "tol": 1e-7,
                "threads": 2, "return_theta": true, "timings": false}"#,
        )
        .unwrap();
        assert!(!r.timings);
        let JobKind::Screen(s) = r.kind else { panic!("expected screen kind") };
        assert_eq!(s.dataset, "toy1");
        assert_eq!(s.pairs, vec![(0.1, 0.2), (0.2, 0.4)]);
        assert_eq!(s.solver.threads, 2);
        assert!(s.return_theta);
        assert!(s.theta.is_none());
        assert_eq!(s.rule, "dvi", "rule defaults to the pre-rule wire behavior");

        let r = parse_line(
            r#"{"kind": "screen", "dataset": "toy1", "rule": "dvi+essnsv",
                "pairs": [[0.1, 0.2]]}"#,
        )
        .unwrap();
        let JobKind::Screen(s) = r.kind else { panic!("expected screen kind") };
        assert_eq!(s.rule, "dvi+essnsv");

        let err = parse_line(
            r#"{"kind": "screen", "dataset": "toy1", "rule": "nope",
                "pairs": [[0.1, 0.2]]}"#,
        )
        .unwrap_err();
        assert!(err.contains("valid rules:"), "{err}");
    }

    #[test]
    fn parse_screen_rejects_bad_input() {
        for bad in [
            // no dataset
            r#"{"kind": "screen", "pairs": [[0.1, 0.2]]}"#,
            // no pairs
            r#"{"kind": "screen", "dataset": "toy1"}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": []}"#,
            // malformed pairs
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1]]}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.2, 0.1]]}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.0, 0.1]]}"#,
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [["a", "b"]]}"#,
            // screen jobs have no grid fields
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1, 0.2]], "points": 5}"#,
            // bad theta
            r#"{"kind": "screen", "dataset": "toy1", "pairs": [[0.1, 0.2]], "theta": ["x"]}"#,
        ] {
            assert!(parse_line(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn parse_object_rejects_nested_batch() {
        assert!(parse_line(r#"{"batch": []}"#).is_err());
    }

    #[test]
    fn parse_train_request() {
        let r = parse_line(
            r#"{"kind": "train", "dataset": "toy1", "model": "wsvm", "scale": 0.2,
                "c": 0.75, "tol": 1e-7, "threads": 2, "storage": "csr",
                "save": "/tmp/m.pallas-model", "timings": false}"#,
        )
        .unwrap();
        assert!(!r.timings);
        let JobKind::Train(s) = r.kind else { panic!("expected train kind") };
        assert_eq!(s.dataset, "toy1");
        assert_eq!(s.model, crate::problem::Model::WeightedSvm);
        assert_eq!(s.c, 0.75);
        assert_eq!(s.solver.tol, 1e-7);
        assert_eq!(s.solver.threads, 2);
        assert_eq!(s.storage, crate::linalg::Storage::Csr);
        assert_eq!(s.save.as_deref(), Some("/tmp/m.pallas-model"));
    }

    #[test]
    fn parse_train_rejects_bad_input() {
        for bad in [
            // missing dataset / missing c
            r#"{"kind": "train", "c": 0.5}"#,
            r#"{"kind": "train", "dataset": "toy1"}"#,
            // bad c
            r#"{"kind": "train", "dataset": "toy1", "c": 0.0}"#,
            r#"{"kind": "train", "dataset": "toy1", "c": -1.0}"#,
            r#"{"kind": "train", "dataset": "toy1", "c": "big"}"#,
            // train has no grid fields
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "points": 5}"#,
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "scale": 2.0}"#,
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "model": "nope"}"#,
            // 1e400 overflows to inf, which would "converge" instantly
            r#"{"kind": "train", "dataset": "toy1", "c": 0.5, "tol": 1e400}"#,
        ] {
            assert!(parse_line(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn parse_predict_request() {
        let r = parse_line(
            r#"{"kind": "predict", "model_id": "svm-abc", "rows": [[1.0, 2.0], [3, 4]],
                "threads": 0, "support_only": true, "timings": false}"#,
        )
        .unwrap();
        let JobKind::Predict(s) = r.kind else { panic!("expected predict kind") };
        assert!(matches!(s.model, super::super::job::ModelRef::Id(ref id) if id == "svm-abc"));
        assert!(s.support_only);
        assert_eq!(s.threads, 0);
        let super::super::job::PredictInput::Rows { flat, width } = s.input else {
            panic!("expected inline rows")
        };
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(width, 2);

        let r = parse_line(
            r#"{"kind": "predict", "model_file": "m.pallas-model",
                "dataset": "toy2", "scale": 0.1, "storage": "dense"}"#,
        )
        .unwrap();
        let JobKind::Predict(s) = r.kind else { panic!("expected predict kind") };
        assert!(matches!(s.model, super::super::job::ModelRef::File(_)));
        assert!(matches!(
            s.input,
            super::super::job::PredictInput::Dataset { ref name, scale, .. }
                if name == "toy2" && scale == 0.1
        ));
    }

    #[test]
    fn parse_predict_rejects_bad_input() {
        for bad in [
            // no model reference / both
            r#"{"kind": "predict", "rows": [[1.0]]}"#,
            r#"{"kind": "predict", "model_id": "a", "model_file": "b", "rows": [[1.0]]}"#,
            // no input / both
            r#"{"kind": "predict", "model_id": "a"}"#,
            r#"{"kind": "predict", "model_id": "a", "rows": [[1.0]], "dataset": "toy1"}"#,
            // malformed rows
            r#"{"kind": "predict", "model_id": "a", "rows": []}"#,
            r#"{"kind": "predict", "model_id": "a", "rows": [[]]}"#,
            r#"{"kind": "predict", "model_id": "a", "rows": [[1.0], [1.0, 2.0]]}"#,
            r#"{"kind": "predict", "model_id": "a", "rows": [["x"]]}"#,
            r#"{"kind": "predict", "model_id": "a", "rows": 5}"#,
            // dataset-only fields alongside inline rows
            r#"{"kind": "predict", "model_id": "a", "rows": [[1.0]], "scale": 0.5}"#,
            r#"{"kind": "predict", "model_id": "a", "rows": [[1.0]], "storage": "csr"}"#,
            // unknown field
            r#"{"kind": "predict", "model_id": "a", "rows": [[1.0]], "points": 3}"#,
        ] {
            assert!(parse_line(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn parse_cache_request() {
        let r = parse_line(r#"{"kind": "cache"}"#).unwrap();
        let JobKind::Cache(s) = r.kind else { panic!("expected cache kind") };
        assert!(matches!(s.op, super::super::job::CacheOp::List));

        let r = parse_line(
            r#"{"kind": "cache", "op": "evict", "target": "model", "model_id": "svm-1"}"#,
        )
        .unwrap();
        let JobKind::Cache(s) = r.kind else { panic!("expected cache kind") };
        assert!(matches!(s.op, super::super::job::CacheOp::EvictModel(ref id) if id == "svm-1"));

        let r = parse_line(
            r#"{"kind": "cache", "op": "evict", "target": "instance",
                "dataset": "toy1", "model": "svm", "scale": 0.05}"#,
        )
        .unwrap();
        let JobKind::Cache(s) = r.kind else { panic!("expected cache kind") };
        assert!(matches!(s.op, super::super::job::CacheOp::EvictInstance(_)));

        for bad in [
            r#"{"kind": "cache", "op": "flush"}"#,
            r#"{"kind": "cache", "op": "evict"}"#,
            r#"{"kind": "cache", "op": "evict", "target": "model"}"#,
            r#"{"kind": "cache", "op": "evict", "target": "instance"}"#,
            r#"{"kind": "cache", "nonsense": 1}"#,
            // selectors that don't belong to the chosen op must not be
            // silently ignored (a typo'd evict would degrade to a list)
            r#"{"kind": "cache", "model_id": "svm-1"}"#,
            r#"{"kind": "cache", "dataset": "toy1", "scale": 0.1}"#,
            r#"{"kind": "cache", "op": "evict", "target": "model", "model_id": "m", "dataset": "toy1"}"#,
            r#"{"kind": "cache", "op": "evict", "target": "instance", "dataset": "toy1", "model_id": "m"}"#,
        ] {
            assert!(parse_line(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn serve_train_predict_cache_round_trip() {
        let mut svc = ScreeningService::new(1); // 1 worker ⇒ in-order execution
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_svc_train_{}.pallas-model", std::process::id()));
        let input = format!(
            concat!(
                r#"{{"kind": "train", "dataset": "toy1", "scale": 0.03, "c": 0.5, "tol": 1e-6, "save": "{}", "timings": false}}"#,
                "\n",
                r#"{{"kind": "cache", "timings": false}}"#,
                "\n"
            ),
            p.display()
        );
        let mut out = Vec::new();
        svc.serve(input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "{text}");
        let train = parse_json(lines[0]).unwrap();
        assert_eq!(train.get("ok").unwrap().as_bool(), Some(true), "{text}");
        assert_eq!(train.get("kind").unwrap().as_str(), Some("train"));
        let model_id = train.get("model_id").unwrap().as_str().unwrap().to_string();
        assert!(train.get("solve_secs").is_none(), "timings stripped");
        let cache_list = parse_json(lines[1]).unwrap();
        assert_eq!(cache_list.get("instances").unwrap().as_array().unwrap().len(), 1);
        assert_eq!(cache_list.get("models").unwrap().as_array().unwrap().len(), 1);
        assert!(p.exists(), "artifact persisted");

        // predict by resident id AND from the artifact file: identical
        // scores, byte for byte, and a double-run of the file variant is
        // byte-identical too
        let by_id = format!(
            r#"{{"kind": "predict", "model_id": "{model_id}", "dataset": "toy1", "scale": 0.03, "timings": false}}"#
        );
        let by_file = format!(
            r#"{{"kind": "predict", "model_file": "{}", "dataset": "toy1", "scale": 0.03, "timings": false}}"#,
            p.display()
        );
        let serve_one = |svc: &mut ScreeningService, line: &str| -> String {
            let mut out = Vec::new();
            svc.serve(line.as_bytes(), &mut out).unwrap();
            String::from_utf8(out).unwrap()
        };
        let a = serve_one(&mut svc, &by_id);
        let b = serve_one(&mut svc, &by_file);
        let c = serve_one(&mut svc, &by_file);
        // ids increment across submissions; everything else must be
        // byte-identical between the two file-loaded runs
        let strip_id = |text: &str| {
            let Json::Object(mut o) = parse_json(text.lines().next().unwrap()).unwrap() else {
                panic!("not an object: {text}")
            };
            o.remove("id");
            Json::Object(o).to_string()
        };
        assert_eq!(strip_id(&b), strip_id(&c), "double run must be byte-identical");
        let ja = parse_json(a.lines().next().unwrap()).unwrap();
        let jb = parse_json(b.lines().next().unwrap()).unwrap();
        assert_eq!(ja.get("ok").unwrap().as_bool(), Some(true), "{a}");
        assert_eq!(jb.get("ok").unwrap().as_bool(), Some(true), "{b}");
        assert_eq!(
            ja.get("scores").unwrap().to_string(),
            jb.get("scores").unwrap().to_string(),
            "resident and file-loaded scoring agree byte for byte"
        );
        assert!(ja.get("labels").is_some(), "svm predictions carry labels");

        // evict the model, then predict-by-id fails cleanly
        let evict = format!(
            r#"{{"kind": "cache", "op": "evict", "target": "model", "model_id": "{model_id}", "timings": false}}"#
        );
        let e = serve_one(&mut svc, &evict);
        let je = parse_json(e.lines().next().unwrap()).unwrap();
        assert_eq!(je.get("evicted").unwrap().as_bool(), Some(true));
        let miss = serve_one(&mut svc, &by_id);
        let jm = parse_json(miss.lines().next().unwrap()).unwrap();
        assert_eq!(jm.get("ok").unwrap().as_bool(), Some(false), "{miss}");
        std::fs::remove_file(&p).ok();
        svc.shutdown();
    }

    #[test]
    fn preload_warms_the_instance_cache() {
        let svc = ScreeningService::new(1);
        let report = svc.preload(&["toy1", "houses", "no-such-set"], 0.03);
        assert_eq!(report.len(), 3);
        assert!(matches!(report[0].1, Ok((crate::problem::Model::Svm, _, _))), "{report:?}");
        // houses is a regression set — preloads under the LAD key,
        // chosen by peek_task, so no failed trial build is ever counted
        assert!(matches!(report[1].1, Ok((crate::problem::Model::Lad, _, _))), "{report:?}");
        assert!(report[2].1.is_err());
        assert_eq!(svc.cache().len(), 2);
        assert_eq!(
            svc.metrics().counter("instance_cache_errors").get(),
            1,
            "only the genuinely unknown set counts an error"
        );
        assert_eq!(svc.metrics().counter("instance_cache_misses").get(), 2);
        // a follow-up request for the preloaded set hits
        let before = svc.metrics().counter("instance_cache_hits").get();
        svc.cache()
            .get_or_build(
                &super::CacheKey::new("toy1", crate::problem::Model::Svm, crate::linalg::Storage::Auto, 0.03),
                svc.metrics(),
            )
            .unwrap();
        assert_eq!(svc.metrics().counter("instance_cache_hits").get(), before + 1);
        svc.shutdown();
    }

    #[test]
    fn serve_round_trip() {
        let mut svc = ScreeningService::new(2);
        let input = br#"
# a comment line
{"dataset": "toy1", "scale": 0.03, "points": 4, "tol": 1e-5}
{"dataset": "no-such", "points": 4}
"#;
        let mut out = Vec::new();
        svc.serve(&input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let ok_count = lines
            .iter()
            .filter(|l| parse_json(l).unwrap().get("ok").unwrap().as_bool() == Some(true))
            .count();
        assert_eq!(ok_count, 1, "{text}");
        assert_eq!(svc.metrics().counter("jobs_done").get(), 2);
        svc.shutdown();
    }

    #[test]
    fn serve_answers_in_input_order() {
        let mut svc = ScreeningService::new(3);
        // a heavyweight first job and featherweight later ones: with
        // completion-order framing the cheap jobs would answer first
        let input = br#"
{"dataset": "toy1", "scale": 0.2, "points": 12, "tol": 1e-7, "timings": false}
{"dataset": "toy2", "scale": 0.03, "points": 4, "tol": 1e-4, "timings": false}
{"not json
{"dataset": "toy3", "scale": 0.03, "points": 4, "tol": 1e-4, "timings": false}
"#;
        let mut out = Vec::new();
        svc.serve(&input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "{text}");
        let ds = |l: &str| {
            parse_json(l)
                .unwrap()
                .get("dataset")
                .and_then(|v| v.as_str().map(str::to_string))
        };
        assert_eq!(ds(lines[0]).as_deref(), Some("toy1"));
        assert_eq!(ds(lines[1]).as_deref(), Some("toy2"));
        assert_eq!(ds(lines[2]), None, "parse error line");
        assert_eq!(ds(lines[3]).as_deref(), Some("toy3"));
        svc.shutdown();
    }

    #[test]
    fn encode_response_contains_series() {
        let outcome = JobOutcome {
            id: 7,
            timings: true,
            result: Ok(JobReply::Path(super::super::job::JobSummary {
                dataset: "d".into(),
                model: "svm".into(),
                rule: "dvi".into(),
                l: 10,
                steps: 2,
                mean_rejection: 0.5,
                rejection_lo: vec![0.0, 0.4],
                rejection_hi: vec![0.0, 0.1],
                grid: vec![0.1, 1.0],
                init_secs: 0.01,
                screen_secs: 0.001,
                total_secs: 0.05,
                total_updates: 123,
                worst_violation: Some(1e-9),
            })),
        };
        let s = ScreeningService::encode_response(&outcome);
        let j = parse_json(&s).unwrap();
        assert_eq!(j.get("id").unwrap().as_int(), Some(7));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("rejection_lo").unwrap().as_array().unwrap().len(), 2);
        assert!(j.get("total_secs").is_some());

        // timings off strips every wall-clock field
        let mut quiet = outcome.clone();
        quiet.timings = false;
        let j = parse_json(&ScreeningService::encode_response(&quiet)).unwrap();
        assert!(j.get("total_secs").is_none());
        assert!(j.get("init_secs").is_none());
        assert!(j.get("screen_secs").is_none());
        assert!(j.get("mean_rejection").is_some());
    }
}
