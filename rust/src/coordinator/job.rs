//! Job definition and execution: one job = one path run.

use crate::config::RunConfig;
use crate::data::registry;
use crate::path::{PathConfig, PathOutput, PathRunner};
use crate::problem::Model;
use crate::screening::RuleKind;

/// A scheduled unit of work.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: u64,
    pub run: RunConfig,
}

/// Result envelope (jobs never panic the pool; failures are data).
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub id: u64,
    pub result: Result<JobSummary, String>,
}

/// What the coordinator keeps from a finished path run (the full
/// [`PathOutput`] can be large; jobs keep the summary plus the series the
/// reports need).
#[derive(Clone, Debug)]
pub struct JobSummary {
    pub dataset: String,
    pub model: String,
    pub rule: String,
    pub l: usize,
    pub steps: usize,
    pub mean_rejection: f64,
    pub rejection_lo: Vec<f64>,
    pub rejection_hi: Vec<f64>,
    pub grid: Vec<f64>,
    pub init_secs: f64,
    pub screen_secs: f64,
    pub total_secs: f64,
    pub total_updates: u64,
    pub worst_violation: Option<f64>,
}

impl JobSummary {
    pub fn from_output(out: &PathOutput) -> JobSummary {
        let (lo, hi) = out.rejection_series();
        JobSummary {
            dataset: out.dataset.clone(),
            model: format!("{:?}", out.model).to_lowercase(),
            rule: out.rule.name().to_string(),
            l: out.l,
            steps: out.steps.len(),
            mean_rejection: out.mean_rejection(),
            rejection_lo: lo,
            rejection_hi: hi,
            grid: out.steps.iter().map(|s| s.c).collect(),
            init_secs: out.init_secs,
            screen_secs: out.screen_secs,
            total_secs: out.total_secs,
            total_updates: out.total_updates(),
            worst_violation: out.worst_violation(),
        }
    }
}

/// Build the runner from a config and execute. `use_pjrt` is honored when
/// the artifacts are present; otherwise the job falls back to the native
/// backend (recorded in the summary via the runner's backend name).
pub fn run_job(spec: &JobSpec) -> JobOutcome {
    let result = run_inner(&spec.run);
    JobOutcome { id: spec.id, result }
}

fn run_inner(cfg: &RunConfig) -> Result<JobSummary, String> {
    let model = Model::parse(&cfg.model).ok_or_else(|| format!("bad model `{}`", cfg.model))?;
    let rule = RuleKind::parse(&cfg.rule).ok_or_else(|| format!("bad rule `{}`", cfg.rule))?;
    let storage = crate::linalg::Storage::parse(&cfg.storage)
        .ok_or_else(|| format!("bad storage `{}` (dense | csr | auto)", cfg.storage))?;
    let ds = registry::resolve_storage(&cfg.dataset, cfg.scale, model.expected_task(), storage)?;
    if ds.task != model.expected_task() {
        return Err(format!(
            "dataset `{}` is a {:?} set but model `{}` expects {:?}",
            cfg.dataset,
            ds.task,
            cfg.model,
            model.expected_task()
        ));
    }
    if rule == RuleKind::Ssnsv || rule == RuleKind::Essnsv {
        if model == Model::Lad {
            return Err("SSNSV/ESSNSV are SVM-only rules".into());
        }
    }
    let path_cfg = PathConfig {
        grid: cfg.grid.values(),
        solver: cfg.solver.clone(),
        validate: cfg.validate,
        warm_start: true,
    };
    let mut runner = PathRunner::new(model, path_cfg, rule);
    if cfg.use_pjrt && rule == RuleKind::DviW {
        match crate::runtime::PjrtScreener::from_default_dir() {
            Ok(s) => runner = runner.with_backend(Box::new(s)),
            Err(e) => eprintln!("[job] pjrt unavailable ({e}); using native scan"),
        }
    }
    let out = runner.run(&ds);
    Ok(JobSummary::from_output(&out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GridConfig, SolverConfig};

    fn quick_run(dataset: &str, model: &str, rule: &str) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: dataset.into(),
            scale: 0.05,
            rule: rule.into(),
            storage: "auto".into(),
            grid: GridConfig { c_min: 0.01, c_max: 10.0, points: 6 },
            solver: SolverConfig { tol: 1e-6, max_outer: 50_000, ..Default::default() },
            use_pjrt: false,
            validate: true,
        }
    }

    #[test]
    fn svm_job_runs() {
        let out = run_job(&JobSpec { id: 1, run: quick_run("toy1", "svm", "dvi") });
        let s = out.result.expect("job failed");
        assert_eq!(s.steps, 6);
        assert!(s.mean_rejection > 0.0);
        assert!(s.worst_violation.unwrap() < 1e-4);
    }

    #[test]
    fn lad_job_runs() {
        let mut run = quick_run("houses", "lad", "dvi");
        run.grid.points = 16; // finer grid so DVI's radius is meaningful
        let out = run_job(&JobSpec { id: 2, run });
        let s = out.result.expect("job failed");
        assert_eq!(s.model, "lad");
        assert!(s.mean_rejection > 0.0, "rejection {}", s.mean_rejection);
    }

    #[test]
    fn bad_config_is_error_not_panic() {
        let mut cfg = quick_run("toy1", "svm", "dvi");
        cfg.dataset = "no-such-set".into();
        let out = run_job(&JobSpec { id: 3, run: cfg });
        assert!(out.result.is_err());
    }

    #[test]
    fn ssnsv_on_lad_is_error() {
        // SSNSV is SVM-only; the instance builder panics, but job
        // resolution catches the model/task mismatch first for LAD sets —
        // exercise the rule mismatch path with an SVM dataset instead.
        let out = run_job(&JobSpec { id: 4, run: quick_run("magic", "svm", "ssnsv") });
        assert!(out.result.is_err()); // magic is a regression set
    }
}
