//! Job definition and execution: a job is a full path run, a lightweight
//! batch-screening pass against a cached instance, a one-C train that
//! persists a model artifact, a batch prediction against a cached model,
//! or a cache introspection op.

use super::cache::{CacheKey, InstanceCache, InstanceEntryInfo, ModelCache, ModelEntryInfo};
use crate::config::{RunConfig, SolverConfig};
use crate::linalg::Storage;
use crate::metrics::Registry;
use crate::model::{self, format as model_format, PredictOptions, TrainedModel};
use crate::path::{PathConfig, PathOutput, PathRunner};
use crate::problem::{Instance, Model};
use crate::screening::{dvi, RuleExpr, RuleKind, ScreenReport, ScreeningRule, StepContext};
use crate::solver::CdSolver;
use std::sync::Arc;
use std::time::Instant;

/// What a job does.
#[derive(Clone, Debug)]
pub enum JobKind {
    /// Screen → reduce → solve along a full C-grid (the original job).
    Path(RunConfig),
    /// Many DVI screening passes against one cached instance.
    Screen(ScreenSpec),
    /// Solve at one C, extract a [`TrainedModel`], make it resident (and
    /// optionally persist the `.pallas-model` artifact).
    Train(TrainSpec),
    /// Score a batch of rows against a resident or on-disk model.
    Predict(PredictSpec),
    /// Introspect/evict the instance and model caches.
    Cache(CacheSpec),
    /// Snapshot every metrics family (counters, gauges, histograms, the
    /// process-wide solver-pool counters) in one response — the scrape
    /// endpoint for a live server.
    Stats,
}

/// A scheduled unit of work.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: u64,
    pub kind: JobKind,
    /// Emit wall-clock fields in the response. The service's
    /// `"timings": false` turns this off so responses are byte-for-byte
    /// deterministic (the batch/single equivalence the protocol promises
    /// — and the smoke test diffs — only holds for deterministic bytes).
    pub timings: bool,
    /// Run only after the job with this id has completed: the pool parks
    /// the spec until that outcome is delivered (success OR failure), so
    /// an in-session predict can depend on a same-session train without
    /// `--workers 1`. Must name an already-submitted job — the service
    /// validates `after < id` at parse, and the pool fails a dangling or
    /// self-referential edge out immediately rather than parking it.
    pub after: Option<u64>,
}

impl JobSpec {
    pub fn path(id: u64, run: RunConfig) -> JobSpec {
        JobSpec { id, kind: JobKind::Path(run), timings: true, after: None }
    }

    pub fn screen(id: u64, spec: ScreenSpec) -> JobSpec {
        JobSpec { id, kind: JobKind::Screen(spec), timings: true, after: None }
    }

    pub fn train(id: u64, spec: TrainSpec) -> JobSpec {
        JobSpec { id, kind: JobKind::Train(spec), timings: true, after: None }
    }

    pub fn predict(id: u64, spec: PredictSpec) -> JobSpec {
        JobSpec { id, kind: JobKind::Predict(spec), timings: true, after: None }
    }

    /// Gate this job on the completion of an earlier one.
    pub fn after(mut self, dep: u64) -> JobSpec {
        self.after = Some(dep);
        self
    }
}

/// A batch-screening job: screen each `(c_prev, c_next)` pair against the
/// cached `(dataset, model, storage, scale)` instance. The anchor dual
/// point θ*(c_prev) comes from `theta` (caller-supplied, anchored at the
/// first pair's `c_prev`) or from the solver (anchors are solved on
/// demand, warm-starting from the most recent one, and reused across
/// pairs sharing a `c_prev` via a small bounded LRU memo). This is the
/// paper's sequential-path amortization as a service primitive: one
/// resident instance, many screening scans.
#[derive(Clone, Debug)]
pub struct ScreenSpec {
    pub dataset: String,
    pub model: Model,
    pub scale: f64,
    pub storage: crate::linalg::Storage,
    /// Screening rule expression (same vocabulary as path jobs,
    /// `+`-composable — e.g. `"dvi"` or `"dvi+essnsv"`). Defaults to
    /// `"dvi"`, which keeps the pre-rule wire behavior bit-for-bit.
    pub rule: String,
    /// `(c_prev, c_next)` pairs, each requiring `0 < c_prev < c_next`.
    pub pairs: Vec<(f64, f64)>,
    /// Optional θ*(pairs[0].0) warm start (length l). Screening safety
    /// holds when this is the optimum at that C — the service trusts the
    /// caller (e.g. a θ returned by an earlier screen response).
    pub theta: Option<Vec<f64>>,
    /// tol/threads for anchor solves and the sharded scan.
    pub solver: SolverConfig,
    /// Echo the most advanced anchor θ in the response (l floats — off by
    /// default to keep lines small).
    pub return_theta: bool,
}

/// Result envelope (jobs never panic the pool; failures are data).
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub id: u64,
    /// Copied from [`JobSpec::timings`] so the response encoder knows
    /// whether to emit wall-clock fields.
    pub timings: bool,
    pub result: Result<JobReply, String>,
}

/// Successful job payload, by kind.
#[derive(Clone, Debug)]
pub enum JobReply {
    Path(JobSummary),
    Screen(ScreenSummary),
    Train(TrainSummary),
    Predict(PredictSummary),
    Cache(CacheSummary),
    Stats(StatsSummary),
}

impl JobReply {
    pub fn as_path(&self) -> Option<&JobSummary> {
        match self {
            JobReply::Path(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_screen(&self) -> Option<&ScreenSummary> {
        match self {
            JobReply::Screen(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_train(&self) -> Option<&TrainSummary> {
        match self {
            JobReply::Train(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_predict(&self) -> Option<&PredictSummary> {
        match self {
            JobReply::Predict(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_cache(&self) -> Option<&CacheSummary> {
        match self {
            JobReply::Cache(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_stats(&self) -> Option<&StatsSummary> {
        match self {
            JobReply::Stats(s) => Some(s),
            _ => None,
        }
    }
}

/// What the coordinator keeps from a finished path run (the full
/// [`PathOutput`] can be large; jobs keep the summary plus the series the
/// reports need).
#[derive(Clone, Debug)]
pub struct JobSummary {
    pub dataset: String,
    pub model: String,
    pub rule: String,
    pub l: usize,
    pub steps: usize,
    pub mean_rejection: f64,
    pub rejection_lo: Vec<f64>,
    pub rejection_hi: Vec<f64>,
    pub grid: Vec<f64>,
    pub init_secs: f64,
    pub screen_secs: f64,
    pub total_secs: f64,
    pub total_updates: u64,
    pub worst_violation: Option<f64>,
}

impl JobSummary {
    pub fn from_output(out: &PathOutput) -> JobSummary {
        let (lo, hi) = out.rejection_series();
        JobSummary {
            dataset: out.dataset.clone(),
            model: out.model.wire_name(),
            rule: out.rule.name(),
            l: out.l,
            steps: out.steps.len(),
            mean_rejection: out.mean_rejection(),
            rejection_lo: lo,
            rejection_hi: hi,
            grid: out.steps.iter().map(|s| s.c).collect(),
            init_secs: out.init_secs,
            screen_secs: out.screen_secs,
            total_secs: out.total_secs,
            total_updates: out.total_updates(),
            worst_violation: out.worst_violation(),
        }
    }
}

/// One screened pair's outcome.
#[derive(Clone, Debug)]
pub struct ScreenPairResult {
    pub c_prev: f64,
    pub c_next: f64,
    pub n_lo: usize,
    pub n_hi: usize,
    pub free: usize,
}

/// What a screening job returns.
#[derive(Clone, Debug)]
pub struct ScreenSummary {
    pub dataset: String,
    pub model: String,
    /// The rule expression the scans used (echoed so clients can tell
    /// composed responses apart).
    pub rule: String,
    pub l: usize,
    pub pairs: Vec<ScreenPairResult>,
    /// Anchor solves this job paid for (0 when every pair reused the
    /// supplied θ).
    pub anchor_solves: usize,
    pub solve_secs: f64,
    pub screen_secs: f64,
    /// θ*(c_prev) of the last pair processed, when `return_theta` — lets
    /// a client chain screening sessions without re-solving.
    pub theta: Option<Vec<f64>>,
    /// The C the returned θ anchors at.
    pub theta_c: Option<f64>,
}

impl ScreenSummary {
    pub fn mean_rejection(&self) -> f64 {
        if self.pairs.is_empty() || self.l == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .pairs
            .iter()
            .map(|p| (p.n_lo + p.n_hi) as f64 / self.l as f64)
            .sum();
        sum / self.pairs.len() as f64
    }
}

/// A train job: solve the boxed QP at one C against the cached instance,
/// extract the [`TrainedModel`], insert it into the pool's model cache,
/// and optionally persist the `.pallas-model` artifact.
#[derive(Clone, Debug)]
pub struct TrainSpec {
    pub dataset: String,
    pub model: Model,
    pub scale: f64,
    pub storage: Storage,
    /// The regularization parameter to solve at (finite, > 0).
    pub c: f64,
    /// tol/threads for the solve (tol doubles as the KKT dead-band that
    /// classifies support vectors).
    pub solver: SolverConfig,
    /// Persist the artifact here after training.
    pub save: Option<String>,
    /// Persist the artifact into this model-registry directory as
    /// `<model_id>.pallas-model` (the serve layer maps `"persist": true`
    /// to its `--model-dir`); a restarted server re-loads it without
    /// retraining.
    pub persist_dir: Option<String>,
    /// Echo the full support-set indices in the summary (`dvi train
    /// --print-support`; the CI smoke leg diffs the parallel solver's
    /// support set against the serial one with this).
    pub report_support: bool,
}

/// What a train job reports.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    /// Deterministic model id ([`TrainedModel::id`]) — the handle predict
    /// requests address the resident model by.
    pub model_id: String,
    pub dataset: String,
    pub model: Model,
    /// Storage as REQUESTED — i.e. the instance-cache key's storage — so
    /// a `"kind": "cache"` evict built from this response matches the
    /// resident entry (the artifact's own resolved storage is part of
    /// the model id's digest and the `.pallas-model` header).
    pub storage: Storage,
    pub c: f64,
    pub l: usize,
    pub n: usize,
    /// Margin support vectors (KKT E-set) — the paper's "the classifier
    /// depends on few instances" number.
    pub support: usize,
    /// Rows with θᵢ ≠ 0 (what the artifact stores in θ-form).
    pub active: usize,
    /// Encoded artifact size in bytes.
    pub artifact_bytes: usize,
    /// Where the artifact was persisted, when requested.
    pub saved: Option<String>,
    /// Registry path the artifact landed at under [`TrainSpec::persist_dir`].
    pub persisted: Option<String>,
    /// Ascending E-set indices, when [`TrainSpec::report_support`].
    pub support_indices: Option<Vec<u32>>,
    pub solve_secs: f64,
}

/// Which model a predict job scores against.
#[derive(Clone, Debug)]
pub enum ModelRef {
    /// A model resident in the pool's cache (trained earlier, or loaded).
    Id(String),
    /// A `.pallas-model` artifact on disk (loaded, then made resident).
    File(String),
}

/// What a predict job scores.
#[derive(Clone, Debug)]
pub enum PredictInput {
    /// Inline dense rows, already flattened row-major (`width` > 0
    /// columns; rectangularity and finiteness validated at parse — the
    /// flat form avoids a 100k-row batch carrying 100k Vec headers
    /// through every JobSpec clone plus a second full copy at scoring).
    Rows { flat: Vec<f64>, width: usize },
    /// A registry dataset (resolved in the requested storage; only its X
    /// matrix is used).
    Dataset { name: String, scale: f64, storage: Storage },
}

/// A predict job: score a batch against a model.
#[derive(Clone, Debug)]
pub struct PredictSpec {
    pub model: ModelRef,
    pub input: PredictInput,
    /// Sharded-scoring worker threads (scores identical for any value).
    pub threads: usize,
    /// Score via the θ-form support payload (bit-identical; see
    /// [`crate::model::PredictOptions`]).
    pub support_only: bool,
}

/// What a predict job reports. Scores are in input-row order and
/// byte-deterministic (independent of threads, storage, and residency).
#[derive(Clone, Debug)]
pub struct PredictSummary {
    pub model_id: String,
    pub model: Model,
    pub rows: usize,
    pub support_only: bool,
    pub scores: Vec<f64>,
    /// ±1 labels for classification models, absent for LAD.
    pub labels: Option<Vec<i8>>,
    pub predict_secs: f64,
}

/// Cache introspection ops (`"kind": "cache"`).
#[derive(Clone, Debug)]
pub enum CacheOp {
    /// List resident entries of both caches.
    List,
    /// Evict one instance entry by its full key.
    EvictInstance(CacheKey),
    /// Evict one model by id.
    EvictModel(String),
}

#[derive(Clone, Debug)]
pub struct CacheSpec {
    pub op: CacheOp,
}

/// What a cache job reports: the (post-op) resident entries, plus
/// whether an evict op actually removed something.
#[derive(Clone, Debug)]
pub struct CacheSummary {
    pub instances: Vec<InstanceEntryInfo>,
    pub models: Vec<ModelEntryInfo>,
    pub evicted: Option<bool>,
}

/// What a stats job reports (`"kind": "stats"`): one point-in-time
/// snapshot of every metrics family in the pool's registry, plus the
/// process-wide solver-pool counters. The snapshot races in-flight jobs
/// exactly like `"kind": "cache"` does, so reproducible values need
/// `--workers 1` or a quiesced session.
#[derive(Clone, Debug)]
pub struct StatsSummary {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    /// Histogram summaries — timing-derived, so the encoder only emits
    /// them under `"timings": true`.
    pub histograms: Vec<crate::metrics::HistStat>,
    pub pool: crate::linalg::par::PoolStats,
}

/// Execute a job without resident caches: transient zero-budget caches
/// make this path identical to the pooled one minus residency. The CLI's
/// one-shot `dvi path` / `dvi train` / `dvi predict` use it.
pub fn run_job(spec: &JobSpec) -> JobOutcome {
    run_job_cached(spec, &InstanceCache::new(0), &ModelCache::new(0), &Registry::default())
}

/// Execute a job against the pool's resident caches.
pub fn run_job_cached(
    spec: &JobSpec,
    cache: &InstanceCache,
    models: &ModelCache,
    metrics: &Registry,
) -> JobOutcome {
    // the job-body span parents onto the request span a serving front-end
    // derived from this job id (absent for CLI one-shots — harmless: the
    // exporter only checks pairs)
    let mut span = crate::obs::Span::enter_under("job", crate::obs::request_span_id(spec.id));
    span.attr("job_id", spec.id as f64);
    span.attr_str(
        "job_kind",
        match &spec.kind {
            JobKind::Path(_) => "path",
            JobKind::Screen(_) => "screen",
            JobKind::Train(_) => "train",
            JobKind::Predict(_) => "predict",
            JobKind::Cache(_) => "cache",
            JobKind::Stats => "stats",
        },
    );
    let result = match &spec.kind {
        JobKind::Path(cfg) => run_path(cfg, cache, metrics).map(JobReply::Path),
        JobKind::Screen(s) => run_screen(s, cache, metrics).map(JobReply::Screen),
        JobKind::Train(s) => run_train(s, cache, models, metrics).map(JobReply::Train),
        JobKind::Predict(s) => run_predict(s, models, metrics).map(JobReply::Predict),
        JobKind::Cache(s) => run_cache(s, cache, models, metrics).map(JobReply::Cache),
        JobKind::Stats => Ok(JobReply::Stats(run_stats(metrics))),
    };
    JobOutcome { id: spec.id, timings: spec.timings, result }
}

/// Build the runner from a config and execute. `use_pjrt` is honored when
/// the artifacts are present; otherwise the job falls back to the native
/// backend.
fn run_path(
    cfg: &RunConfig,
    cache: &InstanceCache,
    metrics: &Registry,
) -> Result<JobSummary, String> {
    let model = Model::parse(&cfg.model).ok_or_else(|| format!("bad model `{}`", cfg.model))?;
    let rule = RuleExpr::parse(&cfg.rule)?;
    let storage = crate::linalg::Storage::parse(&cfg.storage)
        .ok_or_else(|| format!("bad storage `{}` (dense | csr | auto)", cfg.storage))?;
    if rule.svm_only() && model == Model::Lad {
        return Err("SSNSV/ESSNSV are SVM-only rules".into());
    }
    let key = CacheKey::new(&cfg.dataset, model, storage, cfg.scale);
    let inst = cache.get_or_build(&key, metrics)?;
    let path_cfg = PathConfig {
        grid: cfg.grid.values(),
        solver: cfg.solver.clone(),
        validate: cfg.validate,
        warm_start: true,
    };
    let single_dvi = rule.single() == Some(RuleKind::DviW);
    let mut runner = PathRunner::new_expr(model, path_cfg, rule);
    if cfg.use_pjrt && single_dvi {
        match crate::runtime::PjrtScreener::from_default_dir() {
            Ok(s) => runner = runner.with_backend(Box::new(s)),
            Err(e) => eprintln!("[job] pjrt unavailable ({e}); using native scan"),
        }
    }
    let out = runner.run_shared(&inst);
    Ok(JobSummary::from_output(&out))
}

/// Execute a screening job: fetch the cached instance once, then for each
/// `(c_prev, c_next)` pair resolve the anchor θ*(c_prev) (supplied, or
/// solved and memoized) and screen with the requested rule expression.
/// The plain `"dvi"` rule keeps the original sharded w-form scan
/// bit-for-bit; any other expression goes through the composable engine.
fn run_screen(
    spec: &ScreenSpec,
    cache: &InstanceCache,
    metrics: &Registry,
) -> Result<ScreenSummary, String> {
    if spec.pairs.is_empty() {
        return Err("screen: `pairs` must be non-empty".into());
    }
    for &(a, b) in &spec.pairs {
        if !(a.is_finite() && b.is_finite() && a > 0.0 && b > a) {
            return Err(format!("screen: pair ({a}, {b}) must satisfy 0 < c_prev < c_next"));
        }
    }
    let rule = RuleExpr::parse(&spec.rule)?;
    if rule.svm_only() && spec.model == Model::Lad {
        return Err("SSNSV/ESSNSV are SVM-only rules".into());
    }
    let key = CacheKey::new(&spec.dataset, spec.model, spec.storage, spec.scale);
    let inst: Arc<Instance> = cache.get_or_build(&key, metrics)?;
    let l = inst.len();

    // Anchors solved or supplied so far, most-recently-used last:
    // (c_prev, θ, u = Zᵀθ). The memo is BOUNDED — each entry holds 2l
    // floats, so an unbounded memo over a max-size pairs list would hold
    // O(pairs·l) memory; only the latest anchor ever seeds a warm start,
    // and re-solving an evicted c_prev is merely slower, never wrong.
    const MAX_ANCHORS: usize = 8;
    let mut anchors: Vec<(f64, Vec<f64>, Vec<f64>)> = Vec::new();
    if let Some(t0) = &spec.theta {
        if t0.len() != l {
            return Err(format!("screen: theta has {} entries, instance has {l}", t0.len()));
        }
        if t0.iter().any(|v| !v.is_finite()) {
            return Err("screen: theta must be finite".into());
        }
        if !inst.in_box(t0, 1e-6) {
            return Err("screen: theta leaves the dual box [lo, hi]".into());
        }
        let u = inst.u_from_theta_axis(t0, spec.solver.shard_axis, spec.solver.threads);
        anchors.push((spec.pairs[0].0, t0.clone(), u));
    }

    let solver = CdSolver::new(spec.solver.clone());
    let mut anchor_solves = 0usize;
    let mut solve_secs = 0.0;
    let mut screen_secs = 0.0;
    let mut results = Vec::with_capacity(spec.pairs.len());

    // Plain `dvi` keeps the original fast path (bit-compatible with every
    // pre-rule client); anything else builds the composable engine once.
    let mut engine: Option<Box<dyn ScreeningRule>> =
        if rule.single() == Some(RuleKind::DviW) {
            None
        } else {
            let mut e = rule.build_axis(spec.solver.threads, spec.solver.shard_axis);
            let t = Instant::now();
            e.init(&inst, spec.solver.threads);
            screen_secs += t.elapsed().as_secs_f64();
            Some(e)
        };

    // SSNSV-family members need w*(C_max): pay one cold solve at the
    // largest target C in the batch (feasible for every smaller pair).
    let w_feasible: Option<Vec<f64>> = if rule.requires_cmax() {
        let c_max = spec.pairs.iter().map(|p| p.1).fold(f64::MIN, f64::max);
        let t = Instant::now();
        let r = solver.solve(&inst, c_max, inst.cold_start());
        solve_secs += t.elapsed().as_secs_f64();
        anchor_solves += 1;
        Some(inst.w_from_theta_axis(c_max, &r.theta, spec.solver.shard_axis, spec.solver.threads))
    } else {
        None
    };

    for &(c_prev, c_next) in &spec.pairs {
        if let Some(i) = anchors.iter().position(|(c, _, _)| *c == c_prev) {
            // mark most-recently-used by moving to the back
            let a = anchors.remove(i);
            anchors.push(a);
        } else {
            // warm-start from the most recent anchor (projected into the
            // box — it is feasible for every C)
            let warm = match anchors.last() {
                Some((_, t, _)) => {
                    let mut t = t.clone();
                    inst.project_box(&mut t);
                    t
                }
                None => inst.cold_start(),
            };
            let t = Instant::now();
            let r = solver.solve(&inst, c_prev, warm);
            solve_secs += t.elapsed().as_secs_f64();
            anchor_solves += 1;
            // recompute u = Zᵀθ exactly (the solver maintains its u
            // incrementally, with low-bit drift): the scan is then a
            // pure function of θ, so a θ echoed over the wire and fed
            // back reproduces decisions bit-for-bit
            let u = inst.u_from_theta_axis(&r.theta, spec.solver.shard_axis, spec.solver.threads);
            anchors.push((c_prev, r.theta, u));
            if anchors.len() > MAX_ANCHORS {
                anchors.remove(0); // least-recently-used
            }
        }
        let (_, theta_a, u) = anchors.last().expect("anchor just ensured");
        let t = Instant::now();
        let report = match engine.as_mut() {
            None => {
                // the fast path bypasses the Traced engine decorator, so
                // it carries its own span + telemetry
                let mut sp = crate::obs::Span::enter("screen_rows");
                sp.attr_str(
                    "shard_axis",
                    inst.pick_axis(spec.solver.shard_axis).name(),
                );
                let report = dvi::screen_w_par(&inst, c_prev, c_next, u, spec.solver.threads);
                let scanned = l as u64;
                let rejected = (report.n_lo + report.n_hi) as u64;
                crate::obs::telemetry::record_screen("dvi", scanned, rejected);
                sp.attr_str("rule", "dvi");
                sp.attr("rows_scanned", scanned as f64);
                sp.attr("rows_rejected", rejected as f64);
                sp.attr(
                    "rejection_rate",
                    if l == 0 { 0.0 } else { rejected as f64 / scanned as f64 },
                );
                report
            }
            Some(eng) => {
                let ctx = StepContext {
                    c_prev,
                    c_next,
                    theta_prev: theta_a,
                    u_prev: u,
                    w_feasible: w_feasible.as_deref(),
                };
                let region = eng.prepare(&inst, &ctx);
                ScreenReport::from_decisions(eng.screen_rows(&inst, &region, spec.solver.threads))
            }
        };
        screen_secs += t.elapsed().as_secs_f64();
        results.push(ScreenPairResult {
            c_prev,
            c_next,
            n_lo: report.n_lo,
            n_hi: report.n_hi,
            free: l - report.n_lo - report.n_hi,
        });
    }

    let (theta, theta_c) = if spec.return_theta {
        let (c, t, _) = anchors.last().expect("pairs is non-empty");
        (Some(t.clone()), Some(*c))
    } else {
        (None, None)
    };
    Ok(ScreenSummary {
        dataset: spec.dataset.clone(),
        model: spec.model.wire_name(),
        rule: rule.name(),
        l,
        pairs: results,
        anchor_solves,
        solve_secs,
        screen_secs,
        theta,
        theta_c,
    })
}

/// Execute a train job: resolve the cached instance, solve at C (cold
/// start — one C, no path), extract the artifact, persist/cache it.
fn run_train(
    spec: &TrainSpec,
    cache: &InstanceCache,
    models: &ModelCache,
    metrics: &Registry,
) -> Result<TrainSummary, String> {
    if !(spec.c.is_finite() && spec.c > 0.0) {
        return Err(format!("train: C must be finite and positive, got {}", spec.c));
    }
    let key = CacheKey::new(&spec.dataset, spec.model, spec.storage, spec.scale);
    let inst: Arc<Instance> = cache.get_or_build(&key, metrics)?;
    let t = Instant::now();
    let r = CdSolver::new(spec.solver.clone()).solve(&inst, spec.c, inst.cold_start());
    let solve_secs = t.elapsed().as_secs_f64();
    let trained = TrainedModel::from_solution_axis(
        &inst,
        &spec.dataset,
        spec.scale,
        spec.c,
        spec.solver.tol,
        &r.theta,
        spec.solver.shard_axis,
        spec.solver.threads,
    );
    let encoded = model_format::encode(&trained);
    if let Some(path) = &spec.save {
        std::fs::write(path, &encoded).map_err(|e| format!("train: save {path}: {e}"))?;
    }
    // registry persistence: the filename IS the deterministic model id,
    // so retraining the same problem overwrites (idempotent) instead of
    // accumulating duplicates, and a restarted server's registry scan
    // re-loads the artifact under the same resident id
    let persisted = match &spec.persist_dir {
        Some(dir) => {
            let path = std::path::Path::new(dir).join(format!("{}.pallas-model", trained.id()));
            std::fs::write(&path, &encoded)
                .map_err(|e| format!("train: persist {}: {e}", path.display()))?;
            Some(path.to_string_lossy().into_owned())
        }
        None => None,
    };
    let summary = TrainSummary {
        model_id: trained.id(),
        dataset: spec.dataset.clone(),
        model: trained.model,
        storage: spec.storage,
        c: spec.c,
        l: trained.l,
        n: trained.n(),
        support: trained.support.len(),
        active: trained.active.len(),
        artifact_bytes: encoded.len(),
        saved: spec.save.clone(),
        persisted,
        support_indices: spec.report_support.then(|| trained.support.clone()),
        solve_secs,
    };
    models.insert(Arc::new(trained), metrics);
    Ok(summary)
}

/// Execute a predict job: resolve the model (cache or artifact file),
/// materialize the input batch, run the sharded scoring pass.
fn run_predict(
    spec: &PredictSpec,
    models: &ModelCache,
    metrics: &Registry,
) -> Result<PredictSummary, String> {
    // resolve the model AND the id to echo: a by-id request already
    // carries the id string (the cache key it just matched), so only the
    // file path pays the O(n + active) content digest
    let (model, model_id): (Arc<TrainedModel>, String) = match &spec.model {
        ModelRef::Id(id) => (
            models.get(id, metrics).ok_or_else(|| {
                format!(
                    "predict: model `{id}` is not resident (train it first, \
                     or supply model_file)"
                )
            })?,
            id.clone(),
        ),
        ModelRef::File(path) => {
            let m = models.get_or_load(std::path::Path::new(path), metrics)?;
            let id = m.id();
            (m, id)
        }
    };
    let opts = PredictOptions { threads: spec.threads, support_only: spec.support_only };
    let t = Instant::now();
    let (scores, n_rows) = match &spec.input {
        // inline batches score straight off the parsed flat buffer —
        // zero copies on the serving path (scores_flat re-checks width)
        PredictInput::Rows { flat, width } => {
            let scores = model::scores_flat(&model, flat, *width, &opts)
                .map_err(|e| format!("predict: {e}"))?;
            let n = scores.len();
            (scores, n)
        }
        PredictInput::Dataset { name, scale, storage } => {
            // only the X matrix is scored, so resolution must not impose
            // the model's task on the input: the Regression hint accepts
            // any numeric labels (the hint only matters for `file:`
            // loads, where a Classification hint would reject a file
            // whose labels aren't ±1)
            let ds = crate::data::registry::resolve_storage(
                name,
                *scale,
                crate::data::Task::Regression,
                *storage,
            )?;
            let n = ds.x.rows();
            (model::scores(&model, &ds.x, &opts)?, n)
        }
    };
    // a non-finite score (input magnitudes overflowing f64) would
    // serialize as JSON null with ok:true and print as a literal "null"
    // line from the CLI — fail the request with a real error instead
    if let Some(i) = scores.iter().position(|s| !s.is_finite()) {
        return Err(format!(
            "predict: score for row {i} is not finite ({}) — input magnitudes overflow f64",
            scores[i]
        ));
    }
    let labels = model::predict::is_classifier(&model).then(|| model::labels(&scores));
    Ok(PredictSummary {
        model_id,
        model: model.model,
        rows: n_rows,
        support_only: spec.support_only,
        scores,
        labels,
        predict_secs: t.elapsed().as_secs_f64(),
    })
}

/// Snapshot every metrics family plus the process-wide solver-pool
/// counters (infallible — a scrape never errors).
fn run_stats(metrics: &Registry) -> StatsSummary {
    StatsSummary {
        counters: metrics.counters_snapshot(),
        gauges: metrics.gauges_snapshot(),
        histograms: metrics.histograms_snapshot(),
        pool: crate::linalg::par::pool_stats(),
    }
}

/// Execute a cache introspection/evict op against both resident caches.
fn run_cache(
    spec: &CacheSpec,
    cache: &InstanceCache,
    models: &ModelCache,
    metrics: &Registry,
) -> Result<CacheSummary, String> {
    let evicted = match &spec.op {
        CacheOp::List => None,
        CacheOp::EvictInstance(key) => Some(cache.evict_key(key, metrics)),
        CacheOp::EvictModel(id) => Some(models.evict(id, metrics)),
    };
    Ok(CacheSummary { instances: cache.snapshot(), models: models.snapshot(), evicted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GridConfig, SolverConfig};
    use crate::linalg::Storage;

    fn quick_run(dataset: &str, model: &str, rule: &str) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: dataset.into(),
            scale: 0.05,
            rule: rule.into(),
            storage: "auto".into(),
            grid: GridConfig { c_min: 0.01, c_max: 10.0, points: 6 },
            solver: SolverConfig { tol: 1e-6, max_outer: 50_000, ..Default::default() },
            use_pjrt: false,
            validate: true,
        }
    }

    fn quick_screen(dataset: &str, pairs: Vec<(f64, f64)>) -> ScreenSpec {
        ScreenSpec {
            dataset: dataset.into(),
            model: Model::Svm,
            scale: 0.05,
            storage: Storage::Auto,
            rule: "dvi".into(),
            pairs,
            theta: None,
            solver: SolverConfig { tol: 1e-6, ..Default::default() },
            return_theta: false,
        }
    }

    #[test]
    fn svm_job_runs() {
        let out = run_job(&JobSpec::path(1, quick_run("toy1", "svm", "dvi")));
        let r = out.result.expect("job failed");
        let s = r.as_path().unwrap();
        assert_eq!(s.steps, 6);
        assert!(s.mean_rejection > 0.0);
        assert!(s.worst_violation.unwrap() < 1e-4);
    }

    #[test]
    fn lad_job_runs() {
        let mut run = quick_run("houses", "lad", "dvi");
        run.grid.points = 16; // finer grid so DVI's radius is meaningful
        let out = run_job(&JobSpec::path(2, run));
        let r = out.result.expect("job failed");
        let s = r.as_path().unwrap();
        assert_eq!(s.model, "lad");
        assert!(s.mean_rejection > 0.0, "rejection {}", s.mean_rejection);
    }

    #[test]
    fn bad_config_is_error_not_panic() {
        let mut cfg = quick_run("toy1", "svm", "dvi");
        cfg.dataset = "no-such-set".into();
        let out = run_job(&JobSpec::path(3, cfg));
        assert!(out.result.is_err());
    }

    #[test]
    fn ssnsv_on_lad_is_error() {
        // SSNSV is SVM-only; the rule check fires before instance
        // resolution, and the regression-set/SVM mismatch errors cleanly
        // from the cache build either way.
        let out = run_job(&JobSpec::path(4, quick_run("magic", "svm", "ssnsv")));
        assert!(out.result.is_err()); // magic is a regression set
    }

    #[test]
    fn path_jobs_share_the_cached_instance() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let models = ModelCache::new(ModelCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        for (id, rule) in ["dvi", "dvi-theta", "none"].iter().enumerate() {
            let out = run_job_cached(
                &JobSpec::path(id as u64, quick_run("toy1", "svm", rule)),
                &cache,
                &models,
                &m,
            );
            assert!(out.result.is_ok(), "{rule}: {:?}", out.result);
        }
        assert_eq!(m.counter("instance_cache_misses").get(), 1);
        assert_eq!(m.counter("instance_cache_hits").get(), 2);
    }

    #[test]
    fn screen_job_matches_direct_scan() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let models = ModelCache::new(0);
        let m = Registry::default();
        let spec = quick_screen("toy1", vec![(0.5, 0.8), (0.8, 1.6)]);
        let out = run_job_cached(&JobSpec::screen(0, spec.clone()), &cache, &models, &m);
        let reply = out.result.expect("screen job failed");
        let s = reply.as_screen().unwrap();
        assert_eq!(s.pairs.len(), 2);
        assert_eq!(s.anchor_solves, 2, "two distinct c_prev anchors");

        // ground truth straight from the library with the same settings
        // (the job recomputes u = Zᵀθ per anchor, so mirror that)
        let key = CacheKey::new("toy1", Model::Svm, Storage::Auto, 0.05);
        let inst = cache.get_or_build(&key, &m).unwrap();
        let solver = CdSolver::new(spec.solver.clone());
        let r0 = solver.solve(&inst, 0.5, inst.cold_start());
        let u0 = inst.u_from_theta(&r0.theta);
        let rep0 = crate::screening::Dvi::new_w().screen(&inst, 0.5, 0.8, &r0.theta, &u0);
        assert_eq!((s.pairs[0].n_lo, s.pairs[0].n_hi), (rep0.n_lo, rep0.n_hi));
        // the job's second anchor warm-starts from the first — confirm
        // against the same warm-started solve
        let mut warm = r0.theta.clone();
        inst.project_box(&mut warm);
        let r1 = solver.solve(&inst, 0.8, warm);
        let u1 = inst.u_from_theta(&r1.theta);
        let rep1 = crate::screening::Dvi::new_w().screen(&inst, 0.8, 1.6, &r1.theta, &u1);
        assert_eq!((s.pairs[1].n_lo, s.pairs[1].n_hi), (rep1.n_lo, rep1.n_hi));
        assert!(s.mean_rejection() > 0.0);
    }

    #[test]
    fn screen_job_composed_rule_dominates_plain_dvi() {
        let pairs = vec![(0.5, 0.8), (0.8, 1.6)];
        let mut spec = quick_screen("toy1", pairs.clone());
        spec.rule = "dvi+essnsv".into();
        let out = run_job(&JobSpec::screen(0, spec));
        let s = out.result.expect("composed screen failed");
        let s = s.as_screen().unwrap();
        assert_eq!(s.rule, "dvi+essnsv");
        assert_eq!(s.anchor_solves, 3, "two anchors plus the w*(C_max) feasible solve");
        // the anchors are solved identically in both jobs (the feasible
        // solve is separate), so the composite must reject at least what
        // its dvi member — the plain job's scan — rejects, per pair
        let plain = run_job(&JobSpec::screen(1, quick_screen("toy1", pairs)));
        let p = plain.result.unwrap();
        let p = p.as_screen().unwrap();
        assert_eq!(p.rule, "dvi");
        for (a, b) in s.pairs.iter().zip(&p.pairs) {
            assert!(
                a.n_lo + a.n_hi >= b.n_lo + b.n_hi,
                "composite ({}, {}) rejected {} < dvi's {}",
                a.c_prev,
                a.c_next,
                a.n_lo + a.n_hi,
                b.n_lo + b.n_hi
            );
        }
    }

    #[test]
    fn screen_job_rejects_svm_only_rule_on_lad() {
        let mut spec = quick_screen("houses", vec![(0.5, 0.8)]);
        spec.model = Model::Lad;
        spec.rule = "dvi+ssnsv".into();
        let out = run_job(&JobSpec::screen(0, spec));
        let err = out.result.unwrap_err();
        assert!(err.contains("SVM-only"), "{err}");
    }

    #[test]
    fn screen_job_reuses_anchor_for_shared_c_prev() {
        let spec = quick_screen("toy1", vec![(0.5, 0.6), (0.5, 1.0), (0.5, 5.0)]);
        let out = run_job(&JobSpec::screen(0, spec));
        let reply = out.result.unwrap();
        let s = reply.as_screen().unwrap();
        assert_eq!(s.anchor_solves, 1, "one anchor serves all three pairs");
        // closer targets screen no less than far ones (Theorem 6 radius)
        let rej: Vec<usize> = s.pairs.iter().map(|p| p.n_lo + p.n_hi).collect();
        assert!(rej[0] >= rej[2], "{rej:?}");
    }

    #[test]
    fn screen_anchor_memo_is_bounded_but_complete() {
        // 12 distinct ascending anchors exercise the LRU eviction path;
        // every pair still gets screened and answered
        let pairs: Vec<(f64, f64)> = (0..12)
            .map(|k| {
                let c = 0.1 + 0.05 * k as f64;
                (c, c + 0.02)
            })
            .collect();
        let out = run_job(&JobSpec::screen(0, quick_screen("toy1", pairs)));
        let reply = out.result.unwrap();
        let s = reply.as_screen().unwrap();
        assert_eq!(s.pairs.len(), 12);
        assert_eq!(s.anchor_solves, 12);
    }

    #[test]
    fn screen_job_with_supplied_theta_skips_solves() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        let key = CacheKey::new("toy1", Model::Svm, Storage::Auto, 0.05);
        let inst = cache.get_or_build(&key, &m).unwrap();
        let solver = CdSolver::new(SolverConfig { tol: 1e-6, ..Default::default() });
        let r = solver.solve(&inst, 0.5, inst.cold_start());

        let mut spec = quick_screen("toy1", vec![(0.5, 0.8)]);
        spec.theta = Some(r.theta.clone());
        spec.return_theta = true;
        let out = run_job_cached(&JobSpec::screen(0, spec), &cache, &ModelCache::new(0), &m);
        let reply = out.result.unwrap();
        let s = reply.as_screen().unwrap();
        assert_eq!(s.anchor_solves, 0);
        assert_eq!(s.theta.as_ref().unwrap(), &r.theta);
        assert_eq!(s.theta_c, Some(0.5));
        let u = inst.u_from_theta(&r.theta);
        let want = crate::screening::Dvi::new_w().screen(&inst, 0.5, 0.8, &r.theta, &u);
        assert_eq!((s.pairs[0].n_lo, s.pairs[0].n_hi), (want.n_lo, want.n_hi));
    }

    fn quick_train(dataset: &str, c: f64) -> TrainSpec {
        TrainSpec {
            dataset: dataset.into(),
            model: Model::Svm,
            scale: 0.05,
            storage: Storage::Auto,
            c,
            solver: SolverConfig { tol: 1e-7, ..Default::default() },
            save: None,
            persist_dir: None,
            report_support: false,
        }
    }

    #[test]
    fn train_then_predict_matches_direct_scoring() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let models = ModelCache::new(ModelCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        let out = run_job_cached(&JobSpec::train(0, quick_train("toy1", 0.5)), &cache, &models, &m);
        let reply = out.result.expect("train failed");
        let t = reply.as_train().unwrap();
        assert_eq!(t.model, Model::Svm);
        assert_eq!(Model::parse(&t.model.wire_name()), Some(t.model), "name round-trips");
        assert!(t.support > 0 && t.support < t.l);
        assert!(t.artifact_bytes > 0);
        assert_eq!(models.len(), 1, "trained model is resident");

        // predict against the resident model by id, inline rows
        let spec = PredictSpec {
            model: ModelRef::Id(t.model_id.clone()),
            input: PredictInput::Rows { flat: vec![1.0, 1.0, -1.0, -1.0], width: 2 },
            threads: 2,
            support_only: false,
        };
        let out = run_job_cached(&JobSpec::predict(1, spec), &cache, &models, &m);
        let p = out.result.expect("predict failed");
        let p = p.as_predict().unwrap();
        assert_eq!(p.rows, 2);
        assert_eq!(p.scores.len(), 2);
        // ground truth straight from the cached model's w
        let model = models.get(&t.model_id, &m).unwrap();
        let want0 = crate::linalg::dot(&[1.0, 1.0], &model.w);
        assert_eq!(p.scores[0].to_bits(), want0.to_bits());
        let labels = p.labels.as_ref().expect("svm is a classifier");
        assert_eq!(labels.len(), 2);
        assert_eq!(labels[0], -labels[1], "separable toy: opposite corners disagree");
    }

    #[test]
    fn predict_by_dataset_and_support_only_agree_bitwise() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let models = ModelCache::new(ModelCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        let out = run_job_cached(&JobSpec::train(0, quick_train("toy1", 0.5)), &cache, &models, &m);
        let id = out.result.unwrap().as_train().unwrap().model_id.clone();
        let mk = |support_only: bool, threads: usize| PredictSpec {
            model: ModelRef::Id(id.clone()),
            input: PredictInput::Dataset {
                name: "toy2".into(),
                scale: 0.05,
                storage: Storage::Auto,
            },
            threads,
            support_only,
        };
        let full = run_job_cached(&JobSpec::predict(1, mk(false, 1)), &cache, &models, &m);
        let full = full.result.unwrap();
        let full = full.as_predict().unwrap().scores.clone();
        for (support_only, threads) in [(false, 3), (true, 1), (true, 4)] {
            let got =
                run_job_cached(&JobSpec::predict(2, mk(support_only, threads)), &cache, &models, &m);
            let got = got.result.unwrap();
            let got = &got.as_predict().unwrap().scores;
            let a: Vec<u64> = full.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "support_only={support_only} threads={threads}");
        }
    }

    #[test]
    fn predict_errors_are_data() {
        let cache = InstanceCache::new(0);
        let models = ModelCache::new(0);
        let m = Registry::default();
        // unknown resident id
        let spec = PredictSpec {
            model: ModelRef::Id("svm-ffffffffffffffff".into()),
            input: PredictInput::Rows { flat: vec![0.0, 0.0], width: 2 },
            threads: 1,
            support_only: false,
        };
        assert!(run_job_cached(&JobSpec::predict(0, spec), &cache, &models, &m).result.is_err());
        // missing artifact file
        let spec = PredictSpec {
            model: ModelRef::File("/no/such/artifact.pallas-model".into()),
            input: PredictInput::Rows { flat: vec![0.0, 0.0], width: 2 },
            threads: 1,
            support_only: false,
        };
        assert!(run_job_cached(&JobSpec::predict(1, spec), &cache, &models, &m).result.is_err());
        // bad C on train
        let out = run_job(&JobSpec::train(2, quick_train("toy1", -1.0)));
        assert!(out.result.is_err());
        let t = run_job_cached(&JobSpec::train(3, quick_train("toy1", 0.5)), &cache, &models, &m);
        assert!(t.result.is_ok());
        // zero-budget model cache: the model is NOT resident afterwards
        let spec = PredictSpec {
            model: ModelRef::Id(t.result.unwrap().as_train().unwrap().model_id.clone()),
            input: PredictInput::Rows { flat: vec![0.0, 0.0], width: 2 },
            threads: 1,
            support_only: false,
        };
        assert!(run_job_cached(&JobSpec::predict(4, spec), &cache, &models, &m).result.is_err());
    }

    #[test]
    fn parallel_train_reports_the_serial_support_set() {
        // exact-set equality is sound here because the E-band (= tol)
        // only flips for a TRUE margin within ~tol of the band edge, and
        // toy1 is a fixed generic set with no such degenerate margin —
        // integration_cd_par.rs covers arbitrary data with a wide band
        let mk = |threads: usize| {
            let mut spec = quick_train("toy1", 0.5);
            spec.report_support = true;
            spec.solver.tol = 1e-8;
            spec.solver.solver_threads = Some(threads);
            spec
        };
        let serial = run_job(&JobSpec::train(0, mk(1))).result.unwrap();
        let par = run_job(&JobSpec::train(1, mk(4))).result.unwrap();
        let (s, p) = (serial.as_train().unwrap(), par.as_train().unwrap());
        let sup = s.support_indices.as_ref().expect("requested support echo");
        assert!(!sup.is_empty());
        assert_eq!(s.support_indices, p.support_indices, "support sets must agree");
        // without the flag the summary stays lean
        let lean = run_job(&JobSpec::train(2, quick_train("toy1", 0.5))).result.unwrap();
        assert!(lean.as_train().unwrap().support_indices.is_none());
    }

    #[test]
    fn train_save_and_predict_from_file() {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_job_train_{}.pallas-model", std::process::id()));
        let mut spec = quick_train("toy1", 0.5);
        spec.save = Some(p.to_str().unwrap().to_string());
        let out = run_job(&JobSpec::train(0, spec));
        let reply = out.result.expect("train failed");
        assert_eq!(reply.as_train().unwrap().saved.as_deref(), Some(p.to_str().unwrap()));
        assert!(p.exists());

        // a fresh transient context can serve predictions from the file
        let spec = PredictSpec {
            model: ModelRef::File(p.to_str().unwrap().into()),
            input: PredictInput::Rows { flat: vec![0.5, -0.5], width: 2 },
            threads: 1,
            support_only: true,
        };
        let out = run_job(&JobSpec::predict(1, spec));
        let r = out.result.expect("predict from file failed");
        assert_eq!(r.as_predict().unwrap().scores.len(), 1);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn train_persist_dir_writes_id_named_artifact() {
        let dir = std::env::temp_dir().join(format!("dvi_persist_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut spec = quick_train("toy1", 0.5);
        spec.persist_dir = Some(dir.to_str().unwrap().to_string());
        let out = run_job(&JobSpec::train(0, spec.clone()));
        let reply = out.result.expect("train failed");
        let t = reply.as_train().unwrap();
        let path = dir.join(format!("{}.pallas-model", t.model_id));
        assert_eq!(t.persisted.as_deref(), path.to_str());
        assert!(t.saved.is_none(), "persist_dir is independent of save");
        let loaded = model_format::load(&path).expect("persisted artifact loads");
        assert_eq!(loaded.id(), t.model_id, "filename is the content id");
        // retrain is an idempotent overwrite, not an accumulation
        run_job(&JobSpec::train(1, spec)).result.expect("retrain failed");
        let entries = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(entries, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_persist_into_missing_dir_is_error_not_panic() {
        let mut spec = quick_train("toy1", 0.5);
        spec.persist_dir = Some("/no/such/registry-dir".into());
        let out = run_job(&JobSpec::train(0, spec));
        let err = out.result.unwrap_err();
        assert!(err.contains("persist"), "{err}");
    }

    #[test]
    fn stats_job_snapshots_every_family() {
        let cache = InstanceCache::new(0);
        let models = ModelCache::new(0);
        let m = Registry::default();
        m.counter("service_requests").add(2);
        m.gauge("serve_queue_cost").set(5);
        m.histogram("job_secs").record_secs(0.125);
        let spec = JobSpec { id: 0, kind: JobKind::Stats, timings: false, after: None };
        let out = run_job_cached(&spec, &cache, &models, &m);
        let reply = out.result.expect("stats never fails");
        let s = reply.as_stats().unwrap();
        assert!(s.counters.iter().any(|(n, v)| n == "service_requests" && *v == 2));
        assert!(s.gauges.iter().any(|(n, v)| n == "serve_queue_cost" && *v == 5));
        assert!(s.histograms.iter().any(|h| h.name == "job_secs" && h.count == 1));
    }

    #[test]
    fn cache_job_lists_and_evicts() {
        let cache = InstanceCache::new(InstanceCache::DEFAULT_BUDGET_BYTES);
        let models = ModelCache::new(ModelCache::DEFAULT_BUDGET_BYTES);
        let m = Registry::default();
        run_job_cached(&JobSpec::train(0, quick_train("toy1", 0.5)), &cache, &models, &m)
            .result
            .unwrap();
        let list = JobSpec {
            id: 1,
            kind: JobKind::Cache(CacheSpec { op: CacheOp::List }),
            timings: false,
            after: None,
        };
        let out = run_job_cached(&list, &cache, &models, &m).result.unwrap();
        let s = out.as_cache().unwrap();
        assert_eq!(s.instances.len(), 1);
        assert_eq!(s.models.len(), 1);
        assert!(s.evicted.is_none());
        let model_id = s.models[0].id.clone();

        let evict = JobSpec {
            id: 2,
            kind: JobKind::Cache(CacheSpec { op: CacheOp::EvictModel(model_id) }),
            timings: false,
            after: None,
        };
        let out = run_job_cached(&evict, &cache, &models, &m).result.unwrap();
        let s = out.as_cache().unwrap();
        assert_eq!(s.evicted, Some(true));
        assert!(s.models.is_empty());
        assert_eq!(s.instances.len(), 1, "instance cache untouched");

        let evict_inst = JobSpec {
            id: 3,
            kind: JobKind::Cache(CacheSpec {
                op: CacheOp::EvictInstance(CacheKey::new("toy1", Model::Svm, Storage::Auto, 0.05)),
            }),
            timings: false,
            after: None,
        };
        let out = run_job_cached(&evict_inst, &cache, &models, &m).result.unwrap();
        assert_eq!(out.as_cache().unwrap().evicted, Some(true));
        assert!(out.as_cache().unwrap().instances.is_empty());
    }

    #[test]
    fn screen_job_rejects_bad_input() {
        // reversed pair
        let out = run_job(&JobSpec::screen(0, quick_screen("toy1", vec![(1.0, 0.5)])));
        assert!(out.result.is_err());
        // empty pairs
        let out = run_job(&JobSpec::screen(1, quick_screen("toy1", vec![])));
        assert!(out.result.is_err());
        // wrong θ length
        let mut spec = quick_screen("toy1", vec![(0.5, 0.8)]);
        spec.theta = Some(vec![0.0; 3]);
        let out = run_job(&JobSpec::screen(2, spec));
        assert!(out.result.is_err());
        // θ outside the box
        let mut spec = quick_screen("toy1", vec![(0.5, 0.8)]);
        spec.theta = Some(vec![7.0; 100]);
        let out = run_job(&JobSpec::screen(3, spec));
        assert!(out.result.is_err());
    }
}
