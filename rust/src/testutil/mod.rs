//! Property-testing helper ("shrink-lite").
//!
//! proptest is not available offline, so this module provides the minimal
//! machinery our invariant tests need: run a property over N seeded random
//! cases; on failure, retry with a deterministic sequence of *smaller*
//! cases derived from the failing seed and report the smallest failure.

use crate::data::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 32, seed: 0xBEEF }
    }
}

/// Size hint passed to generators; shrinking lowers it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Size(pub usize);

/// Run `prop(rng, size)`; `Ok(())` on pass, `Err(msg)` describing the
/// violation on failure. Panics with a reproduction line on failure.
pub fn check<F>(cfg: PropConfig, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng, Size) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = Size(4 + case * 4); // grow sizes across cases
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // try to find a smaller failing size with the same seed
            let mut smallest = (size, msg);
            let mut s = size.0;
            while s > 4 {
                s /= 2;
                let mut rng = Rng::new(case_seed);
                match prop(&mut rng, Size(s)) {
                    Err(m) => smallest = (Size(s), m),
                    Ok(()) => break,
                }
            }
            panic!(
                "property `{name}` failed (seed={case_seed:#x}, size={}): {}",
                smallest.0 .0, smallest.1
            );
        }
    }
}

/// Assert |a−b| ≤ atol + rtol·|b|, with a readable message.
pub fn assert_close(a: f64, b: f64, atol: f64, rtol: f64, what: &str) -> Result<(), String> {
    let tol = atol + rtol * b.abs();
    if (a - b).abs() > tol {
        Err(format!("{what}: {a} vs {b} (|Δ|={} > tol={tol})", (a - b).abs()))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(PropConfig { cases: 8, seed: 1 }, "tautology", |rng, size| {
            let v: Vec<f64> = (0..size.0).map(|_| rng.uniform()).collect();
            if v.iter().all(|&x| (0.0..1.0).contains(&x)) {
                Ok(())
            } else {
                Err("uniform out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_seed() {
        check(PropConfig { cases: 2, seed: 2 }, "always-fails", |_, _| Err("nope".into()));
    }

    #[test]
    fn shrink_reports_smaller_size() {
        let result = std::panic::catch_unwind(|| {
            check(PropConfig { cases: 1, seed: 3 }, "fails-when-big", |_, size| {
                if size.0 >= 4 {
                    Err("too big".into())
                } else {
                    Ok(())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("size=4"), "{msg}");
    }

    #[test]
    fn assert_close_behaviour() {
        assert!(assert_close(1.0, 1.0 + 1e-9, 1e-8, 0.0, "x").is_ok());
        assert!(assert_close(1.0, 2.0, 1e-8, 0.0, "x").is_err());
        assert!(assert_close(100.0, 100.5, 0.0, 0.01, "x").is_ok());
    }
}
