//! Dual coordinate descent for min_{θ∈box} C/2·‖Zᵀθ‖² − ⟨ȳ, θ⟩.
//!
//! Per coordinate (problem (16)/(17)): with u = Zᵀθ maintained
//! incrementally, the 1-D subproblem over t has the closed form
//!
//! ```text
//!   ∇ᵢ = C·⟨zᵢ, u⟩ − ȳᵢ
//!   θᵢ ← clip(θᵢ − ∇ᵢ / (C·‖zᵢ‖²), loᵢ, hiᵢ);   u += Δθᵢ·zᵢ
//! ```
//!
//! Convergence: maximal projected-gradient violation across a sweep below
//! `tol` (LIBLINEAR's criterion). Shrinking removes bound-stuck,
//! clearly-non-violating coordinates from the sweep and re-checks the full
//! problem before declaring convergence, so the answer is identical with
//! or without shrinking.
//!
//! The per-coordinate kernel ([`coord_step`]), the zero-norm-row pre-pass
//! ([`clip_zero_norm_rows`]), and the shrink-threshold update
//! ([`relax_m_bar`]) are factored out so the block-synchronous parallel
//! sweep ([`super::cd_par`]) performs bit-for-bit the same per-coordinate
//! arithmetic as the serial loop. [`CdSolver::solve_free_with_u`]
//! dispatches on [`SolverConfig::cd_threads`]: 1 keeps this serial path
//! (byte-identical to the pre-parallel solver), anything else routes by
//! [`crate::config::CdMode`] to the block-synchronous sharded engine
//! ([`super::cd_par`], the default) or the asynchronous wild arm
//! ([`super::cd_async`]).

use crate::config::{CdMode, SolverConfig};
use crate::data::Rng;
use crate::linalg::{self};
use crate::problem::Instance;

/// Outcome of a solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Full-length dual vector (fixed coordinates passed through).
    pub theta: Vec<f64>,
    /// u = Zᵀθ at the returned point.
    pub u: Vec<f64>,
    pub stats: SolverStats,
}

/// Work counters for benchmarking (the paper's Tables 1–2 compare solver
/// work with and without screening).
#[derive(Clone, Debug, Default)]
pub struct SolverStats {
    pub outer_iters: usize,
    pub coord_updates: u64,
    /// Coordinate-gradient evaluations — each costs an O(n) dot product.
    /// This is the honest work metric: shrinking avoids *updates* but the
    /// sweep still pays the gradient scan for every active coordinate.
    pub grad_evals: u64,
    pub converged: bool,
    pub final_violation: f64,
    /// Number of coordinates actually optimized: the free set minus the
    /// degenerate zero-norm rows clipped straight to a bound up front
    /// (the post-retain active set the first sweep visits). Identical for
    /// the serial and sharded sweeps.
    pub active_coords: usize,
}

/// One coordinate's pending move: the clipped target plus the Δθ to apply
/// to u (0-delta moves are filtered out by [`coord_step`]).
#[derive(Clone, Copy, Debug)]
pub(super) struct CoordUpdate {
    pub new_theta: f64,
    pub delta: f64,
}

/// Outcome of visiting one coordinate during a sweep.
#[derive(Clone, Copy, Debug)]
pub(super) enum CoordStep {
    /// Clearly bound-stuck and non-violating: drop from the active set.
    Shrunk,
    /// Stays active this sweep; `update` is `None` when the coordinate is
    /// already (numerically) optimal.
    Kept { viol: f64, update: Option<CoordUpdate> },
}

/// The per-coordinate CD kernel — exactly the arithmetic of the serial
/// sweep body, shared with the sharded sweep so both evaluate the same
/// floating-point expressions in the same order. `u` is whatever view of
/// Zᵀθ the caller sequences against (the live vector for Gauss-Seidel,
/// a shard-local copy for the block-synchronous sweep).
#[inline]
pub(super) fn coord_step(
    inst: &Instance,
    c: f64,
    i: usize,
    th: f64,
    u: &[f64],
    m_bar: f64,
    shrink: bool,
) -> CoordStep {
    let g = c * inst.z.row(i).dot(u) - inst.ybar[i];
    coord_step_from_g(inst, c, i, th, g, m_bar, shrink)
}

/// [`coord_step`] with the gradient supplied by the caller — the sharded
/// sweep's sparse-delta path evaluates g = C·(⟨zᵢ, u⟩ + ⟨zᵢ, Δu⟩) − ȳᵢ
/// from two striped dots instead of one dot over a dense local copy;
/// everything after the gradient is this one shared piece.
#[inline]
pub(super) fn coord_step_from_g(
    inst: &Instance,
    c: f64,
    i: usize,
    th: f64,
    g: f64,
    m_bar: f64,
    shrink: bool,
) -> CoordStep {
    let (lo, hi) = (inst.lo[i], inst.hi[i]);
    // projected gradient
    let pg = if th <= lo + 1e-15 {
        // at lower bound we can only increase θ ⇒ only a negative
        // gradient is a violation
        if g > m_bar && shrink {
            // clearly stuck at the bound: shrink out
            return CoordStep::Shrunk;
        }
        g.min(0.0)
    } else if th >= hi - 1e-15 {
        if g < -m_bar && shrink {
            return CoordStep::Shrunk;
        }
        g.max(0.0)
    } else {
        g
    };
    let viol = pg.abs();
    let update = if viol > 1e-15 {
        let denom = c * inst.z_norms_sq[i];
        let new = linalg::clamp(th - g / denom, lo, hi);
        let delta = new - th;
        if delta != 0.0 {
            Some(CoordUpdate { new_theta: new, delta })
        } else {
            None
        }
    } else {
        None
    };
    CoordStep::Kept { viol, update }
}

/// Handle degenerate zero-norm rows up front: their gradient is the
/// constant −ȳᵢ, so the optimum clips straight to a bound (no u update is
/// needed — zᵢ = 0). Returns the surviving active list in `free` order.
pub(super) fn clip_zero_norm_rows(
    inst: &Instance,
    theta: &mut [f64],
    free: &[usize],
) -> Vec<usize> {
    let mut active = Vec::with_capacity(free.len());
    for &i in free {
        if inst.z_norms_sq[i] > 0.0 {
            active.push(i);
        } else if inst.ybar[i] > 0.0 {
            theta[i] = inst.hi[i];
        } else if inst.ybar[i] < 0.0 {
            theta[i] = inst.lo[i];
        }
    }
    active
}

/// One Gauss-Seidel sweep over `active` against the LIVE u: measure each
/// coordinate, apply its move immediately, shrink bound-stuck ones out.
/// Returns (surviving active list, max projected-gradient violation).
/// This is THE serial sweep — `solve_serial` loops it, and the sharded
/// solver calls it for single-shard blocks and for its serial
/// confirmation/stall sweeps, so those paths cannot drift from the
/// serial arithmetic.
pub(super) fn sweep_live(
    inst: &Instance,
    c: f64,
    active: &[usize],
    theta: &mut [f64],
    u: &mut [f64],
    m_bar: f64,
    shrink: bool,
    stats: &mut SolverStats,
) -> (Vec<usize>, f64) {
    let mut max_violation = 0.0f64;
    let mut kept = Vec::with_capacity(active.len());
    for &i in active {
        stats.grad_evals = stats.grad_evals.saturating_add(1);
        match coord_step(inst, c, i, theta[i], u, m_bar, shrink) {
            CoordStep::Shrunk => {}
            CoordStep::Kept { viol, update } => {
                kept.push(i);
                max_violation = max_violation.max(viol);
                if let Some(up) = update {
                    theta[i] = up.new_theta;
                    inst.z.row(i).axpy_into(up.delta, u);
                    stats.coord_updates = stats.coord_updates.saturating_add(1);
                }
            }
        }
    }
    (kept, max_violation)
}

/// End-of-sweep shrink-threshold update (LIBLINEAR §4): relax m̄ toward
/// the sweep's violation; a threshold at or below `tol` would shrink
/// coordinates the convergence test still needs, so it resets to ∞.
#[inline]
pub(super) fn relax_m_bar(max_violation: f64, tol: f64) -> f64 {
    let m = if max_violation.is_finite() { max_violation } else { f64::INFINITY };
    if m <= tol {
        f64::INFINITY
    } else {
        m
    }
}

/// The solver object (holds config; stateless between solves).
#[derive(Clone, Debug)]
pub struct CdSolver {
    pub cfg: SolverConfig,
}

impl CdSolver {
    pub fn new(cfg: SolverConfig) -> Self {
        CdSolver { cfg }
    }

    /// Solve with every coordinate free, cold or warm started at `theta0`.
    pub fn solve(&self, inst: &Instance, c: f64, theta0: Vec<f64>) -> SolveResult {
        let free: Vec<usize> = (0..inst.len()).collect();
        self.solve_free(inst, c, theta0, &free)
    }

    /// Solve the reduced problem of Lemma 4: coordinates not in `free`
    /// stay at their `theta0` value (screened to a bound by the caller),
    /// and their contribution enters through u = Zᵀθ — mathematically
    /// identical to the ŷ = ȳ − C·Ĝ₁₂θ̂ offset in the paper.
    pub fn solve_free(
        &self,
        inst: &Instance,
        c: f64,
        theta: Vec<f64>,
        free: &[usize],
    ) -> SolveResult {
        // the one O(l·n) reconstruction this entry point pays is axis-
        // aware: wide instances shard u = Zᵀθ over column slabs of the
        // lazy mirror (bit-identical to the serial row path)
        let u = inst.u_from_theta_axis(&theta, self.cfg.shard_axis, self.cfg.threads);
        self.solve_free_with_u(inst, c, theta, free, u)
    }

    /// Hot-path variant of [`Self::solve_free`]: the caller supplies
    /// u = Zᵀθ consistent with `theta` (maintained incrementally along a
    /// path), avoiding the O(l·n) recomputation per step that would
    /// otherwise swamp the savings screening buys. The returned `u` is
    /// likewise incrementally maintained.
    pub fn solve_free_with_u(
        &self,
        inst: &Instance,
        c: f64,
        theta: Vec<f64>,
        free: &[usize],
        u: Vec<f64>,
    ) -> SolveResult {
        assert_eq!(theta.len(), inst.len());
        assert_eq!(u.len(), inst.dim());
        assert!(c > 0.0, "C must be positive");
        debug_assert!(inst.in_box(&theta, 1e-9), "warm start leaves the box");
        debug_assert!(
            crate::linalg::max_abs_diff(&u, &inst.u_from_theta(&theta)) < 1e-6,
            "caller-supplied u inconsistent with theta"
        );
        // cd_threads = 1 keeps the serial Gauss-Seidel sweep below —
        // byte-identical to the pre-parallel solver regardless of
        // cd_mode; anything else (0 = auto) routes by mode: Sync is the
        // block-synchronous sharded engine (deterministic per
        // (seed, threads)), Async the wild racing arm (KKT-valid result,
        // nondeterministic trajectory).
        if self.cfg.cd_threads() != 1 {
            return match self.cfg.cd_mode {
                CdMode::Sync => {
                    super::cd_par::solve_free_with_u_par(&self.cfg, inst, c, theta, free, u)
                }
                CdMode::Async => {
                    super::cd_async::solve_free_with_u_async(&self.cfg, inst, c, theta, free, u)
                }
            };
        }
        self.solve_serial(inst, c, theta, free, u)
    }

    /// The serial Gauss-Seidel sweep loop (cd_threads = 1).
    fn solve_serial(
        &self,
        inst: &Instance,
        c: f64,
        mut theta: Vec<f64>,
        free: &[usize],
        mut u: Vec<f64>,
    ) -> SolveResult {
        let mut rng = Rng::new(self.cfg.seed);
        let mut stats = SolverStats::default();

        // Active set for shrinking; indices into `free`'s coordinate ids.
        let mut active = clip_zero_norm_rows(inst, &mut theta, free);
        stats.active_coords = active.len();

        // Shrinking thresholds (LIBLINEAR §4): track max/min projected
        // gradient of the previous sweep.
        let mut m_bar = f64::INFINITY;
        let mut shrunk = false;

        let tol = self.cfg.tol;
        loop {
            if stats.outer_iters >= self.cfg.max_outer {
                break;
            }
            stats.outer_iters += 1;
            rng.shuffle(&mut active);

            let (kept, max_violation) = {
                let mut sp = crate::obs::Span::enter("sweep");
                sp.attr_str("cd_mode", "serial");
                sp.attr_str("shard_axis", inst.pick_axis(self.cfg.shard_axis).name());
                sp.attr("shards", 1.0);
                sp.attr("iter", stats.outer_iters as f64);
                let out = sweep_live(
                    inst,
                    c,
                    &active,
                    &mut theta,
                    &mut u,
                    m_bar,
                    self.cfg.shrink,
                    &mut stats,
                );
                sp.attr("violation", out.1);
                out
            };
            shrunk = shrunk || kept.len() < active.len();
            active = kept;
            stats.final_violation = max_violation;

            if max_violation < tol {
                if self.cfg.shrink && shrunk {
                    // re-expand and confirm on the full free set
                    active = free
                        .iter()
                        .copied()
                        .filter(|&i| inst.z_norms_sq[i] > 0.0)
                        .collect();
                    shrunk = false;
                    m_bar = f64::INFINITY;
                    // one more sweep over everything
                    continue;
                }
                stats.converged = true;
                break;
            }
            // relax the shrink threshold toward the current violation
            m_bar = relax_m_bar(max_violation, tol);
        }

        // u is maintained incrementally (f64 axpy drift is ~machine-eps
        // per update and validated against full recomputes in tests);
        // recomputing here would reintroduce an O(l·n) cost per path step
        // that screening is supposed to eliminate. Path runners refresh u
        // periodically for hygiene.
        SolveResult { theta, u, stats }
    }

    /// Maximum projected-gradient violation of θ for the full problem —
    /// the optimality measure (0 at the exact optimum).
    pub fn kkt_violation(inst: &Instance, c: f64, theta: &[f64]) -> f64 {
        let u = inst.u_from_theta(theta);
        Self::violation_rows(inst, c, theta, &u, 0..inst.len())
    }

    /// Sharded variant of [`Self::kkt_violation`] for `PathConfig::validate`
    /// on large l: both O(l·n) passes (u = Zᵀθ and the per-row projected
    /// gradients) run over contiguous row shards on `std::thread::scope`
    /// workers. The max-reduction is order-independent; u is accumulated
    /// from per-shard partials, so it can differ from the serial sum by
    /// rounding only (irrelevant at validation tolerances). `threads`
    /// follows the crate convention (0 = auto, 1 = serial).
    pub fn kkt_violation_threads(inst: &Instance, c: f64, theta: &[f64], threads: usize) -> f64 {
        let l = inst.len();
        let t = crate::linalg::par::effective_threads(threads, l);
        if t <= 1 {
            return Self::kkt_violation(inst, c, theta);
        }
        // shards are balanced by stored-entry count (nnz for CSR) from
        // the instance's cached prefix, since both passes cost
        // O(shard nnz)
        let shards = inst.balanced_shards(t);
        let partials = crate::linalg::par::run_sharded_ranges(shards.clone(), |rows| {
            let mut u = vec![0.0; inst.dim()];
            for i in rows {
                if theta[i] != 0.0 {
                    inst.z.row(i).axpy_into(theta[i], &mut u);
                }
            }
            u
        });
        let mut u = vec![0.0; inst.dim()];
        for p in &partials {
            for (a, b) in u.iter_mut().zip(p) {
                *a += *b;
            }
        }
        crate::linalg::par::run_sharded_ranges(shards, |rows| {
            Self::violation_rows(inst, c, theta, &u, rows)
        })
        .into_iter()
        .fold(0.0, f64::max)
    }

    /// Projected-gradient violation over one contiguous row range — shared
    /// by the serial and sharded checks.
    fn violation_rows(
        inst: &Instance,
        c: f64,
        theta: &[f64],
        u: &[f64],
        rows: std::ops::Range<usize>,
    ) -> f64 {
        let mut worst = 0.0f64;
        for i in rows {
            let g = c * inst.z.row(i).dot(u) - inst.ybar[i];
            let pg = if theta[i] <= inst.lo[i] + 1e-12 {
                g.min(0.0)
            } else if theta[i] >= inst.hi[i] - 1e-12 {
                g.max(0.0)
            } else {
                g
            };
            worst = worst.max(pg.abs());
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverConfig;
    use crate::data::{synth, Rng};
    use crate::problem::{Instance, Model};

    fn solver() -> CdSolver {
        CdSolver::new(SolverConfig { tol: 1e-8, max_outer: 10_000, seed: 1, ..Default::default() })
    }

    #[test]
    fn solves_tiny_svm_exactly() {
        // two points, one per class, at x = ±1 (1-D). For C ≥ 1/2 the
        // margin is attained with w = 1 when C·2 ≥ ... closed form:
        // dual: min C/2(θ₁+θ₂)²·1 ... z₁ = −x₁ = −1 (y=+1,x=1),
        // z₂ = −(−1)(−1) = −1. So Zᵀθ = −(θ₁+θ₂), g = C/2(θ₁+θ₂)² − θ₁ − θ₂.
        // With s = θ₁+θ₂ ∈ [0,2]: min C/2 s² − s ⇒ s* = min(1/C, 2).
        use crate::data::{Dataset, Task};
        use crate::linalg::RowMatrix;
        let x = RowMatrix::from_flat(2, 1, vec![1.0, -1.0]);
        let ds = Dataset::new("2pt", Task::Classification, x, vec![1.0, -1.0]);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        for &c in &[0.3, 0.5, 1.0, 5.0] {
            let r = solver().solve(&inst, c, inst.cold_start());
            let s = r.theta[0] + r.theta[1];
            let expect = (1.0 / c).min(2.0);
            assert!((s - expect).abs() < 1e-6, "C={c}: s={s} expect={expect}");
            assert!(r.stats.converged);
        }
    }

    #[test]
    fn kkt_violation_small_after_solve() {
        let ds = synth::toy_gaussian(2, 100, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = solver().solve(&inst, 1.0, inst.cold_start());
        assert!(r.stats.converged);
        let v = CdSolver::kkt_violation(&inst, 1.0, &r.theta);
        assert!(v < 1e-6, "violation {v}");
        assert!(inst.in_box(&r.theta, 1e-12));
    }

    #[test]
    fn threaded_kkt_violation_matches_serial() {
        let ds = synth::toy_gaussian(12, 90, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let r = solver().solve(&inst, 0.8, inst.cold_start());
        let serial = CdSolver::kkt_violation(&inst, 0.8, &r.theta);
        for threads in [2usize, 3, 7, 0] {
            let par = CdSolver::kkt_violation_threads(&inst, 0.8, &r.theta, threads);
            // u is summed from per-shard partials ⇒ rounding-level drift only
            assert!(
                (par - serial).abs() <= 1e-9 * serial.abs().max(1.0),
                "threads={threads}: {par} vs {serial}"
            );
        }
        assert_eq!(CdSolver::kkt_violation_threads(&inst, 0.8, &r.theta, 1), serial);
    }

    #[test]
    fn lad_kkt_small_after_solve() {
        let mut rng = Rng::new(3);
        let ds = synth::random_regression(&mut rng, 80, 5);
        let inst = Instance::from_dataset(Model::Lad, &ds);
        let r = solver().solve(&inst, 0.5, inst.cold_start());
        let v = CdSolver::kkt_violation(&inst, 0.5, &r.theta);
        assert!(v < 1e-6, "violation {v}");
    }

    #[test]
    fn shrinking_matches_no_shrinking() {
        let ds = synth::toy_gaussian(7, 80, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let with = CdSolver::new(SolverConfig { shrink: true, tol: 1e-10, ..Default::default() })
            .solve(&inst, 2.0, inst.cold_start());
        let without = CdSolver::new(SolverConfig { shrink: false, tol: 1e-10, ..Default::default() })
            .solve(&inst, 2.0, inst.cold_start());
        // same optimum (strongly convex in u ⇒ u unique; θ may differ on
        // degenerate faces, so compare objectives and u)
        let g1 = inst.dual_objective(2.0, &with.theta);
        let g2 = inst.dual_objective(2.0, &without.theta);
        assert!((g1 - g2).abs() < 1e-8, "{g1} vs {g2}");
        assert!(crate::linalg::max_abs_diff(&with.u, &without.u) < 1e-5);
    }

    #[test]
    fn warm_start_reduces_work() {
        let ds = synth::toy_gaussian(8, 300, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let s = solver();
        let r1 = s.solve(&inst, 1.0, inst.cold_start());
        // warm start at a slightly larger C
        let warm = s.solve(&inst, 1.1, r1.theta.clone());
        let cold = s.solve(&inst, 1.1, inst.cold_start());
        assert!(
            warm.stats.coord_updates < cold.stats.coord_updates,
            "warm {} !< cold {}",
            warm.stats.coord_updates,
            cold.stats.coord_updates
        );
    }

    #[test]
    fn frozen_coordinates_stay_fixed() {
        let ds = synth::toy_gaussian(9, 50, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let full = solver().solve(&inst, 1.0, inst.cold_start());
        // freeze coordinates that are at bounds in the optimum, re-solve
        let free: Vec<usize> = (0..inst.len())
            .filter(|&i| full.theta[i] > 1e-9 && full.theta[i] < 1.0 - 1e-9)
            .collect();
        let mut theta0 = full.theta.clone();
        // jiggle the free coordinates away from the answer
        for &i in &free {
            theta0[i] = 0.5;
        }
        let red = solver().solve_free(&inst, 1.0, theta0, &free);
        for i in 0..inst.len() {
            if !free.contains(&i) {
                assert_eq!(red.theta[i], full.theta[i], "frozen coord {i} moved");
            }
        }
        let g_full = inst.dual_objective(1.0, &full.theta);
        let g_red = inst.dual_objective(1.0, &red.theta);
        assert!((g_full - g_red).abs() < 1e-7, "{g_full} vs {g_red}");
    }

    #[test]
    fn zero_norm_rows_clip_to_bounds() {
        use crate::data::{Dataset, Task};
        use crate::linalg::RowMatrix;
        // one all-zero regression row with positive target
        let x = RowMatrix::from_flat(3, 2, vec![1.0, 0.5, 0.0, 0.0, -1.0, 2.0]);
        let ds = Dataset::new("z", Task::Regression, x, vec![0.3, 2.0, -0.7]);
        let inst = Instance::from_dataset(Model::Lad, &ds);
        let r = solver().solve(&inst, 1.0, inst.cold_start());
        assert_eq!(r.theta[1], 1.0, "zero row with y>0 must sit at β");
    }

    #[test]
    fn counters_pin_tiny_problem_with_zero_norm_row() {
        use crate::data::{Dataset, Task};
        use crate::linalg::RowMatrix;
        // 3 rows, one all-zero: active_coords counts the post-retain set
        let x = RowMatrix::from_flat(3, 2, vec![1.0, 0.5, 0.0, 0.0, -1.0, 2.0]);
        let ds = Dataset::new("z", Task::Regression, x, vec![0.3, 2.0, -0.7]);
        let inst = Instance::from_dataset(Model::Lad, &ds);
        for solver_threads in [1usize, 4] {
            let s = CdSolver::new(SolverConfig {
                tol: 1e-10,
                max_outer: 10_000,
                solver_threads: Some(solver_threads),
                ..Default::default()
            });
            let r = s.solve(&inst, 1.0, inst.cold_start());
            assert!(r.stats.converged);
            assert_eq!(
                r.stats.active_coords, 2,
                "zero-norm row must not count (t={solver_threads})"
            );
            assert_eq!(r.theta[1], 1.0, "zero row clipped to its bound");
            assert!(r.stats.grad_evals >= r.stats.coord_updates);
            // no sweep can visit more than the active set
            assert!(r.stats.grad_evals <= r.stats.outer_iters as u64 * 2);
        }
        // one full sweep with shrinking impossible (m̄ = ∞ on sweep 1):
        // exactly one gradient evaluation per active coordinate
        let one = CdSolver::new(SolverConfig { tol: 1e-16, max_outer: 1, ..Default::default() });
        let r = one.solve(&inst, 1.0, inst.cold_start());
        assert_eq!(r.stats.outer_iters, 1);
        assert_eq!(r.stats.grad_evals, 2);
        assert_eq!(r.stats.active_coords, 2);
    }

    #[test]
    fn respects_max_outer() {
        let ds = synth::toy_gaussian(10, 200, 0.5, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let s = CdSolver::new(SolverConfig { max_outer: 1, tol: 1e-14, ..Default::default() });
        let r = s.solve(&inst, 10.0, inst.cold_start());
        assert_eq!(r.stats.outer_iters, 1);
        assert!(!r.stats.converged);
    }

    #[test]
    fn primal_dual_gap_closes() {
        let ds = synth::toy_gaussian(11, 60, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let c = 0.7;
        let r = solver().solve(&inst, c, inst.cold_start());
        let w = inst.w_from_theta(c, &r.theta);
        let p = inst.primal_objective(c, &w);
        // optimal value of (3) equals −C·g(θ*) under our scaling of (12)
        let d = -c * inst.dual_objective(c, &r.theta);
        assert!((p - d).abs() < 1e-5 * p.abs().max(1.0), "gap {p} vs {d}");
    }
}
