//! Block-synchronous parallel dual coordinate descent.
//!
//! The sweep — the last serial O(nnz) hot path after the scan, Gram
//! build, and KKT validation were sharded — is parallelized the same way
//! those were, with one twist: CD is inherently sequential through
//! u = Zᵀθ, so the shards cannot share a live u. Instead each outer
//! iteration is one *block*:
//!
//! 1. shuffle the active set with the solver's seeded RNG (exactly the
//!    serial permutation schedule);
//! 2. partition the shuffled list into nnz-balanced contiguous shards
//!    ([`Rows::balanced_subset_shards`] — CSR shards carry near-equal
//!    stored-entry counts, dense shards near-equal rows);
//! 3. each shard runs Gauss-Seidel CD *locally*: it reads the shared
//!    read-mostly u, folds its own updates into a shard-private delta-u
//!    buffer — a dense local copy for dense/narrow data, a sparse
//!    accumulator (zero-init + touched-column list, no O(n) clone) for
//!    wide CSR data — and records `(coordinate, new θ)` moves; the
//!    per-coordinate arithmetic is [`super::cd::coord_step`] (the serial
//!    kernel; the sparse path feeds it the gradient from two striped
//!    dots via [`super::cd::coord_step_from_g`]);
//! 4. at the block boundary the main thread merges θ moves and the
//!    per-shard delta-u buffers (u_local − u) in **fixed shard order**, so
//!    a given `(seed, threads)` pair is run-to-run deterministic no matter
//!    how the OS schedules the workers.
//!
//! Between shards this is a Jacobi step (each shard sees the others'
//! block-start u), within a shard it is Gauss-Seidel — the re-shuffle
//! each block re-partitions the coordinates, so cross-shard coupled pairs
//! don't stay split forever and the usual Jacobi oscillation modes are
//! broken up. Jacobi steps on highly coherent data can still stall
//! (near-duplicate rows split across shards overshoot together), so a
//! deterministic stall guard watches the best violation seen: after
//! [`STALL_LIMIT`] sweeps without a new best, sweeps run serially until
//! progress resumes. Serial sweeps provably converge, and the best
//! violation ratchets monotonically down across guard episodes, so the
//! solve always terminates — the guard costs nothing when the parallel
//! sweeps are healthy. Convergence is still judged by the true
//! criterion — and never off stale data: a sharded sweep measures
//! violations against its block-start u, so a sub-`tol` sharded sweep
//! only schedules a serial (live-u) confirmation sweep; `converged` is
//! declared exclusively from serial sweeps, with the serial solver's
//! shrinking thresholds (m̄) and its full-active-set re-check carried
//! over verbatim.
//!
//! Contract (locked by `tests/integration_cd_par.rs`): the returned point
//! is KKT-valid at the same `tol` as the serial solver, and downstream
//! DVI screening decisions and KKT support/E-set classification agree
//! with the serial solution; iterates are deterministic per
//! `(seed, threads)` but — unlike the sharded scan — NOT bitwise-equal
//! across thread counts. `cd_threads = 1` never reaches this module.

use super::cd::{self, CoordStep, SolveResult, SolverStats};
use crate::config::SolverConfig;
use crate::data::Rng;
use crate::linalg::par;
use crate::problem::Instance;

/// Below this many active coordinates per shard the sweep collapses to
/// fewer shards (eventually one): spawning workers for a handful of
/// coordinates costs more than the sweep, and the shrunken endgame —
/// where few coordinates still violate — converges faster Gauss-Seidel
/// anyway. The collapse depends only on the active-set size, which
/// evolves deterministically per `(seed, threads)`.
const MIN_COORDS_PER_SHARD: usize = 32;

/// Sweeps without a new best violation before the stall guard switches
/// to serial sweeps (it switches back the moment a sweep sets a new
/// best). Deterministic: the trigger depends only on the violation
/// trajectory, which is itself deterministic per `(seed, threads)`.
pub(super) const STALL_LIMIT: usize = 8;

/// Above this feature dimension, CSR shards keep their delta-u
/// *sparsely* (a zero-init accumulator plus the touched column list):
/// cloning u costs O(n) per shard per block, which on wide sparse data
/// (n ≫ shard nnz — e.g. text features) would dwarf the sweep itself.
/// Below it, the dense clone is cheaper than paying a second striped
/// dot per gradient. Static per instance, so the choice is
/// deterministic.
const SPARSE_DELTA_MIN_DIM: usize = 4096;

/// A shard's contribution to u, in one of two representations chosen by
/// [`use_sparse_delta`].
enum DeltaU {
    /// u_local − u_block_start, full length (dense or narrow data).
    Dense(Vec<f64>),
    /// Accumulated Δu over only the touched columns; `touched` may hold
    /// duplicates (one entry per stored element of each updated row) —
    /// the merge zeroes each applied column so duplicates are no-ops.
    Sparse { delta: Vec<f64>, touched: Vec<u32> },
}

/// What one shard reports back from a block.
struct ShardSweep {
    /// Coordinates surviving shrinking, in shard (= shuffled) order.
    kept: Vec<usize>,
    /// `(coordinate, new θ)` moves to apply at the block boundary.
    updates: Vec<(usize, f64)>,
    /// The shard's contribution to u.
    delta_u: DeltaU,
    max_violation: f64,
    grad_evals: u64,
    coord_updates: u64,
}

/// Whether shards of this instance should carry sparse delta-u buffers.
fn use_sparse_delta(inst: &Instance) -> bool {
    inst.z.is_sparse() && inst.dim() > SPARSE_DELTA_MIN_DIM
}

/// Resolve how many shards this block runs (shared with the async arm,
/// so both modes collapse to serial sweeps at the same active-set size).
pub(super) fn plan_shards(requested: usize, active_len: usize) -> usize {
    let t = par::effective_threads(requested, active_len.max(1));
    t.min((active_len / MIN_COORDS_PER_SHARD).max(1))
}

/// One shard's local Gauss-Seidel pass over `coords` (a contiguous slice
/// of the shuffled active set). Reads the shared θ and block-start u;
/// every write is deferred into the returned buffers.
fn sweep_shard(
    inst: &Instance,
    c: f64,
    coords: &[usize],
    theta: &[f64],
    u: &[f64],
    m_bar: f64,
    shrink: bool,
    sparse_delta: bool,
) -> ShardSweep {
    let mut out = ShardSweep {
        kept: Vec::with_capacity(coords.len()),
        updates: Vec::new(),
        delta_u: DeltaU::Dense(Vec::new()),
        max_violation: 0.0,
        grad_evals: 0,
        coord_updates: 0,
    };
    if sparse_delta {
        // wide CSR data: never materialize an O(n) copy of u — fold the
        // shard's own moves into a zero-init accumulator (untouched
        // pages stay untouched) read via a second striped dot
        let mut delta = vec![0.0; u.len()];
        let mut touched: Vec<u32> = Vec::new();
        for &i in coords {
            out.grad_evals += 1;
            let zi = inst.z.row(i);
            let g = c * (zi.dot(u) + zi.dot(&delta)) - inst.ybar[i];
            match cd::coord_step_from_g(inst, c, i, theta[i], g, m_bar, shrink) {
                CoordStep::Shrunk => {}
                CoordStep::Kept { viol, update } => {
                    out.kept.push(i);
                    out.max_violation = out.max_violation.max(viol);
                    if let Some(up) = update {
                        out.updates.push((i, up.new_theta));
                        // fused axpy + touched-column recording (stored
                        // entries only — this path is CSR by selection)
                        for (j, v) in zi.iter() {
                            delta[j] += up.delta * v;
                            touched.push(j as u32);
                        }
                        out.coord_updates += 1;
                    }
                }
            }
        }
        out.delta_u = DeltaU::Sparse { delta, touched };
    } else {
        let mut u_local = u.to_vec();
        for &i in coords {
            out.grad_evals += 1;
            match cd::coord_step(inst, c, i, theta[i], &u_local, m_bar, shrink) {
                CoordStep::Shrunk => {}
                CoordStep::Kept { viol, update } => {
                    out.kept.push(i);
                    out.max_violation = out.max_violation.max(viol);
                    if let Some(up) = update {
                        out.updates.push((i, up.new_theta));
                        inst.z.row(i).axpy_into(up.delta, &mut u_local);
                        out.coord_updates += 1;
                    }
                }
            }
        }
        // turn u_local into the delta-u buffer against the block-start u
        for (d, &base) in u_local.iter_mut().zip(u) {
            *d -= base;
        }
        out.delta_u = DeltaU::Dense(u_local);
    }
    out
}

/// The sharded counterpart of `CdSolver::solve_free_with_u` — same
/// reduced-problem semantics (Lemma 4: frozen coordinates live inside u),
/// same shrinking, same convergence re-check. Input invariants (θ/u
/// lengths, box membership, u ≈ Zᵀθ) were already asserted by the
/// dispatching wrapper.
pub(super) fn solve_free_with_u_par(
    cfg: &SolverConfig,
    inst: &Instance,
    c: f64,
    mut theta: Vec<f64>,
    free: &[usize],
    mut u: Vec<f64>,
) -> SolveResult {
    let requested = cfg.cd_threads();
    let sparse_delta = use_sparse_delta(inst);
    let mut rng = Rng::new(cfg.seed);
    let mut stats = SolverStats::default();

    let mut active = cd::clip_zero_norm_rows(inst, &mut theta, free);
    stats.active_coords = active.len();

    let mut m_bar = f64::INFINITY;
    let mut shrunk = false;
    // stall guard state: the best (lowest) sweep violation seen, and how
    // many sweeps have passed since it improved
    let mut best_violation = f64::INFINITY;
    let mut stalled = 0usize;
    // set when a SHARDED sweep measures sub-tol violations: those were
    // taken against per-shard stale u, so the next sweep re-measures
    // Gauss-Seidel against the live u before any convergence decision —
    // `converged` is only ever declared off a serial sweep, exactly the
    // serial solver's criterion
    let mut confirm_serial = false;

    let tol = cfg.tol;
    loop {
        if stats.outer_iters >= cfg.max_outer {
            break;
        }
        stats.outer_iters += 1;
        rng.shuffle(&mut active);

        let t = if confirm_serial || stalled >= STALL_LIMIT {
            1 // confirming convergence, or stalled: Gauss-Seidel sweep
        } else {
            plan_shards(requested, active.len())
        };
        confirm_serial = false;
        let mut sweep_span = crate::obs::Span::enter("sweep");
        sweep_span.attr_str("cd_mode", if t <= 1 { "sync_serial" } else { "sync" });
        sweep_span.attr_str("shard_axis", inst.pick_axis(cfg.shard_axis).name());
        sweep_span.attr("shards", t as f64);
        sweep_span.attr("iter", stats.outer_iters as f64);
        let (kept, max_violation) = if t <= 1 {
            // single shard: THE serial sweep against the live u (shared
            // with `solve_serial`, so small/endgame/confirmation blocks
            // cannot drift from the serial arithmetic)
            cd::sweep_live(
                inst,
                c,
                &active,
                &mut theta,
                &mut u,
                m_bar,
                cfg.shrink,
                &mut stats,
            )
        } else {
            let mut max_violation = 0.0f64;
            let mut kept = Vec::with_capacity(active.len());
            let ranges = inst.balanced_subset_shards(&active, t);
            let sweeps = {
                let (theta_ro, u_ro, active_ro) = (&theta, &u, &active);
                par::run_sharded_ranges(ranges, move |r| {
                    sweep_shard(
                        inst,
                        c,
                        &active_ro[r],
                        theta_ro,
                        u_ro,
                        m_bar,
                        cfg.shrink,
                        sparse_delta,
                    )
                })
            };
            // deterministic merge: fixed shard order, θ moves first (the
            // coordinate sets are disjoint), then each delta-u buffer
            for s in sweeps {
                for &(i, new_theta) in &s.updates {
                    theta[i] = new_theta;
                }
                match s.delta_u {
                    DeltaU::Dense(d) => {
                        for (uj, dv) in u.iter_mut().zip(&d) {
                            if *dv != 0.0 {
                                *uj += *dv;
                            }
                        }
                    }
                    DeltaU::Sparse { mut delta, touched } => {
                        for &j in &touched {
                            let j = j as usize;
                            let dv = delta[j];
                            if dv != 0.0 {
                                u[j] += dv;
                                delta[j] = 0.0; // dedupe repeat columns
                            }
                        }
                    }
                }
                max_violation = max_violation.max(s.max_violation);
                stats.grad_evals = stats.grad_evals.saturating_add(s.grad_evals);
                stats.coord_updates = stats.coord_updates.saturating_add(s.coord_updates);
                kept.extend_from_slice(&s.kept);
            }
            (kept, max_violation)
        };
        sweep_span.attr("violation", max_violation);
        drop(sweep_span);

        shrunk = shrunk || kept.len() < active.len();
        active = kept;
        stats.final_violation = max_violation;
        if max_violation < best_violation {
            best_violation = max_violation;
            stalled = 0;
        } else {
            stalled = stalled.saturating_add(1);
        }

        if max_violation < tol {
            if t > 1 {
                // sub-tol, but measured against block-start u per shard:
                // re-measure with a live-u sweep before believing it
                confirm_serial = true;
                m_bar = cd::relax_m_bar(max_violation, tol);
                continue;
            }
            if cfg.shrink && shrunk {
                // re-expand and confirm on the full free set — the same
                // full-problem re-check as the serial solver, so a point
                // is never declared converged off a shrunken subset
                active = free
                    .iter()
                    .copied()
                    .filter(|&i| inst.z_norms_sq[i] > 0.0)
                    .collect();
                shrunk = false;
                m_bar = f64::INFINITY;
                // new regime: the shrunken set's tiny violations would
                // otherwise read every full-set sweep as a stall
                best_violation = f64::INFINITY;
                stalled = 0;
                continue;
            }
            stats.converged = true;
            break;
        }
        m_bar = cd::relax_m_bar(max_violation, tol);
    }

    SolveResult { theta, u, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::problem::{Instance, Model};
    use crate::solver::CdSolver;

    fn cfg(solver_threads: usize) -> SolverConfig {
        SolverConfig {
            tol: 1e-8,
            max_outer: 100_000,
            solver_threads: Some(solver_threads),
            ..Default::default()
        }
    }

    #[test]
    fn plan_shards_collapses_small_blocks() {
        assert_eq!(plan_shards(4, 0), 1);
        assert_eq!(plan_shards(4, 10), 1, "10 coords are not worth 4 workers");
        assert_eq!(plan_shards(4, 2 * MIN_COORDS_PER_SHARD), 2);
        assert!(plan_shards(4, 100 * MIN_COORDS_PER_SHARD) <= 4);
        assert_eq!(plan_shards(1, 10_000), 1);
    }

    #[test]
    fn parallel_solve_is_kkt_valid_and_converges() {
        let ds = synth::toy_gaussian(21, 120, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        for threads in [2usize, 4, 7] {
            let r = CdSolver::new(cfg(threads)).solve(&inst, 1.0, inst.cold_start());
            assert!(r.stats.converged, "threads={threads}");
            assert!(inst.in_box(&r.theta, 1e-12));
            let v = CdSolver::kkt_violation(&inst, 1.0, &r.theta);
            assert!(v < 1e-6, "threads={threads}: violation {v}");
        }
    }

    #[test]
    fn same_seed_threads_is_deterministic() {
        let ds = synth::toy_gaussian(22, 150, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        for threads in [2usize, 4] {
            let a = CdSolver::new(cfg(threads)).solve(&inst, 0.7, inst.cold_start());
            let b = CdSolver::new(cfg(threads)).solve(&inst, 0.7, inst.cold_start());
            assert_eq!(a.theta, b.theta, "threads={threads}");
            assert_eq!(a.u, b.u, "threads={threads}");
            assert_eq!(a.stats.outer_iters, b.stats.outer_iters);
            assert_eq!(a.stats.grad_evals, b.stats.grad_evals);
            assert_eq!(a.stats.coord_updates, b.stats.coord_updates);
        }
    }

    #[test]
    fn frozen_coordinates_stay_fixed_under_sharding() {
        let ds = synth::toy_gaussian(23, 140, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let full = CdSolver::new(cfg(1)).solve(&inst, 1.0, inst.cold_start());
        let free: Vec<usize> = (0..inst.len())
            .filter(|&i| full.theta[i] > 1e-9 && full.theta[i] < 1.0 - 1e-9)
            .collect();
        let red = CdSolver::new(cfg(4)).solve_free(&inst, 1.0, full.theta.clone(), &free);
        for i in 0..inst.len() {
            if !free.contains(&i) {
                assert_eq!(red.theta[i], full.theta[i], "frozen coord {i} moved");
            }
        }
        let g_full = inst.dual_objective(1.0, &full.theta);
        let g_red = inst.dual_objective(1.0, &red.theta);
        assert!((g_full - g_red).abs() < 1e-7, "{g_full} vs {g_red}");
    }

    #[test]
    fn wide_csr_uses_sparse_delta_and_matches_serial_decisions() {
        // n > SPARSE_DELTA_MIN_DIM forces the sparse delta-u path; the
        // parallel solve must still land on the serial optimum
        let ds = synth::sparse_classes(25, 200, SPARSE_DELTA_MIN_DIM + 10, 0.002);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        assert!(use_sparse_delta(&inst));
        let serial = CdSolver::new(cfg(1)).solve(&inst, 0.8, inst.cold_start());
        assert!(serial.stats.converged);
        for threads in [2usize, 4] {
            let par = CdSolver::new(cfg(threads)).solve(&inst, 0.8, inst.cold_start());
            assert!(par.stats.converged, "threads={threads}");
            let v = CdSolver::kkt_violation(&inst, 0.8, &par.theta);
            assert!(v < 1e-6, "threads={threads}: violation {v}");
            // run-to-run determinism holds on this path too
            let again = CdSolver::new(cfg(threads)).solve(&inst, 0.8, inst.cold_start());
            assert_eq!(par.theta, again.theta, "threads={threads}");
            assert_eq!(par.u, again.u, "threads={threads}");
        }
    }

    #[test]
    fn shard_sweep_counters_lose_nothing() {
        // one full sweep (max_outer = 1, shrinking can't trigger on the
        // first sweep because m̄ = ∞): every active coordinate must be
        // charged exactly one gradient evaluation, across all shards
        let ds = synth::toy_gaussian(24, 200, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let one_sweep = SolverConfig {
            tol: 1e-14,
            max_outer: 1,
            solver_threads: Some(4),
            ..Default::default()
        };
        let r = CdSolver::new(one_sweep).solve(&inst, 5.0, inst.cold_start());
        assert_eq!(r.stats.outer_iters, 1);
        assert_eq!(r.stats.active_coords, inst.len());
        assert_eq!(r.stats.grad_evals, inst.len() as u64, "a shard dropped its counts");
        assert!(r.stats.coord_updates > 0);
        assert!(r.stats.coord_updates <= inst.len() as u64);
    }
}
