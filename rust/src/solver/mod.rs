//! Solvers for the dual boxed QP (12)/(15).
//!
//! The workhorse is [`cd::CdSolver`] — a LIBLINEAR-style dual coordinate
//! descent (Hsieh et al., ICML'08; the paper's §2 "Method to solve problem
//! (15)") with optional active-set shrinking and warm starts. It solves
//! the *reduced* problem of Lemma 4 natively: fixed coordinates are simply
//! frozen and their contribution stays inside the running vector
//! u = Zᵀθ, which is exactly the ŷ-offset construction of the lemma
//! without materializing any sub-matrix.
//!
//! The sweep itself is sharded over a persistent pinned worker pool
//! ([`crate::linalg::par::SolverPool`]) in one of two modes selected by
//! [`crate::config::CdMode`] (`--cd-mode`, default `sync`):
//!
//! * [`cd_par`] — block-synchronous parallel CD over nnz-balanced shards
//!   of the active set. `cd_threads = 1` is byte-identical to the serial
//!   solver; other values converge to the same optimum at `tol` and are
//!   deterministic per `(seed, threads)`.
//! * [`cd_async`] — opt-in asynchronous ("wild") CD: workers race
//!   against one shared atomic u with no block barrier, with θ
//!   reconciliation and a serial confirmation sweep guaranteeing the
//!   returned point is KKT-valid at `tol`. Nondeterministic trajectory.
//!
//! Thread count comes from [`crate::config::SolverConfig::cd_threads`]
//! (`--solver-threads`; defaults to the scan's `threads`) — see README
//! §Solver for the full determinism contract.
//!
//! A projected-gradient solver ([`pg::PgSolver`]) is included as an
//! independent cross-check used by the test suite (different algorithm,
//! same optimum).

pub mod cd;
mod cd_async;
mod cd_par;
pub mod pg;

pub use cd::{CdSolver, SolveResult, SolverStats};
pub use pg::PgSolver;
