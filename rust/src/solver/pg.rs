//! Projected-gradient solver — an independent cross-check for the CD
//! solver (different algorithm, same unique u* and objective).
//!
//! Minimizes g(θ) = C/2·‖Zᵀθ‖² − ⟨ȳ, θ⟩ over the box with Armijo
//! backtracking on the projected step. Intended for tests and small
//! problems; the CD solver is the production path.

use crate::linalg::{self};
use crate::problem::Instance;

#[derive(Clone, Debug)]
pub struct PgSolver {
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for PgSolver {
    fn default() -> Self {
        PgSolver { tol: 1e-8, max_iters: 50_000 }
    }
}

impl PgSolver {
    /// Solve from `theta0`; returns (θ, converged).
    pub fn solve(&self, inst: &Instance, c: f64, mut theta: Vec<f64>) -> (Vec<f64>, bool) {
        assert_eq!(theta.len(), inst.len());
        inst.project_box(&mut theta);
        let l = inst.len();
        let mut grad = vec![0.0; l];
        let mut step = 1.0f64;
        let mut converged = false;
        for _ in 0..self.max_iters {
            let u = inst.u_from_theta(&theta);
            // ∇g = C·Z·u − ȳ
            for i in 0..l {
                grad[i] = c * inst.z.row(i).dot(&u) - inst.ybar[i];
            }
            // projected-gradient optimality measure
            let mut viol = 0.0f64;
            for i in 0..l {
                let pg = if theta[i] <= inst.lo[i] + 1e-12 {
                    grad[i].min(0.0)
                } else if theta[i] >= inst.hi[i] - 1e-12 {
                    grad[i].max(0.0)
                } else {
                    grad[i]
                };
                viol = viol.max(pg.abs());
            }
            if viol < self.tol {
                converged = true;
                break;
            }
            // backtracking: g(P(θ − s∇)) ≤ g(θ) − (σ/s)·‖P(θ−s∇) − θ‖²
            let g0 = inst.dual_objective(c, &theta);
            let mut accepted = false;
            for _ in 0..60 {
                let mut cand = theta.clone();
                for i in 0..l {
                    cand[i] = linalg::clamp(theta[i] - step * grad[i], inst.lo[i], inst.hi[i]);
                }
                let diff_sq: f64 = cand
                    .iter()
                    .zip(&theta)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if diff_sq == 0.0 {
                    break;
                }
                let g1 = inst.dual_objective(c, &cand);
                if g1 <= g0 - 1e-4 / step * diff_sq {
                    theta = cand;
                    accepted = true;
                    step *= 1.3; // try growing again next iter
                    break;
                }
                step *= 0.5;
            }
            if !accepted {
                // step collapsed — numerically at the optimum
                converged = viol < self.tol * 100.0;
                break;
            }
        }
        (theta, converged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverConfig;
    use crate::data::{synth, Rng};
    use crate::problem::{Instance, Model};
    use crate::solver::CdSolver;

    #[test]
    fn agrees_with_cd_on_svm() {
        let ds = synth::toy_gaussian(21, 40, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let c = 1.0;
        let cd = CdSolver::new(SolverConfig { tol: 1e-10, ..Default::default() })
            .solve(&inst, c, inst.cold_start());
        let (pg, conv) = PgSolver::default().solve(&inst, c, inst.cold_start());
        assert!(conv);
        let g_cd = inst.dual_objective(c, &cd.theta);
        let g_pg = inst.dual_objective(c, &pg);
        assert!((g_cd - g_pg).abs() < 1e-6, "{g_cd} vs {g_pg}");
        let u_pg = inst.u_from_theta(&pg);
        assert!(crate::linalg::max_abs_diff(&cd.u, &u_pg) < 1e-4);
    }

    #[test]
    fn agrees_with_cd_on_lad() {
        let mut rng = Rng::new(5);
        let ds = synth::random_regression(&mut rng, 30, 4);
        let inst = Instance::from_dataset(Model::Lad, &ds);
        let c = 0.3;
        let cd = CdSolver::new(SolverConfig { tol: 1e-10, ..Default::default() })
            .solve(&inst, c, inst.cold_start());
        let (pg, _) = PgSolver::default().solve(&inst, c, inst.cold_start());
        let g_cd = inst.dual_objective(c, &cd.theta);
        let g_pg = inst.dual_objective(c, &pg);
        assert!((g_cd - g_pg).abs() < 1e-6, "{g_cd} vs {g_pg}");
    }
}
