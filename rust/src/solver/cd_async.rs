//! Asynchronous ("wild") parallel dual coordinate descent — the opt-in
//! `--cd-mode async` arm.
//!
//! Where [`super::cd_par`] is block-synchronous (shards work against a
//! frozen block-start u and merge deterministically at a barrier), this
//! arm lets workers race: during a *wild round* every worker runs
//! Gauss-Seidel locally over its own slice of the active set while
//! folding each Δθᵢ·zᵢ straight into one SHARED u through per-component
//! f64 CAS-adds — no block barrier, no delta-u buffers, gradients read
//! whatever mix of neighbours' updates has landed (the Hogwild-style
//! trade: staleness for zero synchronization). θ itself needs no atomics:
//! the active set is kept *sorted* for the wild phase, so each shard owns
//! a contiguous interval of coordinate ids and writes its own disjoint
//! θ slab.
//!
//! Two design points keep this exact-in-the-end rather than
//! approximately-converged:
//!
//! * **Deferred θ reconciliation.** After each wild round, u is recomputed
//!   exactly as Zᵀθ from the (race-free) θ — CAS interleaving and atomic
//!   rounding drift never survive a round.
//! * **Serial confirmation.** Convergence is declared exclusively by the
//!   serial live-u sweep ([`super::cd::sweep_live`]) with the serial
//!   solver's shrinking thresholds, full-active-set re-check, and stall
//!   guard — the same criterion `cd_par` confirms with. Wild rounds only
//!   ever *accelerate* θ toward the optimum; they decide nothing. Once
//!   the stall guard trips, wild rounds stop and the solve degenerates to
//!   pure serial sweeps, so termination is inherited from the serial
//!   solver.
//!
//! Stable shard affinity: the wild phase cuts the *sorted* active set
//! into standing nnz-balanced intervals ([`Instance::balanced_subset_shards`]
//! from the cached prefix) and dispatches slab k to pool worker k−1
//! (see [`crate::linalg::par`]), so a worker keeps touching the same
//! Z-row interval across rounds and first-touch NUMA placement sticks —
//! unlike `cd_par`, whose shuffled shards intentionally re-deal rows to
//! preserve its bitwise contract.
//!
//! Contract (locked by `tests/integration_cd_async.rs`): the returned
//! point is KKT-valid at the same `tol`, with the serial solution's
//! support/E-sets; run-to-run determinism is explicitly traded away —
//! two async solves of the same problem may return different bit
//! patterns (both valid). `--cd-mode sync` never reaches this module.

use std::sync::atomic::{AtomicU64, Ordering};

use super::cd::{self, CoordStep, SolveResult, SolverStats};
use super::cd_par;
use crate::config::SolverConfig;
use crate::data::Rng;
use crate::linalg::{par, RowView};
use crate::problem::Instance;

/// Local Gauss-Seidel sweeps per worker per wild round. More sweeps
/// amortize the round's reconciliation O(l·n) better but read staler
/// neighbours; a handful is the usual wild-CD sweet spot.
const WILD_SWEEPS: usize = 4;

/// One CAS-add of `add` onto an f64 stored as bits. Relaxed ordering is
/// sufficient: wild gradients tolerate any staleness, and the exact u is
/// rebuilt from θ after the round anyway.
#[inline]
fn atomic_add(slot: &AtomicU64, add: f64) {
    let mut cur = slot.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + add).to_bits();
        match slot.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// ⟨row, u⟩ against the racing atomic u (relaxed loads; explicitly
/// stored zeros skipped — dense rows iterate every column).
#[inline]
fn dot_atomic(row: RowView<'_>, u: &[AtomicU64]) -> f64 {
    let mut acc = 0.0;
    for (j, v) in row.iter() {
        if v != 0.0 {
            acc += v * f64::from_bits(u[j].load(Ordering::Relaxed));
        }
    }
    acc
}

/// One wild round: cut the sorted active set into standing nnz-balanced
/// θ slabs, race [`WILD_SWEEPS`] local Gauss-Seidel sweeps per slab
/// against the shared atomic u, then return with θ updated in place
/// (u is left to the caller's reconciliation). Returns nothing decision-
/// relevant by design.
#[allow(clippy::too_many_arguments)]
fn wild_round(
    inst: &Instance,
    c: f64,
    tol: f64,
    seed: u64,
    epoch: u64,
    shards: usize,
    active_sorted: &[usize],
    theta: &mut [f64],
    u: &[f64],
    stats: &mut SolverStats,
) {
    let l = inst.len();
    let ranges = inst.balanced_subset_shards(active_sorted, shards);
    // slab boundaries in θ-index space: the active set is sorted, so
    // shard k's coordinate ids all fall in [cuts[k], cuts[k+1])
    let mut cuts = Vec::with_capacity(ranges.len() + 1);
    cuts.push(0usize);
    for r in ranges.iter().skip(1) {
        cuts.push(active_sorted.get(r.start).copied().unwrap_or(l));
    }
    cuts.push(l);

    let u_atomic: Vec<AtomicU64> = u.iter().map(|&v| AtomicU64::new(v.to_bits())).collect();
    let grad_evals = AtomicU64::new(0);
    let coord_updates = AtomicU64::new(0);
    {
        let (u_ro, ge, cu) = (&u_atomic, &grad_evals, &coord_updates);
        par::run_sharded_mut(theta, 1, &cuts, move |rows, block| {
            let lo = rows.start;
            let p0 = active_sorted.partition_point(|&i| i < rows.start);
            let p1 = active_sorted.partition_point(|&i| i < rows.end);
            if p0 == p1 {
                return;
            }
            let mut order: Vec<usize> = active_sorted[p0..p1].to_vec();
            // any per-(round, slab) stream works — wild sweeps make no
            // determinism promise, the seed just decorrelates slabs
            let mut rng = Rng::new(
                seed ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ (rows.start as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
            );
            for _ in 0..WILD_SWEEPS {
                rng.shuffle(&mut order);
                let mut max_viol = 0.0f64;
                for &i in &order {
                    ge.fetch_add(1, Ordering::Relaxed);
                    let g = c * dot_atomic(inst.z.row(i), u_ro) - inst.ybar[i];
                    // m̄ = ∞ / shrink = false: wild measurements are too
                    // stale to shrink on — the serial sweeps own shrinking
                    match cd::coord_step_from_g(inst, c, i, block[i - lo], g, f64::INFINITY, false)
                    {
                        CoordStep::Shrunk => {}
                        CoordStep::Kept { viol, update } => {
                            max_viol = max_viol.max(viol);
                            if let Some(up) = update {
                                block[i - lo] = up.new_theta;
                                for (j, v) in inst.z.row(i).iter() {
                                    if v != 0.0 {
                                        atomic_add(&u_ro[j], up.delta * v);
                                    }
                                }
                                cu.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                if max_viol < tol {
                    break; // slab locally quiescent — stop burning sweeps
                }
            }
        });
    }
    stats.grad_evals = stats.grad_evals.saturating_add(grad_evals.into_inner());
    stats.coord_updates = stats.coord_updates.saturating_add(coord_updates.into_inner());
}

/// The asynchronous counterpart of `CdSolver::solve_free_with_u` — same
/// reduced-problem semantics, same convergence criterion (serial sweeps
/// decide everything), nondeterministic intermediate trajectory. Input
/// invariants were asserted by the dispatching wrapper.
pub(super) fn solve_free_with_u_async(
    cfg: &SolverConfig,
    inst: &Instance,
    c: f64,
    mut theta: Vec<f64>,
    free: &[usize],
    mut u: Vec<f64>,
) -> SolveResult {
    let requested = cfg.cd_threads();
    let mut rng = Rng::new(cfg.seed);
    let mut stats = SolverStats::default();

    let mut active = cd::clip_zero_norm_rows(inst, &mut theta, free);
    stats.active_coords = active.len();

    let mut m_bar = f64::INFINITY;
    let mut shrunk = false;
    // same stall guard as cd_par, same role: wild rounds that stop
    // helping (coherent data oscillating under staleness) are cut off and
    // the solve falls through to pure serial sweeps, which provably
    // terminate
    let mut best_violation = f64::INFINITY;
    let mut stalled = 0usize;
    let mut epoch = 0u64;

    let tol = cfg.tol;
    loop {
        if stats.outer_iters >= cfg.max_outer {
            break;
        }
        let t = cd_par::plan_shards(requested, active.len());
        if t > 1 && stalled < cd_par::STALL_LIMIT {
            stats.outer_iters += 1;
            epoch += 1;
            let mut sorted = active.clone();
            sorted.sort_unstable();
            {
                let mut sp = crate::obs::Span::enter("sweep");
                sp.attr_str("cd_mode", "async");
                sp.attr_str("shard_axis", inst.pick_axis(cfg.shard_axis).name());
                sp.attr("shards", t as f64);
                sp.attr("iter", stats.outer_iters as f64);
                wild_round(
                    inst, c, tol, cfg.seed, epoch, t, &sorted, &mut theta, &u, &mut stats,
                );
                // deferred reconciliation: the racing u is discarded and
                // rebuilt exactly from θ, so CAS drift never compounds —
                // this once-per-round O(nnz) rebuild is the async arm's
                // dominant fixed cost on wide data, so it is axis-aware
                u = inst.u_from_theta_axis(&theta, cfg.shard_axis, cfg.threads);
            }
            if stats.outer_iters >= cfg.max_outer {
                break;
            }
        }

        // serial confirmation sweep — verbatim the serial solver's loop
        // body, so shrinking, m̄, re-expansion, and `converged` are the
        // serial criterion
        stats.outer_iters += 1;
        rng.shuffle(&mut active);
        let (kept, max_violation) = {
            let mut sp = crate::obs::Span::enter("sweep");
            sp.attr_str("cd_mode", "async_confirm");
            sp.attr_str("shard_axis", inst.pick_axis(cfg.shard_axis).name());
            sp.attr("shards", 1.0);
            sp.attr("iter", stats.outer_iters as f64);
            let out = cd::sweep_live(
                inst,
                c,
                &active,
                &mut theta,
                &mut u,
                m_bar,
                cfg.shrink,
                &mut stats,
            );
            sp.attr("violation", out.1);
            out
        };
        shrunk = shrunk || kept.len() < active.len();
        active = kept;
        stats.final_violation = max_violation;
        if max_violation < best_violation {
            best_violation = max_violation;
            stalled = 0;
        } else {
            stalled = stalled.saturating_add(1);
        }

        if max_violation < tol {
            if cfg.shrink && shrunk {
                active = free
                    .iter()
                    .copied()
                    .filter(|&i| inst.z_norms_sq[i] > 0.0)
                    .collect();
                shrunk = false;
                m_bar = f64::INFINITY;
                best_violation = f64::INFINITY;
                stalled = 0;
                continue;
            }
            stats.converged = true;
            break;
        }
        m_bar = cd::relax_m_bar(max_violation, tol);
    }

    SolveResult { theta, u, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CdMode;
    use crate::data::synth;
    use crate::problem::{Instance, Model};
    use crate::solver::CdSolver;

    fn cfg(solver_threads: usize) -> SolverConfig {
        SolverConfig {
            tol: 1e-8,
            max_outer: 100_000,
            solver_threads: Some(solver_threads),
            cd_mode: CdMode::Async,
            ..Default::default()
        }
    }

    #[test]
    fn atomic_add_accumulates() {
        let slot = AtomicU64::new(1.5f64.to_bits());
        atomic_add(&slot, 0.25);
        atomic_add(&slot, -2.0);
        assert_eq!(f64::from_bits(slot.into_inner()), -0.25);
    }

    #[test]
    fn dot_atomic_matches_plain_dot() {
        let ds = synth::toy_gaussian(41, 30, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let u: Vec<f64> = (0..inst.dim()).map(|j| 0.1 * j as f64 - 0.05).collect();
        let ua: Vec<AtomicU64> = u.iter().map(|&v| AtomicU64::new(v.to_bits())).collect();
        for i in 0..inst.len() {
            let plain = inst.z.row(i).dot(&u);
            let atomic = dot_atomic(inst.z.row(i), &ua);
            assert!((plain - atomic).abs() < 1e-12, "row {i}: {plain} vs {atomic}");
        }
    }

    #[test]
    fn async_solve_is_kkt_valid_and_converges() {
        let ds = synth::toy_gaussian(42, 160, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        for threads in [2usize, 4] {
            let r = CdSolver::new(cfg(threads)).solve(&inst, 1.0, inst.cold_start());
            assert!(r.stats.converged, "threads={threads}");
            assert!(inst.in_box(&r.theta, 1e-12));
            let v = CdSolver::kkt_violation(&inst, 1.0, &r.theta);
            assert!(v < 1e-6, "threads={threads}: violation {v}");
        }
    }

    #[test]
    fn async_matches_serial_objective() {
        let ds = synth::toy_gaussian(43, 140, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let serial = CdSolver::new(SolverConfig {
            solver_threads: Some(1),
            ..cfg(1)
        })
        .solve(&inst, 0.7, inst.cold_start());
        let wild = CdSolver::new(cfg(4)).solve(&inst, 0.7, inst.cold_start());
        let gs = inst.dual_objective(0.7, &serial.theta);
        let gw = inst.dual_objective(0.7, &wild.theta);
        assert!((gs - gw).abs() < 1e-7, "{gs} vs {gw}");
        assert!(crate::linalg::max_abs_diff(&serial.u, &wild.u) < 1e-5);
    }

    #[test]
    fn async_respects_max_outer() {
        let ds = synth::toy_gaussian(44, 200, 0.5, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let s = CdSolver::new(SolverConfig { max_outer: 2, tol: 1e-14, ..cfg(4) });
        let r = s.solve(&inst, 10.0, inst.cold_start());
        assert!(r.stats.outer_iters <= 2);
        assert!(!r.stats.converged);
    }

    #[test]
    fn async_mode_with_one_thread_is_bitwise_serial() {
        // cd_threads() == 1 never reaches the parallel arms at all —
        // cd_mode must be irrelevant there
        let ds = synth::toy_gaussian(45, 120, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let a = CdSolver::new(cfg(1)).solve(&inst, 0.9, inst.cold_start());
        let b = CdSolver::new(SolverConfig { cd_mode: CdMode::Sync, ..cfg(1) })
            .solve(&inst, 0.9, inst.cold_start());
        assert_eq!(a.theta, b.theta);
        assert_eq!(a.u, b.u);
        assert_eq!(a.stats.grad_evals, b.stats.grad_evals);
    }
}
