//! The paper-experiment harness: one regenerator per table and figure in
//! the paper's §7 evaluation (see `DESIGN.md` experiment index).
//!
//! Every regenerator writes machine-readable CSV under `out_dir` and
//! returns the human-readable rendering (tables in the paper's layout,
//! stacked-area charts for the figures). The benches and the CLI
//! `experiment` subcommand are thin wrappers over these functions.

use crate::config::SolverConfig;
use crate::data::{registry, simreal, synth, Dataset};
use crate::path::{PathConfig, PathOutput, PathRunner};
use crate::problem::Model;
use crate::report::{CsvWriter, StackedArea, Table};
use crate::screening::RuleKind;
use std::path::PathBuf;

/// Options shared by all experiment regenerators.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// Scale for the simulated real sets ((0,1]; 1.0 = paper-size).
    pub scale: f64,
    /// Grid points (paper: 100).
    pub points: usize,
    /// Solver tolerance.
    pub tol: f64,
    /// Output directory for CSVs.
    pub out_dir: PathBuf,
    /// Route the DVI scan through the PJRT artifact when available.
    pub use_pjrt: bool,
    /// Per-step full-KKT validation (slower; for the test suite).
    pub validate: bool,
    /// Worker threads for the sharded scan/Gram/validation engine
    /// (crate convention: 1 = serial, 0 = auto-detect, n = n workers).
    /// Table/figure regeneration at paper scale should run 0 (auto) so
    /// the ParScan engine is exploited; results are identical either way.
    pub threads: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: 0.25,
            points: 100,
            tol: 1e-6,
            out_dir: PathBuf::from("results"),
            use_pjrt: false,
            validate: false,
            threads: 1,
        }
    }
}

impl ExpOptions {
    fn path_config(&self, c_min: f64, c_max: f64) -> PathConfig {
        PathConfig::log_grid(c_min, c_max, self.points)
            .with_solver(SolverConfig {
                tol: self.tol,
                threads: self.threads,
                ..Default::default()
            })
            .with_validation(self.validate)
    }

    fn run_path(&self, model: Model, ds: &Dataset, rule: RuleKind) -> PathOutput {
        let mut runner = PathRunner::new(model, self.path_config(1e-2, 10.0), rule);
        if self.use_pjrt && rule == RuleKind::DviW {
            if let Ok(s) = crate::runtime::PjrtScreener::from_default_dir() {
                runner = runner.with_backend(Box::new(s));
            }
        }
        runner.run(ds)
    }

    /// The paper's "Solver" arm: every grid point solved independently
    /// (no warm start) — the protocol behind Tables 1–3.
    fn run_cold_baseline(&self, model: Model, ds: &Dataset) -> PathOutput {
        let cfg = self.path_config(1e-2, 10.0).with_cold_baseline();
        PathRunner::new(model, cfg, RuleKind::None).run(ds)
    }
}

/// Dispatch an experiment id. Returns the rendered report.
pub fn run(id: &str, opts: &ExpOptions) -> Result<String, String> {
    match id {
        "fig1" => Ok(fig1(opts)),
        "tab1" => Ok(tab1(opts)),
        "fig2" => Ok(fig2(opts)),
        "tab2" => Ok(tab2(opts)),
        "fig3" => Ok(fig3(opts)),
        "tab3" => Ok(tab3(opts)),
        "ablation" => Ok(ablation_grid_density(opts)),
        "all" => {
            let mut out = String::new();
            for id in ["fig1", "tab1", "fig2", "tab2", "fig3", "tab3", "ablation"] {
                out.push_str(&run(id, opts)?);
                out.push('\n');
            }
            Ok(out)
        }
        _ => Err(format!(
            "unknown experiment id `{id}` (fig1..fig3, tab1..tab3, ablation, all)"
        )),
    }
}

fn toys(opts: &ExpOptions) -> Vec<Dataset> {
    // The paper's toys are small (1000/class); always run them at full
    // size — `scale` only shrinks the six large real-set analogs. Tests
    // pass scale ≪ 1 to shrink everything, so honor very small scales.
    let per_class = if opts.scale >= 0.25 {
        1000
    } else {
        ((1000.0 * opts.scale).round() as usize).max(25)
    };
    synth::paper_toys(per_class)
}

fn write_series_csv(opts: &ExpOptions, name: &str, out: &PathOutput) {
    let path = opts.out_dir.join(name);
    let mut w = match CsvWriter::create(&path, &["c", "rej_lo", "rej_hi", "free", "solve_secs"]) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("[experiments] csv {name}: {e}");
            return;
        }
    };
    let l = out.l as f64;
    for s in &out.steps {
        let _ = w.row_f64(&[
            s.c,
            s.n_lo as f64 / l,
            s.n_hi as f64 / l,
            s.free as f64,
            s.solve_secs,
        ]);
    }
    let _ = w.flush();
}

// ---------------------------------------------------------------- fig 1 --

/// Fig. 1: DVI_s rejection stacked-area charts on Toy1/2/3.
pub fn fig1(opts: &ExpOptions) -> String {
    let mut report = String::from("=== Figure 1: DVI_s rejection on the 2-D toys ===\n");
    for ds in toys(opts) {
        let out = opts.run_path(Model::Svm, &ds, RuleKind::DviW);
        write_series_csv(opts, &format!("fig1_{}.csv", ds.name), &out);
        let (lo, hi) = out.rejection_series();
        let chart = StackedArea::new(
            format!("{} (l={}, mean rejection {:.1}%)", ds.name, out.l, 100.0 * out.mean_rejection()),
            lo,
            hi,
        )
        .height(14);
        report.push_str(&chart.render());
        report.push('\n');
    }
    report
}

// ---------------------------------------------------------------- tab 1 --

/// Table 1: Solver vs Solver+DVI_s runtimes on the toys. "Solver" is the
/// paper's protocol (independent solves per C); "Solver(warm)" is the
/// stronger warm-started baseline we also report for honesty.
pub fn tab1(opts: &ExpOptions) -> String {
    svm_lad_speedup_table(
        "=== Table 1: SVM path runtimes on the toys (seconds) ===",
        "tab1.csv",
        opts,
        Model::Svm,
        toys(opts),
    )
}

fn svm_lad_speedup_table(
    title: &str,
    csv_name: &str,
    opts: &ExpOptions,
    model: Model,
    datasets: Vec<Dataset>,
) -> String {
    let mut table = Table::new(title).header(&[
        "dataset",
        "Solver",
        "Solver(warm)",
        "Solver+DVIs",
        "DVIs",
        "Init.",
        "Speedup",
        "Speedup(warm)",
        "work x",
    ]);
    let csv = opts.out_dir.join(csv_name);
    let mut w = CsvWriter::create(
        &csv,
        &[
            "dataset",
            "solver_cold_secs",
            "solver_warm_secs",
            "screened_secs",
            "rule_secs",
            "init_secs",
            "speedup_cold",
            "speedup_warm",
            "grad_eval_ratio",
        ],
    )
    .ok();
    for ds in datasets {
        let cold = opts.run_cold_baseline(model, &ds);
        let warm = opts.run_path(model, &ds, RuleKind::None);
        let dvi = opts.run_path(model, &ds, RuleKind::DviW);
        let speedup_cold = cold.total_secs / dvi.total_secs;
        let speedup_warm = warm.total_secs / dvi.total_secs;
        let work = cold.total_grad_evals() as f64 / dvi.total_grad_evals().max(1) as f64;
        table.row(&[
            ds.name.clone(),
            format!("{:.3}", cold.total_secs),
            format!("{:.3}", warm.total_secs),
            format!("{:.3}", dvi.total_secs),
            format!("{:.4}", dvi.screen_secs),
            format!("{:.3}", dvi.init_secs),
            format!("{speedup_cold:.2}x"),
            format!("{speedup_warm:.2}x"),
            format!("{work:.1}x"),
        ]);
        if let Some(w) = w.as_mut() {
            let _ = w.row(&[
                ds.name.clone(),
                cold.total_secs.to_string(),
                warm.total_secs.to_string(),
                dvi.total_secs.to_string(),
                dvi.screen_secs.to_string(),
                dvi.init_secs.to_string(),
                speedup_cold.to_string(),
                speedup_warm.to_string(),
                work.to_string(),
            ]);
        }
    }
    if let Some(w) = w.as_mut() {
        let _ = w.flush();
    }
    table.render()
}

// ---------------------------------------------------------------- fig 2 --

/// Fig. 2: SSNSV vs ESSNSV vs DVI_s rejection on the SVM real-set analogs.
pub fn fig2(opts: &ExpOptions) -> String {
    let mut report =
        String::from("=== Figure 2: rejection ratio, SSNSV vs ESSNSV vs DVI_s (SVM) ===\n");
    for name in simreal::SVM_SETS {
        let ds = registry::resolve(name, opts.scale, crate::data::Task::Classification)
            .expect("registry");
        let mut rows: Vec<(RuleKind, PathOutput)> = Vec::new();
        for rule in [RuleKind::Ssnsv, RuleKind::Essnsv, RuleKind::DviW] {
            let out = opts.run_path(Model::Svm, &ds, rule);
            write_series_csv(opts, &format!("fig2_{}_{}.csv", ds.name, rule.name()), &out);
            rows.push((rule, out));
        }
        let mut t = Table::new(format!("{} (l={}, n={})", ds.name, ds.len(), ds.dim()))
            .header(&["rule", "mean rejection", "final-step rejection"]);
        for (rule, out) in &rows {
            let last = out.steps.last().unwrap().rejection(out.l);
            t.row(&[
                rule.name().to_string(),
                format!("{:.1}%", 100.0 * out.mean_rejection()),
                format!("{:.1}%", 100.0 * last),
            ]);
        }
        report.push_str(&t.render());
        // curve for DVI (the paper's strongest series) as a stacked chart
        let (lo, hi) = rows.last().unwrap().1.rejection_series();
        report.push_str(&StackedArea::new(format!("{} DVI_s", ds.name), lo, hi).height(10).render());
        report.push('\n');
    }
    report
}

// ---------------------------------------------------------------- tab 2 --

/// Table 2: SVM path runtimes with SSNSV / ESSNSV / DVI_s on the real-set
/// analogs.
pub fn tab2(opts: &ExpOptions) -> String {
    let mut report = String::new();
    let csv = opts.out_dir.join("tab2.csv");
    let mut w = CsvWriter::create(
        &csv,
        &["dataset", "arm", "rule_secs", "init_secs", "total_secs", "speedup"],
    )
    .ok();
    for name in simreal::SVM_SETS {
        let ds = registry::resolve(name, opts.scale, crate::data::Task::Classification)
            .expect("registry");
        let mut t = Table::new(format!(
            "=== Table 2 [{}] (l={}, n={}) ===",
            ds.name,
            ds.len(),
            ds.dim()
        ))
        .header(&["arm", "rule", "Init.", "Total", "Speedup"]);
        let plain = opts.run_cold_baseline(Model::Svm, &ds);
        t.row(&[
            "Solver".into(),
            "-".into(),
            "-".into(),
            format!("{:.2}", plain.total_secs),
            "-".into(),
        ]);
        if let Some(w) = w.as_mut() {
            let _ = w.row(&[
                ds.name.clone(),
                "solver".into(),
                "0".into(),
                "0".into(),
                plain.total_secs.to_string(),
                "1.0".into(),
            ]);
        }
        for rule in [RuleKind::Ssnsv, RuleKind::Essnsv, RuleKind::DviW] {
            let out = opts.run_path(Model::Svm, &ds, rule);
            let speedup = plain.total_secs / out.total_secs;
            t.row(&[
                format!("Solver+{}", rule.name().to_uppercase()),
                format!("{:.3}", out.screen_secs),
                format!("{:.2}", out.init_secs),
                format!("{:.2}", out.total_secs),
                format!("{speedup:.2}x"),
            ]);
            if let Some(w) = w.as_mut() {
                let _ = w.row(&[
                    ds.name.clone(),
                    rule.name().into(),
                    out.screen_secs.to_string(),
                    out.init_secs.to_string(),
                    out.total_secs.to_string(),
                    speedup.to_string(),
                ]);
            }
        }
        report.push_str(&t.render());
        report.push('\n');
    }
    if let Some(w) = w.as_mut() {
        let _ = w.flush();
    }
    report
}

// ---------------------------------------------------------------- fig 3 --

/// Fig. 3: DVI_s rejection for LAD on the regression analogs.
pub fn fig3(opts: &ExpOptions) -> String {
    let mut report = String::from("=== Figure 3: DVI_s rejection for LAD ===\n");
    for name in simreal::LAD_SETS {
        let ds = registry::resolve(name, opts.scale, crate::data::Task::Regression)
            .expect("registry");
        let out = opts.run_path(Model::Lad, &ds, RuleKind::DviW);
        write_series_csv(opts, &format!("fig3_{}.csv", ds.name), &out);
        let (lo, hi) = out.rejection_series();
        report.push_str(
            &StackedArea::new(
                format!(
                    "{} (l={}, mean rejection {:.1}%)",
                    ds.name,
                    out.l,
                    100.0 * out.mean_rejection()
                ),
                lo,
                hi,
            )
            .height(12)
            .render(),
        );
        report.push('\n');
    }
    report
}

// ---------------------------------------------------------------- tab 3 --

/// Table 3: LAD path runtimes, Solver vs Solver+DVI_s (same dual-baseline
/// structure as Table 1).
pub fn tab3(opts: &ExpOptions) -> String {
    let datasets: Vec<Dataset> = simreal::LAD_SETS
        .iter()
        .map(|name| {
            registry::resolve(name, opts.scale, crate::data::Task::Regression)
                .expect("registry")
        })
        .collect();
    svm_lad_speedup_table(
        "=== Table 3: LAD path runtimes (seconds) ===",
        "tab3.csv",
        opts,
        Model::Lad,
        datasets,
    )
}

// ------------------------------------------------------------ ablation --

/// Design-choice ablation (DESIGN.md): DVI's screening power as a
/// function of grid density, against the grid-independent ESSNSV region.
/// Exposes the crossover: sequential DVI needs a reasonably dense path
/// (its Theorem-6 radius scales with the C-gap), while ESSNSV is flat.
pub fn ablation_grid_density(opts: &ExpOptions) -> String {
    let ds = synth::toy_gaussian(2, ((1000.0 * opts.scale).max(100.0)) as usize, 0.75, 0.75);
    let mut table = Table::new(
        "=== Ablation: rejection vs grid density (toy2) — DVI (sequential) vs ESSNSV (static) ===",
    )
    .header(&["grid points", "DVI_s", "ESSNSV", "winner"]);
    let csv = opts.out_dir.join("ablation_grid.csv");
    let mut w = CsvWriter::create(&csv, &["points", "dvi", "essnsv"]).ok();
    for points in [5usize, 10, 25, 50, 100, 200] {
        let cfg = || {
            PathConfig::log_grid(1e-2, 10.0, points).with_solver(SolverConfig {
                tol: opts.tol,
                threads: opts.threads,
                ..Default::default()
            })
        };
        let dvi = PathRunner::new(Model::Svm, cfg(), RuleKind::DviW).run(&ds);
        let ess = PathRunner::new(Model::Svm, cfg(), RuleKind::Essnsv).run(&ds);
        let (a, b) = (dvi.mean_rejection(), ess.mean_rejection());
        table.row(&[
            points.to_string(),
            format!("{:.1}%", 100.0 * a),
            format!("{:.1}%", 100.0 * b),
            if a >= b { "DVI" } else { "ESSNSV" }.into(),
        ]);
        if let Some(w) = w.as_mut() {
            let _ = w.row_f64(&[points as f64, a, b]);
        }
    }
    if let Some(w) = w.as_mut() {
        let _ = w.flush();
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExpOptions {
        let mut dir = std::env::temp_dir();
        dir.push(format!("dvi_exp_test_{}", std::process::id()));
        ExpOptions {
            scale: 0.02,
            points: 4,
            tol: 1e-5,
            out_dir: dir,
            use_pjrt: false,
            validate: false,
            threads: 2, // exercise the sharded engine in the harness tests
        }
    }

    #[test]
    fn unknown_id_is_error() {
        assert!(run("nope", &tiny_opts()).is_err());
    }

    #[test]
    fn fig1_and_tab1_render() {
        let opts = tiny_opts();
        let f = fig1(&opts);
        assert!(f.contains("toy1"));
        assert!(f.contains("█"));
        let t = tab1(&opts);
        assert!(t.contains("Speedup"));
        assert!(opts.out_dir.join("tab1.csv").exists());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }

    #[test]
    fn fig3_tab3_render() {
        let opts = tiny_opts();
        let f = fig3(&opts);
        assert!(f.contains("magic-sim"));
        let t = tab3(&opts);
        assert!(t.contains("houses-sim"));
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
