//! The paper-experiment harness: one regenerator per table and figure in
//! the paper's §7 evaluation (see `DESIGN.md` experiment index).
//!
//! Every regenerator writes machine-readable CSV under `out_dir` and
//! returns the human-readable rendering (tables in the paper's layout,
//! stacked-area charts for the figures). The benches and the CLI
//! `experiment` subcommand are thin wrappers over these functions.

use crate::config::SolverConfig;
use crate::data::{registry, simreal, synth, Dataset};
use crate::path::{PathConfig, PathOutput, PathRunner};
use crate::problem::Model;
use crate::report::{CsvWriter, StackedArea, Table};
use crate::screening::RuleKind;
use std::path::PathBuf;

/// Options shared by all experiment regenerators.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// Scale for the simulated real sets ((0,1]; 1.0 = paper-size).
    pub scale: f64,
    /// Grid points (paper: 100).
    pub points: usize,
    /// Solver tolerance.
    pub tol: f64,
    /// Output directory for CSVs.
    pub out_dir: PathBuf,
    /// Route the DVI scan through the PJRT artifact when available.
    pub use_pjrt: bool,
    /// Per-step full-KKT validation (slower; for the test suite).
    pub validate: bool,
    /// Worker threads for the sharded scan/Gram/validation engine
    /// (crate convention: 1 = serial, 0 = auto-detect, n = n workers).
    /// Table/figure regeneration at paper scale should run 0 (auto) so
    /// the ParScan engine is exploited; results are identical either way.
    pub threads: usize,
    /// Rule expressions the `gauntlet` races (`--rule` syntax, including
    /// `+`-compositions).
    pub rules: Vec<String>,
    /// Classification registry datasets the `gauntlet` screens.
    pub bench_datasets: Vec<String>,
    /// Emit wall-clock fields (scan/solve seconds, speedups) in
    /// `BENCH_screening.json`. Off ⇒ the file is byte-deterministic
    /// across double runs — what the CI smoke job diffs.
    pub bench_timings: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: 0.25,
            points: 100,
            tol: 1e-6,
            out_dir: PathBuf::from("results"),
            use_pjrt: false,
            validate: false,
            threads: 1,
            rules: ["dvi", "dvi-theta", "ssnsv", "essnsv", "dvi+essnsv"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            bench_datasets: vec!["toy1".to_string(), "toy2".to_string()],
            bench_timings: true,
        }
    }
}

impl ExpOptions {
    fn path_config(&self, c_min: f64, c_max: f64) -> PathConfig {
        PathConfig::log_grid(c_min, c_max, self.points)
            .with_solver(SolverConfig {
                tol: self.tol,
                threads: self.threads,
                ..Default::default()
            })
            .with_validation(self.validate)
    }

    fn run_path(&self, model: Model, ds: &Dataset, rule: RuleKind) -> PathOutput {
        let mut runner = PathRunner::new(model, self.path_config(1e-2, 10.0), rule);
        if self.use_pjrt && rule == RuleKind::DviW {
            if let Ok(s) = crate::runtime::PjrtScreener::from_default_dir() {
                runner = runner.with_backend(Box::new(s));
            }
        }
        runner.run(ds)
    }

    /// The paper's "Solver" arm: every grid point solved independently
    /// (no warm start) — the protocol behind Tables 1–3.
    fn run_cold_baseline(&self, model: Model, ds: &Dataset) -> PathOutput {
        let cfg = self.path_config(1e-2, 10.0).with_cold_baseline();
        PathRunner::new(model, cfg, RuleKind::None).run(ds)
    }
}

/// Dispatch an experiment id. Returns the rendered report.
pub fn run(id: &str, opts: &ExpOptions) -> Result<String, String> {
    match id {
        "fig1" => Ok(fig1(opts)),
        "tab1" => Ok(tab1(opts)),
        "fig2" => Ok(fig2(opts)),
        "tab2" => Ok(tab2(opts)),
        "fig3" => Ok(fig3(opts)),
        "tab3" => Ok(tab3(opts)),
        "ablation" => Ok(ablation_grid_density(opts)),
        "gauntlet" => gauntlet(opts),
        "all" => {
            let mut out = String::new();
            for id in ["fig1", "tab1", "fig2", "tab2", "fig3", "tab3", "ablation"] {
                out.push_str(&run(id, opts)?);
                out.push('\n');
            }
            Ok(out)
        }
        _ => Err(format!(
            "unknown experiment id `{id}` (fig1..fig3, tab1..tab3, ablation, gauntlet, all)"
        )),
    }
}

fn toys(opts: &ExpOptions) -> Vec<Dataset> {
    // The paper's toys are small (1000/class); always run them at full
    // size — `scale` only shrinks the six large real-set analogs. Tests
    // pass scale ≪ 1 to shrink everything, so honor very small scales.
    let per_class = if opts.scale >= 0.25 {
        1000
    } else {
        ((1000.0 * opts.scale).round() as usize).max(25)
    };
    synth::paper_toys(per_class)
}

fn write_series_csv(opts: &ExpOptions, name: &str, out: &PathOutput) {
    let path = opts.out_dir.join(name);
    let mut w = match CsvWriter::create(&path, &["c", "rej_lo", "rej_hi", "free", "solve_secs"]) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("[experiments] csv {name}: {e}");
            return;
        }
    };
    let l = out.l as f64;
    for s in &out.steps {
        let _ = w.row_f64(&[
            s.c,
            s.n_lo as f64 / l,
            s.n_hi as f64 / l,
            s.free as f64,
            s.solve_secs,
        ]);
    }
    let _ = w.flush();
}

// ---------------------------------------------------------------- fig 1 --

/// Fig. 1: DVI_s rejection stacked-area charts on Toy1/2/3.
pub fn fig1(opts: &ExpOptions) -> String {
    let mut report = String::from("=== Figure 1: DVI_s rejection on the 2-D toys ===\n");
    for ds in toys(opts) {
        let out = opts.run_path(Model::Svm, &ds, RuleKind::DviW);
        write_series_csv(opts, &format!("fig1_{}.csv", ds.name), &out);
        let (lo, hi) = out.rejection_series();
        let chart = StackedArea::new(
            format!("{} (l={}, mean rejection {:.1}%)", ds.name, out.l, 100.0 * out.mean_rejection()),
            lo,
            hi,
        )
        .height(14);
        report.push_str(&chart.render());
        report.push('\n');
    }
    report
}

// ---------------------------------------------------------------- tab 1 --

/// Table 1: Solver vs Solver+DVI_s runtimes on the toys. "Solver" is the
/// paper's protocol (independent solves per C); "Solver(warm)" is the
/// stronger warm-started baseline we also report for honesty.
pub fn tab1(opts: &ExpOptions) -> String {
    svm_lad_speedup_table(
        "=== Table 1: SVM path runtimes on the toys (seconds) ===",
        "tab1.csv",
        opts,
        Model::Svm,
        toys(opts),
    )
}

fn svm_lad_speedup_table(
    title: &str,
    csv_name: &str,
    opts: &ExpOptions,
    model: Model,
    datasets: Vec<Dataset>,
) -> String {
    let mut table = Table::new(title).header(&[
        "dataset",
        "Solver",
        "Solver(warm)",
        "Solver+DVIs",
        "DVIs",
        "Init.",
        "Speedup",
        "Speedup(warm)",
        "work x",
    ]);
    let csv = opts.out_dir.join(csv_name);
    let mut w = CsvWriter::create(
        &csv,
        &[
            "dataset",
            "solver_cold_secs",
            "solver_warm_secs",
            "screened_secs",
            "rule_secs",
            "init_secs",
            "speedup_cold",
            "speedup_warm",
            "grad_eval_ratio",
        ],
    )
    .ok();
    for ds in datasets {
        let cold = opts.run_cold_baseline(model, &ds);
        let warm = opts.run_path(model, &ds, RuleKind::None);
        let dvi = opts.run_path(model, &ds, RuleKind::DviW);
        let speedup_cold = cold.total_secs / dvi.total_secs;
        let speedup_warm = warm.total_secs / dvi.total_secs;
        let work = cold.total_grad_evals() as f64 / dvi.total_grad_evals().max(1) as f64;
        table.row(&[
            ds.name.clone(),
            format!("{:.3}", cold.total_secs),
            format!("{:.3}", warm.total_secs),
            format!("{:.3}", dvi.total_secs),
            format!("{:.4}", dvi.screen_secs),
            format!("{:.3}", dvi.init_secs),
            format!("{speedup_cold:.2}x"),
            format!("{speedup_warm:.2}x"),
            format!("{work:.1}x"),
        ]);
        if let Some(w) = w.as_mut() {
            let _ = w.row(&[
                ds.name.clone(),
                cold.total_secs.to_string(),
                warm.total_secs.to_string(),
                dvi.total_secs.to_string(),
                dvi.screen_secs.to_string(),
                dvi.init_secs.to_string(),
                speedup_cold.to_string(),
                speedup_warm.to_string(),
                work.to_string(),
            ]);
        }
    }
    if let Some(w) = w.as_mut() {
        let _ = w.flush();
    }
    table.render()
}

// ---------------------------------------------------------------- fig 2 --

/// Fig. 2: SSNSV vs ESSNSV vs DVI_s rejection on the SVM real-set analogs.
pub fn fig2(opts: &ExpOptions) -> String {
    let mut report =
        String::from("=== Figure 2: rejection ratio, SSNSV vs ESSNSV vs DVI_s (SVM) ===\n");
    for name in simreal::SVM_SETS {
        let ds = registry::resolve(name, opts.scale, crate::data::Task::Classification)
            .expect("registry");
        let mut rows: Vec<(RuleKind, PathOutput)> = Vec::new();
        for rule in [RuleKind::Ssnsv, RuleKind::Essnsv, RuleKind::DviW] {
            let out = opts.run_path(Model::Svm, &ds, rule);
            write_series_csv(opts, &format!("fig2_{}_{}.csv", ds.name, rule.name()), &out);
            rows.push((rule, out));
        }
        let mut t = Table::new(format!("{} (l={}, n={})", ds.name, ds.len(), ds.dim()))
            .header(&["rule", "mean rejection", "final-step rejection"]);
        for (rule, out) in &rows {
            let last = out.steps.last().unwrap().rejection(out.l);
            t.row(&[
                rule.name().to_string(),
                format!("{:.1}%", 100.0 * out.mean_rejection()),
                format!("{:.1}%", 100.0 * last),
            ]);
        }
        report.push_str(&t.render());
        // curve for DVI (the paper's strongest series) as a stacked chart
        let (lo, hi) = rows.last().unwrap().1.rejection_series();
        report.push_str(&StackedArea::new(format!("{} DVI_s", ds.name), lo, hi).height(10).render());
        report.push('\n');
    }
    report
}

// ---------------------------------------------------------------- tab 2 --

/// Table 2: SVM path runtimes with SSNSV / ESSNSV / DVI_s on the real-set
/// analogs.
pub fn tab2(opts: &ExpOptions) -> String {
    let mut report = String::new();
    let csv = opts.out_dir.join("tab2.csv");
    let mut w = CsvWriter::create(
        &csv,
        &["dataset", "arm", "rule_secs", "init_secs", "total_secs", "speedup"],
    )
    .ok();
    for name in simreal::SVM_SETS {
        let ds = registry::resolve(name, opts.scale, crate::data::Task::Classification)
            .expect("registry");
        let mut t = Table::new(format!(
            "=== Table 2 [{}] (l={}, n={}) ===",
            ds.name,
            ds.len(),
            ds.dim()
        ))
        .header(&["arm", "rule", "Init.", "Total", "Speedup"]);
        let plain = opts.run_cold_baseline(Model::Svm, &ds);
        t.row(&[
            "Solver".into(),
            "-".into(),
            "-".into(),
            format!("{:.2}", plain.total_secs),
            "-".into(),
        ]);
        if let Some(w) = w.as_mut() {
            let _ = w.row(&[
                ds.name.clone(),
                "solver".into(),
                "0".into(),
                "0".into(),
                plain.total_secs.to_string(),
                "1.0".into(),
            ]);
        }
        for rule in [RuleKind::Ssnsv, RuleKind::Essnsv, RuleKind::DviW] {
            let out = opts.run_path(Model::Svm, &ds, rule);
            let speedup = plain.total_secs / out.total_secs;
            t.row(&[
                format!("Solver+{}", rule.name().to_uppercase()),
                format!("{:.3}", out.screen_secs),
                format!("{:.2}", out.init_secs),
                format!("{:.2}", out.total_secs),
                format!("{speedup:.2}x"),
            ]);
            if let Some(w) = w.as_mut() {
                let _ = w.row(&[
                    ds.name.clone(),
                    rule.name().into(),
                    out.screen_secs.to_string(),
                    out.init_secs.to_string(),
                    out.total_secs.to_string(),
                    speedup.to_string(),
                ]);
            }
        }
        report.push_str(&t.render());
        report.push('\n');
    }
    if let Some(w) = w.as_mut() {
        let _ = w.flush();
    }
    report
}

// ---------------------------------------------------------------- fig 3 --

/// Fig. 3: DVI_s rejection for LAD on the regression analogs.
pub fn fig3(opts: &ExpOptions) -> String {
    let mut report = String::from("=== Figure 3: DVI_s rejection for LAD ===\n");
    for name in simreal::LAD_SETS {
        let ds = registry::resolve(name, opts.scale, crate::data::Task::Regression)
            .expect("registry");
        let out = opts.run_path(Model::Lad, &ds, RuleKind::DviW);
        write_series_csv(opts, &format!("fig3_{}.csv", ds.name), &out);
        let (lo, hi) = out.rejection_series();
        report.push_str(
            &StackedArea::new(
                format!(
                    "{} (l={}, mean rejection {:.1}%)",
                    ds.name,
                    out.l,
                    100.0 * out.mean_rejection()
                ),
                lo,
                hi,
            )
            .height(12)
            .render(),
        );
        report.push('\n');
    }
    report
}

// ---------------------------------------------------------------- tab 3 --

/// Table 3: LAD path runtimes, Solver vs Solver+DVI_s (same dual-baseline
/// structure as Table 1).
pub fn tab3(opts: &ExpOptions) -> String {
    let datasets: Vec<Dataset> = simreal::LAD_SETS
        .iter()
        .map(|name| {
            registry::resolve(name, opts.scale, crate::data::Task::Regression)
                .expect("registry")
        })
        .collect();
    svm_lad_speedup_table(
        "=== Table 3: LAD path runtimes (seconds) ===",
        "tab3.csv",
        opts,
        Model::Lad,
        datasets,
    )
}

// ------------------------------------------------------------ ablation --

/// Design-choice ablation (DESIGN.md): DVI's screening power as a
/// function of grid density, against the grid-independent ESSNSV region.
/// Exposes the crossover: sequential DVI needs a reasonably dense path
/// (its Theorem-6 radius scales with the C-gap), while ESSNSV is flat.
pub fn ablation_grid_density(opts: &ExpOptions) -> String {
    let ds = synth::toy_gaussian(2, ((1000.0 * opts.scale).max(100.0)) as usize, 0.75, 0.75);
    let mut table = Table::new(
        "=== Ablation: rejection vs grid density (toy2) — DVI (sequential) vs ESSNSV (static) ===",
    )
    .header(&["grid points", "DVI_s", "ESSNSV", "winner"]);
    let csv = opts.out_dir.join("ablation_grid.csv");
    let mut w = CsvWriter::create(&csv, &["points", "dvi", "essnsv"]).ok();
    for points in [5usize, 10, 25, 50, 100, 200] {
        let cfg = || {
            PathConfig::log_grid(1e-2, 10.0, points).with_solver(SolverConfig {
                tol: opts.tol,
                threads: opts.threads,
                ..Default::default()
            })
        };
        let dvi = PathRunner::new(Model::Svm, cfg(), RuleKind::DviW).run(&ds);
        let ess = PathRunner::new(Model::Svm, cfg(), RuleKind::Essnsv).run(&ds);
        let (a, b) = (dvi.mean_rejection(), ess.mean_rejection());
        table.row(&[
            points.to_string(),
            format!("{:.1}%", 100.0 * a),
            format!("{:.1}%", 100.0 * b),
            if a >= b { "DVI" } else { "ESSNSV" }.into(),
        ]);
        if let Some(w) = w.as_mut() {
            let _ = w.row_f64(&[points as f64, a, b]);
        }
    }
    if let Some(w) = w.as_mut() {
        let _ = w.flush();
    }
    table.render()
}

// ------------------------------------------------------------ gauntlet --

/// The `dvi gauntlet`: race a grid of screening-rule expressions over
/// datasets × one shared C-path, and write a versioned, schema-stable
/// `BENCH_screening.json` under `out_dir` (schema_version 1).
///
/// Every rule replays against the SAME reference trajectory — one
/// warm-started, unscreened path per dataset whose per-step (θ*, u = Zᵀθ)
/// anchors are recorded, plus one feasible w*(C_max) from the final point
/// for the SSNSV family — so per-step rejection rates are directly
/// comparable, and a composed rule's rate dominates each raced member's
/// *by construction* (intersection of member regions keeps the tightest
/// per-row bounds; see [`crate::screening::composite`]). With
/// `bench_timings` off the file carries no wall-clock field and a double
/// run is byte-identical — that is what `scripts/gauntlet_smoke.sh`
/// diffs in CI.
pub fn gauntlet(opts: &ExpOptions) -> Result<String, String> {
    use crate::config::json::Json;
    use crate::problem::Instance;
    use crate::screening::{RuleExpr, ScreenReport, StepContext};
    use crate::solver::CdSolver;
    use std::collections::BTreeMap;
    use std::time::Instant;

    if opts.rules.is_empty() {
        return Err("gauntlet: `rules` must name at least one rule expression".into());
    }
    if opts.bench_datasets.is_empty() {
        return Err("gauntlet: `bench_datasets` must name at least one dataset".into());
    }
    let exprs: Vec<RuleExpr> =
        opts.rules.iter().map(|s| RuleExpr::parse(s)).collect::<Result<_, _>>()?;
    std::fs::create_dir_all(&opts.out_dir)
        .map_err(|e| format!("gauntlet: create {}: {e}", opts.out_dir.display()))?;
    let cfg = opts.path_config(1e-2, 10.0);
    let grid = cfg.grid.clone();
    if grid.len() < 2 {
        return Err("gauntlet: need at least 2 grid points".into());
    }

    struct Raced {
        name: String,
        atoms: Vec<String>,
        steps: Vec<f64>,
        mean: f64,
        scan_secs: f64,
        solve_secs: Option<f64>,
    }

    let mut report =
        String::from("=== dvi gauntlet: screening-rate race on shared solved paths ===\n");
    let mut ds_entries: Vec<Json> = Vec::new();
    for name in &opts.bench_datasets {
        let ds = registry::resolve(name, opts.scale, crate::data::Task::Classification)?;
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let l = inst.len();

        // the shared reference trajectory (warm-started, no screening)
        let solver = CdSolver::new(cfg.solver.clone());
        let mut trail: Vec<(Vec<f64>, Vec<f64>)> = Vec::with_capacity(grid.len());
        let mut warm = inst.cold_start();
        let t_ref = Instant::now();
        for &c in &grid {
            let r = solver.solve(&inst, c, warm);
            let u = inst.u_from_theta(&r.theta);
            warm = r.theta.clone();
            inst.project_box(&mut warm);
            trail.push((r.theta, u));
        }
        let ref_secs = t_ref.elapsed().as_secs_f64();
        let theta_last = &trail.last().expect("non-empty grid").0;
        let w_feasible = inst.w_from_theta(*grid.last().expect("non-empty grid"), theta_last);

        // end-to-end baseline only matters when wall-clock is reported
        let baseline_secs: Option<f64> = if opts.bench_timings {
            let cfg = opts.path_config(1e-2, 10.0);
            Some(PathRunner::new(Model::Svm, cfg, RuleKind::None).run(&ds).total_secs)
        } else {
            None
        };

        let mut raced: Vec<Raced> = Vec::new();
        for expr in &exprs {
            let mut engine = expr.build(opts.threads);
            engine.init(&inst, opts.threads);
            let mut steps: Vec<f64> = Vec::with_capacity(grid.len() - 1);
            let mut scan_secs = 0.0;
            for k in 1..grid.len() {
                let ctx = StepContext {
                    c_prev: grid[k - 1],
                    c_next: grid[k],
                    theta_prev: &trail[k - 1].0,
                    u_prev: &trail[k - 1].1,
                    w_feasible: Some(&w_feasible),
                };
                let t0 = Instant::now();
                let region = engine.prepare(&inst, &ctx);
                let rep = ScreenReport::from_decisions(engine.screen_rows(
                    &inst,
                    &region,
                    opts.threads,
                ));
                scan_secs += t0.elapsed().as_secs_f64();
                steps.push(rep.rejection());
            }
            let mean = steps.iter().sum::<f64>() / steps.len() as f64;
            let solve_secs = if opts.bench_timings {
                let cfg = opts.path_config(1e-2, 10.0);
                Some(PathRunner::new_expr(Model::Svm, cfg, expr.clone()).run(&ds).total_secs)
            } else {
                None
            };
            raced.push(Raced {
                name: expr.name(),
                atoms: expr.atoms().iter().map(|a| a.name().to_string()).collect(),
                steps,
                mean,
                scan_secs,
                solve_secs,
            });
        }

        // members raced as singles, for the composed-dominance record
        let singles: BTreeMap<&str, &Vec<f64>> = raced
            .iter()
            .filter(|r| r.atoms.len() == 1)
            .map(|r| (r.name.as_str(), &r.steps))
            .collect();
        let mut t = Table::new(format!("{} (l={l}, n={})", ds.name, ds.dim()))
            .header(&["rule", "mean rejection", "final step", "scan"]);
        let mut rule_entries: Vec<Json> = Vec::new();
        for r in &raced {
            let mut o = BTreeMap::new();
            o.insert("rule".to_string(), Json::Str(r.name.clone()));
            o.insert(
                "per_step_rejection".to_string(),
                Json::Array(r.steps.iter().map(|&v| Json::Float(v)).collect()),
            );
            o.insert("mean_rejection".to_string(), Json::Float(r.mean));
            if r.atoms.len() > 1 {
                // exact (not epsilon) comparison: the composite evaluates
                // the identical member bounds and intersects them
                let dominates = r.atoms.iter().all(|a| match singles.get(a.as_str()) {
                    Some(ms) => ms.iter().zip(&r.steps).all(|(m, c)| c >= m),
                    None => true, // member not raced as a single
                });
                o.insert("dominates_members".to_string(), Json::Bool(dominates));
            }
            if opts.bench_timings {
                o.insert("scan_secs".to_string(), Json::Float(r.scan_secs));
                if let (Some(s), Some(b)) = (r.solve_secs, baseline_secs) {
                    o.insert("solve_total_secs".to_string(), Json::Float(s));
                    o.insert("speedup_vs_warm".to_string(), Json::Float(b / s));
                }
            }
            rule_entries.push(Json::Object(o));
            let last = *r.steps.last().expect("at least one step");
            t.row(&[
                r.name.clone(),
                format!("{:.1}%", 100.0 * r.mean),
                format!("{:.1}%", 100.0 * last),
                if opts.bench_timings { format!("{:.4}s", r.scan_secs) } else { "-".into() },
            ]);
        }

        let mut d = BTreeMap::new();
        d.insert("dataset".to_string(), Json::Str(ds.name.clone()));
        d.insert("l".to_string(), Json::Int(l as i64));
        d.insert("n".to_string(), Json::Int(ds.dim() as i64));
        d.insert("grid".to_string(), Json::Array(grid.iter().map(|&c| Json::Float(c)).collect()));
        d.insert("rules".to_string(), Json::Array(rule_entries));
        if opts.bench_timings {
            d.insert("reference_path_secs".to_string(), Json::Float(ref_secs));
            if let Some(b) = baseline_secs {
                d.insert("baseline_warm_secs".to_string(), Json::Float(b));
            }
        }
        ds_entries.push(Json::Object(d));
        report.push_str(&t.render());
        report.push('\n');
    }

    let mut top = BTreeMap::new();
    top.insert("schema_version".to_string(), Json::Int(1));
    top.insert("kind".to_string(), Json::Str("dvi-gauntlet".to_string()));
    top.insert("model".to_string(), Json::Str("svm".to_string()));
    top.insert("scale".to_string(), Json::Float(opts.scale));
    top.insert("points".to_string(), Json::Int(opts.points as i64));
    top.insert("tol".to_string(), Json::Float(opts.tol));
    top.insert(
        "rules".to_string(),
        Json::Array(opts.rules.iter().map(|r| Json::Str(r.clone())).collect()),
    );
    top.insert("datasets".to_string(), Json::Array(ds_entries));
    let path = opts.out_dir.join("BENCH_screening.json");
    let mut text = Json::Object(top).to_string();
    text.push('\n');
    std::fs::write(&path, &text)
        .map_err(|e| format!("gauntlet: write {}: {e}", path.display()))?;
    report.push_str(&format!("wrote {}\n", path.display()));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExpOptions {
        let mut dir = std::env::temp_dir();
        dir.push(format!("dvi_exp_test_{}", std::process::id()));
        ExpOptions {
            scale: 0.02,
            points: 4,
            tol: 1e-5,
            out_dir: dir,
            use_pjrt: false,
            validate: false,
            threads: 2, // exercise the sharded engine in the harness tests
            rules: vec!["dvi".into(), "essnsv".into(), "dvi+essnsv".into()],
            bench_datasets: vec!["toy1".into()],
            bench_timings: false,
        }
    }

    #[test]
    fn unknown_id_is_error() {
        assert!(run("nope", &tiny_opts()).is_err());
    }

    #[test]
    fn gauntlet_bench_is_deterministic_and_composite_dominates() {
        let mut opts = tiny_opts();
        // own directory: sibling tests remove_dir_all the shared tiny dir
        opts.out_dir = std::env::temp_dir();
        opts.out_dir.push(format!("dvi_exp_gauntlet_{}", std::process::id()));
        let report = run("gauntlet", &opts).expect("gauntlet runs");
        assert!(report.contains("dvi+essnsv"), "{report}");
        let path = opts.out_dir.join("BENCH_screening.json");
        let text = std::fs::read_to_string(&path).unwrap();
        // timings off ⇒ no wall-clock field and a byte-identical double run
        assert!(!text.contains("secs"), "{text}");
        run("gauntlet", &opts).expect("gauntlet reruns");
        assert_eq!(text, std::fs::read_to_string(&path).unwrap(), "double run must be stable");

        let j = crate::config::json::parse_json(&text).unwrap();
        assert_eq!(j.get("schema_version").unwrap().as_int(), Some(1));
        assert_eq!(j.get("kind").unwrap().as_str(), Some("dvi-gauntlet"));
        let dsets = j.get("datasets").unwrap().as_array().unwrap();
        assert_eq!(dsets.len(), 1);
        let rules = dsets[0].get("rules").unwrap().as_array().unwrap();
        assert_eq!(rules.len(), 3);
        let steps = |r: &crate::config::json::Json| -> Vec<f64> {
            r.get("per_step_rejection")
                .unwrap()
                .as_array()
                .unwrap()
                .iter()
                .map(|v| v.as_float().unwrap())
                .collect()
        };
        let dvi = steps(&rules[0]);
        let ess = steps(&rules[1]);
        let both = steps(&rules[2]);
        assert_eq!(rules[2].get("rule").unwrap().as_str(), Some("dvi+essnsv"));
        assert_eq!(rules[2].get("dominates_members").unwrap().as_bool(), Some(true));
        for k in 0..both.len() {
            assert!(both[k] >= dvi[k].max(ess[k]), "step {k}: {both:?} vs {dvi:?}/{ess:?}");
        }
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }

    #[test]
    fn fig1_and_tab1_render() {
        let opts = tiny_opts();
        let f = fig1(&opts);
        assert!(f.contains("toy1"));
        assert!(f.contains("█"));
        let t = tab1(&opts);
        assert!(t.contains("Speedup"));
        assert!(opts.out_dir.join("tab1.csv").exists());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }

    #[test]
    fn fig3_tab3_render() {
        let opts = tiny_opts();
        let f = fig3(&opts);
        assert!(f.contains("magic-sim"));
        let t = tab3(&opts);
        assert!(t.contains("houses-sim"));
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
