//! The server core: shared routing state, the outcome dispatcher thread,
//! and the TCP / unix-socket accept loops.
//!
//! One [`Server`] fronts one [`WorkerPool`]. Connections submit jobs
//! under globally unique pool ids (`next_pool_id`); the dispatcher drains
//! the pool's results channel and routes each outcome to the submitting
//! connection's event channel, where the per-connection writer rewrites
//! the id back to the connection-local one before encoding. All of this
//! is std-only: plain threads, `mpsc` channels, and atomics.

use super::conn::{self, ConnEvent};
use crate::coordinator::WorkerPool;
use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Admission-control and registry knobs for a [`Server`]. The default is
/// fully open (no caps, no registry) — exactly the historical stdin-loop
/// behavior.
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    /// Per-connection in-flight request cap; 0 = unlimited. Requests over
    /// the cap answer with a typed `"code": "rejected"` error and consume
    /// no id — the connection stays usable.
    pub max_inflight: u64,
    /// Global queued-cost budget across every connection; 0 = unlimited.
    /// Requests whose cost estimate does not fit answer with a typed
    /// `"code": "overloaded"` error and consume no id.
    pub queue_cost: u64,
    /// Model registry directory: `"persist": true` train requests write
    /// `<model_id>.pallas-model` here (see
    /// [`super::ModelRegistry`] for the startup scan).
    pub model_dir: Option<std::path::PathBuf>,
}

/// Per-connection admission state, shared between the connection's
/// submit path and the dispatcher's release path.
pub(crate) struct ConnShared {
    pub(crate) inflight: AtomicU64,
}

/// Where one submitted job's outcome must be delivered.
pub(crate) struct Route {
    pub(crate) tx: Sender<ConnEvent>,
    /// The connection-local id the client knows this job by.
    pub(crate) local_id: u64,
    /// Whether the outcome streams immediately or buffers for replay.
    pub(crate) stream: bool,
    /// Admission cost reserved at submit, released on completion.
    pub(crate) cost: u64,
    pub(crate) conn: Arc<ConnShared>,
    /// When the route was registered (just before pool submit); the
    /// dispatcher turns this into the `serve_request_secs` latency
    /// histogram when the outcome is routed back.
    pub(crate) submitted: std::time::Instant,
}

/// State shared by the dispatcher, the accept loops, and every live
/// connection handler.
pub(crate) struct ServeShared {
    pub(crate) pool: Arc<WorkerPool>,
    /// Pool-side job ids are globally unique across connections; each
    /// connection keeps its own dense local id space for the wire.
    pub(crate) next_pool_id: AtomicU64,
    pub(crate) routes: Mutex<HashMap<u64, Route>>,
    /// Sum of cost estimates for submitted-but-unfinished jobs.
    pub(crate) queued_cost: AtomicU64,
    /// Count of submitted-but-unfinished jobs across all connections.
    pub(crate) inflight_total: AtomicU64,
    pub(crate) opts: ServeOptions,
    pub(crate) stop: AtomicBool,
    /// Graceful-shutdown latch: once set, connections refuse every new
    /// request with a typed `"code": "draining"` error while already
    /// submitted jobs keep running to completion.
    pub(crate) draining: AtomicBool,
}

/// Multi-client server over one worker pool. Dropping (or [`Server::stop`])
/// shuts the listeners and joins the dispatcher; the pool itself is owned
/// by the caller and survives.
pub struct Server {
    shared: Arc<ServeShared>,
    dispatcher: Option<JoinHandle<()>>,
    accept_handles: Vec<JoinHandle<()>>,
    /// Bound addresses, kept to wake the blocking accept loops at stop.
    tcp_wake: Vec<SocketAddr>,
    #[cfg(unix)]
    sock_wake: Vec<std::path::PathBuf>,
}

impl Server {
    /// A server over `pool` with pool-side job ids starting at 0.
    pub fn new(pool: Arc<WorkerPool>, opts: ServeOptions) -> Server {
        Self::with_start(pool, opts, 0)
    }

    /// A server whose pool-side job ids start at `start_pool_id` — the
    /// stdin adapter threads the service's persistent id counter through
    /// here so ids keep incrementing across `serve()` calls.
    pub fn with_start(pool: Arc<WorkerPool>, opts: ServeOptions, start_pool_id: u64) -> Server {
        let shared = Arc::new(ServeShared {
            pool,
            next_pool_id: AtomicU64::new(start_pool_id),
            routes: Mutex::new(HashMap::new()),
            queued_cost: AtomicU64::new(0),
            inflight_total: AtomicU64::new(0),
            opts,
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
        });
        let dispatcher = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("dvi-serve-dispatch".into())
                .spawn(move || dispatch_loop(&shared))
                .expect("spawn serve dispatcher")
        };
        Server {
            shared,
            dispatcher: Some(dispatcher),
            accept_handles: Vec::new(),
            tcp_wake: Vec::new(),
            #[cfg(unix)]
            sock_wake: Vec::new(),
        }
    }

    /// The server's admission/registry options.
    pub fn options(&self) -> &ServeOptions {
        &self.shared.opts
    }

    /// A handle that can drain this server from another thread (the
    /// SIGTERM watcher): flip admission off, then wait for in-flight
    /// jobs to finish and their responses to reach the wire.
    pub fn drain_handle(&self) -> DrainHandle {
        DrainHandle { shared: self.shared.clone() }
    }

    /// Run one blocking line-protocol session on the caller's thread —
    /// the stdin/stdout adapter. `start_local` seeds the session's id
    /// space (network connections use 0; the service adapter passes its
    /// persistent counter). Returns the next unissued local id.
    pub fn serve_session<R: BufRead, W: Write + Send>(
        &self,
        input: R,
        output: W,
        start_local: u64,
    ) -> io::Result<u64> {
        conn::run_session(&self.shared, input, output, start_local)
    }

    /// Bind a TCP listener and spawn its accept loop. `addr` may use port
    /// 0 for an OS-assigned port — the actually bound address is
    /// returned (and printed by the CLI for scripts to parse).
    pub fn bind_tcp(&mut self, addr: &str) -> io::Result<SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        self.tcp_wake.push(local);
        let shared = self.shared.clone();
        let handle = std::thread::Builder::new()
            .name("dvi-accept-tcp".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    // checked before handling so the stop() wake
                    // connection is never served
                    if shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    spawn_conn_thread(&shared, stream);
                }
            })?;
        self.accept_handles.push(handle);
        Ok(local)
    }

    /// Bind a unix-domain socket listener and spawn its accept loop. A
    /// stale socket file from a previous run is removed first.
    #[cfg(unix)]
    pub fn bind_unix(&mut self, path: &std::path::Path) -> io::Result<()> {
        use std::os::unix::net::UnixListener;
        // a dead server's socket file would otherwise make rebinding
        // fail with AddrInUse forever
        if path.exists() {
            std::fs::remove_file(path)?;
        }
        let listener = UnixListener::bind(path)?;
        self.sock_wake.push(path.to_path_buf());
        let shared = self.shared.clone();
        let handle = std::thread::Builder::new()
            .name("dvi-accept-unix".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let shared = shared.clone();
                    let _ = std::thread::Builder::new().name("dvi-conn".into()).spawn(
                        move || {
                            shared.pool.metrics.counter("serve_connections_opened").inc();
                            if let Ok(read) = stream.try_clone() {
                                let _ = conn::run_session(
                                    &shared,
                                    BufReader::new(read),
                                    stream,
                                    0,
                                );
                            }
                            shared.pool.metrics.counter("serve_connections_closed").inc();
                        },
                    );
                }
            })?;
        self.accept_handles.push(handle);
        Ok(())
    }

    /// Block until every accept loop exits (i.e. until [`Server::stop`]
    /// is called from another thread or the process dies) — the CLI's
    /// serve-forever mode.
    pub fn wait(&mut self) {
        for h in self.accept_handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Shut down: stop accepting, drop live routes (their connections
    /// answer outstanding jobs as lost), and join the dispatcher. Safe to
    /// call more than once; `Drop` calls it too.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // wake each blocking accept loop with a throwaway connection;
        // the loop re-checks the stop flag before serving it
        for addr in self.tcp_wake.drain(..) {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
        }
        #[cfg(unix)]
        for p in self.sock_wake.drain(..) {
            let _ = std::os::unix::net::UnixStream::connect(&p);
            let _ = std::fs::remove_file(&p);
        }
        for h in self.accept_handles.drain(..) {
            let _ = h.join();
        }
        // dropping the routes drops their event senders: connection
        // writers blocked on the channel unblock and answer any still-
        // missing buffered job as lost instead of hanging
        self.shared.routes.lock().unwrap().clear();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Graceful-shutdown control detached from the [`Server`]'s lifetime, so
/// the SIGTERM watcher thread can drive a drain while the main thread
/// stays blocked in [`Server::wait`].
pub struct DrainHandle {
    shared: Arc<ServeShared>,
}

impl DrainHandle {
    /// Stop admitting: every request parsed after this answers with a
    /// typed `"code": "draining"` refusal. Jobs already submitted are
    /// unaffected.
    pub fn begin(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a drain has begun.
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Block until every in-flight job has completed, or `timeout`
    /// elapses — returns whether the server went idle. The dispatcher
    /// decrements `inflight_total` *before* the outcome reaches the
    /// connection writer, so after the count hits zero this waits one
    /// short grace period for the final response bytes to hit the wire.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while self.shared.inflight_total.load(Ordering::SeqCst) > 0 {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        std::thread::sleep(Duration::from_millis(250));
        true
    }
}

fn spawn_conn_thread(shared: &Arc<ServeShared>, stream: TcpStream) {
    let shared = shared.clone();
    // per-connection reader thread; detached — process teardown (or the
    // client closing its write half) ends it
    let _ = std::thread::Builder::new().name("dvi-conn".into()).spawn(move || {
        shared.pool.metrics.counter("serve_connections_opened").inc();
        if let Ok(read) = stream.try_clone() {
            let _ = conn::run_session(&shared, BufReader::new(read), stream, 0);
        }
        shared.pool.metrics.counter("serve_connections_closed").inc();
    });
}

/// Drain pool outcomes and route each to its submitting connection,
/// releasing the admission cost it reserved. Exits when the stop flag is
/// set (checked between receives) or the pool closes.
fn dispatch_loop(shared: &ServeShared) {
    loop {
        match shared.pool.recv_timeout(Duration::from_millis(25)) {
            Ok(outcome) => {
                let (route, backlog) = {
                    let mut routes = shared.routes.lock().unwrap();
                    let route = routes.remove(&outcome.id);
                    (route, routes.len() as u64)
                };
                // no route: the job was submitted outside the serve layer
                // (direct pool API) or its connection was torn down — the
                // outcome has no consumer either way
                let Some(route) = route else { continue };
                shared.pool.metrics.gauge("serve_dispatcher_backlog").set(backlog);
                crate::obs::event_end("request", crate::obs::request_span_id(outcome.id));
                shared
                    .pool
                    .metrics
                    .bounded_histogram("serve_request_secs")
                    .record_secs(route.submitted.elapsed().as_secs_f64());
                let new_cost = shared
                    .queued_cost
                    .fetch_sub(route.cost, Ordering::SeqCst)
                    .saturating_sub(route.cost);
                let inflight = shared
                    .inflight_total
                    .fetch_sub(1, Ordering::SeqCst)
                    .saturating_sub(1);
                shared.pool.metrics.gauge("serve_queue_cost").set(new_cost);
                shared.pool.metrics.gauge("serve_inflight").set(inflight);
                route.conn.inflight.fetch_sub(1, Ordering::SeqCst);
                // a connection that died mid-flight just drops the event
                let _ = route.tx.send(ConnEvent::Outcome {
                    local_id: route.local_id,
                    stream: route.stream,
                    outcome,
                });
            }
            Err(RecvTimeoutError::Timeout) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}
