//! Network serving subsystem: multi-client listeners in front of the
//! coordinator's [`crate::coordinator::WorkerPool`].
//!
//! The screening service's line protocol (one JSON request per line, one
//! JSON response per line — see [`crate::coordinator::service`]) was
//! historically bound to a single stdin/stdout session. This module puts
//! a real server in front of the same pool:
//!
//! - [`Server`] owns a dispatcher thread that drains pool outcomes and
//!   routes each to the connection that submitted it, plus any number of
//!   TCP ([`Server::bind_tcp`]) and unix-socket ([`Server::bind_unix`])
//!   accept loops. Every connection runs the identical per-connection
//!   handler, so N concurrent clients multiplex onto one warm
//!   instance/model cache and one worker pool.
//! - `"stream": true` on a request (or batch line) emits responses as
//!   each job completes instead of buffering for input-order replay;
//!   entries stay tagged with their per-connection `id`, so a streamed
//!   session re-sorted by id is byte-identical to the buffered one under
//!   `"timings": false`.
//! - [`ServeOptions`] carries admission control: a per-connection
//!   in-flight cap (typed `"code": "rejected"` errors) and a global
//!   queued-cost budget (typed `"code": "overloaded"`), with a cheap
//!   rows-scan cost estimate per request so a huge predict cannot
//!   silently starve screen traffic.
//! - [`DrainHandle`] is the graceful-shutdown path: the CLI's SIGTERM
//!   watcher flips admission off (new requests answer a typed
//!   `"code": "draining"` refusal), waits for in-flight jobs to flush
//!   their responses, then lets the trace flush and the process exit.
//! - [`ModelRegistry`] is the `--model-dir` artifact store: persisted
//!   `.pallas-model` files auto-load into the model cache at startup
//!   (corrupt files are skipped with a typed warning, never a panic),
//!   and train requests carrying `"persist": true` write their artifact
//!   back into the directory — a restart serves predict-by-id with zero
//!   retrains.
//!
//! The historical stdin/stdout loop ([`ScreeningService::serve`]) is a
//! thin adapter over [`Server::serve_session`] with admission unlimited,
//! so scripted sessions stay byte-for-byte identical.
//!
//! # Observability
//!
//! Every stage of the request lifecycle is instrumented through
//! [`crate::obs`]: a `connection` span per session, an async `request`
//! span from submit (reader thread) to outcome routing (dispatcher
//! thread), and a `serve_dispatcher_backlog` gauge plus
//! `serve_request_secs` bounded latency histogram on the pool's metrics
//! registry. All of it writes to the trace ring / `/metrics` endpoint
//! only — the response byte stream is untouched, so `"timings": false`
//! sessions stay deterministic with tracing enabled.
//!
//! [`ScreeningService::serve`]: crate::coordinator::ScreeningService::serve

mod conn;
mod registry;
mod server;

pub use registry::{ModelRegistry, RegistryScan};
pub use server::{DrainHandle, ServeOptions, Server};
