//! The `--model-dir` artifact registry: a plain directory of
//! `<model_id>.pallas-model` files shared across server restarts (and,
//! on shared storage, across a fleet).
//!
//! At startup [`ModelRegistry::load_all`] scans the directory and makes
//! every readable artifact resident in the pool's model cache, so a
//! restarted server answers `predict` by `model_id` with zero retrains.
//! A corrupt or truncated file is *skipped* with its typed
//! [`ModelIoError`] carried in the scan report — one bad artifact must
//! never abort startup or panic. New artifacts enter the directory via
//! train requests carrying `"persist": true` (the connection handler
//! maps them to [`TrainSpec::persist_dir`]); the filename is the
//! deterministic model id, so re-training the same problem overwrites
//! in place instead of accumulating duplicates.
//!
//! [`ModelIoError`]: crate::model::ModelIoError
//! [`TrainSpec::persist_dir`]: crate::coordinator::TrainSpec::persist_dir

use crate::coordinator::ModelCache;
use crate::metrics::Registry;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Handle on a model registry directory.
pub struct ModelRegistry {
    dir: PathBuf,
}

/// What a startup scan found, for the caller to log.
#[derive(Debug, Default)]
pub struct RegistryScan {
    /// `(model_id, path)` per artifact made resident.
    pub loaded: Vec<(String, PathBuf)>,
    /// `(path, error)` per artifact skipped as unreadable/corrupt.
    pub skipped: Vec<(PathBuf, String)>,
}

impl ModelRegistry {
    pub fn new(dir: impl Into<PathBuf>) -> ModelRegistry {
        ModelRegistry { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Scan the directory (sorted, for deterministic logs) and load every
    /// `*.pallas-model` artifact into `models`. Io/decode failures on
    /// individual files are collected, not raised; only an unreadable
    /// directory itself is an error.
    pub fn load_all(
        &self,
        models: &ModelCache,
        metrics: &Registry,
    ) -> std::io::Result<RegistryScan> {
        let mut scan = RegistryScan::default();
        let mut paths: Vec<PathBuf> = std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.is_file() && p.extension().map_or(false, |x| x == "pallas-model")
            })
            .collect();
        paths.sort();
        for path in paths {
            // the loader is typed-error based (ModelIoError), but a
            // hostile artifact must not be able to abort startup even
            // through an unforeseen decoder panic
            let loaded = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                crate::model::load(&path)
            }));
            match loaded {
                Ok(Ok(m)) => {
                    let id = models.insert(Arc::new(m), metrics);
                    metrics.counter("model_registry_loaded").inc();
                    scan.loaded.push((id, path));
                }
                Ok(Err(e)) => {
                    metrics.counter("model_registry_skipped").inc();
                    scan.skipped.push((path, e.to_string()));
                }
                Err(_) => {
                    metrics.counter("model_registry_skipped").inc();
                    scan.skipped.push((path, "model io: decoder panicked".into()));
                }
            }
        }
        Ok(scan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverConfig;
    use crate::coordinator::{run_job, JobSpec, TrainSpec, TrainSummary};
    use crate::problem::Model;

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dvi_registry_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Train toy1 and persist its artifact into `dir` via the same
    /// `persist_dir` path the serve layer uses for `"persist": true`.
    fn persist_one(dir: &Path) -> TrainSummary {
        let spec = TrainSpec {
            dataset: "toy1".into(),
            model: Model::Svm,
            scale: 0.03,
            storage: crate::linalg::Storage::Auto,
            c: 0.5,
            solver: SolverConfig { tol: 1e-6, ..Default::default() },
            save: None,
            persist_dir: Some(dir.to_str().unwrap().to_string()),
            report_support: false,
        };
        let outcome = run_job(&JobSpec::train(0, spec));
        outcome.result.unwrap().as_train().unwrap().clone()
    }

    #[test]
    fn load_all_skips_corrupt_and_loads_good() {
        let dir = fresh_dir("mixed");
        let summary = persist_one(&dir);
        assert!(summary.persisted.is_some());
        // one corrupt file with the right extension, one ignorable file
        std::fs::write(dir.join("junk.pallas-model"), b"PALLASMD garbage").unwrap();
        std::fs::write(dir.join("README.txt"), b"not a model").unwrap();

        let models = ModelCache::new(ModelCache::DEFAULT_BUDGET_BYTES);
        let metrics = Registry::default();
        let scan = ModelRegistry::new(&dir).load_all(&models, &metrics).unwrap();
        assert_eq!(scan.loaded.len(), 1, "{scan:?}");
        assert_eq!(scan.loaded[0].0, summary.model_id);
        assert_eq!(scan.skipped.len(), 1, "{scan:?}");
        assert!(scan.skipped[0].1.contains("model io"), "{scan:?}");
        assert_eq!(metrics.counter("model_registry_loaded").get(), 1);
        assert_eq!(metrics.counter("model_registry_skipped").get(), 1);
        // the good artifact is resident — predict-by-id needs no retrain
        assert!(models.get(&summary.model_id, &metrics).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_all_missing_dir_is_io_error() {
        let models = ModelCache::new(0);
        let metrics = Registry::default();
        let err = ModelRegistry::new("/no/such/registry-dir").load_all(&models, &metrics);
        assert!(err.is_err());
    }

    #[test]
    fn persist_then_rescan_round_trip() {
        let dir = fresh_dir("roundtrip");
        let summary = persist_one(&dir);
        // re-training the same problem overwrites in place: still 1 file
        let again = persist_one(&dir);
        assert_eq!(again.model_id, summary.model_id);

        // a "restarted" server scans the same directory
        let models = ModelCache::new(ModelCache::DEFAULT_BUDGET_BYTES);
        let metrics = Registry::default();
        let scan = ModelRegistry::new(&dir).load_all(&models, &metrics).unwrap();
        assert_eq!(scan.loaded.len(), 1, "{scan:?}");
        assert_eq!(scan.loaded[0].0, summary.model_id);
        assert!(scan.skipped.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
