//! The per-connection protocol handler: parses request lines, applies
//! admission control, submits jobs under translated pool ids, and writes
//! responses — buffered in input order by default, or streamed per
//! completion under `"stream": true`.
//!
//! Each session owns two halves. The *reader* (the caller's thread)
//! parses lines, admits and submits jobs, and forwards one event per
//! line to the writer. The *writer* (a scoped thread, so it may borrow
//! the output) interleaves those line slots with job outcomes arriving
//! from the server's dispatcher, emitting streamed responses
//! immediately and replaying buffered ones in input order once EOF has
//! been read and every submitted job has reported. With no `"stream"`
//! requests the emitted bytes are identical to the historical
//! single-session loop in [`crate::coordinator::service`].

use super::server::{ConnShared, Route, ServeShared};
use crate::config::json::{parse_json, Json};
use crate::coordinator::job::{JobKind, JobSpec, PredictInput};
use crate::coordinator::service::{self, ParsedRequest, ScreeningService, MAX_BATCH};
use crate::coordinator::JobOutcome;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Reader-to-writer events. Outcomes are injected by the server's
/// dispatcher thread through the [`Route`] registered at submit.
pub(crate) enum ConnEvent {
    /// One input line's response slot, in input order.
    Slot(SlotInfo),
    /// One submitted job finished; `local_id` is the wire id.
    Outcome { local_id: u64, stream: bool, outcome: JobOutcome },
    /// The input reached EOF; no further slots follow.
    Eof,
}

/// One response-in-waiting: already answerable (parse/admission errors)
/// or pending a submitted job's outcome.
pub(crate) enum Pending {
    Ready(Json),
    Job(u64),
}

/// One input line's worth of pendings.
pub(crate) enum SlotInfo {
    Single { stream: bool, p: Pending },
    Batch { stream: bool, ps: Vec<Pending> },
}

/// Run one full session: read `input` to EOF, answer on `output`.
/// Returns the next unissued local id (the stdin adapter persists it so
/// ids keep incrementing across `serve()` calls on one service).
pub(crate) fn run_session<R: BufRead, W: Write + Send>(
    shared: &Arc<ServeShared>,
    input: R,
    output: W,
    start_local: u64,
) -> std::io::Result<u64> {
    let (tx, rx) = channel::<ConnEvent>();
    // the whole session — parse, admission, submits — parents under one
    // connection span on the reader thread; request spans opened at
    // submit nest beneath it in the exported trace
    let mut conn_span = crate::obs::Span::enter("connection");
    conn_span.attr("start_local", start_local as f64);
    let conn = Arc::new(ConnShared { inflight: AtomicU64::new(0) });
    let mut sess = Session {
        shared,
        conn: &conn,
        tx,
        start_local,
        next_local: start_local,
        pool_ids: Vec::new(),
    };
    std::thread::scope(|scope| {
        let writer = scope.spawn(move || write_loop(rx, output));
        let mut read_err: Option<std::io::Error> = None;
        for line in input.lines() {
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    read_err = Some(e);
                    break;
                }
            };
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let slot = sess.accept_line(line);
            if sess.tx.send(ConnEvent::Slot(slot)).is_err() {
                break; // writer died (output io error) — stop reading
            }
        }
        let _ = sess.tx.send(ConnEvent::Eof);
        let next_local = sess.next_local;
        // drop the session (and with it the reader's event sender) BEFORE
        // joining the writer: on forced teardown the writer unblocks only
        // once every sender — reader and routed — is gone
        drop(sess);
        let write_result = match writer.join() {
            Ok(r) => r,
            Err(_) => Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "connection writer panicked",
            )),
        };
        match read_err {
            Some(e) => Err(e),
            None => write_result.map(|()| next_local),
        }
    })
}

/// Reader-side session state: id bookkeeping and the submit path.
struct Session<'a> {
    shared: &'a Arc<ServeShared>,
    conn: &'a Arc<ConnShared>,
    tx: Sender<ConnEvent>,
    start_local: u64,
    next_local: u64,
    /// Pool id for each local id issued this session
    /// (`pool_ids[local - start_local]`) — the `after` translation table.
    pool_ids: Vec<u64>,
}

impl Session<'_> {
    /// Parse one input line into its response slot, submitting any jobs
    /// it contains. Never blocks on job execution.
    fn accept_line(&mut self, line: &str) -> SlotInfo {
        let err = |msg: String| SlotInfo::Single {
            stream: false,
            p: Pending::Ready(service::error_json(msg)),
        };
        let j = match parse_json(line) {
            Ok(j) => j,
            Err(e) => return err(e.to_string()),
        };
        let Some(obj) = j.as_object() else {
            return err("request must be a JSON object".into());
        };
        if let Some(batch) = obj.get("batch") {
            // `stream` is the only key allowed next to `batch` — it
            // frames the whole line, never an individual entry
            let mut stream = false;
            for (k, v) in obj {
                match k.as_str() {
                    "batch" => {}
                    "stream" => match v.as_bool() {
                        Some(b) => stream = b,
                        None => return err("stream: bool".into()),
                    },
                    _ => {
                        return err(
                            "a batch request must contain only the `batch` field".into(),
                        )
                    }
                }
            }
            let Some(entries) = batch.as_array() else {
                return err("batch must be an array of request objects".into());
            };
            if entries.len() > MAX_BATCH {
                return err(format!("batch is capped at {MAX_BATCH} entries"));
            }
            self.shared.pool.metrics.counter("service_batches").inc();
            let ps = entries
                .iter()
                .map(|e| {
                    let parsed = e
                        .as_object()
                        .ok_or("batch entry must be a request object".to_string())
                        .and_then(|o| {
                            if o.contains_key("stream") {
                                return Err(
                                    "stream applies to the whole line, not batch entries"
                                        .to_string(),
                                );
                            }
                            ScreeningService::parse_object(o)
                        });
                    match parsed {
                        Ok(req) => self.admit_and_submit(req, stream),
                        Err(msg) => Pending::Ready(service::error_json(msg)),
                    }
                })
                .collect();
            SlotInfo::Batch { stream, ps }
        } else {
            match ScreeningService::parse_object(obj) {
                Ok(req) => {
                    let stream = req.stream;
                    SlotInfo::Single { stream, p: self.admit_and_submit(req, stream) }
                }
                Err(msg) => err(msg),
            }
        }
    }

    /// Admission control, id issue, route registration, pool submit.
    /// A refused request answers with a typed error and consumes no id.
    fn admit_and_submit(&mut self, req: ParsedRequest, stream: bool) -> Pending {
        // a draining server refuses everything new before any other
        // admission check — in-flight jobs keep running to completion
        if self.shared.draining.load(Ordering::SeqCst) {
            self.shared.pool.metrics.counter("serve_draining_refused").inc();
            return Pending::Ready(admission_error(
                "draining",
                "server is draining for shutdown; no new requests are admitted".into(),
            ));
        }
        // the dependency edge must name an id this session has already
        // issued — parse-failed and refused lines consume none
        if let Some(a) = req.after {
            if a >= self.next_local {
                return Pending::Ready(service::error_json(format!(
                    "after: {a} does not name an already-submitted job \
                     (next id is {})",
                    self.next_local
                )));
            }
        }
        let mut kind = req.kind;
        if req.persist {
            let Some(dir) = &self.shared.opts.model_dir else {
                return Pending::Ready(service::error_json(
                    "persist: true requires a server --model-dir registry".into(),
                ));
            };
            match &mut kind {
                JobKind::Train(spec) => {
                    spec.persist_dir = Some(dir.to_string_lossy().into_owned());
                }
                // parse_object only sets persist on train requests
                _ => {
                    return Pending::Ready(service::error_json(
                        "persist applies to train requests".into(),
                    ))
                }
            }
        }

        let metrics = &self.shared.pool.metrics;
        let opts = &self.shared.opts;
        let cost = estimate_cost(&kind);
        // per-connection cap first: one greedy client is refused before
        // it can contend for the global budget
        if opts.max_inflight > 0
            && self.conn.inflight.load(Ordering::SeqCst) >= opts.max_inflight
        {
            metrics.counter("serve_rejected").inc();
            return Pending::Ready(admission_error(
                "rejected",
                format!("connection in-flight cap ({}) reached", opts.max_inflight),
            ));
        }
        let new_cost = if opts.queue_cost > 0 {
            // reserve the cost only if it fits — CAS loop against
            // concurrent connections
            let mut cur = self.shared.queued_cost.load(Ordering::SeqCst);
            loop {
                if cur.saturating_add(cost) > opts.queue_cost {
                    metrics.counter("serve_overloaded").inc();
                    return Pending::Ready(admission_error(
                        "overloaded",
                        format!(
                            "global queue budget ({}) exceeded: {cur} queued + {cost} requested",
                            opts.queue_cost
                        ),
                    ));
                }
                match self.shared.queued_cost.compare_exchange(
                    cur,
                    cur + cost,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                ) {
                    Ok(_) => break cur + cost,
                    Err(actual) => cur = actual,
                }
            }
        } else {
            self.shared.queued_cost.fetch_add(cost, Ordering::SeqCst) + cost
        };
        metrics.gauge("serve_queue_cost").set(new_cost);
        let inflight = self.shared.inflight_total.fetch_add(1, Ordering::SeqCst) + 1;
        metrics.gauge("serve_inflight").set(inflight);
        self.conn.inflight.fetch_add(1, Ordering::SeqCst);
        metrics.counter("service_requests").inc();

        let local = self.next_local;
        self.next_local += 1;
        let pool_id = self.shared.next_pool_id.fetch_add(1, Ordering::SeqCst);
        self.pool_ids.push(pool_id);
        // locals below start_local were issued by an earlier session on
        // the same service (the stdin adapter keeps local == pool in
        // lockstep there, so the raw id is the pool id)
        let after = req.after.map(|a| {
            if a < self.start_local {
                a
            } else {
                self.pool_ids[(a - self.start_local) as usize]
            }
        });
        // route BEFORE submit: the outcome may arrive immediately
        let backlog = {
            let mut routes = self.shared.routes.lock().unwrap();
            routes.insert(
                pool_id,
                Route {
                    tx: self.tx.clone(),
                    local_id: local,
                    stream,
                    cost,
                    conn: self.conn.clone(),
                    submitted: std::time::Instant::now(),
                },
            );
            routes.len() as u64
        };
        metrics.gauge("serve_dispatcher_backlog").set(backlog);
        // the request span opens here on the reader thread and closes on
        // the dispatcher thread when the outcome routes back — exported
        // as an async event pair keyed by the derived pool-id span id
        crate::obs::event_begin(
            "request",
            crate::obs::request_span_id(pool_id),
            crate::obs::current_span(),
        );
        self.shared.pool.submit(JobSpec { id: pool_id, kind, timings: req.timings, after });
        Pending::Job(local)
    }
}

/// Typed admission refusal: like an error response but carrying a
/// machine-readable `"code"` so clients can back off without string
/// matching.
fn admission_error(code: &str, msg: String) -> Json {
    let mut o = BTreeMap::new();
    o.insert("ok".to_string(), Json::Bool(false));
    o.insert("code".to_string(), Json::Str(code.to_string()));
    o.insert("error".to_string(), Json::Str(msg));
    Json::Object(o)
}

/// Cheap admission cost in row-scan-equivalent units: proportional to
/// the rows × work-per-row class of the job, never its exact runtime.
/// The point is ordering (a 4096-row dataset predict outweighs a screen
/// pair), not precision.
pub(crate) fn estimate_cost(kind: &JobKind) -> u64 {
    match kind {
        // one full path run screens `points` grid steps over l rows
        JobKind::Path(cfg) => (cfg.grid.points as u64).saturating_mul(1000),
        // one anchor solve plus a row scan per pair
        JobKind::Screen(s) => 1000u64.saturating_add((s.pairs.len() as u64) * 100),
        JobKind::Train(_) => 2000,
        JobKind::Predict(p) => match &p.input {
            PredictInput::Rows { flat, width } => {
                ((flat.len() / (*width).max(1)) as u64).max(1)
            }
            // a registry dataset can be arbitrarily large; treat it as
            // the heavyweight class
            PredictInput::Dataset { .. } => 100_000,
        },
        JobKind::Cache(_) | JobKind::Stats => 1,
    }
}

/// The writer half: stream or buffer each response, then replay buffered
/// slots in input order. Exits once EOF has been read and every awaited
/// job has reported — or when every event sender is gone (forced
/// teardown), in which case missing buffered jobs answer as lost.
fn write_loop<W: Write>(rx: Receiver<ConnEvent>, mut output: W) -> std::io::Result<()> {
    let mut slots: Vec<SlotInfo> = Vec::new();
    let mut outcomes_seen: HashSet<u64> = HashSet::new();
    let mut done: HashMap<u64, Json> = HashMap::new();
    let mut awaited: HashSet<u64> = HashSet::new();
    let mut eof = false;
    loop {
        if eof && awaited.is_empty() {
            break;
        }
        let Ok(event) = rx.recv() else { break };
        match event {
            ConnEvent::Eof => eof = true,
            ConnEvent::Outcome { local_id, stream, mut outcome } => {
                outcomes_seen.insert(local_id);
                awaited.remove(&local_id);
                // the wire speaks connection-local ids only
                outcome.id = local_id;
                let json = ScreeningService::encode_response_json(&outcome);
                if stream {
                    writeln!(output, "{}", json.to_string())?;
                    output.flush()?;
                } else {
                    done.insert(local_id, json);
                }
            }
            ConnEvent::Slot(slot) => {
                // every submitted job — streamed or buffered — gates
                // session completion (an outcome may already have beaten
                // its slot here, hence the seen check)
                let mut register = |p: &Pending| {
                    if let Pending::Job(id) = p {
                        if !outcomes_seen.contains(id) {
                            awaited.insert(*id);
                        }
                    }
                };
                match &slot {
                    SlotInfo::Single { p, .. } => register(p),
                    SlotInfo::Batch { ps, .. } => ps.iter().for_each(&mut register),
                }
                match slot {
                    // streamed slots: answerable pendings (parse and
                    // admission errors) emit now; job outcomes will
                    // stream from the dispatcher; nothing to replay
                    SlotInfo::Single { stream: true, p } => {
                        if let Pending::Ready(j) = p {
                            writeln!(output, "{}", j.to_string())?;
                            output.flush()?;
                        }
                    }
                    SlotInfo::Batch { stream: true, ps } => {
                        for p in ps {
                            if let Pending::Ready(j) = p {
                                writeln!(output, "{}", j.to_string())?;
                                output.flush()?;
                            }
                        }
                    }
                    buffered => slots.push(buffered),
                }
            }
        }
    }
    // input-order replay of the buffered session — with no streamed
    // requests this is the whole output, byte-identical to the
    // historical loop
    for slot in slots {
        let json = match slot {
            SlotInfo::Single { p, .. } => resolve(p, &mut done),
            SlotInfo::Batch { ps, .. } => {
                let entries: Vec<Json> = ps.into_iter().map(|p| resolve(p, &mut done)).collect();
                let mut o = BTreeMap::new();
                o.insert("batch".to_string(), Json::Array(entries));
                Json::Object(o)
            }
        };
        writeln!(output, "{}", json.to_string())?;
        output.flush()?;
    }
    Ok(())
}

/// Answer one buffered pending from the routed outcomes. A job whose
/// outcome never arrived (forced teardown) still yields an error object
/// instead of a hole in the session.
fn resolve(p: Pending, done: &mut HashMap<u64, Json>) -> Json {
    match p {
        Pending::Ready(j) => j,
        Pending::Job(id) => done.remove(&id).unwrap_or_else(|| {
            let mut o = BTreeMap::new();
            o.insert("id".to_string(), Json::Int(id as i64));
            o.insert("ok".to_string(), Json::Bool(false));
            o.insert("error".to_string(), Json::Str("job result lost".into()));
            Json::Object(o)
        }),
    }
}
