//! Materialized problem instance: `(Z, ȳ, box)` plus cached row norms.

use crate::data::{Dataset, Task};
use crate::linalg::{self, Cols, RowMatrix, Rows, ShardAxis};
use std::sync::OnceLock;

/// Which special case of problem (3) to instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Model {
    /// Hinge-loss SVM, Eq. (24). Dual box [0, 1].
    Svm,
    /// Least absolute deviations, Eq. (29). Dual box [−1, 1].
    Lad,
    /// Weighted SVM (paper §8 extension): per-class costs; dual box
    /// [0, cᵢ].
    WeightedSvm,
}

impl Model {
    pub fn parse(s: &str) -> Option<Model> {
        match s {
            "svm" => Some(Model::Svm),
            "lad" => Some(Model::Lad),
            "wsvm" => Some(Model::WeightedSvm),
            _ => None,
        }
    }

    pub fn expected_task(&self) -> Task {
        match self {
            Model::Svm | Model::WeightedSvm => Task::Classification,
            Model::Lad => Task::Regression,
        }
    }

    /// Canonical name — the token [`Model::parse`] accepts, so names
    /// echoed in service responses round-trip into follow-up requests.
    pub fn name(&self) -> &'static str {
        match self {
            Model::Svm => "svm",
            Model::Lad => "lad",
            Model::WeightedSvm => "wsvm",
        }
    }

    /// Owned wire-format name. Every response summary (path, screen,
    /// train, predict) and the model artifact metadata goes through this
    /// one helper, so a model name emitted anywhere always round-trips
    /// through [`Model::parse`] — the bug class PR 3 fixed for screen
    /// responses cannot regrow a call site at a time.
    pub fn wire_name(&self) -> String {
        self.name().to_string()
    }
}

/// A dual problem instance:
/// min_{θ, loᵢ ≤ θᵢ ≤ hiᵢ}  C/2·‖Zᵀθ‖² − ⟨ȳ, θ⟩.
#[derive(Clone, Debug)]
pub struct Instance {
    pub model: Model,
    pub name: String,
    /// Z (l×n): row i is zᵢ = aᵢ·xᵢ. Inherits the dataset's storage
    /// (dense or CSR — Z has exactly X's sparsity pattern).
    pub z: Rows,
    /// ȳᵢ = bᵢ·yᵢ.
    pub ybar: Vec<f64>,
    /// Per-coordinate lower bound α (uniform for SVM/LAD).
    pub lo: Vec<f64>,
    /// Per-coordinate upper bound β.
    pub hi: Vec<f64>,
    /// Cached ‖zᵢ‖².
    pub z_norms_sq: Vec<f64>,
    /// Cumulative stored-entry prefix over the rows of Z (length `l + 1`,
    /// `nnz_prefix[0] = 0`): `nnz_prefix[i+1] − nnz_prefix[i]` is row i's
    /// stored-entry count (`n` for dense, the CSR row nnz for sparse).
    /// This is the `par::cumulative_weights` input the sharded scan and
    /// the CD block loop previously recomputed per scan/block; caching it
    /// here amortizes it once per instance and evicts it with the
    /// instance in the coordinator's `InstanceCache` (it is charged to
    /// [`Instance::approx_bytes`]).
    pub nnz_prefix: Vec<usize>,
    /// Lazily built column-access mirror of Z (dense → column-major, CSR →
    /// CSC), used by the feature-sharded (`cols`-axis) reconstruction
    /// kernels. Built on first use via [`Instance::cols`], cached for the
    /// instance's lifetime, and evicted with the instance when the
    /// coordinator's `InstanceCache` drops the entry. Its *projected* size
    /// is charged to [`Instance::approx_bytes`] up front (the projection
    /// equals the built size — see [`Cols::projected_bytes`]), so lazily
    /// materializing it never changes an admitted entry's LRU cost.
    cols: OnceLock<Cols>,
}

impl Instance {
    /// Build from a dataset. Weighted SVM uses inverse-class-frequency
    /// costs normalized to mean 1 (a common imbalanced-data choice).
    pub fn from_dataset(model: Model, ds: &Dataset) -> Instance {
        assert_eq!(
            ds.task,
            model.expected_task(),
            "dataset task does not match model"
        );
        let (l, n) = (ds.len(), ds.dim());
        // Z keeps X's storage: dense builds a dense buffer, CSR maps the
        // stored values in place (same indptr/indices — no densify).
        let z: Rows = match &ds.x {
            Rows::Dense(x) => {
                let mut z = RowMatrix::zeros(l, n);
                for i in 0..l {
                    // zᵢ = −yᵢxᵢ for (weighted) SVM, −xᵢ for LAD
                    let a = match model {
                        Model::Svm | Model::WeightedSvm => -ds.y[i],
                        Model::Lad => -1.0,
                    };
                    for (j, &v) in x.row(i).iter().enumerate() {
                        z.set(i, j, a * v);
                    }
                }
                Rows::Dense(z)
            }
            Rows::Sparse(x) => Rows::Sparse(x.map_values(|i, _, v| match model {
                Model::Svm | Model::WeightedSvm => -ds.y[i] * v,
                Model::Lad => -v,
            })),
        };
        let ybar: Vec<f64> = match model {
            // ȳᵢ = yᵢ² = 1 for (weighted) SVM, yᵢ for LAD
            Model::Svm | Model::WeightedSvm => vec![1.0; l],
            Model::Lad => ds.y.clone(),
        };
        let (lo, hi) = match model {
            Model::Svm => (vec![0.0; l], vec![1.0; l]),
            Model::Lad => (vec![-1.0; l], vec![1.0; l]),
            Model::WeightedSvm => {
                let pos = ds.y.iter().filter(|&&v| v > 0.0).count().max(1);
                let neg = (l - pos).max(1);
                // inverse-frequency, normalized to mean ≈ 1
                let (cp, cn) = (l as f64 / (2.0 * pos as f64), l as f64 / (2.0 * neg as f64));
                let hi: Vec<f64> =
                    ds.y.iter().map(|&v| if v > 0.0 { cp } else { cn }).collect();
                (vec![0.0; l], hi)
            }
        };
        let z_norms_sq = z.row_norms_sq();
        let nnz_prefix = match &z {
            Rows::Dense(_) => (0..=l).map(|i| i * n).collect(),
            Rows::Sparse(m) => m.indptr().to_vec(),
        };
        Instance {
            model,
            name: ds.name.clone(),
            z,
            ybar,
            lo,
            hi,
            z_norms_sq,
            nnz_prefix,
            cols: OnceLock::new(),
        }
    }

    /// Number of instances l.
    #[inline]
    pub fn len(&self) -> usize {
        self.z.rows()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimension n.
    #[inline]
    pub fn dim(&self) -> usize {
        self.z.cols()
    }

    /// Approximate resident size in bytes — the Z storage footprint
    /// ([`Rows::approx_bytes`]), the four l-length side vectors, the nnz
    /// prefix, and the column mirror's projected footprint
    /// ([`Instance::mirror_bytes`]). The coordinator's instance cache
    /// charges entries against its byte budget with this estimate; the
    /// mirror is charged whether or not it has been built yet so the
    /// lazy build can never grow an entry past its admitted cost.
    pub fn approx_bytes(&self) -> usize {
        self.z.approx_bytes()
            + 8 * (self.ybar.len() + self.lo.len() + self.hi.len() + self.z_norms_sq.len())
            + 8 * self.nnz_prefix.len()
            + self.mirror_bytes()
            + std::mem::size_of::<Instance>()
    }

    /// Size of the column-access mirror in bytes, computed from the cached
    /// shape/nnz *without* building it. Exactly equal to
    /// `self.cols().approx_bytes()` once the mirror exists (pinned by the
    /// `mirror_charge_is_projected_upfront` test), so
    /// [`Instance::approx_bytes`] is identical before and after the lazy
    /// build.
    pub fn mirror_bytes(&self) -> usize {
        let nnz = *self.nnz_prefix.last().unwrap_or(&0);
        Cols::projected_bytes(self.z.is_sparse(), self.len(), self.dim(), nnz)
    }

    /// The column-access mirror of Z, built on first use (O(nnz) counting
    /// sort for CSR, O(l·n) transpose copy for dense) and cached for the
    /// instance's lifetime.
    pub fn cols(&self) -> &Cols {
        self.cols.get_or_init(|| Cols::from_rows(&self.z))
    }

    /// Whether the lazy mirror has been materialized (cache accounting
    /// tests and diagnostics only — the charge is identical either way).
    pub fn cols_built(&self) -> bool {
        self.cols.get().is_some()
    }

    /// Resolve `Auto` to a concrete shard axis from the cached shape/nnz
    /// balance: `cols` when the feature dimension is wide enough to
    /// amortize slab dispatch (n ≥ 1024) and the data is not strongly tall
    /// (4·n ≥ l — per-column work is l-proportional dense and nnz/n-
    /// proportional sparse, so very tall shapes keep the row path).
    /// `Rows`/`Cols` pass through unchanged. The resolved axis never
    /// changes any result byte — it only partitions work.
    pub fn pick_axis(&self, axis: ShardAxis) -> ShardAxis {
        match axis {
            ShardAxis::Auto => {
                if self.dim() >= 1024 && 4 * self.dim() >= self.len() {
                    ShardAxis::Cols
                } else {
                    ShardAxis::Rows
                }
            }
            fixed => fixed,
        }
    }

    /// Stored entries in row i of Z, from the cached prefix.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.nnz_prefix[i + 1] - self.nnz_prefix[i]
    }

    /// Stored-entry-balanced contiguous shards over all l rows — the same
    /// cuts as [`Rows::balanced_shards`], served from the cached
    /// [`Self::nnz_prefix`] instead of re-deriving weights from storage.
    pub fn balanced_shards(&self, shards: usize) -> Vec<std::ops::Range<usize>> {
        match &self.z {
            // dense rows are uniform: the even split, NOT a cumulative cut
            // (the two differ at rounding boundaries, and every dense
            // bitwise contract is pinned to `shard_ranges`)
            Rows::Dense(_) => linalg::par::shard_ranges(self.len(), shards),
            Rows::Sparse(_) => linalg::par::cumulative_ranges(&self.nnz_prefix, shards),
        }
    }

    /// Stored-entry-balanced shards over positions of an arbitrary row
    /// subset (e.g. the CD sweep's shuffled active set) — identical cuts
    /// to [`Rows::balanced_subset_shards`], weights from the cached
    /// prefix. The returned ranges index into `idx`, not into Z.
    pub fn balanced_subset_shards(
        &self,
        idx: &[usize],
        shards: usize,
    ) -> Vec<std::ops::Range<usize>> {
        match &self.z {
            Rows::Dense(_) => linalg::par::shard_ranges(idx.len(), shards),
            Rows::Sparse(_) => {
                let cum =
                    linalg::par::cumulative_weights(idx.iter().map(|&i| self.row_nnz(i)));
                linalg::par::cumulative_ranges(&cum, shards)
            }
        }
    }

    /// u = Zᵀθ (n-vector). w*(C) = −C·u at the optimum.
    pub fn u_from_theta(&self, theta: &[f64]) -> Vec<f64> {
        let mut u = vec![0.0; self.dim()];
        self.z.t_matvec(theta, &mut u);
        u
    }

    /// Axis-aware u = Zᵀθ: the `rows` axis is the serial row-major
    /// t_matvec above; the `cols` axis shards disjoint contiguous column
    /// slabs of the lazy mirror across the solver pool, each slab
    /// replaying the row-major per-component accumulation exactly
    /// ([`Cols::t_matvec_slab`]) — so the result is bit-identical to
    /// [`Instance::u_from_theta`] for every axis and thread count.
    pub fn u_from_theta_axis(
        &self,
        theta: &[f64],
        axis: ShardAxis,
        threads: usize,
    ) -> Vec<f64> {
        match self.pick_axis(axis) {
            ShardAxis::Cols => self.u_from_theta_cols(theta, threads),
            _ => self.u_from_theta(theta),
        }
    }

    /// Feature-sharded u = Zᵀθ over the column mirror. Slab boundaries are
    /// nnz-balanced (uniform for dense); merges are write-disjoint because
    /// each shard owns its contiguous output slab.
    fn u_from_theta_cols(&self, theta: &[f64], threads: usize) -> Vec<f64> {
        let n = self.dim();
        let mut u = vec![0.0; n];
        if n == 0 {
            return u;
        }
        let cols = self.cols();
        let t = linalg::par::effective_threads(threads, n);
        let bounds = cols.balanced_bounds(t);
        linalg::par::run_sharded_mut(&mut u, 1, &bounds, |range, slab| {
            cols.t_matvec_slab(theta, range.start, range.end, slab);
        });
        u
    }

    /// Primal weight vector from the dual point: w = −C·Zᵀθ (Eq. 13).
    pub fn w_from_theta(&self, c: f64, theta: &[f64]) -> Vec<f64> {
        let mut w = self.u_from_theta(theta);
        linalg::scale(-c, &mut w);
        w
    }

    /// Axis-aware w = −C·Zᵀθ — bit-identical to
    /// [`Instance::w_from_theta`] for every axis and thread count (the
    /// final scale is the same serial pass either way).
    pub fn w_from_theta_axis(
        &self,
        c: f64,
        theta: &[f64],
        axis: ShardAxis,
        threads: usize,
    ) -> Vec<f64> {
        let mut w = self.u_from_theta_axis(theta, axis, threads);
        linalg::scale(-c, &mut w);
        w
    }

    /// Dual objective g(θ) = C/2·‖Zᵀθ‖² − ⟨ȳ, θ⟩ (problem (12)).
    pub fn dual_objective(&self, c: f64, theta: &[f64]) -> f64 {
        let u = self.u_from_theta(theta);
        0.5 * c * linalg::norm_sq(&u) - linalg::dot(&self.ybar, theta)
    }

    /// Primal objective of problem (3): 1/2‖w‖² + C·Σφ(⟨w,zᵢ⟩+ȳᵢ).
    /// φ = [t]₊ for (weighted) SVM and |t| for LAD.
    pub fn primal_objective(&self, c: f64, w: &[f64]) -> f64 {
        let mut loss = 0.0;
        for i in 0..self.len() {
            let t = self.z.row(i).dot(w) + self.ybar[i];
            let phi = match self.model {
                Model::Svm => t.max(0.0),
                Model::Lad => t.abs(),
                Model::WeightedSvm => self.hi[i] * t.max(0.0),
            };
            loss += phi;
        }
        // weighted SVM folds the cost into φ via the hi (=cᵢ) vector, so
        // the C multiplier is uniform
        0.5 * linalg::norm_sq(w) + c * loss
    }

    /// Project a θ vector into the box (used for warm starts).
    pub fn project_box(&self, theta: &mut [f64]) {
        for i in 0..theta.len() {
            theta[i] = linalg::clamp(theta[i], self.lo[i], self.hi[i]);
        }
    }

    /// Whether θ is inside the box (with tolerance).
    pub fn in_box(&self, theta: &[f64], tol: f64) -> bool {
        theta
            .iter()
            .enumerate()
            .all(|(i, &t)| t >= self.lo[i] - tol && t <= self.hi[i] + tol)
    }

    /// Mid-point of the box — a reasonable cold-start θ⁰. For SVM the
    /// classic cold start is θ=0 (all lower bounds); we follow LIBLINEAR.
    pub fn cold_start(&self) -> Vec<f64> {
        match self.model {
            Model::Svm | Model::WeightedSvm => vec![0.0; self.len()],
            Model::Lad => vec![0.0; self.len()],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::data::Rng;

    #[test]
    fn svm_instance_construction() {
        let ds = synth::toy_gaussian(1, 10, 1.5, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        assert_eq!(inst.len(), 20);
        assert_eq!(inst.dim(), 2);
        // zᵢ = −yᵢxᵢ
        for i in 0..inst.len() {
            for j in 0..2 {
                assert_eq!(inst.z.get(i, j), -ds.y[i] * ds.x.get(i, j));
            }
            assert_eq!(inst.ybar[i], 1.0);
            assert_eq!((inst.lo[i], inst.hi[i]), (0.0, 1.0));
        }
    }

    #[test]
    fn lad_instance_construction() {
        let mut rng = Rng::new(2);
        let ds = synth::random_regression(&mut rng, 12, 3);
        let inst = Instance::from_dataset(Model::Lad, &ds);
        for i in 0..12 {
            for j in 0..3 {
                assert_eq!(inst.z.get(i, j), -ds.x.get(i, j));
            }
            assert_eq!(inst.ybar[i], ds.y[i]);
            assert_eq!((inst.lo[i], inst.hi[i]), (-1.0, 1.0));
        }
    }

    #[test]
    fn weighted_svm_box() {
        let ds = synth::gaussian_classes(5, 200, 4, 1.0, 1.0, 0.25, 1.0);
        let inst = Instance::from_dataset(Model::WeightedSvm, &ds);
        // minority (positive) class gets the larger cost
        let pos_cost = (0..200).find(|&i| ds.y[i] > 0.0).map(|i| inst.hi[i]).unwrap();
        let neg_cost = (0..200).find(|&i| ds.y[i] < 0.0).map(|i| inst.hi[i]).unwrap();
        assert!(pos_cost > neg_cost);
        assert!(inst.lo.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sparse_instance_matches_dense() {
        use crate::linalg::Storage;
        for model in [Model::Svm, Model::WeightedSvm] {
            let ds = synth::sparse_classes(3, 40, 25, 0.15);
            let dense_ds = ds.clone().into_storage(Storage::Dense);
            let a = Instance::from_dataset(model, &ds);
            let b = Instance::from_dataset(model, &dense_ds);
            assert!(a.z.is_sparse() && !b.z.is_sparse());
            assert_eq!(a.z_norms_sq, b.z_norms_sq, "norms must be bit-identical");
            for i in 0..a.len() {
                for j in 0..a.dim() {
                    assert_eq!(a.z.get(i, j), b.z.get(i, j));
                }
            }
            let theta: Vec<f64> = (0..a.len()).map(|i| (i % 3) as f64 * 0.5).collect();
            assert_eq!(a.u_from_theta(&theta), b.u_from_theta(&theta));
            assert_eq!((a.lo, a.hi), (b.lo, b.hi));
        }
        let rds = synth::sparse_regression(4, 30, 20, 0.2, 0.1);
        let a = Instance::from_dataset(Model::Lad, &rds);
        let b = Instance::from_dataset(Model::Lad, &rds.clone().into_storage(Storage::Dense));
        assert_eq!(a.z_norms_sq, b.z_norms_sq);
        assert_eq!(a.ybar, b.ybar);
    }

    #[test]
    #[should_panic]
    fn task_mismatch_panics() {
        let ds = synth::toy_gaussian(1, 5, 1.0, 0.5);
        Instance::from_dataset(Model::Lad, &ds);
    }

    #[test]
    fn w_theta_identity() {
        let ds = synth::toy_gaussian(3, 8, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let theta: Vec<f64> = (0..16).map(|i| (i % 2) as f64).collect();
        let c = 2.5;
        let w = inst.w_from_theta(c, &theta);
        // w = −C·Σθᵢzᵢ = C·Σ_{θᵢ=1} yᵢxᵢ
        let mut expect = vec![0.0; 2];
        for i in 0..16 {
            if theta[i] == 1.0 {
                for j in 0..2 {
                    expect[j] += c * ds.y[i] * ds.x.get(i, j);
                }
            }
        }
        for j in 0..2 {
            assert!((w[j] - expect[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn objectives_finite_and_weak_duality() {
        let ds = synth::toy_gaussian(4, 20, 0.75, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let c = 1.0;
        let theta = vec![0.5; inst.len()];
        let w = inst.w_from_theta(c, &theta);
        // weak duality of (3)/(11): primal(w) ≥ −C·dual(θ)... our dual
        // objective (12) is scaled: max of (11) = −C·min of (12). So
        // primal ≥ −C·g(θ) for any feasible θ, w.
        let p = inst.primal_objective(c, &w);
        let g = inst.dual_objective(c, &theta);
        assert!(p.is_finite() && g.is_finite());
        assert!(p >= -c * g - 1e-9, "weak duality violated: {p} < {}", -c * g);
    }

    #[test]
    fn project_and_in_box() {
        let ds = synth::toy_gaussian(5, 5, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let mut theta = vec![-0.5, 0.5, 2.0, 1.0, 0.0, -0.1, 0.9, 1.1, 0.2, 0.3];
        assert!(!inst.in_box(&theta, 1e-12));
        inst.project_box(&mut theta);
        assert!(inst.in_box(&theta, 1e-12));
        assert_eq!(theta[0], 0.0);
        assert_eq!(theta[2], 1.0);
    }

    #[test]
    fn model_name_round_trips_through_parse() {
        for m in [Model::Svm, Model::Lad, Model::WeightedSvm] {
            assert_eq!(Model::parse(m.name()), Some(m));
            assert_eq!(Model::parse(&m.wire_name()), Some(m));
        }
    }

    #[test]
    fn approx_bytes_tracks_storage() {
        use crate::linalg::Storage;
        let ds = synth::sparse_classes(8, 50, 40, 0.1);
        let sp = Instance::from_dataset(Model::Svm, &ds);
        let de = Instance::from_dataset(Model::Svm, &ds.clone().into_storage(Storage::Dense));
        // dense charges the full l·n buffer; CSR only the stored entries
        assert!(de.approx_bytes() > sp.approx_bytes());
        assert!(de.approx_bytes() >= 50 * 40 * 8);
        assert!(sp.approx_bytes() >= sp.z.nnz() * 12);
    }

    #[test]
    fn nnz_prefix_cached_and_shards_match_rows() {
        use crate::linalg::Storage;
        let ds = synth::sparse_classes(9, 60, 30, 0.12);
        let sp = Instance::from_dataset(Model::Svm, &ds);
        let de = Instance::from_dataset(Model::Svm, &ds.clone().into_storage(Storage::Dense));
        for inst in [&sp, &de] {
            assert_eq!(inst.nnz_prefix.len(), inst.len() + 1);
            assert_eq!(inst.nnz_prefix[0], 0);
            assert_eq!(*inst.nnz_prefix.last().unwrap(), inst.z.nnz());
            for i in 0..inst.len() {
                assert_eq!(inst.row_nnz(i), inst.z.row(i).nnz(), "row {i}");
            }
        }
        // the cached-prefix cuts must be byte-identical to the Rows cuts —
        // cd_par and the scans route through these, and their bitwise
        // contracts depend on the groupings not moving
        let subset: Vec<usize> = (0..sp.len()).rev().step_by(2).collect();
        for shards in [1usize, 2, 3, 4, 7] {
            for inst in [&sp, &de] {
                assert_eq!(inst.balanced_shards(shards), inst.z.balanced_shards(shards));
                assert_eq!(
                    inst.balanced_subset_shards(&subset, shards),
                    inst.z.balanced_subset_shards(&subset, shards)
                );
            }
        }
        // and the prefix is charged to the cache budget estimate
        assert!(sp.approx_bytes() >= sp.z.approx_bytes() + 8 * (sp.len() + 1));
    }

    #[test]
    fn mirror_charge_is_projected_upfront() {
        use crate::linalg::Storage;
        let ds = synth::sparse_classes(12, 40, 30, 0.15);
        let sp = Instance::from_dataset(Model::Svm, &ds);
        let de = Instance::from_dataset(Model::Svm, &ds.clone().into_storage(Storage::Dense));
        for inst in [&sp, &de] {
            assert!(!inst.cols_built(), "mirror must be lazy");
            let before = inst.approx_bytes();
            // the mirror is charged before it exists...
            assert!(before >= inst.z.approx_bytes() + inst.mirror_bytes());
            let built = inst.cols().approx_bytes();
            // ...the projection equals the built footprint exactly...
            assert_eq!(inst.mirror_bytes(), built, "{}", inst.z.storage_name());
            // ...so building never changes the LRU charge
            assert!(inst.cols_built());
            assert_eq!(inst.approx_bytes(), before, "{}", inst.z.storage_name());
        }
        // concrete projections: dense l·n·8; CSC nnz·12 + (n+1)·8
        assert_eq!(de.mirror_bytes(), 40 * 30 * 8);
        assert_eq!(sp.mirror_bytes(), sp.z.nnz() * 12 + 31 * 8);
    }

    #[test]
    fn axis_reconstruction_bit_identical() {
        use crate::linalg::Storage;
        let ds = synth::sparse_classes(21, 50, 33, 0.2);
        let sp = Instance::from_dataset(Model::Svm, &ds);
        let de = Instance::from_dataset(Model::Svm, &ds.clone().into_storage(Storage::Dense));
        for inst in [&sp, &de] {
            let theta: Vec<f64> =
                (0..inst.len()).map(|i| if i % 5 == 0 { 0.0 } else { (i as f64 * 0.17).sin() }).collect();
            let want_u = inst.u_from_theta(&theta);
            let want_w = inst.w_from_theta(1.75, &theta);
            for threads in [1usize, 2, 4, 7] {
                for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Auto] {
                    assert_eq!(
                        inst.u_from_theta_axis(&theta, axis, threads),
                        want_u,
                        "{} u axis={} threads={threads}",
                        inst.z.storage_name(),
                        axis.name()
                    );
                    assert_eq!(
                        inst.w_from_theta_axis(1.75, &theta, axis, threads),
                        want_w,
                        "{} w axis={} threads={threads}",
                        inst.z.storage_name(),
                        axis.name()
                    );
                }
            }
        }
    }

    #[test]
    fn pick_axis_resolves_auto_from_shape() {
        let ds = synth::toy_gaussian(1, 10, 1.5, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        // fixed axes pass through untouched
        assert_eq!(inst.pick_axis(ShardAxis::Rows), ShardAxis::Rows);
        assert_eq!(inst.pick_axis(ShardAxis::Cols), ShardAxis::Cols);
        // n = 2 ≪ 1024: auto stays on the row path for tall/narrow data
        assert_eq!(inst.pick_axis(ShardAxis::Auto), ShardAxis::Rows);
    }

    #[test]
    fn norms_cached_correctly() {
        let ds = synth::toy_gaussian(6, 7, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        for i in 0..inst.len() {
            let manual = inst.z.row(i).norm_sq();
            assert!((inst.z_norms_sq[i] - manual).abs() < 1e-12);
        }
    }
}
