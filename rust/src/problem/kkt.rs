//! KKT-condition classification (Eq. 14) — the ground truth the screening
//! rules are validated against.
//!
//! With w* the primal optimum:
//!
//! * i ∈ R  ⟺  −⟨w*, zᵢ⟩ > ȳᵢ  ⟺  θᵢ* = α   (SVM: margin exceeded)
//! * i ∈ E  ⟺  −⟨w*, zᵢ⟩ = ȳᵢ               (support vectors)
//! * i ∈ L  ⟺  −⟨w*, zᵢ⟩ < ȳᵢ  ⟺  θᵢ* = β   (SVM: inside / violating)
//!
//! Both R and L are *non-support* vectors in the paper's terminology.

use super::instance::Instance;

/// Membership of one instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KktClass {
    /// θᵢ* = α (lower bound active).
    R,
    /// support vector: ȳᵢ hit exactly (within tolerance).
    E,
    /// θᵢ* = β (upper bound active).
    L,
}

/// Full-problem membership for every instance.
#[derive(Clone, Debug)]
pub struct Membership {
    pub classes: Vec<KktClass>,
}

impl Membership {
    pub fn count(&self, k: KktClass) -> usize {
        self.classes.iter().filter(|&&c| c == k).count()
    }
    /// Fraction of instances that are non-support vectors (R ∪ L).
    pub fn non_sv_fraction(&self) -> f64 {
        if self.classes.is_empty() {
            return 0.0;
        }
        (self.count(KktClass::R) + self.count(KktClass::L)) as f64 / self.classes.len() as f64
    }

    /// Ascending indices of the instances in class `k` — the support-set
    /// extraction the model artifact layer persists (`indices_of(E)` is
    /// the margin support-vector set).
    pub fn indices_of(&self, k: KktClass) -> Vec<usize> {
        self.classes
            .iter()
            .enumerate()
            .filter(|(_, &c)| c == k)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Classify every instance by the KKT conditions at (C, w*). `tol` is the
/// dead-band around equality: an instance within tol of the margin is
/// conservatively labeled E (support vector).
pub fn classify_kkt(inst: &Instance, w: &[f64], tol: f64) -> Membership {
    let classes = (0..inst.len())
        .map(|i| {
            let s = -inst.z.row(i).dot(w); // −⟨w, zᵢ⟩
            if s > inst.ybar[i] + tol {
                KktClass::R
            } else if s < inst.ybar[i] - tol {
                KktClass::L
            } else {
                KktClass::E
            }
        })
        .collect();
    Membership { classes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::problem::instance::Model;

    #[test]
    fn classification_matches_margins() {
        // hand-built: 1-D SVM, w = [1]. margin yᵢ·w·xᵢ.
        use crate::data::{Dataset, Task};
        use crate::linalg::RowMatrix;
        let x = RowMatrix::from_flat(3, 1, vec![2.0, 1.0, 0.5]);
        let ds = Dataset::new("m", Task::Classification, x, vec![1.0, 1.0, 1.0]);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        // zᵢ = −xᵢ, ȳ = 1; −⟨w,zᵢ⟩ = w·xᵢ = margin
        let m = classify_kkt(&inst, &[1.0], 1e-9);
        assert_eq!(m.classes, vec![KktClass::R, KktClass::E, KktClass::L]);
        assert_eq!(m.count(KktClass::E), 1);
        assert!((m.non_sv_fraction() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.indices_of(KktClass::E), vec![1]);
        assert_eq!(m.indices_of(KktClass::R), vec![0]);
        assert_eq!(m.indices_of(KktClass::L), vec![2]);
    }

    #[test]
    fn tolerance_widens_e_band() {
        use crate::data::{Dataset, Task};
        use crate::linalg::RowMatrix;
        let x = RowMatrix::from_flat(2, 1, vec![1.05, 0.95]);
        let ds = Dataset::new("t", Task::Classification, x, vec![1.0, 1.0]);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        let sharp = classify_kkt(&inst, &[1.0], 1e-6);
        assert_eq!(sharp.classes, vec![KktClass::R, KktClass::L]);
        let fuzzy = classify_kkt(&inst, &[1.0], 0.1);
        assert_eq!(fuzzy.classes, vec![KktClass::E, KktClass::E]);
    }

    #[test]
    fn separated_toy_mostly_r_at_large_margin() {
        let ds = synth::toy_gaussian(1, 200, 1.5, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        // direction (1,1)/√2 with a generous scale classifies nearly all
        let w = [3.0, 3.0];
        let m = classify_kkt(&inst, &w, 1e-9);
        assert!(m.count(KktClass::R) > 350, "R = {}", m.count(KktClass::R));
    }
}
