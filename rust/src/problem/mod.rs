//! The paper's unified formulation (problem (3)):
//!
//! ```text
//!   min_w  1/2‖w‖² + C·Σᵢ φ(wᵀ(aᵢxᵢ) + bᵢyᵢ)
//! ```
//!
//! with φ a nonnegative continuous sublinear function, whose conjugate is
//! the indicator of a box `[α, β]` (Lemma 3). The dual (12) is the boxed QP
//!
//! ```text
//!   min_{θ ∈ [α,β]^l}  C/2·‖Zᵀθ‖² − ⟨ȳ, θ⟩,    zᵢ = aᵢxᵢ, ȳᵢ = bᵢyᵢ,
//! ```
//!
//! and w*(C) = −C·Zᵀθ*(C) (Eq. 13).
//!
//! [`Instance`] materializes `(Z, ȳ, [α,β])` from a [`Dataset`] for a
//! chosen [`Model`]:
//!
//! * **SVM** (24): φ=[t]₊, aᵢ=−yᵢ, bᵢ=yᵢ ⇒ zᵢ=−yᵢxᵢ, ȳᵢ=1, box [0,1]
//!   (Lemma 10).
//! * **LAD** (29): φ=|t|, aᵢ=−1, bᵢ=1 ⇒ zᵢ=−xᵢ, ȳᵢ=yᵢ, box [−1,1]
//!   (Lemma 13).
//! * **Weighted SVM** (§8 future work): per-instance misclassification
//!   costs cᵢ scale the loss term; in the dual the box becomes
//!   [0, cᵢ] per coordinate. We support per-coordinate boxes throughout so
//!   the DVI derivation carries over verbatim (Theorem 6 never uses the
//!   box shape, only θ ∈ feasible set for both parameter values).

pub mod instance;
pub mod kkt;

pub use instance::{Instance, Model};
pub use kkt::{classify_kkt, KktClass, Membership};
