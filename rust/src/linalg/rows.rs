//! Storage-polymorphic row matrix: the load-bearing data interface of the
//! crate. Every hot path (DVI scan, Gram upper triangle, KKT validation,
//! CD sweep) and every constructor site works through [`Rows`] /
//! [`RowView`] instead of assuming a dense `&[f64]` row.
//!
//! The two storages are interchangeable by construction: the CSR kernels
//! ([`super::csr`]) reproduce the dense kernels' floating-point results
//! bit-for-bit, so screening decisions and solver iterates are identical
//! whichever storage holds the data.

use super::csr::{self, CsrMatrix};
use super::matrix::RowMatrix;

/// Storage selection for loaded/converted datasets. `Auto` picks CSR when
/// the density is at or below [`Storage::AUTO_DENSITY_THRESHOLD`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Storage {
    Dense,
    Csr,
    Auto,
}

impl Storage {
    /// Auto-selection switches to CSR at or below this density — sparse
    /// row traversal carries an index per value (50% overhead at f64 +
    /// u32), so the crossover sits well below one-half.
    pub const AUTO_DENSITY_THRESHOLD: f64 = 0.25;

    pub fn parse(s: &str) -> Option<Storage> {
        match s {
            "dense" => Some(Storage::Dense),
            "csr" => Some(Storage::Csr),
            "auto" => Some(Storage::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Storage::Dense => "dense",
            Storage::Csr => "csr",
            Storage::Auto => "auto",
        }
    }
}

/// A row matrix in either dense or CSR storage.
#[derive(Clone, Debug, PartialEq)]
pub enum Rows {
    Dense(RowMatrix),
    Sparse(CsrMatrix),
}

impl From<RowMatrix> for Rows {
    fn from(m: RowMatrix) -> Rows {
        Rows::Dense(m)
    }
}

impl From<CsrMatrix> for Rows {
    fn from(m: CsrMatrix) -> Rows {
        Rows::Sparse(m)
    }
}

impl Rows {
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            Rows::Dense(m) => m.rows(),
            Rows::Sparse(m) => m.rows(),
        }
    }

    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            Rows::Dense(m) => m.cols(),
            Rows::Sparse(m) => m.cols(),
        }
    }

    /// Stored-entry count (rows·cols for dense).
    pub fn nnz(&self) -> usize {
        match self {
            Rows::Dense(m) => m.rows() * m.cols(),
            Rows::Sparse(m) => m.nnz(),
        }
    }

    /// Approximate buffer footprint in bytes: the full `l·n·8` payload for
    /// dense, `nnz·(8 + 4)` values+indices plus the `(l+1)·8` indptr for
    /// CSR. The coordinator's instance cache budgets resident entries with
    /// this estimate.
    pub fn approx_bytes(&self) -> usize {
        match self {
            Rows::Dense(m) => m.rows() * m.cols() * 8,
            Rows::Sparse(m) => m.nnz() * (8 + 4) + (m.rows() + 1) * 8,
        }
    }

    /// Fraction of stored entries (1.0 for dense, even if zeros occur).
    pub fn density(&self) -> f64 {
        let cells = self.rows() * self.cols();
        if cells == 0 {
            1.0
        } else {
            self.nnz() as f64 / cells as f64
        }
    }

    #[inline]
    pub fn is_sparse(&self) -> bool {
        matches!(self, Rows::Sparse(_))
    }

    pub fn storage_name(&self) -> &'static str {
        match self {
            Rows::Dense(_) => "dense",
            Rows::Sparse(_) => "csr",
        }
    }

    /// Convert to the requested storage (no-op when already there; `Auto`
    /// decides by stored density — a dense matrix re-measures its true
    /// nonzero fraction first so synthetic dense data stays dense).
    pub fn into_storage(self, storage: Storage) -> Rows {
        match storage {
            Storage::Dense => match self {
                Rows::Dense(_) => self,
                Rows::Sparse(m) => Rows::Dense(m.to_dense()),
            },
            Storage::Csr => match self {
                Rows::Sparse(_) => self,
                Rows::Dense(m) => Rows::Sparse(CsrMatrix::from_dense(&m)),
            },
            Storage::Auto => {
                let true_density = match &self {
                    Rows::Sparse(_) => self.density(),
                    Rows::Dense(m) => {
                        let cells = m.rows() * m.cols();
                        if cells == 0 {
                            1.0
                        } else {
                            let nz = m.flat().iter().filter(|&&v| v != 0.0).count();
                            nz as f64 / cells as f64
                        }
                    }
                };
                if true_density <= Storage::AUTO_DENSITY_THRESHOLD {
                    self.into_storage(Storage::Csr)
                } else {
                    self.into_storage(Storage::Dense)
                }
            }
        }
    }

    /// Borrow row i as a storage-polymorphic view.
    #[inline]
    pub fn row(&self, i: usize) -> RowView<'_> {
        match self {
            Rows::Dense(m) => RowView::Dense(m.row(i)),
            Rows::Sparse(m) => {
                let (indices, values) = m.row(i);
                RowView::Sparse { cols: m.cols(), indices, values }
            }
        }
    }

    /// Element accessor (O(1) dense, O(log nnz_row) sparse).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            Rows::Dense(m) => m.get(i, j),
            Rows::Sparse(m) => m.get(i, j),
        }
    }

    /// Element setter — dense storage only; CSR cannot grow its pattern
    /// in place (convert with [`Rows::into_storage`] first).
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        match self {
            Rows::Dense(m) => m.set(i, j, v),
            Rows::Sparse(_) => panic!("element-wise set is not supported on CSR storage"),
        }
    }

    /// out[i] = ⟨row_i, v⟩.
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        match self {
            Rows::Dense(m) => m.matvec(v, out),
            Rows::Sparse(m) => m.matvec(v, out),
        }
    }

    /// out = Mᵀ v.
    pub fn t_matvec(&self, v: &[f64], out: &mut [f64]) {
        match self {
            Rows::Dense(m) => m.t_matvec(v, out),
            Rows::Sparse(m) => m.t_matvec(v, out),
        }
    }

    /// Squared norm of every row.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        match self {
            Rows::Dense(m) => m.row_norms_sq(),
            Rows::Sparse(m) => m.row_norms_sq(),
        }
    }

    /// Gram entry G[i,j] = ⟨row_i, row_j⟩.
    #[inline]
    pub fn gram(&self, i: usize, j: usize) -> f64 {
        match self {
            Rows::Dense(m) => m.gram(i, j),
            Rows::Sparse(m) => m.gram(i, j),
        }
    }

    /// Sub-matrix of the given rows (copies, same storage).
    pub fn select_rows(&self, idx: &[usize]) -> Rows {
        match self {
            Rows::Dense(m) => Rows::Dense(m.select_rows(idx)),
            Rows::Sparse(m) => Rows::Sparse(m.select_rows(idx)),
        }
    }

    /// Scale row i in place by s.
    pub fn scale_row(&mut self, i: usize, s: f64) {
        match self {
            Rows::Dense(m) => m.scale_row(i, s),
            Rows::Sparse(m) => m.scale_row(i, s),
        }
    }

    /// Contiguous row shards for `shards` workers, area-balanced by the
    /// *stored-entry* count: uniform for dense, nonzero-weighted (via
    /// `indptr`) for CSR, so sparse shards with wildly uneven row lengths
    /// still carry near-equal work. Results of sharded row-wise maps are
    /// independent of the boundaries, so balancing never changes output.
    pub fn balanced_shards(&self, shards: usize) -> Vec<std::ops::Range<usize>> {
        match self {
            Rows::Dense(m) => super::par::shard_ranges(m.rows(), shards),
            Rows::Sparse(m) => super::par::cumulative_ranges(m.indptr(), shards),
        }
    }

    /// Like [`Rows::balanced_shards`], but over an arbitrary *subset* of
    /// rows: split positions `0..idx.len()` of the given row-index list
    /// into `shards` contiguous ranges carrying near-equal stored-entry
    /// weight (uniform for dense, per-row nnz for CSR). The parallel CD
    /// sweep partitions its shuffled active set with this, so a CSR shard
    /// full of heavy rows still costs about the same as its neighbours.
    /// The returned ranges index into `idx`, not into the matrix.
    pub fn balanced_subset_shards(
        &self,
        idx: &[usize],
        shards: usize,
    ) -> Vec<std::ops::Range<usize>> {
        match self {
            Rows::Dense(_) => super::par::shard_ranges(idx.len(), shards),
            Rows::Sparse(m) => {
                let ip = m.indptr();
                let cum = super::par::cumulative_weights(idx.iter().map(|&i| ip[i + 1] - ip[i]));
                super::par::cumulative_ranges(&cum, shards)
            }
        }
    }

    /// Row boundaries (length `shards + 1`) splitting the θ-form Gram
    /// upper triangle into row blocks of near-equal *cost*: entry (i,j)
    /// costs nnzᵢ + nnzⱼ, so on CSR data with uneven row lengths an
    /// area-balanced split would still pile heavy rows onto one worker.
    /// Dense rows all carry n nonzeros, where the cost model reduces to
    /// plain upper-triangle area. The bounds only partition work — every
    /// Gram entry is the same dot either way — so the built matrix is
    /// identical for any boundary choice.
    pub fn gram_triangle_bounds(&self, shards: usize) -> Vec<usize> {
        match self {
            Rows::Dense(m) => super::par::triangle_bounds(m.rows(), shards),
            Rows::Sparse(m) => {
                let ip = m.indptr();
                let nnz: Vec<usize> = ip.windows(2).map(|w| w[1] - w[0]).collect();
                super::par::weighted_triangle_bounds(&nnz, shards)
            }
        }
    }
}

/// Borrowed view of one row in either storage.
#[derive(Clone, Copy, Debug)]
pub enum RowView<'a> {
    Dense(&'a [f64]),
    Sparse {
        cols: usize,
        indices: &'a [u32],
        values: &'a [f64],
    },
}

impl<'a> RowView<'a> {
    /// Logical length (the feature dimension n, both storages).
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            RowView::Dense(r) => r.len(),
            RowView::Sparse { cols, .. } => *cols,
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored-entry count.
    #[inline]
    pub fn nnz(&self) -> usize {
        match self {
            RowView::Dense(r) => r.len(),
            RowView::Sparse { values, .. } => values.len(),
        }
    }

    /// ⟨row, y⟩ — bit-identical across storages (see [`super::csr`]).
    #[inline]
    pub fn dot(&self, y: &[f64]) -> f64 {
        match self {
            RowView::Dense(r) => super::dot(r, y),
            RowView::Sparse { cols, indices, values } => {
                csr::striped_sparse_dot(indices, values, y, *cols)
            }
        }
    }

    /// ‖row‖² — bit-identical across storages.
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        match self {
            RowView::Dense(r) => super::norm_sq(r),
            RowView::Sparse { cols, indices, values } => {
                csr::striped_sparse_self_dot(indices, values, *cols)
            }
        }
    }

    /// out += a·row — bit-identical across storages.
    #[inline]
    pub fn axpy_into(&self, a: f64, out: &mut [f64]) {
        match self {
            RowView::Dense(r) => super::axpy(a, r, out),
            RowView::Sparse { indices, values, .. } => csr::sparse_axpy(a, indices, values, out),
        }
    }

    /// Iterate the *stored* entries as `(col, value)` in ascending column
    /// order. Dense rows yield every entry (including zeros); callers that
    /// want nonzeros only should filter.
    pub fn iter(&self) -> RowViewIter<'a> {
        match self {
            RowView::Dense(r) => RowViewIter::Dense(r.iter().enumerate()),
            RowView::Sparse { indices, values, .. } => {
                RowViewIter::Sparse(indices.iter().zip(values.iter()))
            }
        }
    }

    /// Densified copy (tests and cold paths only).
    pub fn to_vec(&self) -> Vec<f64> {
        match self {
            RowView::Dense(r) => r.to_vec(),
            RowView::Sparse { cols, indices, values } => {
                let mut out = vec![0.0; *cols];
                for (&j, &v) in indices.iter().zip(*values) {
                    out[j as usize] = v;
                }
                out
            }
        }
    }
}

impl PartialEq for RowView<'_> {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len() && self.to_vec() == other.to_vec()
    }
}

/// Iterator over a row view's stored `(col, value)` entries.
pub enum RowViewIter<'a> {
    Dense(std::iter::Enumerate<std::slice::Iter<'a, f64>>),
    Sparse(std::iter::Zip<std::slice::Iter<'a, u32>, std::slice::Iter<'a, f64>>),
}

impl Iterator for RowViewIter<'_> {
    type Item = (usize, f64);

    fn next(&mut self) -> Option<(usize, f64)> {
        match self {
            RowViewIter::Dense(it) => it.next().map(|(j, &v)| (j, v)),
            RowViewIter::Sparse(it) => it.next().map(|(&j, &v)| (j as usize, v)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both() -> (Rows, Rows) {
        let d = RowMatrix::from_flat(3, 4, vec![
            1.0, 0.0, 2.0, 0.0, //
            0.0, 0.0, 0.0, 3.0, //
            -1.0, 4.0, 0.0, 0.5,
        ]);
        let s = Rows::Dense(d.clone()).into_storage(Storage::Csr);
        (Rows::Dense(d), s)
    }

    #[test]
    fn storage_parse_and_names() {
        assert_eq!(Storage::parse("csr"), Some(Storage::Csr));
        assert_eq!(Storage::parse("dense"), Some(Storage::Dense));
        assert_eq!(Storage::parse("auto"), Some(Storage::Auto));
        assert_eq!(Storage::parse("sparse"), None);
        assert_eq!(Storage::Csr.name(), "csr");
    }

    #[test]
    fn conversions_roundtrip() {
        let (d, s) = both();
        assert!(s.is_sparse());
        assert_eq!(s.nnz(), 6);
        assert_eq!(s.clone().into_storage(Storage::Dense), d);
        assert_eq!(d.clone().into_storage(Storage::Csr), s);
        // auto: 6/12 = 0.5 density > threshold → dense either way
        assert_eq!(s.clone().into_storage(Storage::Auto).storage_name(), "dense");
        assert_eq!(d.clone().into_storage(Storage::Auto).storage_name(), "dense");
    }

    #[test]
    fn auto_picks_csr_when_sparse_enough() {
        let mut m = RowMatrix::zeros(10, 10);
        m.set(3, 7, 1.0);
        let r = Rows::Dense(m).into_storage(Storage::Auto);
        assert_eq!(r.storage_name(), "csr");
        assert_eq!(r.nnz(), 1);
    }

    #[test]
    fn views_agree_across_storage() {
        let (d, s) = both();
        let y = [0.5, -1.0, 2.0, 1.5];
        for i in 0..3 {
            assert_eq!(d.row(i).dot(&y), s.row(i).dot(&y), "row {i} dot");
            assert_eq!(d.row(i).norm_sq(), s.row(i).norm_sq(), "row {i} norm");
            assert_eq!(d.row(i), s.row(i), "row {i} view eq");
            let mut a = vec![1.0; 4];
            let mut b = vec![1.0; 4];
            d.row(i).axpy_into(2.0, &mut a);
            s.row(i).axpy_into(2.0, &mut b);
            assert_eq!(a, b, "row {i} axpy");
            for j in 0..4 {
                assert_eq!(d.get(i, j), s.get(i, j));
            }
        }
        assert_eq!(d.row_norms_sq(), s.row_norms_sq());
        assert_eq!(d.gram(0, 2), s.gram(0, 2));
        let (mut u1, mut u2) = (vec![0.0; 4], vec![0.0; 4]);
        d.t_matvec(&[1.0, 0.0, -2.0], &mut u1);
        s.t_matvec(&[1.0, 0.0, -2.0], &mut u2);
        assert_eq!(u1, u2);
    }

    #[test]
    fn iter_yields_stored_entries() {
        let (_, s) = both();
        let nz: Vec<(usize, f64)> = s.row(2).iter().collect();
        assert_eq!(nz, vec![(0, -1.0), (1, 4.0), (3, 0.5)]);
        let (d, _) = both();
        assert_eq!(d.row(1).iter().count(), 4); // dense yields zeros too
    }

    #[test]
    fn select_preserves_storage() {
        let (d, s) = both();
        assert_eq!(d.select_rows(&[2]).storage_name(), "dense");
        let ss = s.select_rows(&[2, 0]);
        assert_eq!(ss.storage_name(), "csr");
        assert_eq!(ss.get(0, 1), 4.0);
        assert_eq!(ss.get(1, 2), 2.0);
    }

    #[test]
    fn balanced_shards_cover() {
        let (d, s) = both();
        for shards in [1usize, 2, 3] {
            for r in [&d, &s] {
                let ranges = r.balanced_shards(shards);
                assert_eq!(ranges.first().unwrap().start, 0);
                assert_eq!(ranges.last().unwrap().end, 3);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn balanced_subset_shards_cover_and_balance() {
        let (d, s) = both();
        // a subset in arbitrary (shuffled) order, with repeats of heavy rows
        let idx = [2usize, 0, 1, 2];
        for shards in [1usize, 2, 3] {
            for r in [&d, &s] {
                let ranges = r.balanced_subset_shards(&idx, shards);
                assert_eq!(ranges.first().unwrap().start, 0);
                assert_eq!(ranges.last().unwrap().end, idx.len());
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
        // CSR balances by nnz: row 1 holds 1 nonzero, row 2 holds 3; a
        // 2-way split of [2, 1] must put the heavy row alone
        let ranges = s.balanced_subset_shards(&[2, 1], 2);
        assert_eq!(ranges[0], 0..1, "{ranges:?}");
        // empty subset stays well-formed
        let ranges = s.balanced_subset_shards(&[], 2);
        assert_eq!(ranges.last().unwrap().end, 0);
    }

    #[test]
    fn approx_bytes_by_storage() {
        let (d, s) = both();
        assert_eq!(d.approx_bytes(), 3 * 4 * 8);
        assert_eq!(s.approx_bytes(), 6 * 12 + 4 * 8);
    }

    #[test]
    fn gram_triangle_bounds_cover() {
        let (d, s) = both();
        for shards in [1usize, 2, 3] {
            for r in [&d, &s] {
                let b = r.gram_triangle_bounds(shards);
                assert_eq!(b.len(), shards + 1);
                assert_eq!((b[0], b[shards]), (0, 3));
                assert!(b.windows(2).all(|w| w[0] <= w[1]), "{b:?}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "not supported on CSR")]
    fn sparse_set_panics() {
        let (_, mut s) = both();
        s.set(0, 0, 9.0);
    }
}
