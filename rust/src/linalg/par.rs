//! Sharded parallel execution on a persistent solver worker pool (std-only
//! — no rayon offline).
//!
//! The screening scan, the θ-form Gram build, full-problem KKT validation,
//! and every `cd_par` block are all embarrassingly parallel over contiguous
//! row shards. This module provides the one primitive they share: split
//! `0..items` into contiguous shards, evaluate a closure per shard on
//! worker threads, and return the per-shard results **in shard order** so
//! callers can concatenate or reduce deterministically. Because shards are
//! contiguous and each row's result is computed by exactly the same
//! floating-point expression as the serial code, sharded row-wise maps are
//! byte-identical to their serial counterparts for any thread count.
//!
//! Execution lives on [`SolverPool`]: N long-lived workers, each owning an
//! mpsc job queue, grown lazily the first time a dispatch needs worker k
//! and then reused for the rest of the process. Shard 0 always runs inline
//! on the calling thread; shard k is pinned to worker k−1, so a solve that
//! re-cuts shards every block still lands shard k on the *same* OS thread
//! every time — thread spawn/join is paid at most once per process-lifetime
//! worker instead of once per block, and shard→thread affinity lets
//! first-touch NUMA placement of Z stick across blocks. The pre-pool
//! `std::thread::scope` implementations remain available as
//! [`run_sharded_ranges_scoped`] / [`run_sharded_mut_scoped`] (they are the
//! nested-dispatch fallback and the bench baseline).
//!
//! Thread-count convention used throughout the crate (and in
//! [`crate::config::SolverConfig::threads`]): `1` = serial (no threads
//! spawned), `0` = auto-detect via `std::thread::available_parallelism`,
//! `n` = exactly n workers (clamped to the number of items).

use std::cell::Cell;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Resolve a requested thread count: 0 = auto-detect, otherwise the
/// requested count; always ≥ 1, never more than `items`, and capped at
/// 4× the detected hardware parallelism — an absurd request (e.g. a
/// service caller asking for 500k workers) must degrade to a sane shard
/// count, not abort the process in `thread::spawn`. Decisions produced by
/// the sharded kernels are identical for every shard count, so clamping
/// never changes results.
pub fn effective_threads(requested: usize, items: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 {
        hw
    } else {
        requested.min(hw.saturating_mul(4))
    };
    t.max(1).min(items.max(1))
}

/// Split `0..items` into `shards` contiguous near-equal ranges (the first
/// `items % shards` ranges get one extra element).
pub fn shard_ranges(items: usize, shards: usize) -> Vec<Range<usize>> {
    assert!(shards >= 1, "need at least one shard");
    let base = items / shards;
    let extra = items % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0;
    for k in 0..shards {
        let len = base + usize::from(k < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, items);
    out
}

/// Split rows into `shards` contiguous ranges balanced by a cumulative
/// weight vector `cum` (length `rows + 1`, non-decreasing, `cum[0] = 0`) —
/// e.g. a CSR `indptr`, so each shard carries a near-equal *nonzero*
/// count rather than a near-equal row count. Ranges cover `0..rows` in
/// order; a pathologically heavy row can leave neighbouring ranges empty.
pub fn cumulative_ranges(cum: &[usize], shards: usize) -> Vec<Range<usize>> {
    assert!(shards >= 1, "need at least one shard");
    assert!(!cum.is_empty() && cum[0] == 0, "cum must start at 0");
    let rows = cum.len() - 1;
    let total = cum[rows] as u128;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for k in 1..=shards {
        let end = if k == shards {
            rows
        } else {
            let target = total * k as u128 / shards as u128;
            cum.partition_point(|&c| (c as u128) < target)
                .min(rows)
                .max(start)
        };
        out.push(start..end);
        start = end;
    }
    out
}

/// Prefix-sum a weight sequence into the cumulative form
/// [`cumulative_ranges`] consumes (length `items + 1`, `cum[0] = 0`).
/// Lets callers balance shards over an arbitrary *subset* of rows (e.g.
/// the CD sweep's shuffled active set) by feeding the subset's per-row
/// weights.
pub fn cumulative_weights(weights: impl Iterator<Item = usize>) -> Vec<usize> {
    let mut cum = Vec::with_capacity(weights.size_hint().0 + 1);
    cum.push(0usize);
    let mut acc = 0usize;
    for w in weights {
        acc = acc.saturating_add(w);
        cum.push(acc);
    }
    cum
}

/// Row boundaries (length `shards + 1`) that split the upper triangle of
/// an l×l matrix into row blocks of near-equal area: row i contributes
/// `l − i` entries, so early rows are "heavier" and equal-row splits would
/// starve the later workers.
pub fn triangle_bounds(l: usize, shards: usize) -> Vec<usize> {
    assert!(shards >= 1, "need at least one shard");
    let total = (l as u128) * (l as u128 + 1) / 2;
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0usize);
    let mut acc: u128 = 0;
    let mut i = 0usize;
    for k in 1..shards {
        let target = total * k as u128 / shards as u128;
        while i < l && acc < target {
            acc += (l - i) as u128;
            i += 1;
        }
        bounds.push(i);
    }
    bounds.push(l);
    bounds
}

/// Like [`triangle_bounds`], but with a per-row weight vector: entry
/// (i,j) of the upper triangle (j ≥ i) is assumed to cost
/// `weights[i] + weights[j]` — the nnzᵢ+nnzⱼ cost of a CSR Gram dot — so
/// row i's block costs `(l−i)·weights[i] + Σ_{j≥i} weights[j]`. Uniform
/// weights degrade to an area-balanced split (up to integer-division
/// boundary rounding vs [`triangle_bounds`]). Accumulation is u128 so
/// huge nnz totals cannot overflow.
pub fn weighted_triangle_bounds(weights: &[usize], shards: usize) -> Vec<usize> {
    assert!(shards >= 1, "need at least one shard");
    let l = weights.len();
    let mut row_cost = vec![0u128; l];
    let mut suffix = 0u128;
    for i in (0..l).rev() {
        suffix += weights[i] as u128;
        row_cost[i] = (l - i) as u128 * weights[i] as u128 + suffix;
    }
    let total: u128 = row_cost.iter().sum();
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0usize);
    let mut acc: u128 = 0;
    let mut i = 0usize;
    for k in 1..shards {
        let target = total * k as u128 / shards as u128;
        while i < l && acc < target {
            acc += row_cost[i];
            i += 1;
        }
        bounds.push(i);
    }
    bounds.push(l);
    bounds
}

// ---------------------------------------------------------------------------
// The persistent solver pool
// ---------------------------------------------------------------------------

/// A unit of work queued to a pool worker. The `'static` bound is a lie
/// told at exactly one place — the transmute in [`SolverPool::run_ranges`] /
/// [`SolverPool::run_mut`] — and made true by the dispatch protocol: the
/// dispatching call does not return (and therefore the borrows captured by
/// the job cannot die) until every job has acknowledged completion.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Upper bound on lazily-grown pool workers. [`effective_threads`] already
/// caps shard counts at 4× the hardware parallelism, so this is a backstop
/// against a caller hand-rolling thousands of ranges, not a working limit;
/// excess shards wrap onto existing workers via the modulo in dispatch.
const MAX_POOL_WORKERS: usize = 512;

thread_local! {
    /// Set once, to `true`, on every pool worker thread. Dispatching from
    /// inside a pool worker would deadlock-by-queueing (the nested jobs
    /// would wait behind the very job that is waiting for them), so the
    /// routed entry points check this flag and fall back to the scoped
    /// spawn-per-shard path for nested parallelism.
    static IN_POOL_WORKER: Cell<bool> = Cell::new(false);
}

fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|c| c.get())
}

/// Spawn count of the scoped (non-pool) fallback paths, for the bench
/// comparison between per-block spawning and the persistent pool.
static SCOPED_SPAWNS: AtomicU64 = AtomicU64::new(0);

/// A persistent, work-stealing-free pinned worker pool.
///
/// Workers are long-lived OS threads, each consuming one private mpsc
/// queue — there is no shared deque and no stealing, so the mapping from
/// shard index to worker thread is a pure function (`shard k → worker
/// k−1`, shard 0 inline on the caller) and stays stable across every
/// dispatch for the life of the process. Workers are grown lazily up to
/// the largest shard count ever requested (capped at
/// [`MAX_POOL_WORKERS`]), then reused: one spawn per worker per process,
/// instead of one spawn per shard per block.
///
/// Panic protocol: every job wraps its closure in `catch_unwind` and
/// *always* acknowledges completion, even on panic; the dispatcher
/// collects every acknowledgement before resuming the first panic on the
/// calling thread. Workers therefore never die, and — critically for the
/// lifetime-erasure safety argument — no borrow captured by a job can
/// outlive the dispatching call.
pub struct SolverPool {
    /// Per-worker job queue plus that worker's cumulative busy-time
    /// counter (nanoseconds spent executing jobs).
    senders: Mutex<Vec<(mpsc::Sender<Job>, Arc<AtomicU64>)>>,
    workers_spawned: AtomicU64,
    jobs_dispatched: AtomicU64,
    /// Jobs enqueued but not yet picked up by a worker — incremented at
    /// enqueue, decremented as the job body starts, so it reads 0 whenever
    /// the pool is quiescent (the `/metrics` `pool_queue_depth` gauge).
    queue_depth: AtomicU64,
}

/// Monotonic counters describing pool (and fallback) activity since
/// process start — consumed by `bench_micro`'s pool-reuse series and by
/// the bench smoke gate's "≤ 1 spawn per solve" check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolStats {
    /// OS threads ever spawned by the pool (lifetime ≈ process lifetime).
    pub workers_spawned: u64,
    /// Jobs enqueued to pool workers (excludes inline shard-0 work).
    pub jobs_dispatched: u64,
    /// OS threads spawned by the scoped fallback paths.
    pub scoped_spawns: u64,
}

static GLOBAL_POOL: SolverPool = SolverPool::new();

/// The process-wide pool every routed entry point dispatches through.
pub fn solver_pool() -> &'static SolverPool {
    &GLOBAL_POOL
}

/// Counters for the global pool plus the scoped-fallback spawn count.
pub fn pool_stats() -> PoolStats {
    let p = solver_pool();
    PoolStats {
        workers_spawned: p.workers_spawned(),
        jobs_dispatched: p.jobs_dispatched(),
        scoped_spawns: SCOPED_SPAWNS.load(Ordering::Relaxed),
    }
}

/// Live pool utilization: current queue depth and per-worker cumulative
/// busy time. Kept OUT of [`PoolStats`] deliberately — that struct's
/// fields are enumerated verbatim into the deterministic `"stats"`
/// response JSON, whereas these values are wall-clock-dependent and only
/// surface on the `/metrics` exposition.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PoolBusy {
    /// Jobs enqueued to workers but not yet started.
    pub queue_depth: u64,
    /// Nanoseconds worker k has spent executing jobs, one entry per
    /// spawned worker.
    pub busy_nanos: Vec<u64>,
}

/// Utilization snapshot of the global pool.
pub fn pool_busy() -> PoolBusy {
    solver_pool().busy()
}

impl SolverPool {
    /// An empty pool; workers are spawned on first use. `const` so the
    /// global pool is a plain `static` with no lazy-init cell.
    pub const fn new() -> SolverPool {
        SolverPool {
            senders: Mutex::new(Vec::new()),
            workers_spawned: AtomicU64::new(0),
            jobs_dispatched: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
        }
    }

    /// OS threads this pool has ever spawned.
    pub fn workers_spawned(&self) -> u64 {
        self.workers_spawned.load(Ordering::Relaxed)
    }

    /// Jobs this pool has enqueued to workers (inline shard-0 excluded).
    pub fn jobs_dispatched(&self) -> u64 {
        self.jobs_dispatched.load(Ordering::Relaxed)
    }

    /// Utilization snapshot: queue depth + per-worker busy nanoseconds.
    pub fn busy(&self) -> PoolBusy {
        let senders = self.senders.lock().unwrap_or_else(|e| e.into_inner());
        PoolBusy {
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            busy_nanos: senders.iter().map(|(_, b)| b.load(Ordering::Relaxed)).collect(),
        }
    }

    /// Lock the sender table, growing it to `want` workers first (capped
    /// at [`MAX_POOL_WORKERS`]). The lock is held only while enqueueing —
    /// never while waiting for results — so concurrent solves interleave
    /// jobs onto the shared workers instead of serializing whole solves.
    fn lock_and_grow(&self, want: usize) -> MutexGuard<'_, Vec<(mpsc::Sender<Job>, Arc<AtomicU64>)>> {
        let mut senders = self.senders.lock().unwrap_or_else(|e| e.into_inner());
        let want = want.min(MAX_POOL_WORKERS);
        while senders.len() < want {
            let (tx, rx) = mpsc::channel::<Job>();
            let idx = senders.len();
            let busy = Arc::new(AtomicU64::new(0));
            std::thread::Builder::new()
                .name(format!("dvi-solver-{idx}"))
                .spawn(move || {
                    IN_POOL_WORKER.with(|c| c.set(true));
                    // Jobs never unwind (each catches its own panic), so
                    // this loop ends only when every sender is dropped.
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .expect("spawn solver pool worker");
            self.workers_spawned.fetch_add(1, Ordering::Relaxed);
            senders.push((tx, busy));
        }
        senders
    }

    /// Evaluate `f` over `ranges` with shard 0 inline on the caller and
    /// shard k pinned to worker k−1; results come back in shard order, so
    /// output is byte-identical to the scoped implementation.
    ///
    /// SAFETY argument for the lifetime erasure below: each queued job
    /// owns a clone of `ack_tx` and sends on it unconditionally (the user
    /// closure runs under `catch_unwind`, and a failed enqueue runs the
    /// returned job inline — which still sends). This loop does not return
    /// until it has received exactly `ranges.len() − 1` acknowledgements,
    /// so every borrow captured by a job (`&f`, the ack sender, the range)
    /// is live for the job's entire execution.
    pub fn run_ranges<T, F>(&self, ranges: Vec<Range<usize>>, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        let n = ranges.len();
        if n <= 1 {
            return ranges.into_iter().map(f).collect();
        }
        let (ack_tx, ack_rx) = mpsc::channel::<(usize, std::thread::Result<T>)>();
        {
            let senders = self.lock_and_grow(n - 1);
            self.jobs_dispatched.fetch_add((n - 1) as u64, Ordering::Relaxed);
            for (k, r) in ranges.iter().enumerate().skip(1) {
                let r = r.clone();
                let ack = ack_tx.clone();
                let f = &f;
                let depth = &self.queue_depth;
                let busy = senders[(k - 1) % senders.len()].1.clone();
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    // symmetric with the enqueue-side increment below; the
                    // send-failure inline path runs this same body, so the
                    // gauge always returns to 0 at quiescence
                    depth.fetch_sub(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    let out = catch_unwind(AssertUnwindSafe(|| f(r)));
                    busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let _ = ack.send((k, out));
                });
                let job: Job = unsafe { std::mem::transmute(job) };
                self.queue_depth.fetch_add(1, Ordering::Relaxed);
                if let Err(err) = senders[(k - 1) % senders.len()].0.send(job) {
                    // A worker's queue can only be gone if its thread
                    // failed to start; run the job here — it still acks.
                    (err.0)();
                }
            }
        }
        drop(ack_tx);
        let mut slots: Vec<Option<std::thread::Result<T>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        slots[0] = Some(catch_unwind(AssertUnwindSafe(|| f(ranges[0].clone()))));
        for _ in 1..n {
            let (k, res) = ack_rx.recv().expect("solver pool worker lost its ack channel");
            slots[k] = Some(res);
        }
        slots
            .into_iter()
            .map(|s| match s.expect("every shard acknowledged") {
                Ok(v) => v,
                Err(payload) => resume_unwind(payload),
            })
            .collect()
    }

    /// Writer-side twin of [`Self::run_ranges`]: split `data` into the row
    /// blocks delimited by `bounds`, run block 0 inline and block w on
    /// worker w−1. Same acknowledgement/panic protocol (and the same
    /// safety argument for the lifetime erasure).
    pub fn run_mut<T, F>(&self, data: &mut [T], row_len: usize, bounds: &[usize], f: F)
    where
        T: Send,
        F: Fn(Range<usize>, &mut [T]) + Sync,
    {
        let blocks = bounds.len() - 1;
        debug_assert!(blocks >= 2, "single block is handled by the caller");
        let (ack_tx, ack_rx) = mpsc::channel::<std::thread::Result<()>>();
        let mut first: Option<(Range<usize>, &mut [T])> = None;
        {
            let senders = self.lock_and_grow(blocks - 1);
            self.jobs_dispatched.fetch_add((blocks - 1) as u64, Ordering::Relaxed);
            let mut rest: &mut [T] = data;
            for w in 0..blocks {
                let (lo, hi) = (bounds[w], bounds[w + 1]);
                let taken = std::mem::take(&mut rest);
                let (head, tail) = taken.split_at_mut((hi - lo) * row_len);
                rest = tail;
                if w == 0 {
                    first = Some((lo..hi, head));
                    continue;
                }
                let ack = ack_tx.clone();
                let f = &f;
                let depth = &self.queue_depth;
                let busy = senders[(w - 1) % senders.len()].1.clone();
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    let out = catch_unwind(AssertUnwindSafe(|| f(lo..hi, head)));
                    busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let _ = ack.send(out);
                });
                let job: Job = unsafe { std::mem::transmute(job) };
                self.queue_depth.fetch_add(1, Ordering::Relaxed);
                if let Err(err) = senders[(w - 1) % senders.len()].0.send(job) {
                    (err.0)();
                }
            }
        }
        drop(ack_tx);
        let (r0, head0) = first.expect("bounds delimit at least one block");
        let mut results = vec![catch_unwind(AssertUnwindSafe(|| f(r0, head0)))];
        for _ in 1..blocks {
            results.push(ack_rx.recv().expect("solver pool worker lost its ack channel"));
        }
        for res in results {
            if let Err(payload) = res {
                resume_unwind(payload);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Routed entry points (pool) and scoped fallbacks (spawn-per-shard)
// ---------------------------------------------------------------------------

/// Evaluate `f` over contiguous shards of `0..items` on pool workers;
/// results are returned in shard order. `threads` follows the crate
/// convention (0 = auto, 1 = serial in the calling thread).
pub fn run_sharded<T, F>(items: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if items == 0 {
        return Vec::new();
    }
    let t = effective_threads(threads, items);
    run_sharded_ranges(shard_ranges(items, t), f)
}

/// Like [`run_sharded`], but over caller-supplied contiguous ranges (e.g.
/// nonzero-balanced shards from [`cumulative_ranges`] or
/// [`crate::linalg::Rows::balanced_shards`]). One range runs serially in
/// the calling thread; results come back in range order. Dispatches
/// through the global [`SolverPool`] (shard k pinned to worker k−1);
/// nested calls from inside a pool worker fall back to
/// [`run_sharded_ranges_scoped`].
pub fn run_sharded_ranges<T, F>(ranges: Vec<Range<usize>>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if ranges.len() <= 1 {
        return ranges.into_iter().map(f).collect();
    }
    if in_pool_worker() {
        return run_sharded_ranges_scoped(ranges, f);
    }
    solver_pool().run_ranges(ranges, f)
}

/// The pre-pool implementation of [`run_sharded_ranges`]: one scoped OS
/// thread per range, joined in order. Kept public as the nested-dispatch
/// fallback and as the spawn-per-block baseline the pool-reuse bench
/// series compares against.
pub fn run_sharded_ranges_scoped<T, F>(ranges: Vec<Range<usize>>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if ranges.len() <= 1 {
        return ranges.into_iter().map(f).collect();
    }
    SCOPED_SPAWNS.fetch_add(ranges.len() as u64, Ordering::Relaxed);
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| s.spawn(move || f(r)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

/// Like [`run_sharded`], but for writers: split `data` — a row-major
/// buffer of `row_len`-sized rows — into the contiguous row blocks
/// delimited by `bounds` (e.g. from [`triangle_bounds`], or the edges of
/// [`shard_ranges`]) and run `f(rows, block)` on each block on pool
/// workers. `bounds` must start at 0, be non-decreasing, and end at
/// `data.len() / row_len`. Two bounds (one block) runs serially in the
/// calling thread; nested calls from a pool worker fall back to
/// [`run_sharded_mut_scoped`].
pub fn run_sharded_mut<T, F>(data: &mut [T], row_len: usize, bounds: &[usize], f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert!(bounds.len() >= 2, "bounds must delimit at least one block");
    assert_eq!(bounds[0], 0, "bounds must start at row 0");
    assert_eq!(
        bounds[bounds.len() - 1] * row_len,
        data.len(),
        "bounds must cover the whole buffer"
    );
    if bounds.len() == 2 {
        f(bounds[0]..bounds[1], data);
        return;
    }
    if in_pool_worker() {
        return run_sharded_mut_scoped(data, row_len, bounds, f);
    }
    solver_pool().run_mut(data, row_len, bounds, f)
}

/// The pre-pool implementation of [`run_sharded_mut`]: one scoped OS
/// thread per block. Kept public as the nested-dispatch fallback and the
/// bench baseline; performs the same bounds checks as the routed entry.
pub fn run_sharded_mut_scoped<T, F>(data: &mut [T], row_len: usize, bounds: &[usize], f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert!(bounds.len() >= 2, "bounds must delimit at least one block");
    assert_eq!(bounds[0], 0, "bounds must start at row 0");
    assert_eq!(
        bounds[bounds.len() - 1] * row_len,
        data.len(),
        "bounds must cover the whole buffer"
    );
    if bounds.len() == 2 {
        f(bounds[0]..bounds[1], data);
        return;
    }
    SCOPED_SPAWNS.fetch_add((bounds.len() - 1) as u64, Ordering::Relaxed);
    std::thread::scope(|s| {
        let f = &f;
        let mut rest: &mut [T] = data;
        for w in 0..bounds.len() - 1 {
            let (lo, hi) = (bounds[w], bounds[w + 1]);
            let taken = std::mem::take(&mut rest);
            let (head, tail) = taken.split_at_mut((hi - lo) * row_len);
            rest = tail;
            s.spawn(move || f(lo..hi, head));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_exactly() {
        for items in [0usize, 1, 5, 16, 103] {
            for shards in [1usize, 2, 4, 7] {
                let rs = shard_ranges(items, shards);
                assert_eq!(rs.len(), shards);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
                assert_eq!(next, items);
                // balanced: sizes differ by at most 1
                let sizes: Vec<usize> = rs.iter().map(|r| r.end - r.start).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "{sizes:?}");
            }
        }
    }

    #[test]
    fn effective_threads_clamps() {
        // 4 ≤ 4×hw for any hw ≥ 1, so the request is honored exactly
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(8, 3), 3);
        assert_eq!(effective_threads(1, 0), 1);
        assert!(effective_threads(0, 1_000_000) >= 1);
        // an absurd request degrades instead of trying to spawn that many
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert!(effective_threads(500_000, 1_000_000) <= 4 * hw);
    }

    #[test]
    fn cumulative_ranges_cover_and_balance() {
        // uneven weights: row i carries i+1 units
        for rows in [1usize, 7, 64, 103] {
            let mut cum = vec![0usize];
            for i in 0..rows {
                cum.push(cum[i] + i + 1);
            }
            for shards in [1usize, 2, 4, 7] {
                let rs = cumulative_ranges(&cum, shards);
                assert_eq!(rs.len(), shards);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    assert!(r.end >= r.start);
                    next = r.end;
                }
                assert_eq!(next, rows);
                if rows >= 32 && shards > 1 {
                    let total = cum[rows];
                    for r in &rs {
                        let area = cum[r.end] - cum[r.start];
                        // each shard within one max-row-weight of ideal
                        assert!(
                            area <= total / shards + rows + 1,
                            "area {area} of {total} in {shards} shards"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn cumulative_weights_prefix_sums() {
        assert_eq!(cumulative_weights([].into_iter()), vec![0]);
        assert_eq!(cumulative_weights([3usize, 0, 5].into_iter()), vec![0, 3, 3, 8]);
        // feeds straight into cumulative_ranges
        let cum = cumulative_weights((0..10usize).map(|i| i + 1));
        let rs = cumulative_ranges(&cum, 3);
        assert_eq!(rs.last().unwrap().end, 10);
    }

    #[test]
    fn cumulative_ranges_uniform_matches_even_split() {
        let cum: Vec<usize> = (0..=20).map(|i| i * 3).collect();
        let rs = cumulative_ranges(&cum, 4);
        let sizes: Vec<usize> = rs.iter().map(|r| r.end - r.start).collect();
        assert_eq!(sizes, vec![5, 5, 5, 5]);
    }

    #[test]
    fn run_sharded_ranges_preserves_order() {
        let cum: Vec<usize> = (0..=11).map(|i| i * i).collect();
        let ranges = cumulative_ranges(&cum, 4);
        let flat: Vec<usize> = run_sharded_ranges(ranges, |r| r.collect::<Vec<usize>>())
            .into_iter()
            .flatten()
            .collect();
        assert_eq!(flat, (0..11).collect::<Vec<usize>>());
    }

    #[test]
    fn triangle_bounds_monotone_and_balanced() {
        for l in [1usize, 7, 64, 103] {
            for shards in [1usize, 2, 4, 7] {
                let b = triangle_bounds(l, shards);
                assert_eq!(b.len(), shards + 1);
                assert_eq!(b[0], 0);
                assert_eq!(b[shards], l);
                assert!(b.windows(2).all(|w| w[0] <= w[1]), "{b:?}");
                // areas within one row's worth of each other is too strict
                // for tiny l; just check no shard exceeds 2x the ideal for
                // larger inputs
                if l >= 32 && shards > 1 {
                    let total = l * (l + 1) / 2;
                    for w in b.windows(2) {
                        let area: usize = (w[0]..w[1]).map(|i| l - i).sum();
                        assert!(area <= 2 * total / shards + l, "area {area} of {total}");
                    }
                }
            }
        }
    }

    #[test]
    fn weighted_triangle_bounds_cover_and_balance() {
        // heavy head: row 0 carries 1000 nonzeros, the rest 1 each — an
        // area split would give the row-0 shard ~1000× the work
        let mut w = vec![1usize; 64];
        w[0] = 1000;
        let b = weighted_triangle_bounds(&w, 4);
        assert_eq!(b.len(), 5);
        assert_eq!((b[0], b[4]), (0, 64));
        assert!(b.windows(2).all(|x| x[0] <= x[1]), "{b:?}");
        // the heavy row must sit alone (its block cost already exceeds a
        // quarter of the total)
        assert_eq!(b[1], 1, "{b:?}");
        // per-block cost within one max-row of the ideal quarter
        let l = w.len();
        let cost = |i: usize| (l - i) * w[i] + (i..l).map(|j| w[j]).sum::<usize>();
        let total: usize = (0..l).map(cost).sum();
        for k in 1..4 {
            let area: usize = (b[k]..b[k + 1]).map(cost).sum();
            assert!(area <= total / 4 + cost(b[k].min(l - 1)), "block {k}: {area} of {total}");
        }
    }

    #[test]
    fn weighted_triangle_bounds_uniform_is_area_balanced() {
        for l in [1usize, 7, 64, 103] {
            for shards in [1usize, 2, 4, 7] {
                let w = vec![5usize; l];
                let b = weighted_triangle_bounds(&w, shards);
                assert_eq!(b.len(), shards + 1);
                assert_eq!((b[0], b[shards]), (0, l), "l={l} shards={shards}");
                assert!(b.windows(2).all(|x| x[0] <= x[1]), "{b:?}");
                // uniform weights ⇒ block areas near-equal for larger l
                if l >= 32 && shards > 1 {
                    let total = l * (l + 1) / 2;
                    for x in b.windows(2) {
                        let area: usize = (x[0]..x[1]).map(|i| l - i).sum();
                        assert!(area <= 2 * total / shards + l, "area {area} of {total}");
                    }
                }
            }
        }
    }

    #[test]
    fn weighted_triangle_bounds_degenerate() {
        assert_eq!(weighted_triangle_bounds(&[], 3), vec![0, 0, 0, 0]);
        let b = weighted_triangle_bounds(&[0, 0, 0], 2);
        assert_eq!((b[0], b[2]), (0, 3));
        assert!(b.windows(2).all(|x| x[0] <= x[1]));
    }

    #[test]
    fn run_sharded_preserves_order() {
        for threads in [1usize, 2, 3, 7, 0] {
            let shards = run_sharded(103, threads, |r| r.collect::<Vec<usize>>());
            let flat: Vec<usize> = shards.into_iter().flatten().collect();
            assert_eq!(flat, (0..103).collect::<Vec<usize>>(), "threads={threads}");
        }
    }

    #[test]
    fn run_sharded_empty_input() {
        let out: Vec<Vec<usize>> = run_sharded(0, 4, |r| r.collect());
        assert!(out.is_empty());
    }

    #[test]
    fn run_sharded_more_threads_than_items() {
        let shards = run_sharded(3, 8, |r| r.len());
        assert_eq!(shards.len(), 3);
        assert_eq!(shards.iter().sum::<usize>(), 3);
    }

    #[test]
    fn run_sharded_mut_writes_disjoint_blocks() {
        let (rows, row_len) = (11usize, 3usize);
        for shards in [1usize, 2, 4, 7] {
            let mut data = vec![0usize; rows * row_len];
            let mut bounds: Vec<usize> = shard_ranges(rows, shards).iter().map(|r| r.start).collect();
            bounds.push(rows);
            run_sharded_mut(&mut data, row_len, &bounds, |rs, block| {
                let lo = rs.start;
                for i in rs {
                    for j in 0..row_len {
                        block[(i - lo) * row_len + j] = 100 * i + j;
                    }
                }
            });
            for i in 0..rows {
                for j in 0..row_len {
                    assert_eq!(data[i * row_len + j], 100 * i + j, "shards={shards}");
                }
            }
        }
    }

    #[test]
    fn run_sharded_mut_empty_buffer() {
        let mut data: Vec<f64> = Vec::new();
        run_sharded_mut(&mut data, 0, &[0, 0], |_, block| assert!(block.is_empty()));
    }

    // -- pool-specific tests (private pool instances: the global pool is
    //    shared with concurrently-running tests, so its counters are not
    //    deterministic here) --

    #[test]
    fn pool_matches_scoped_and_reuses_workers() {
        let pool = SolverPool::new();
        let cum: Vec<usize> = (0..=57).map(|i| i * i).collect();
        for round in 0..3 {
            let ranges = cumulative_ranges(&cum, 4);
            let via_pool = pool.run_ranges(ranges.clone(), |r| r.collect::<Vec<usize>>());
            let via_scoped = run_sharded_ranges_scoped(ranges, |r| r.collect::<Vec<usize>>());
            assert_eq!(via_pool, via_scoped, "round {round}");
            // 4 ranges → 3 workers, spawned once on the first round only
            assert_eq!(pool.workers_spawned(), 3, "round {round}");
            assert_eq!(pool.jobs_dispatched(), 3 * (round + 1), "round {round}");
        }
    }

    #[test]
    fn pool_grows_to_largest_request_only() {
        let pool = SolverPool::new();
        pool.run_ranges(shard_ranges(40, 2), |r| r.len());
        assert_eq!(pool.workers_spawned(), 1);
        pool.run_ranges(shard_ranges(40, 8), |r| r.len());
        assert_eq!(pool.workers_spawned(), 7);
        pool.run_ranges(shard_ranges(40, 3), |r| r.len());
        assert_eq!(pool.workers_spawned(), 7);
    }

    #[test]
    fn pool_run_mut_matches_direct_writes() {
        let pool = SolverPool::new();
        let (rows, row_len) = (13usize, 2usize);
        let mut data = vec![0usize; rows * row_len];
        let mut bounds: Vec<usize> = shard_ranges(rows, 5).iter().map(|r| r.start).collect();
        bounds.push(rows);
        pool.run_mut(&mut data, row_len, &bounds, |rs, block| {
            let lo = rs.start;
            for i in rs {
                for j in 0..row_len {
                    block[(i - lo) * row_len + j] = 10 * i + j;
                }
            }
        });
        for i in 0..rows {
            for j in 0..row_len {
                assert_eq!(data[i * row_len + j], 10 * i + j);
            }
        }
        assert_eq!(pool.workers_spawned(), 4);
    }

    #[test]
    fn pool_propagates_panics_and_survives() {
        let pool = SolverPool::new();
        let ranges = shard_ranges(8, 4);
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_ranges(ranges.clone(), |r| {
                if r.start >= 4 {
                    panic!("shard detonated");
                }
                r.len()
            })
        }));
        assert!(boom.is_err(), "panic must propagate to the dispatcher");
        // workers survive a panicking job and keep serving
        let ok = pool.run_ranges(ranges, |r| r.len());
        assert_eq!(ok.iter().sum::<usize>(), 8);
        assert_eq!(pool.workers_spawned(), 3);
    }

    #[test]
    fn nested_dispatch_falls_back_to_scoped() {
        // a job running on a pool worker that itself calls the routed
        // entry point must not enqueue onto the (busy) pool
        let pool = SolverPool::new();
        let out = pool.run_ranges(shard_ranges(4, 2), |outer| {
            let inner: usize = run_sharded(16, 2, |r| r.len()).iter().sum();
            outer.len() + inner
        });
        assert_eq!(out, vec![18, 18]);
    }

    #[test]
    fn pool_busy_tracks_depth_and_worker_time() {
        let pool = SolverPool::new();
        assert_eq!(pool.busy(), PoolBusy { queue_depth: 0, busy_nanos: vec![] });
        pool.run_ranges(shard_ranges(8, 4), |r| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            r.len()
        });
        let b = pool.busy();
        // quiescent: every enqueued job started, so the gauge is back to 0
        assert_eq!(b.queue_depth, 0);
        assert_eq!(b.busy_nanos.len(), 3);
        assert!(b.busy_nanos.iter().all(|&n| n >= 1_000_000), "{:?}", b.busy_nanos);
    }

    #[test]
    fn global_pool_counters_monotone() {
        let before = pool_stats();
        let flat: Vec<usize> = run_sharded(64, 4, |r| r.collect::<Vec<usize>>())
            .into_iter()
            .flatten()
            .collect();
        assert_eq!(flat.len(), 64);
        let after = pool_stats();
        assert!(after.workers_spawned >= before.workers_spawned);
        assert!(after.jobs_dispatched >= before.jobs_dispatched + 3);
    }
}
