//! Sharded parallel execution on `std::thread::scope` workers (std-only —
//! no rayon offline).
//!
//! The screening scan, the θ-form Gram build, and full-problem KKT
//! validation are all embarrassingly parallel over the l data rows. This
//! module provides the one primitive they share: split `0..items` into
//! contiguous shards, evaluate a closure per shard on scoped worker
//! threads, and return the per-shard results **in shard order** so callers
//! can concatenate or reduce deterministically. Because shards are
//! contiguous and each row's result is computed by exactly the same
//! floating-point expression as the serial code, sharded row-wise maps are
//! byte-identical to their serial counterparts for any thread count.
//!
//! Thread-count convention used throughout the crate (and in
//! [`crate::config::SolverConfig::threads`]): `1` = serial (no threads
//! spawned), `0` = auto-detect via `std::thread::available_parallelism`,
//! `n` = exactly n workers (clamped to the number of items).

use std::ops::Range;

/// Resolve a requested thread count: 0 = auto-detect, otherwise the
/// requested count; always ≥ 1, never more than `items`, and capped at
/// 4× the detected hardware parallelism — an absurd request (e.g. a
/// service caller asking for 500k workers) must degrade to a sane shard
/// count, not abort the process in `thread::spawn`. Decisions produced by
/// the sharded kernels are identical for every shard count, so clamping
/// never changes results.
pub fn effective_threads(requested: usize, items: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 {
        hw
    } else {
        requested.min(hw.saturating_mul(4))
    };
    t.max(1).min(items.max(1))
}

/// Split `0..items` into `shards` contiguous near-equal ranges (the first
/// `items % shards` ranges get one extra element).
pub fn shard_ranges(items: usize, shards: usize) -> Vec<Range<usize>> {
    assert!(shards >= 1, "need at least one shard");
    let base = items / shards;
    let extra = items % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0;
    for k in 0..shards {
        let len = base + usize::from(k < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, items);
    out
}

/// Split rows into `shards` contiguous ranges balanced by a cumulative
/// weight vector `cum` (length `rows + 1`, non-decreasing, `cum[0] = 0`) —
/// e.g. a CSR `indptr`, so each shard carries a near-equal *nonzero*
/// count rather than a near-equal row count. Ranges cover `0..rows` in
/// order; a pathologically heavy row can leave neighbouring ranges empty.
pub fn cumulative_ranges(cum: &[usize], shards: usize) -> Vec<Range<usize>> {
    assert!(shards >= 1, "need at least one shard");
    assert!(!cum.is_empty() && cum[0] == 0, "cum must start at 0");
    let rows = cum.len() - 1;
    let total = cum[rows] as u128;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for k in 1..=shards {
        let end = if k == shards {
            rows
        } else {
            let target = total * k as u128 / shards as u128;
            cum.partition_point(|&c| (c as u128) < target)
                .min(rows)
                .max(start)
        };
        out.push(start..end);
        start = end;
    }
    out
}

/// Prefix-sum a weight sequence into the cumulative form
/// [`cumulative_ranges`] consumes (length `items + 1`, `cum[0] = 0`).
/// Lets callers balance shards over an arbitrary *subset* of rows (e.g.
/// the CD sweep's shuffled active set) by feeding the subset's per-row
/// weights.
pub fn cumulative_weights(weights: impl Iterator<Item = usize>) -> Vec<usize> {
    let mut cum = Vec::with_capacity(weights.size_hint().0 + 1);
    cum.push(0usize);
    let mut acc = 0usize;
    for w in weights {
        acc = acc.saturating_add(w);
        cum.push(acc);
    }
    cum
}

/// Row boundaries (length `shards + 1`) that split the upper triangle of
/// an l×l matrix into row blocks of near-equal area: row i contributes
/// `l − i` entries, so early rows are "heavier" and equal-row splits would
/// starve the later workers.
pub fn triangle_bounds(l: usize, shards: usize) -> Vec<usize> {
    assert!(shards >= 1, "need at least one shard");
    let total = (l as u128) * (l as u128 + 1) / 2;
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0usize);
    let mut acc: u128 = 0;
    let mut i = 0usize;
    for k in 1..shards {
        let target = total * k as u128 / shards as u128;
        while i < l && acc < target {
            acc += (l - i) as u128;
            i += 1;
        }
        bounds.push(i);
    }
    bounds.push(l);
    bounds
}

/// Like [`triangle_bounds`], but with a per-row weight vector: entry
/// (i,j) of the upper triangle (j ≥ i) is assumed to cost
/// `weights[i] + weights[j]` — the nnzᵢ+nnzⱼ cost of a CSR Gram dot — so
/// row i's block costs `(l−i)·weights[i] + Σ_{j≥i} weights[j]`. Uniform
/// weights degrade to an area-balanced split (up to integer-division
/// boundary rounding vs [`triangle_bounds`]). Accumulation is u128 so
/// huge nnz totals cannot overflow.
pub fn weighted_triangle_bounds(weights: &[usize], shards: usize) -> Vec<usize> {
    assert!(shards >= 1, "need at least one shard");
    let l = weights.len();
    let mut row_cost = vec![0u128; l];
    let mut suffix = 0u128;
    for i in (0..l).rev() {
        suffix += weights[i] as u128;
        row_cost[i] = (l - i) as u128 * weights[i] as u128 + suffix;
    }
    let total: u128 = row_cost.iter().sum();
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0usize);
    let mut acc: u128 = 0;
    let mut i = 0usize;
    for k in 1..shards {
        let target = total * k as u128 / shards as u128;
        while i < l && acc < target {
            acc += row_cost[i];
            i += 1;
        }
        bounds.push(i);
    }
    bounds.push(l);
    bounds
}

/// Evaluate `f` over contiguous shards of `0..items` on scoped worker
/// threads; results are returned in shard order. `threads` follows the
/// crate convention (0 = auto, 1 = serial in the calling thread).
pub fn run_sharded<T, F>(items: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if items == 0 {
        return Vec::new();
    }
    let t = effective_threads(threads, items);
    run_sharded_ranges(shard_ranges(items, t), f)
}

/// Like [`run_sharded`], but over caller-supplied contiguous ranges (e.g.
/// nonzero-balanced shards from [`cumulative_ranges`] or
/// [`crate::linalg::Rows::balanced_shards`]). One range runs serially in
/// the calling thread; results come back in range order.
pub fn run_sharded_ranges<T, F>(ranges: Vec<Range<usize>>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if ranges.len() <= 1 {
        return ranges.into_iter().map(f).collect();
    }
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| s.spawn(move || f(r)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

/// Like [`run_sharded`], but for writers: split `data` — a row-major
/// buffer of `row_len`-sized rows — into the contiguous row blocks
/// delimited by `bounds` (e.g. from [`triangle_bounds`], or the edges of
/// [`shard_ranges`]) and run `f(rows, block)` on each block on scoped
/// worker threads. `bounds` must start at 0, be non-decreasing, and end
/// at `data.len() / row_len`. Two bounds (one block) runs serially in the
/// calling thread.
pub fn run_sharded_mut<T, F>(data: &mut [T], row_len: usize, bounds: &[usize], f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert!(bounds.len() >= 2, "bounds must delimit at least one block");
    assert_eq!(bounds[0], 0, "bounds must start at row 0");
    assert_eq!(
        bounds[bounds.len() - 1] * row_len,
        data.len(),
        "bounds must cover the whole buffer"
    );
    if bounds.len() == 2 {
        f(bounds[0]..bounds[1], data);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest: &mut [T] = data;
        for w in 0..bounds.len() - 1 {
            let (lo, hi) = (bounds[w], bounds[w + 1]);
            let taken = std::mem::take(&mut rest);
            let (head, tail) = taken.split_at_mut((hi - lo) * row_len);
            rest = tail;
            s.spawn(move || f(lo..hi, head));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_exactly() {
        for items in [0usize, 1, 5, 16, 103] {
            for shards in [1usize, 2, 4, 7] {
                let rs = shard_ranges(items, shards);
                assert_eq!(rs.len(), shards);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
                assert_eq!(next, items);
                // balanced: sizes differ by at most 1
                let sizes: Vec<usize> = rs.iter().map(|r| r.end - r.start).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "{sizes:?}");
            }
        }
    }

    #[test]
    fn effective_threads_clamps() {
        // 4 ≤ 4×hw for any hw ≥ 1, so the request is honored exactly
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(8, 3), 3);
        assert_eq!(effective_threads(1, 0), 1);
        assert!(effective_threads(0, 1_000_000) >= 1);
        // an absurd request degrades instead of trying to spawn that many
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert!(effective_threads(500_000, 1_000_000) <= 4 * hw);
    }

    #[test]
    fn cumulative_ranges_cover_and_balance() {
        // uneven weights: row i carries i+1 units
        for rows in [1usize, 7, 64, 103] {
            let mut cum = vec![0usize];
            for i in 0..rows {
                cum.push(cum[i] + i + 1);
            }
            for shards in [1usize, 2, 4, 7] {
                let rs = cumulative_ranges(&cum, shards);
                assert_eq!(rs.len(), shards);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    assert!(r.end >= r.start);
                    next = r.end;
                }
                assert_eq!(next, rows);
                if rows >= 32 && shards > 1 {
                    let total = cum[rows];
                    for r in &rs {
                        let area = cum[r.end] - cum[r.start];
                        // each shard within one max-row-weight of ideal
                        assert!(
                            area <= total / shards + rows + 1,
                            "area {area} of {total} in {shards} shards"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn cumulative_weights_prefix_sums() {
        assert_eq!(cumulative_weights([].into_iter()), vec![0]);
        assert_eq!(cumulative_weights([3usize, 0, 5].into_iter()), vec![0, 3, 3, 8]);
        // feeds straight into cumulative_ranges
        let cum = cumulative_weights((0..10usize).map(|i| i + 1));
        let rs = cumulative_ranges(&cum, 3);
        assert_eq!(rs.last().unwrap().end, 10);
    }

    #[test]
    fn cumulative_ranges_uniform_matches_even_split() {
        let cum: Vec<usize> = (0..=20).map(|i| i * 3).collect();
        let rs = cumulative_ranges(&cum, 4);
        let sizes: Vec<usize> = rs.iter().map(|r| r.end - r.start).collect();
        assert_eq!(sizes, vec![5, 5, 5, 5]);
    }

    #[test]
    fn run_sharded_ranges_preserves_order() {
        let cum: Vec<usize> = (0..=11).map(|i| i * i).collect();
        let ranges = cumulative_ranges(&cum, 4);
        let flat: Vec<usize> = run_sharded_ranges(ranges, |r| r.collect::<Vec<usize>>())
            .into_iter()
            .flatten()
            .collect();
        assert_eq!(flat, (0..11).collect::<Vec<usize>>());
    }

    #[test]
    fn triangle_bounds_monotone_and_balanced() {
        for l in [1usize, 7, 64, 103] {
            for shards in [1usize, 2, 4, 7] {
                let b = triangle_bounds(l, shards);
                assert_eq!(b.len(), shards + 1);
                assert_eq!(b[0], 0);
                assert_eq!(b[shards], l);
                assert!(b.windows(2).all(|w| w[0] <= w[1]), "{b:?}");
                // areas within one row's worth of each other is too strict
                // for tiny l; just check no shard exceeds 2x the ideal for
                // larger inputs
                if l >= 32 && shards > 1 {
                    let total = l * (l + 1) / 2;
                    for w in b.windows(2) {
                        let area: usize = (w[0]..w[1]).map(|i| l - i).sum();
                        assert!(area <= 2 * total / shards + l, "area {area} of {total}");
                    }
                }
            }
        }
    }

    #[test]
    fn weighted_triangle_bounds_cover_and_balance() {
        // heavy head: row 0 carries 1000 nonzeros, the rest 1 each — an
        // area split would give the row-0 shard ~1000× the work
        let mut w = vec![1usize; 64];
        w[0] = 1000;
        let b = weighted_triangle_bounds(&w, 4);
        assert_eq!(b.len(), 5);
        assert_eq!((b[0], b[4]), (0, 64));
        assert!(b.windows(2).all(|x| x[0] <= x[1]), "{b:?}");
        // the heavy row must sit alone (its block cost already exceeds a
        // quarter of the total)
        assert_eq!(b[1], 1, "{b:?}");
        // per-block cost within one max-row of the ideal quarter
        let l = w.len();
        let cost = |i: usize| (l - i) * w[i] + (i..l).map(|j| w[j]).sum::<usize>();
        let total: usize = (0..l).map(cost).sum();
        for k in 1..4 {
            let area: usize = (b[k]..b[k + 1]).map(cost).sum();
            assert!(area <= total / 4 + cost(b[k].min(l - 1)), "block {k}: {area} of {total}");
        }
    }

    #[test]
    fn weighted_triangle_bounds_uniform_is_area_balanced() {
        for l in [1usize, 7, 64, 103] {
            for shards in [1usize, 2, 4, 7] {
                let w = vec![5usize; l];
                let b = weighted_triangle_bounds(&w, shards);
                assert_eq!(b.len(), shards + 1);
                assert_eq!((b[0], b[shards]), (0, l), "l={l} shards={shards}");
                assert!(b.windows(2).all(|x| x[0] <= x[1]), "{b:?}");
                // uniform weights ⇒ block areas near-equal for larger l
                if l >= 32 && shards > 1 {
                    let total = l * (l + 1) / 2;
                    for x in b.windows(2) {
                        let area: usize = (x[0]..x[1]).map(|i| l - i).sum();
                        assert!(area <= 2 * total / shards + l, "area {area} of {total}");
                    }
                }
            }
        }
    }

    #[test]
    fn weighted_triangle_bounds_degenerate() {
        assert_eq!(weighted_triangle_bounds(&[], 3), vec![0, 0, 0, 0]);
        let b = weighted_triangle_bounds(&[0, 0, 0], 2);
        assert_eq!((b[0], b[2]), (0, 3));
        assert!(b.windows(2).all(|x| x[0] <= x[1]));
    }

    #[test]
    fn run_sharded_preserves_order() {
        for threads in [1usize, 2, 3, 7, 0] {
            let shards = run_sharded(103, threads, |r| r.collect::<Vec<usize>>());
            let flat: Vec<usize> = shards.into_iter().flatten().collect();
            assert_eq!(flat, (0..103).collect::<Vec<usize>>(), "threads={threads}");
        }
    }

    #[test]
    fn run_sharded_empty_input() {
        let out: Vec<Vec<usize>> = run_sharded(0, 4, |r| r.collect());
        assert!(out.is_empty());
    }

    #[test]
    fn run_sharded_more_threads_than_items() {
        let shards = run_sharded(3, 8, |r| r.len());
        assert_eq!(shards.len(), 3);
        assert_eq!(shards.iter().sum::<usize>(), 3);
    }

    #[test]
    fn run_sharded_mut_writes_disjoint_blocks() {
        let (rows, row_len) = (11usize, 3usize);
        for shards in [1usize, 2, 4, 7] {
            let mut data = vec![0usize; rows * row_len];
            let mut bounds: Vec<usize> = shard_ranges(rows, shards).iter().map(|r| r.start).collect();
            bounds.push(rows);
            run_sharded_mut(&mut data, row_len, &bounds, |rs, block| {
                let lo = rs.start;
                for i in rs {
                    for j in 0..row_len {
                        block[(i - lo) * row_len + j] = 100 * i + j;
                    }
                }
            });
            for i in 0..rows {
                for j in 0..row_len {
                    assert_eq!(data[i * row_len + j], 100 * i + j, "shards={shards}");
                }
            }
        }
    }

    #[test]
    fn run_sharded_mut_empty_buffer() {
        let mut data: Vec<f64> = Vec::new();
        run_sharded_mut(&mut data, 0, &[0, 0], |_, block| assert!(block.is_empty()));
    }
}
