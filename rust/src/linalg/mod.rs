//! Dense linear-algebra kernels used by the solver and the native
//! screening implementations.
//!
//! Everything operates on `f64` slices (row-major matrices). The inner
//! loops are written so rustc/LLVM auto-vectorizes them (4-way unrolled
//! accumulators, no bounds checks in the hot loop via exact-length
//! `chunks_exact`). These are the L3 hot paths profiled in
//! `EXPERIMENTS.md §Perf`.

pub mod cols;
pub mod csr;
pub mod matrix;
pub mod par;
pub mod rows;

pub use cols::{ColMatrix, ColView, Cols, CscMatrix, ShardAxis};
pub use csr::CsrMatrix;
pub use matrix::RowMatrix;
pub use rows::{RowView, Rows, Storage};

/// Dot product ⟨x, y⟩ with 8 independent accumulators (breaks the FP
/// dependency chain so LLVM emits vector FMAs).
///
/// Perf note (EXPERIMENTS.md §Perf): measured against a 4-way unrolled
/// and a plain-iterator variant on this machine — 8-way wins at every
/// row length the screening scan sees (+26% at n=22, +34% at n=54,
/// +6% at n=512); the single-accumulator version collapses on long rows
/// (FP dependency chain).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let (xa, xr) = x.split_at(chunks * 8);
    let (ya, yr) = y.split_at(chunks * 8);
    let mut s = [0.0f64; 8];
    for (xc, yc) in xa.chunks_exact(8).zip(ya.chunks_exact(8)) {
        for k in 0..8 {
            s[k] += xc[k] * yc[k];
        }
    }
    let mut tail = 0.0;
    for (a, b) in xr.iter().zip(yr.iter()) {
        tail += a * b;
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail
}

/// y ← y + a·x.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// x ← a·x.
#[inline]
pub fn scale(a: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn norm(x: &[f64]) -> f64 {
    norm_sq(x).sqrt()
}

/// ℓ∞ distance between two vectors.
pub fn max_abs_diff(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max)
}

/// Sum of elements.
#[inline]
pub fn sum(x: &[f64]) -> f64 {
    let mut acc = [0.0f64; 4];
    for c in x.chunks(4) {
        for (a, v) in acc.iter_mut().zip(c) {
            *a += *v;
        }
    }
    acc.iter().sum()
}

/// Mean of elements (0 for empty input).
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        0.0
    } else {
        sum(x) / x.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    let v = x.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64;
    v.sqrt()
}

/// Clamp `v` into [lo, hi].
#[inline]
pub fn clamp(v: f64, lo: f64, hi: f64) -> f64 {
    v.max(lo).min(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..103).map(|i| i as f64 * 0.5 - 3.0).collect();
        let y: Vec<f64> = (0..103).map(|i| (i as f64).sin()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn dot_empty_and_short() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn scale_and_norm() {
        let mut x = vec![3.0, 4.0];
        assert_eq!(norm(&x), 5.0);
        scale(2.0, &mut x);
        assert_eq!(norm_sq(&x), 100.0);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[test]
    fn stats() {
        let x = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&x) - 5.0).abs() < 1e-12);
        assert!((std_dev(&x) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn clamp_basic() {
        assert_eq!(clamp(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clamp(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clamp(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn sum_matches_naive() {
        let x: Vec<f64> = (0..57).map(|i| i as f64 * 0.25).collect();
        let naive: f64 = x.iter().sum();
        assert!((sum(&x) - naive).abs() < 1e-9);
    }
}
